//! End-to-end serving driver (the repo's E2E validation run).
//!
//! Loads the AOT transformer bundle (tiny-3m: 4 layers, d_model 256,
//! 3.45M params, real weights from `artifacts/weights/`), starts the
//! threaded serving coordinator, and pushes a batched workload through
//! the full stack — router → continuous batcher → prefill/decode
//! scheduler → KV-cache manager → PJRT-executed JAX/Pallas model —
//! reporting per-request latency and engine throughput.
//!
//!   make artifacts && cargo run --release --example serve_llm
//!
//! The resulting numbers are recorded in EXPERIMENTS.md §E2E.

use std::time::Instant;

use fastattn::benchkit::ms;
use fastattn::coordinator::{EngineConfig, GenParams, Server};
use fastattn::metrics::LatencyHistogram;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests: usize = std::env::args()
        .nth(2)
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(24);
    let gen_tokens = 12usize;

    println!("starting engine over {dir}/ …");
    let t_load = Instant::now();
    let server = Server::start(dir, EngineConfig::default())?;
    println!("engine ready in {:.2}s", t_load.elapsed().as_secs_f64());

    // Deterministic synthetic workload: mixed prompt lengths across the
    // prefill buckets (32/64/128), generating 12 tokens each.
    println!("submitting {n_requests} requests (gen {gen_tokens} tokens each) …");
    let t0 = Instant::now();
    let waits: Vec<_> = (0..n_requests)
        .map(|i| {
            let len = match i % 4 {
                0 => 5 + i % 20,
                1 => 30 + i % 30,
                2 => 70 + i % 50,
                _ => 12,
            };
            let prompt: Vec<i32> =
                (0..len).map(|j| ((i * 131 + j * 17) % 500 + 1) as i32).collect();
            server.submit(prompt, GenParams { max_new_tokens: gen_tokens, ..GenParams::default() })
        })
        .collect::<Result<_, _>>()?;

    let mut ttft = LatencyHistogram::default();
    let mut total = LatencyHistogram::default();
    let mut generated = 0usize;
    for stream in waits {
        let id = stream.id();
        let r = stream.wait()?;
        assert_eq!(r.id, id);
        assert_eq!(r.tokens.len(), gen_tokens, "req {id} under-generated");
        ttft.record(r.ttft_s);
        total.record(r.total_s);
        generated += r.tokens.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics()?;

    println!("\n== E2E serving run ==");
    println!("requests           : {n_requests} (all completed)");
    println!("generated tokens   : {generated}");
    println!("wall time          : {wall:.2} s");
    println!("throughput         : {:.1} tok/s end-to-end", generated as f64 / wall);
    println!(
        "ttft               : mean {} | p50 {} | p99 {}",
        ms(ttft.mean_s()),
        ms(ttft.quantile_s(0.5)),
        ms(ttft.quantile_s(0.99))
    );
    println!(
        "request latency    : mean {} | p99 {}",
        ms(total.mean_s()),
        ms(total.quantile_s(0.99))
    );
    println!(
        "engine             : {} prefill steps ({:.0} tok/s) | {} decode steps ({:.1} tok/s, mean batch {:.2})",
        m.prefill_steps,
        m.prefill_tps(),
        m.decode_steps,
        m.decode_tps(),
        m.mean_decode_batch()
    );
    // engine-side per-request latency histograms (the SLO surface):
    // unlike the client-side numbers above, these come straight from
    // EngineMetrics, so any serving front-end can export them.
    println!(
        "engine ttft        : mean {} | p50 {} | p99 {} ({} requests)",
        ms(m.ttft.mean_s()),
        ms(m.ttft.quantile_s(0.5)),
        ms(m.ttft.quantile_s(0.99)),
        m.ttft.count()
    );
    println!(
        "engine tpot        : mean {} | p50 {} | p99 {}",
        ms(m.tpot.mean_s()),
        ms(m.tpot.quantile_s(0.5)),
        ms(m.tpot.quantile_s(0.99))
    );
    if m.preemptions > 0 {
        println!(
            "reclamation        : {} preemptions ({} swap-outs, {} resumes, {} tok replay avoided), {} promotions",
            m.preemptions, m.swaps_out, m.swaps_in, m.recompute_tokens_avoided, m.promotions
        );
    }
    println!("serve_llm OK");
    Ok(())
}
