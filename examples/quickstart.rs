//! Quickstart: load the AOT FastAttention Pallas kernel, run it on the
//! PJRT CPU client, and check it against the standard-attention oracle —
//! the smallest end-to-end round trip through all three layers.
//!
//!   make artifacts && cargo run --release --example quickstart

use fastattn::benchkit::{bench, fmt_time};
use fastattn::runtime::{HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("loading kernels from {dir}/ …");
    let rt = Runtime::load_filtered(&dir, |n| n.starts_with("kernel_"))?;
    println!("platform = {}", rt.platform());
    for (name, secs) in &rt.compile_times {
        println!("  compiled {name} in {}", fmt_time(*secs));
    }

    // (batch=1, heads=4, seq=128, head_dim=64) — the lowered kernel shape.
    let n = 4 * 128 * 64;
    let mk = |salt: f32| {
        HostTensor::f32(
            vec![1, 4, 128, 64],
            (0..n).map(|i| ((i as f32 * 0.137 + salt).sin()) * 0.5).collect(),
        )
    };
    let (q, k, v) = (mk(0.0), mk(1.0), mk(2.0));

    let fast = rt.run_host("kernel_fastattn_causal", &[q.clone(), k.clone(), v.clone()])?;
    let oracle = rt.run_host("kernel_standard_causal", &[q.clone(), k.clone(), v.clone()])?;

    let a = fast[0].as_f32()?;
    let b = oracle[0].as_f32()?;
    let max_err = a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    println!("\nFastAttention (Pallas, two-level tiling + tiling-mask) vs standard attention:");
    println!("  max |err| = {max_err:.2e}  (tolerance 2e-5)");
    assert!(max_err < 2e-5);

    let s_fast = bench(2, 10, || {
        let _ = rt.run("kernel_fastattn_causal", &[q.clone(), k.clone(), v.clone()]).unwrap();
    });
    let s_std = bench(2, 10, || {
        let _ = rt.run("kernel_standard_causal", &[q.clone(), k.clone(), v.clone()]).unwrap();
    });
    println!("  fastattn kernel : {}", fmt_time(s_fast.p50_s));
    println!("  standard kernel : {}", fmt_time(s_std.p50_s));
    println!(
        "\n(CPU-interpret timings are not TPU estimates — see DESIGN.md §6 for \
         the VMEM/MXU model; `repro table fig7` for the Ascend numbers.)"
    );
    println!("quickstart OK");
    Ok(())
}
