//! Ultra-long-sequence inference via the CPU–GPU cooperative strategy
//! (§4.4) — the Table 3 / Fig 11 scenario as a runnable walk-through.
//!
//! For PanGu-38B on an 8× V100 node, this example:
//!   1. plans the L_GPU/L_CPU layer split per eq. 15–20 for sequence
//!      lengths 1K → 256K,
//!   2. compares classical offloading vs the cooperative strategy with
//!      the calibrated device model,
//!   3. runs the host-side decode attention *for real* (the rust
//!      FlashAttention2 kernel) for one layer shard and reports the
//!      measured CPU_Calc next to the modeled one.
//!
//!   cargo run --release --example long_context

use fastattn::benchkit::{ms, x, Table};
use fastattn::coordinator::offload::{
    layer_latency_model, measured_cpu_attention, plan, step_latency,
};
use fastattn::models::PANGU_38B;
use fastattn::sim::memory::Deployment;
use fastattn::sim::volta::VoltaSpec;

fn main() {
    let spec = VoltaSpec::default();
    let model = PANGU_38B;

    println!("== CPU–GPU cooperative strategy: {} on 8× V100-16GB ==\n", model.name);

    let mut t = Table::new(
        "per-layer decode attention + full-step aggregate",
        &[
            "seq", "L_GPU", "L_CPU", "upload", "GPU calc", "CPU calc (model)",
            "CPU calc (live)", "classical step", "coop step", "speedup",
        ],
    );
    for s in [1024u64, 8192, 16 * 1024, 64 * 1024, 256 * 1024] {
        let dep = Deployment::v100_node(model, s, 50);
        let p = plan(&dep);
        let per = layer_latency_model(&spec, &model, 8, 1, s);
        let step = step_latency(&spec, &dep, &p);
        // Live host attention for one layer's per-GPU shard (5 heads).
        let live = if p.offload_needed {
            ms(measured_cpu_attention(5, s as usize, 128))
        } else {
            "—".into()
        };
        t.row(&[
            format!("{}K", s / 1024),
            format!("{}", p.l_gpu),
            format!("{}", p.l_cpu),
            if p.offload_needed { ms(per.upload_s) } else { "—".into() },
            ms(per.gpu_calc_s),
            if p.offload_needed { ms(per.cpu_calc_s) } else { "—".into() },
            live,
            ms(step.classical_s),
            ms(step.cooperative_s),
            x(step.classical_s / step.cooperative_s.max(1e-12)),
        ]);
    }
    t.print();

    let dep = Deployment::v100_node(model, 0, 50);
    println!(
        "\nmax context: {}K without offload  →  {}K with the cooperative strategy (768 GiB host)",
        dep.max_seq_without_offload() / 1024,
        dep.max_seq_with_offload(768 << 30) / 1024
    );
    println!(
        "(paper: 16K → 256K on the same node; Table 3 per-layer speedups 1.27–1.48×)"
    );
    println!("long_context OK");
}
