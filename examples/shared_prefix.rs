//! Shared-prefix KV demo: system-prompt caching on the paged engine.
//!
//! Serves the same workload twice over the pure-rust host backend (no
//! artifact bundle needed) — N requests that all carry one long system
//! prompt plus a short per-user suffix — first with `share_prefix` off,
//! then on, and prints the deltas: prompt tokens actually prefilled,
//! prefix-cache hits, copy-on-write splits, peak KV pages.  Tokens are
//! asserted identical: sharing reuses bit-identical KV rows, so it can
//! never change what the model says.
//!
//!   cargo run --release --example shared_prefix
//!
//! See `docs/ARCHITECTURE.md` (sharing state machine) and
//! `coordinator::kv_cache::PrefixIndex` for how the cache works.

use fastattn::attention::batch::ParallelConfig;
use fastattn::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
};
use fastattn::metrics::EngineMetrics;

fn main() -> anyhow::Result<()> {
    let n_requests = 12usize;
    let system_len = 32usize;
    let gen_tokens = 12usize;

    // one "system prompt" shared by every request + a user suffix
    let system: Vec<i32> = (0..system_len).map(|j| (j * 7 % 64) as i32).collect();
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|i| {
            let mut p = system.clone();
            p.extend((0..4 + i % 5).map(|j| ((i * 31 + j * 11) % 64) as i32));
            p
        })
        .collect();

    let run = |share: bool| -> anyhow::Result<(Vec<Vec<i32>>, EngineMetrics)> {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads: 2, min_work_per_thread: 0 },
            kv_layout: KvLayout::Paged,
            page_size: 16,
            ..EngineConfig::default()
        };
        let mut engine = Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        );
        let gp = GenParams { max_new_tokens: gen_tokens, eos_token: None, share_prefix: share };
        for p in &prompts {
            engine.submit(p.clone(), gp)?;
        }
        let mut out = engine.run_until_idle()?;
        out.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        Ok((tokens, engine.metrics.clone()))
    };

    let (base_tokens, base) = run(false)?;
    let (shared_tokens, shared) = run(true)?;
    assert_eq!(base_tokens, shared_tokens, "sharing must never change tokens");

    println!("== shared-prefix KV demo ==");
    println!("{n_requests} requests × ({system_len}-token system prompt + suffix)\n");
    println!("                      unshared    shared");
    println!(
        "prefilled tokens    : {:>8}  {:>8}",
        base.prefilled_tokens, shared.prefilled_tokens
    );
    println!("prefix hits         : {:>8}  {:>8}", base.prefix_hits, shared.prefix_hits);
    println!("tokens saved        : {:>8}  {:>8}", base.prefix_tokens_saved, shared.prefix_tokens_saved);
    println!("cow splits          : {:>8}  {:>8}", base.cow_splits, shared.cow_splits);
    println!("peak KV pages       : {:>8}  {:>8}", base.peak_pages_used, shared.peak_pages_used);
    println!("prefix-cache pages  : {:>8}  {:>8}", base.shared_pages, shared.shared_pages);
    println!(
        "\nprefill work saved  : {:.0}%  (tokens identical in both runs)",
        shared.prefix_savings() * 100.0
    );
    println!("shared_prefix OK");
    Ok(())
}
