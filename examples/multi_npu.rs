//! Tiling-AllReduce (§4.2) live demo: a *real* multi-worker ring
//! AllReduce over in-process workers, serial vs per-block-overlapped,
//! verifying numerics and showing the overlap win, then the calibrated
//! 8×910B model numbers (Figs 16/17).
//!
//!   cargo run --release --example multi_npu

use std::time::{Duration, Instant};

use fastattn::attention::batch::ParallelConfig;
use fastattn::benchkit::{fmt_time, ms, x, Table};
use fastattn::coordinator::allreduce::{
    ring_all_reduce, serial_all_reduce, tiled_all_reduce, BlockCompute,
};
use fastattn::coordinator::{
    Backend, Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout,
    ShardedBackend, ShardedConfig,
};
use fastattn::metrics::EngineMetrics;
use fastattn::models::ModelShape;
use fastattn::sim::collective::{
    best_block_count, make_blocks, serial_schedule, RingSpec,
};

fn main() -> anyhow::Result<()> {
    println!("== tiling-AllReduce: real in-process ring ==\n");

    // 1) correctness: ring AllReduce == elementwise sum
    let n_workers = 4;
    let shards: Vec<Vec<f32>> = (0..n_workers)
        .map(|r| (0..1024).map(|i| (r * 1000 + i) as f32).collect())
        .collect();
    let want: Vec<f32> = (0..1024)
        .map(|i| (0..n_workers).map(|r| (r * 1000 + i) as f32).sum())
        .collect();
    let reduced = ring_all_reduce(shards);
    assert!(reduced.iter().all(|r| r == &want));
    println!("ring_all_reduce({n_workers} workers, 1K f32): numerics OK");

    // 2) serial vs tiled with real per-block compute
    let compute: Box<BlockCompute> = Box::new(|b, buf| {
        for (i, v) in buf.iter_mut().enumerate() {
            *v = ((b * 97 + i) % 13) as f32 * 0.5;
        }
    });
    let block_elems = 128 * 1024;
    let n_blocks = 8;
    let delay = Duration::from_millis(4); // stands in for fused attn+Linear

    let t0 = Instant::now();
    let a = serial_all_reduce(n_workers, block_elems, n_blocks, &compute, delay)?;
    let serial_t = t0.elapsed();
    let t1 = Instant::now();
    let b = tiled_all_reduce(n_workers, block_elems, n_blocks, &compute, delay)?;
    let tiled_t = t1.elapsed();
    assert_eq!(a.len(), b.len());
    let max_err = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "tiled != serial: {max_err}");

    println!(
        "serial (compute-then-AllReduce) : {}\ntiled  (B-allreduce overlapped) : {}  ({:.2}× on this host)",
        fmt_time(serial_t.as_secs_f64()),
        fmt_time(tiled_t.as_secs_f64()),
        serial_t.as_secs_f64() / tiled_t.as_secs_f64()
    );

    // 3) the calibrated 8×910B projection (Fig 16 shape)
    println!("\n== 8× Ascend 910B model (PanGu-38B layer, Fig 16) ==");
    let ring = RingSpec::default();
    let mut t = Table::new(
        "serial vs tiling-AllReduce (modeled)",
        &["seq", "serial", "tiled", "blocks", "speedup"],
    );
    for s in [2048u64, 8192, 32768] {
        let (compute_s, bytes) =
            fastattn::reports::allreduce::pangu38_layer_compute_and_bytes(1, s);
        let serial = serial_schedule(&ring, &make_blocks(bytes, compute_s, 1, 1.0));
        let (nb, over) = best_block_count(&ring, bytes, compute_s);
        t.row(&[
            format!("{}K", s / 1024),
            ms(serial),
            ms(over),
            format!("{nb}"),
            x(serial / over),
        ]);
    }
    t.print();
    println!("(paper: up to 1.53× — Appendix D.3)");

    // 4) end-to-end: the serving engine over simulated tensor-parallel
    //    devices — KV heads sharded into per-device page pools, each
    //    decode tile combined through the same in-process ring with the
    //    tiling-AllReduce schedule modeled on top
    println!("\n== sharded serving engine (KV heads across simulated devices) ==");
    let cfg = HostModelConfig {
        model: ModelShape {
            name: "demo-tp-mini",
            params: 0,
            layers: 2,
            heads: 8,
            kv_heads: 8,
            head_dim: 4,
            ffn: 32,
            vocab: 32,
        },
        max_seq: 64,
        ..HostModelConfig::tiny_gqa()
    };
    let prompts: Vec<Vec<i32>> =
        (0..6).map(|i| (0..6).map(|t| (t * 5 + i as i32 + 1) % 32).collect()).collect();
    let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
    let serve = |backend: Box<dyn Backend>| -> anyhow::Result<(Vec<Vec<i32>>, EngineMetrics)> {
        let mut e = Engine::with_backend(
            backend,
            EngineConfig {
                parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
                kv_layout: KvLayout::Paged,
                page_size: 16,
                ..EngineConfig::default()
            },
        );
        for pr in &prompts {
            e.submit(pr.clone(), p)?;
        }
        let mut out = e.run_until_idle()?;
        out.sort_by_key(|r| r.id);
        Ok((out.into_iter().map(|r| r.tokens).collect(), e.metrics.clone()))
    };
    let (want, _) = serve(Box::new(HostModelBackend::new(cfg.clone())))?;
    for shards in [2usize, 4, 8] {
        let scfg = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(shards) };
        let (got, m) = serve(Box::new(ShardedBackend::new(cfg.clone(), scfg)?))?;
        assert_eq!(got, want, "{shards}-shard tokens diverged from single device");
        println!(
            "{shards} devices: tokens identical to 1 device; {} combine tiles, AllReduce {} \
             ({:.0}% hidden, {} vs serial)",
            m.allreduce_tiles,
            fmt_time(m.allreduce_modeled_s),
            m.allreduce_hidden_frac() * 100.0,
            x(m.allreduce_overlap_speedup()),
        );
    }

    println!("multi_npu OK");
    Ok(())
}
