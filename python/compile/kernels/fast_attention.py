"""FastAttention Pallas kernel — Layer 1 of the three-layer stack.

This is the paper's single-device contribution (§4.1) re-expressed for the
TPU/Pallas programming model (see DESIGN.md §Hardware-Adaptation):

* **Two-level tiling** — the kernel body iterates over *first-level*
  (L1-buffer / VMEM sized) K/V slabs with an outer ``fori_loop`` and over
  *second-level* (L0-buffer / MXU-tile sized) sub-tiles of each slab with an
  inner ``fori_loop``.  On Ascend the first level amortizes Cube<->Vector
  synchronizations and keeps GM loads large and contiguous; the second level
  fits the Cube's L0.  On TPU the same structure is the HBM->VMEM schedule
  (BlockSpec granularity) plus the in-VMEM MXU tile loop.

* **Tiling-mask** — the causal ``attention_mask`` is never materialized at
  S×S.  Each *B-mask* is generated in-kernel from the block's global row /
  column offsets (a shifted view of the paper's (2M)x(2M) *M-mask*; the
  equivalence is property-tested against the explicit shift generator in
  ``maskgen.py``).  Blocks are classified:
    - fully-masked  -> skipped entirely (the paper's ~50% Cube saving,
      realized here by bounding the reduction loop trip count),
    - fully-visible -> the ``QK^T + mask`` add is skipped (Vector saving),
    - partial       -> B-mask applied.

* **Variable KV length** — decode-time masking by a runtime ``kv_len``
  (scalar, or a per-batch-row vector for continuous batching), again
  without materializing a mask, and with the reduction loop bounded by
  ``ceil(kv_len / block_k1)`` so padded cache tail blocks are skipped.

The kernel runs under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against the pure-jnp oracle in
``ref.py``.  Real-TPU perf is estimated from the VMEM footprint / MXU
utilization model in DESIGN.md §6 and EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K1 = 64  # first-level (L1/VMEM) block, multiple of BLOCK_K2
DEFAULT_BLOCK_K2 = 16  # second-level (L0/MXU) sub-block

NEG_INF = -1e30


def _ceil_div(a: int, b: int) -> int:
    return (a + b - 1) // b


def vmem_footprint_bytes(
    block_q: int,
    block_k1: int,
    head_dim: int,
    dtype_bytes: int = 4,
) -> int:
    """Estimated VMEM residency of one kernel program (DESIGN.md §Perf).

    q block + first-level K and V slabs + f32 accumulator + softmax stats.
    """
    q = block_q * head_dim * dtype_bytes
    kv = 2 * block_k1 * head_dim * dtype_bytes
    acc = block_q * head_dim * 4
    stats = 2 * block_q * 4
    return q + kv + acc + stats


def _attn_kernel(
    kv_len_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    sm_scale: float,
    causal: bool,
    block_q: int,
    block_k1: int,
    block_k2: int,
    seq_kv: int,
):
    """One (batch*head, q-block) program of the FastAttention forward.

    Refs: kv_len_ref (1,) i32; q_ref (block_q, d); k_ref/v_ref (seq_kv, d);
    o_ref (block_q, d).  The outer loop carves first-level slabs out of
    k_ref/v_ref, the inner loop second-level sub-tiles.
    """
    qi = pl.program_id(1)
    q0 = qi * block_q  # global row offset of this q block

    q = q_ref[...].astype(jnp.float32) * sm_scale
    d = q.shape[-1]

    kv_len = kv_len_ref[0]  # runtime valid KV length (== seq_kv in prefill)

    m_init = jnp.full((block_q,), NEG_INF, jnp.float32)
    l_init = jnp.zeros((block_q,), jnp.float32)
    acc_init = jnp.zeros((block_q, d), jnp.float32)

    n_inner = block_k1 // block_k2

    def inner_body(i2, carry, *, k1_base):
        m_prev, l_prev, acc_prev = carry
        k0 = k1_base + i2 * block_k2  # global col offset of this sub-block

        # --- Cube/MXU stage: QK^T on one second-level sub-tile -----------
        k_blk = k_ref[pl.dslice(k0, block_k2), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (block_q, block_k2)

        # --- tiling-mask: generate the B-mask from block offsets ---------
        col = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k2), 1)
        if causal:
            row = q0 + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k2), 0
            )
            # Fully-visible classification: the sub-block's last column is
            # <= the q block's first row -> every entry is unmasked.
            fully_visible = (k0 + block_k2 - 1) <= q0

            def masked(s):
                keep = (col <= row) & (col < kv_len)
                return jnp.where(keep, s, NEG_INF)

            def unmasked(s):
                # Paper: all-ones B-mask -> skip the QK^T + mask add
                # (Vector-unit saving).  kv_len can still clip in decode.
                return jnp.where(col < kv_len, s, NEG_INF)

            s = jax.lax.cond(fully_visible, unmasked, masked, s)
        else:
            s = jnp.where(col < kv_len, s, NEG_INF)

        # --- Vector/VPU stage: online softmax update ----------------------
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows: exp(NEG_INF - NEG_INF) would be exp(0).
        row_dead = m_new <= NEG_INF / 2
        alpha = jnp.where(row_dead, 1.0, jnp.exp(m_prev - m_new))
        p = jnp.where(row_dead[:, None], 0.0, jnp.exp(s - m_new[:, None]))

        l_new = l_prev * alpha + jnp.sum(p, axis=1)

        # --- Cube/MXU stage: PV on the same sub-tile ----------------------
        # p stays resident between the two dots — the TPU analogue of the
        # paper's Volta FP16-accumulator layout trick (no inter-thread
        # exchange between back-to-back GEMMs).
        v_blk = v_ref[pl.dslice(k0, block_k2), :].astype(jnp.float32)
        acc_new = acc_prev * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc_new

    def outer_body(i1, carry):
        # One first-level slab: [i1*block_k1, i1*block_k1 + block_k1).
        k1_base = i1 * block_k1
        return jax.lax.fori_loop(
            0, n_inner, functools.partial(inner_body, k1_base=k1_base), carry
        )

    # Block skipping (the all-zero B-mask case): bound the loop trip count.
    # Causal: only slabs intersecting [0, q0 + block_q) contribute.
    # Decode: only slabs intersecting [0, kv_len) contribute.
    limit = kv_len
    if causal:
        limit = jnp.minimum(limit, q0 + block_q)
    n_outer = jnp.minimum(
        (limit + block_k1 - 1) // block_k1, _ceil_div(seq_kv, block_k1)
    ).astype(jnp.int32)

    m, l, acc = jax.lax.fori_loop(
        0, n_outer, outer_body, (m_init, l_init, acc_init)
    )

    safe_l = jnp.where(l == 0.0, 1.0, l)
    out = jnp.where((l == 0.0)[:, None], 0.0, acc / safe_l[:, None])
    o_ref[...] = out.astype(o_ref.dtype)


def fast_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    kv_len: Optional[jax.Array] = None,
    sm_scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k1: int = DEFAULT_BLOCK_K1,
    block_k2: int = DEFAULT_BLOCK_K2,
) -> jax.Array:
    """FastAttention forward pass.

    Args:
      q: (batch, num_heads, seq_q, head_dim).
      k, v: (batch, num_kv_heads, seq_kv, head_dim).  ``num_kv_heads`` must
        divide ``num_heads`` (GQA/MQA sharing via index mapping, no copies).
      causal: apply the causal tiling-mask (requires seq_q == seq_kv; the
        serving decode path uses ``causal=False`` + ``kv_len`` instead).
      kv_len: optional int32 — runtime valid KV length for decode over a
        padded cache.  Scalar (shared) or shape ``(batch,)`` (per row,
        for continuous batching).  Defaults to ``seq_kv``.
      sm_scale: softmax scale, default ``1/sqrt(head_dim)``.
      block_q / block_k1 / block_k2: two-level tile sizes; ``block_k2``
        must divide ``block_k1``.

    Returns:
      (batch, num_heads, seq_q, head_dim) in the dtype of ``q``.
    """
    batch, num_heads, seq_q, head_dim = q.shape
    kb, num_kv_heads, seq_kv, kd = k.shape
    if kb != batch or kd != head_dim or v.shape != k.shape:
        raise ValueError(f"shape mismatch: q={q.shape} k={k.shape} v={v.shape}")
    if num_heads % num_kv_heads != 0:
        raise ValueError(f"{num_heads=} not a multiple of {num_kv_heads=}")
    if causal and seq_q != seq_kv:
        raise NotImplementedError(
            "causal requires seq_q == seq_kv; decode uses kv_len masking"
        )
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(head_dim)

    # Shrink blocks to the problem, keeping the block_k2 | block_k1 invariant.
    block_q = max(1, min(block_q, seq_q))
    block_k1 = max(1, min(block_k1, seq_kv))
    block_k2 = max(1, min(block_k2, block_k1))
    if block_k1 % block_k2 != 0:
        block_k2 = math.gcd(block_k1, block_k2)

    # Pad sequences to block multiples.  Padded K columns are masked via
    # kv_len; padded Q rows are sliced off the output.
    pq = _ceil_div(seq_q, block_q) * block_q
    pk = _ceil_div(seq_kv, block_k1) * block_k1
    if kv_len is None:
        kv_len_arr = jnp.full((batch,), seq_kv, jnp.int32)
    else:
        kv_len_arr = jnp.asarray(kv_len, jnp.int32)
        if kv_len_arr.ndim == 0:
            kv_len_arr = jnp.broadcast_to(kv_len_arr, (batch,))
        elif kv_len_arr.shape != (batch,):
            raise ValueError(
                f"kv_len shape {kv_len_arr.shape} != () or ({batch},)"
            )
        kv_len_arr = jnp.minimum(kv_len_arr, seq_kv)
    if pq != seq_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq - seq_q), (0, 0)))
    if pk != seq_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk - seq_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk - seq_kv), (0, 0)))

    out = _fast_attention_impl(
        q,
        k,
        v,
        kv_len_arr,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k1=block_k1,
        block_k2=block_k2,
    )
    return out[:, :, :seq_q, :]


def _fast_attention_impl(
    q, k, v, kv_len_arr, *, causal, sm_scale, block_q, block_k1, block_k2
):
    batch, num_heads, pq, head_dim = q.shape
    _, num_kv_heads, pk, _ = k.shape
    group = num_heads // num_kv_heads
    bh = batch * num_heads
    qr = q.reshape(bh, pq, head_dim)
    kr = k.reshape(batch * num_kv_heads, pk, head_dim)
    vr = v.reshape(batch * num_kv_heads, pk, head_dim)
    grid = (bh, pq // block_q)

    def kv_len_index(b, i):
        # one valid-length entry per batch row
        return (b // num_heads,)

    def q_index(b, i):
        return (b, i, 0)

    def kv_index(b, i):
        bb = b // num_heads
        h = b % num_heads
        return (bb * num_kv_heads + h // group, 0, 0)

    kernel = functools.partial(
        _attn_kernel,
        sm_scale=sm_scale,
        causal=causal,
        block_q=block_q,
        block_k1=block_k1,
        block_k2=block_k2,
        seq_kv=pk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), kv_len_index),
            pl.BlockSpec((None, block_q, head_dim), q_index),
            pl.BlockSpec((None, pk, head_dim), kv_index),
            pl.BlockSpec((None, pk, head_dim), kv_index),
        ],
        out_specs=pl.BlockSpec((None, block_q, head_dim), q_index),
        out_shape=jax.ShapeDtypeStruct((bh, pq, head_dim), q.dtype),
        interpret=True,
    )(kv_len_arr, qr, kr, vr)
    return out.reshape(batch, num_heads, pq, head_dim)
