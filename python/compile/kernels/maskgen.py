"""The tiling-mask generator (paper §4.1, Figure 3) — explicit form.

The paper replaces the S×S causal ``attention_mask`` with a single
(2M)×(2M) *M-mask* (M = maximal block size).  Any b×b *B-mask* required by
an attention_score block at global offset (row0, col0), b <= M, is a shifted
contiguous view of the M-mask.  This module implements that generator
literally (it is what the rust ``attention::mask`` module mirrors); the
Pallas kernel generates the same masks from iota arithmetic, and
``python/tests/test_maskgen.py`` proves the two agree.

Mask convention: ``1`` = visible (keep score), ``0`` = masked.
For the causal mask, entry (i, j) is visible iff ``j <= i``.
"""

from __future__ import annotations

import numpy as np


def m_mask(m: int) -> np.ndarray:
    """The (2M)×(2M) master mask: lower-triangular ones.

    Memory: (2M)^2 entries regardless of sequence length — e.g. M=512 is
    256 KiB in fp16 vs 8 GiB for an S=64K full mask (paper §4.1).
    """
    n = 2 * m
    return np.tril(np.ones((n, n), dtype=np.int8))


def b_mask_from_m(mm: np.ndarray, row0: int, col0: int, b: int) -> np.ndarray:
    """Extract the B-mask for the block at global offset (row0, col0).

    The causal B-mask depends only on ``diag = row0 - col0`` (how far the
    block sits from the diagonal).  Within the M-mask, the view starting at
    (r, c) has the same diagonal offset whenever ``r - c == diag``; the
    generator picks the in-bounds shift:

      * diag >= 0 (block on/below the diagonal, partially or fully visible):
        view at (diag, 0);
      * diag <  0 (block above the diagonal): clamp — every entry with
        ``col > row`` is masked; view at (0, min(-diag, 2M - b)).

    Requires ``b <= M`` (paper: "the block size b of the B-mask should be
    less than [or equal to] M") so the shifted view stays in bounds.
    """
    m = mm.shape[0] // 2
    if b > m:
        raise ValueError(f"B-mask size {b} exceeds M={m}")
    diag = row0 - col0
    if diag >= 0:
        r = min(diag, 2 * m - b)
        c = 0
        if diag > 2 * m - b:
            # Far below the diagonal: fully visible, and the clamped view
            # at (2M - b, 0) is all-ones precisely because 2M - b >= M >= b.
            r = 2 * m - b
    else:
        r = 0
        c = min(-diag, 2 * m - b)
        if -diag > 2 * m - b:
            c = 2 * m - b
    return mm[r : r + b, c : c + b]


def b_mask_direct(row0: int, col0: int, b: int) -> np.ndarray:
    """Direct (non-generator) computation of the same B-mask, for tests."""
    rows = row0 + np.arange(b)[:, None]
    cols = col0 + np.arange(b)[None, :]
    return (cols <= rows).astype(np.int8)


def classify_block(row0: int, col0: int, b: int) -> str:
    """Tiling-mask block classification (paper §4.1).

    Returns:
      'zero'    — all-masked: skip the block entirely (~50% Cube saving),
      'full'    — all-visible: skip the QK^T + mask add (Vector saving),
      'partial' — apply the B-mask.
    """
    if col0 > row0 + b - 1:
        return "zero"
    if col0 + b - 1 <= row0:
        return "full"
    return "partial"
