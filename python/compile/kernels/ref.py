"""Pure-jnp correctness oracle for the FastAttention kernel.

Implements the paper's "standard attention" definition (§5.1): the naive
``softmax(Q K^T / sqrt(d)) V`` without operator fusion or online softmax.
Every kernel result is compared against this oracle in pytest / hypothesis.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def standard_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    kv_len: Optional[jax.Array] = None,
    sm_scale: Optional[float] = None,
) -> jax.Array:
    """Naive attention oracle.

    q: (B, N, Sq, D); k, v: (B, Nkv, Skv, D) with Nkv | N (GQA).
    Materializes the full (Sq, Skv) score matrix and, when ``causal``,
    the full attention mask — exactly the memory behaviour FastAttention's
    tiling-mask eliminates.
    """
    batch, num_heads, seq_q, head_dim = q.shape
    _, num_kv_heads, seq_kv, _ = k.shape
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(head_dim)
    if num_kv_heads != num_heads:
        rep = num_heads // num_kv_heads
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    s = jnp.einsum("bnqd,bnkd->bnqk", qf, kf) * sm_scale

    col = jnp.arange(seq_kv)[None, :]
    row = jnp.arange(seq_q)[:, None]
    keep = jnp.ones((seq_q, seq_kv), bool)
    if causal:
        keep = keep & (col <= row + (seq_kv - seq_q))
    keep = jnp.broadcast_to(keep[None, None], (batch, num_heads, seq_q, seq_kv))
    if kv_len is not None:
        kl = jnp.asarray(kv_len, jnp.int32)
        if kl.ndim == 0:
            kl = jnp.broadcast_to(kl, (batch,))
        keep = keep & (col[None, None] < kl[:, None, None, None])
    s = jnp.where(keep, s, NEG_INF)

    # Softmax with dead-row guard (rows where everything is masked).
    m = jnp.max(s, axis=-1, keepdims=True)
    dead = m <= NEG_INF / 2
    p = jnp.where(dead, 0.0, jnp.exp(s - jnp.where(dead, 0.0, m)))
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(l == 0.0, 0.0, p / jnp.where(l == 0.0, 1.0, l))

    out = jnp.einsum("bnqk,bnkd->bnqd", p, vf)
    return out.astype(q.dtype)
