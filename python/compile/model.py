"""Layer 2 — JAX transformer model calling the FastAttention kernel.

A decoder-only LM (pre-LN, GELU MLP, learned positions) whose attention is
the Pallas FastAttention kernel from ``kernels/fast_attention.py``.  The
model exists in two AOT entrypoints consumed by the rust coordinator:

  * ``prefill``  — tokens (B, S) -> last-token logits + per-layer KV cache
                   (causal FastAttention, seq_q == seq_kv);
  * ``decode``   — one token + padded KV caches + position -> next logits +
                   updated caches (FastAttention with runtime ``kv_len``).

Parameters are an *ordered flat list* (see ``param_specs``) so the rust side
can feed them positionally from the binary dumps ``aot.py`` writes.
Python never runs at serving time; these functions are lowered once to HLO
text by ``aot.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.fast_attention import fast_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration (Table 1 analogue)."""

    name: str = "tiny-3m"
    vocab: int = 512
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    max_seq: int = 160
    block_q: int = 64
    block_k1: int = 64
    block_k2: int = 32

    @property
    def n_params(self) -> int:
        return sum(int(math.prod(s)) for _, s, _ in param_specs(self))


# The tiny end-to-end serving model (examples/serve_llm.rs).
TINY = ModelConfig()
# A ~100M-class config used for memory-model tests (never lowered).
SMALL_100M = ModelConfig(
    name="small-124m",
    vocab=32000,
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    max_seq=2048,
)


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], str]]:
    """Ordered (name, shape, dtype) for every parameter.

    This order is the wire format between ``aot.py`` (binary dumps +
    manifest) and the rust artifact loader — do not reorder.
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs: List[Tuple[str, Tuple[int, ...], str]] = [
        ("tok_embed", (v, d), "f32"),
        ("pos_embed", (cfg.max_seq, d), "f32"),
    ]
    for i in range(cfg.n_layers):
        specs += [
            (f"layer{i}.ln1_scale", (d,), "f32"),
            (f"layer{i}.wq", (d, nh * hd), "f32"),
            (f"layer{i}.wk", (d, nkv * hd), "f32"),
            (f"layer{i}.wv", (d, nkv * hd), "f32"),
            (f"layer{i}.wo", (nh * hd, d), "f32"),
            (f"layer{i}.ln2_scale", (d,), "f32"),
            (f"layer{i}.w1", (d, f), "f32"),
            (f"layer{i}.w2", (f, d), "f32"),
        ]
    specs += [
        ("ln_f_scale", (d,), "f32"),
        ("lm_head", (d, v), "f32"),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Deterministic small-scale init; the E2E run uses synthetic weights."""
    params: List[jax.Array] = []
    key = jax.random.PRNGKey(seed)
    for name, shape, _ in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / math.sqrt(fan_in)
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * std
            )
    return params


def _unflatten(cfg: ModelConfig, flat: List[jax.Array]):
    """flat list -> (embeds, per-layer dicts, final)."""
    specs = param_specs(cfg)
    if len(flat) != len(specs):
        raise ValueError(f"expected {len(specs)} params, got {len(flat)}")
    by_name = {name: arr for (name, _, _), arr in zip(specs, flat)}
    layers = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        layers.append({k[len(p):]: v for k, v in by_name.items() if k.startswith(p)})
    return by_name, layers


def _rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def _split_heads(x: jax.Array, n: int, hd: int) -> jax.Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)  # (B, N, S, D)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, n, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * d)


def _layer_prefill(cfg: ModelConfig, lp, x: jax.Array):
    """One decoder layer, prefill: returns (x_out, k, v) with full-seq KV."""
    h = _rms_norm(x, lp["ln1_scale"])
    q = _split_heads(h @ lp["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(h @ lp["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(h @ lp["wv"], cfg.n_kv_heads, cfg.head_dim)
    attn = fast_attention(
        q, k, v,
        causal=True,
        block_q=cfg.block_q,
        block_k1=cfg.block_k1,
        block_k2=cfg.block_k2,
    )
    x = x + _merge_heads(attn) @ lp["wo"]
    h = _rms_norm(x, lp["ln2_scale"])
    x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
    return x, k, v


def _layer_decode(cfg: ModelConfig, lp, x, k_cache, v_cache, pos):
    """One decoder layer, decode step.

    x: (B, 1, d).  k_cache/v_cache: (B, Nkv, max_seq, D) padded.  pos:
    (B,) i32 — per-row index of the current token (continuous batching:
    rows may sit at different positions); per-row kv_len = pos + 1 after
    insertion.
    """
    h = _rms_norm(x, lp["ln1_scale"])
    q = _split_heads(h @ lp["wq"], cfg.n_heads, cfg.head_dim)
    k_new = _split_heads(h @ lp["wk"], cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(h @ lp["wv"], cfg.n_kv_heads, cfg.head_dim)
    # Per-row scatter at pos[b]: one-hot over the sequence dimension.
    onehot = (
        jnp.arange(cfg.max_seq)[None, :] == pos[:, None]
    )[:, None, :, None]  # (B, 1, max_seq, 1)
    k_cache = jnp.where(onehot, k_new, k_cache)
    v_cache = jnp.where(onehot, v_new, v_cache)
    attn = fast_attention(
        q, k_cache, v_cache,
        causal=False,
        kv_len=pos + 1,
        block_q=cfg.block_q,
        block_k1=cfg.block_k1,
        block_k2=cfg.block_k2,
    )
    x = x + _merge_heads(attn) @ lp["wo"]
    h = _rms_norm(x, lp["ln2_scale"])
    x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
    return x, k_cache, v_cache


def prefill(
    cfg: ModelConfig,
    flat_params: List[jax.Array],
    tokens: jax.Array,
    lengths: jax.Array = None,
):
    """Prefill entrypoint.

    tokens: (B, S) int32, right-padded per row to the bucket length S.
    lengths: (B,) int32 — true prompt length per row (defaults to S for
    every row).  Returns (logits (B, vocab) at each row's LAST REAL
    position, k_caches (L, B, Nkv, max_seq, D), v_caches (...)) — caches
    are padded to ``max_seq`` so decode can consume them without
    reshaping.  Rows' cache entries beyond their length are junk; decode
    masks them via per-row kv_len and overwrites them as it generates.
    """
    by_name, layers = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    x = by_name["tok_embed"][tokens] + by_name["pos_embed"][None, :s, :]
    pad = cfg.max_seq - s
    ks, vs = [], []
    for lp in layers:
        x, k, v = _layer_prefill(cfg, lp, x)
        ks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    x = _rms_norm(x, by_name["ln_f_scale"])
    # Per-row gather at lengths - 1 (causality: that position never saw
    # the right-padding).
    idx = (lengths - 1).astype(jnp.int32)[:, None, None]  # (B, 1, 1)
    last = jnp.take_along_axis(x, jnp.broadcast_to(idx, (b, 1, cfg.d_model)), axis=1)
    logits = last[:, 0, :] @ by_name["lm_head"]
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode(
    cfg: ModelConfig,
    flat_params: List[jax.Array],
    token: jax.Array,
    k_caches: jax.Array,
    v_caches: jax.Array,
    pos: jax.Array,
):
    """Decode-one-token entrypoint.

    token: (B, 1) i32; k_caches/v_caches: (L, B, Nkv, max_seq, D); pos:
    (B,) i32 — the position each row's token occupies (rows advance
    independently under continuous batching).  Returns (logits (B, vocab),
    new_k_caches, new_v_caches).
    """
    by_name, layers = _unflatten(cfg, flat_params)
    b = token.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    x = by_name["tok_embed"][token] + by_name["pos_embed"][pos][:, None, :]
    new_ks, new_vs = [], []
    for i, lp in enumerate(layers):
        x, kc, vc = _layer_decode(
            cfg, lp, x, k_caches[i], v_caches[i], pos
        )
        new_ks.append(kc)
        new_vs.append(vc)
    x = _rms_norm(x, by_name["ln_f_scale"])
    logits = x[:, -1, :] @ by_name["lm_head"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def prefill_reference(cfg: ModelConfig, flat_params, tokens):
    """Prefill with the naive oracle attention — model-level numeric check."""
    from compile.kernels.ref import standard_attention

    by_name, layers = _unflatten(cfg, flat_params)
    b, s = tokens.shape
    x = by_name["tok_embed"][tokens] + by_name["pos_embed"][None, :s, :]
    for lp in layers:
        h = _rms_norm(x, lp["ln1_scale"])
        q = _split_heads(h @ lp["wq"], cfg.n_heads, cfg.head_dim)
        k = _split_heads(h @ lp["wk"], cfg.n_kv_heads, cfg.head_dim)
        v = _split_heads(h @ lp["wv"], cfg.n_kv_heads, cfg.head_dim)
        attn = standard_attention(q, k, v, causal=True)
        x = x + _merge_heads(attn) @ lp["wo"]
        h = _rms_norm(x, lp["ln2_scale"])
        x = x + jax.nn.gelu(h @ lp["w1"]) @ lp["w2"]
    x = _rms_norm(x, by_name["ln_f_scale"])
    return x[:, -1, :] @ by_name["lm_head"]
