"""AOT compile path: lower L2/L1 to HLO text + dump weights.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
  artifacts/<name>.hlo.txt      — HLO text per entrypoint (the interchange
                                  format: xla_extension 0.5.1 rejects jax
                                  >=0.5 serialized protos with 64-bit ids;
                                  the text parser reassigns ids).
  artifacts/weights/<i>_<name>.bin — little-endian f32 dumps, one per param,
                                  in ``param_specs`` order.
  artifacts/manifest.json       — model config, artifact inputs/outputs
                                  (names, shapes, dtypes), weight index.

The rust runtime (rust/src/runtime/artifacts.rs) consumes the manifest and
never touches Python again.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.fast_attention import fast_attention
from compile.kernels.ref import standard_attention

PREFILL_BATCHES = (1, 4)
PREFILL_SEQS = (32, 64, 128)
DECODE_BATCHES = (1, 4)

# Standalone kernel artifact shape (quickstart + kernel-vs-baseline demo).
KERNEL_SHAPE = dict(batch=1, heads=4, seq=128, head_dim=64)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shape_entry(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def lower_entry(fn: Callable, arg_specs, out_path: str) -> str:
    lowered = jax.jit(fn).lower(*arg_specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return text


def build(out_dir: str, cfg: M.ModelConfig, seed: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    wdir = os.path.join(out_dir, "weights")
    os.makedirs(wdir, exist_ok=True)

    specs = M.param_specs(cfg)
    params = M.init_params(cfg, seed=seed)

    weights_index = []
    for i, ((name, shape, dtype), arr) in enumerate(zip(specs, params)):
        fname = f"{i:03d}_{name.replace('.', '_')}.bin"
        np.asarray(arr, dtype=np.float32).tofile(os.path.join(wdir, fname))
        weights_index.append(
            {"name": name, "file": f"weights/{fname}", "shape": list(shape),
             "dtype": dtype}
        )

    param_arg_specs = [_spec(s, jnp.float32) for _, s, _ in specs]
    param_inputs = [_shape_entry(n, s, d) for n, s, d in specs]

    artifacts = []

    def add(name, fn, arg_specs, inputs, outputs):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        lower_entry(fn, arg_specs, path)
        artifacts.append(
            {"name": name, "file": f"{name}.hlo.txt", "inputs": inputs,
             "outputs": outputs}
        )
        print(f"  lowered {name}")

    L, Nkv, Smax, D = cfg.n_layers, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim
    V = cfg.vocab

    # --- model prefill entrypoints -------------------------------------
    for b in PREFILL_BATCHES:
        for s in PREFILL_SEQS:
            name = f"prefill_b{b}_s{s}"

            def fn(tokens, lengths, *flat, _b=b, _s=s):
                return M.prefill(cfg, list(flat), tokens, lengths)

            add(
                name,
                fn,
                [_spec((b, s), jnp.int32), _spec((b,), jnp.int32)]
                + param_arg_specs,
                [
                    _shape_entry("tokens", (b, s), "i32"),
                    _shape_entry("lengths", (b,), "i32"),
                ]
                + param_inputs,
                [
                    _shape_entry("logits", (b, V), "f32"),
                    _shape_entry("k_caches", (L, b, Nkv, Smax, D), "f32"),
                    _shape_entry("v_caches", (L, b, Nkv, Smax, D), "f32"),
                ],
            )

    # --- model decode entrypoints ---------------------------------------
    for b in DECODE_BATCHES:
        name = f"decode_b{b}"

        def fn(token, k_caches, v_caches, pos, *flat, _b=b):
            return M.decode(cfg, list(flat), token, k_caches, v_caches, pos)

        add(
            name,
            fn,
            [
                _spec((b, 1), jnp.int32),
                _spec((L, b, Nkv, Smax, D), jnp.float32),
                _spec((L, b, Nkv, Smax, D), jnp.float32),
                _spec((b,), jnp.int32),
            ]
            + param_arg_specs,
            [
                _shape_entry("token", (b, 1), "i32"),
                _shape_entry("k_caches", (L, b, Nkv, Smax, D), "f32"),
                _shape_entry("v_caches", (L, b, Nkv, Smax, D), "f32"),
                _shape_entry("pos", (b,), "i32"),
            ]
            + param_inputs,
            [
                _shape_entry("logits", (b, V), "f32"),
                _shape_entry("k_caches", (L, b, Nkv, Smax, D), "f32"),
                _shape_entry("v_caches", (L, b, Nkv, Smax, D), "f32"),
            ],
        )

    # --- standalone attention kernels (quickstart / baseline) -----------
    ks = KERNEL_SHAPE
    qkv = _spec((ks["batch"], ks["heads"], ks["seq"], ks["head_dim"]))
    qkv_in = [
        _shape_entry(n, (ks["batch"], ks["heads"], ks["seq"], ks["head_dim"]),
                     "f32")
        for n in ("q", "k", "v")
    ]
    out_e = [_shape_entry(
        "o", (ks["batch"], ks["heads"], ks["seq"], ks["head_dim"]), "f32")]

    add(
        "kernel_fastattn_causal",
        lambda q, k, v: (fast_attention(q, k, v, causal=True),),
        [qkv, qkv, qkv],
        qkv_in,
        out_e,
    )
    add(
        "kernel_standard_causal",
        lambda q, k, v: (standard_attention(q, k, v, causal=True),),
        [qkv, qkv, qkv],
        qkv_in,
        out_e,
    )

    manifest = {
        "model": {
            "name": cfg.name,
            "vocab": V,
            "n_layers": L,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_kv_heads": Nkv,
            "head_dim": D,
            "d_ff": cfg.d_ff,
            "max_seq": Smax,
            "n_params": cfg.n_params,
            "seed": seed,
        },
        "prefill_batches": list(PREFILL_BATCHES),
        "prefill_seqs": list(PREFILL_SEQS),
        "decode_batches": list(DECODE_BATCHES),
        "kernel_shape": KERNEL_SHAPE,
        "weights": weights_index,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.TINY
    print(f"AOT-lowering model '{cfg.name}' ({cfg.n_params} params) "
          f"-> {args.out_dir}")
    manifest = build(args.out_dir, cfg, args.seed)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + {len(manifest['weights'])} weight files")


if __name__ == "__main__":
    main()
