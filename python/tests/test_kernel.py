"""L1 correctness: FastAttention Pallas kernel vs the pure-jnp oracle.

The CORE correctness signal of the build path — `make artifacts` refuses to
ship artifacts unless this suite is green (see Makefile `test` target, run in
CI order before cargo test).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fast_attention import (
    DEFAULT_BLOCK_K1,
    DEFAULT_BLOCK_K2,
    fast_attention,
    vmem_footprint_bytes,
)
from compile.kernels.ref import standard_attention

RNG = np.random.default_rng(1234)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def _max_err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


def _run(b, n, nkv, sq, skv, d, *, causal=False, kv_len=None, dtype=jnp.float32,
         tol=2e-5, **kw):
    q = _rand((b, n, sq, d), dtype)
    k = _rand((b, nkv, skv, d), dtype)
    v = _rand((b, nkv, skv, d), dtype)
    kl = None if kv_len is None else jnp.int32(kv_len)
    out = fast_attention(q, k, v, causal=causal, kv_len=kl, **kw)
    ref = standard_attention(q, k, v, causal=causal, kv_len=kl)
    assert out.shape == ref.shape
    assert out.dtype == q.dtype
    assert _max_err(out, ref) < tol, f"max err {_max_err(out, ref)}"


# ---------------------------------------------------------------- basic --

class TestBasic:
    def test_noncausal_square(self):
        _run(1, 2, 2, 64, 64, 32)

    def test_causal_square(self):
        _run(1, 2, 2, 64, 64, 32, causal=True)

    def test_batched(self):
        _run(3, 4, 4, 32, 32, 16, causal=True)

    def test_cross_attention_rect(self):
        _run(1, 2, 2, 32, 96, 16)

    def test_single_query_decode(self):
        _run(2, 4, 4, 1, 128, 64, kv_len=77)

    def test_head_dim_128(self):
        _run(1, 2, 2, 32, 32, 128, causal=True)

    def test_seq_one_kv_one(self):
        _run(1, 1, 1, 1, 1, 8)


# ------------------------------------------------------------------ GQA --

class TestGQA:
    def test_gqa_2x(self):
        _run(1, 4, 2, 32, 32, 16, causal=True)

    def test_mqa(self):
        _run(2, 8, 1, 32, 32, 16, causal=True)

    def test_gqa_decode(self):
        _run(1, 8, 2, 1, 64, 32, kv_len=40)

    def test_bad_group_raises(self):
        q = _rand((1, 3, 8, 8))
        k = _rand((1, 2, 8, 8))
        with pytest.raises(ValueError):
            fast_attention(q, k, k)


# --------------------------------------------------------- tiling shapes --

class TestTiling:
    """Two-level tiling: every (block_q, block_k1, block_k2) agrees."""

    @pytest.mark.parametrize("bq,bk1,bk2", [
        (8, 8, 8),     # degenerate: one level
        (16, 32, 8),   # 4 sub-blocks per slab
        (32, 64, 16),
        (64, 16, 16),  # slab == sub-block
        (8, 64, 4),
    ])
    def test_block_shapes_causal(self, bq, bk1, bk2):
        _run(1, 2, 2, 64, 64, 16, causal=True,
             block_q=bq, block_k1=bk1, block_k2=bk2)

    @pytest.mark.parametrize("bq,bk1,bk2", [(16, 32, 8), (32, 64, 16)])
    def test_block_shapes_noncausal(self, bq, bk1, bk2):
        _run(1, 2, 2, 64, 64, 16, block_q=bq, block_k1=bk1, block_k2=bk2)

    def test_non_divisible_seq(self):
        # seq not a multiple of any block size — padding + masking path.
        _run(1, 2, 2, 50, 50, 16, causal=True,
             block_q=16, block_k1=16, block_k2=8)

    def test_blocks_larger_than_seq(self):
        _run(1, 1, 1, 5, 7, 8, block_q=64, block_k1=64, block_k2=16)

    def test_bad_block_divisibility_fixed_by_gcd(self):
        # block_k2=12 does not divide block_k1=32; impl falls back to gcd.
        _run(1, 1, 1, 32, 32, 8, causal=True,
             block_q=16, block_k1=32, block_k2=12)


# ---------------------------------------------------------- tiling mask --

class TestTilingMask:
    """Mask semantics without materializing S×S."""

    def test_kv_len_zero_rows_are_zero(self):
        q = _rand((1, 1, 4, 8))
        k = _rand((1, 1, 16, 8))
        v = _rand((1, 1, 16, 8))
        out = fast_attention(q, k, v, kv_len=jnp.int32(0))
        assert float(jnp.max(jnp.abs(out))) == 0.0

    def test_kv_len_one(self):
        _run(1, 2, 2, 4, 32, 8, kv_len=1)

    def test_kv_len_per_row(self):
        # continuous batching: every row has its own valid KV length
        q = _rand((3, 2, 1, 16))
        k = _rand((3, 2, 40, 16))
        v = _rand((3, 2, 40, 16))
        kl = jnp.array([5, 17, 40], jnp.int32)
        out = fast_attention(q, k, v, kv_len=kl)
        ref = standard_attention(q, k, v, kv_len=kl)
        assert _max_err(out, ref) < 2e-5

    def test_kv_len_bad_shape_raises(self):
        q = _rand((2, 1, 4, 8))
        k = _rand((2, 1, 8, 8))
        with pytest.raises(ValueError):
            fast_attention(q, k, k, kv_len=jnp.array([1, 2, 3], jnp.int32))

    def test_kv_len_exact_block_boundary(self):
        _run(1, 2, 2, 4, 64, 8, kv_len=16,
             block_k1=16, block_k2=16)

    def test_kv_len_mid_block(self):
        _run(1, 2, 2, 4, 64, 8, kv_len=19, block_k1=16, block_k2=8)

    def test_causal_first_row_attends_self_only(self):
        q = _rand((1, 1, 8, 4))
        k = _rand((1, 1, 8, 4))
        v = _rand((1, 1, 8, 4))
        out = fast_attention(q, k, v, causal=True)
        # row 0 sees only position 0 -> output equals v[0].
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]), np.asarray(v[0, 0, 0]), rtol=1e-5
        )

    def test_masked_tail_is_ignored(self):
        # Garbage in the masked KV tail must not change the result.
        q = _rand((1, 2, 4, 8))
        k = _rand((1, 2, 32, 8))
        v = _rand((1, 2, 32, 8))
        k_dirty = k.at[:, :, 20:, :].set(1e9)
        v_dirty = v.at[:, :, 20:, :].set(-1e9)
        a = fast_attention(q, k, v, kv_len=jnp.int32(20))
        b = fast_attention(q, k_dirty, v_dirty, kv_len=jnp.int32(20))
        assert _max_err(a, b) < 1e-5


# ------------------------------------------------------------- numerics --

class TestNumerics:
    def test_large_scores_stable(self):
        # online softmax must not overflow with large logits
        q = _rand((1, 1, 32, 16), scale=30.0)
        k = _rand((1, 1, 32, 16), scale=30.0)
        v = _rand((1, 1, 32, 16))
        out = fast_attention(q, k, v, causal=True)
        ref = standard_attention(q, k, v, causal=True)
        assert bool(jnp.all(jnp.isfinite(out)))
        assert _max_err(out, ref) < 1e-4

    def test_uniform_scores(self):
        # all-equal scores -> output is the running mean of V.
        q = jnp.zeros((1, 1, 8, 4))
        k = _rand((1, 1, 8, 4))
        v = _rand((1, 1, 8, 4))
        out = fast_attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out[0, 0, 0]),
            np.asarray(jnp.mean(v[0, 0], axis=0)),
            rtol=1e-5,
        )

    def test_bf16_inputs(self):
        _run(1, 2, 2, 32, 32, 16, causal=True, dtype=jnp.bfloat16, tol=3e-2)

    def test_custom_scale(self):
        q = _rand((1, 1, 16, 8))
        k = _rand((1, 1, 16, 8))
        v = _rand((1, 1, 16, 8))
        out = fast_attention(q, k, v, sm_scale=0.25)
        ref = standard_attention(q, k, v, sm_scale=0.25)
        assert _max_err(out, ref) < 2e-5

    def test_permutation_invariance_noncausal(self):
        # non-causal attention is invariant to a KV permutation.
        q = _rand((1, 1, 8, 8))
        k = _rand((1, 1, 16, 8))
        v = _rand((1, 1, 16, 8))
        perm = np.asarray(RNG.permutation(16))
        a = fast_attention(q, k, v)
        b = fast_attention(q, k[:, :, perm], v[:, :, perm])
        assert _max_err(a, b) < 2e-5


# ---------------------------------------------------- hypothesis sweeps --

@st.composite
def attn_shapes(draw):
    b = draw(st.integers(1, 2))
    nkv = draw(st.sampled_from([1, 2]))
    n = nkv * draw(st.sampled_from([1, 2, 4]))
    skv = draw(st.integers(1, 80))
    causal = draw(st.booleans())
    sq = skv if causal else draw(st.integers(1, 48))
    d = draw(st.sampled_from([4, 8, 16, 32]))
    kv_len = draw(st.one_of(st.none(), st.integers(0, skv)))
    bq = draw(st.sampled_from([8, 16, 32]))
    bk2 = draw(st.sampled_from([4, 8, 16]))
    bk1 = bk2 * draw(st.sampled_from([1, 2, 4]))
    return b, n, nkv, sq, skv, d, causal, kv_len, bq, bk1, bk2


@settings(max_examples=40, deadline=None)
@given(attn_shapes())
def test_hypothesis_matches_oracle(shape):
    b, n, nkv, sq, skv, d, causal, kv_len, bq, bk1, bk2 = shape
    _run(b, n, nkv, sq, skv, d, causal=causal, kv_len=kv_len,
         block_q=bq, block_k1=bk1, block_k2=bk2)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([jnp.float32, jnp.bfloat16]),
    st.integers(1, 64),
    st.sampled_from([8, 16, 32, 64]),
)
def test_hypothesis_dtypes(dtype, skv, d):
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    _run(1, 2, 2, skv, skv, d, causal=True, dtype=dtype, tol=tol)


# ----------------------------------------------------------- misc/meta --

def test_vmem_footprint_monotone():
    a = vmem_footprint_bytes(64, 64, 64)
    b = vmem_footprint_bytes(64, 128, 64)
    c = vmem_footprint_bytes(128, 128, 64)
    assert a < b < c


def test_shape_mismatch_raises():
    q = _rand((1, 2, 8, 8))
    k = _rand((1, 2, 8, 4))
    with pytest.raises(ValueError):
        fast_attention(q, k, k)


def test_causal_rect_not_implemented():
    q = _rand((1, 1, 4, 8))
    k = _rand((1, 1, 8, 8))
    with pytest.raises(NotImplementedError):
        fast_attention(q, k, k, causal=True)
