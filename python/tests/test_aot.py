"""AOT path checks: HLO text emission, manifest integrity, weight dumps."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

SMALL = M.ModelConfig(
    name="aot-test",
    vocab=32,
    n_layers=1,
    d_model=16,
    n_heads=2,
    n_kv_heads=2,
    head_dim=8,
    d_ff=32,
    max_seq=16,
    block_q=8,
    block_k1=8,
    block_k2=4,
)


def test_to_hlo_text_roundtrippable():
    lowered = jax.jit(lambda x, y: (jnp.matmul(x, y) + 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # text parser requirement: no 64-bit-id serialized protos involved
    assert "f32[2,2]" in text


def test_pallas_kernel_lowers_to_plain_hlo():
    from compile.kernels.fast_attention import fast_attention

    spec = jax.ShapeDtypeStruct((1, 1, 16, 8), jnp.float32)
    lowered = jax.jit(
        lambda q, k, v: (fast_attention(q, k, v, causal=True,
                                        block_q=8, block_k1=8, block_k2=4),)
    ).lower(spec, spec, spec)
    text = aot.to_hlo_text(lowered)
    # interpret=True means no mosaic custom-calls -> CPU-executable
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
    assert "while" in text  # the two-level reduction loops survive lowering


class TestBuild:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory):
        out = str(tmp_path_factory.mktemp("artifacts"))
        old = (aot.PREFILL_BATCHES, aot.PREFILL_SEQS, aot.DECODE_BATCHES,
               dict(aot.KERNEL_SHAPE))
        aot.PREFILL_BATCHES = (1,)
        aot.PREFILL_SEQS = (8,)
        aot.DECODE_BATCHES = (1,)
        aot.KERNEL_SHAPE = dict(batch=1, heads=2, seq=16, head_dim=8)
        try:
            manifest = aot.build(out, SMALL, seed=0)
        finally:
            (aot.PREFILL_BATCHES, aot.PREFILL_SEQS, aot.DECODE_BATCHES,
             ks) = old
            aot.KERNEL_SHAPE.update(ks)
        return out, manifest

    def test_manifest_written(self, built):
        out, manifest = built
        with open(os.path.join(out, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk["model"]["name"] == "aot-test"
        assert on_disk["model"]["n_params"] == SMALL.n_params
        assert len(on_disk["artifacts"]) == len(manifest["artifacts"]) == 4

    def test_artifact_files_exist_and_parse(self, built):
        out, manifest = built
        for a in manifest["artifacts"]:
            path = os.path.join(out, a["file"])
            assert os.path.exists(path), a["name"]
            text = open(path).read()
            assert text.startswith("HloModule"), a["name"]

    def test_weight_dumps_roundtrip(self, built):
        out, manifest = built
        params = M.init_params(SMALL, seed=0)
        specs = M.param_specs(SMALL)
        assert len(manifest["weights"]) == len(specs)
        for w, (name, shape, _), arr in zip(manifest["weights"], specs, params):
            assert w["name"] == name
            data = np.fromfile(os.path.join(out, w["file"]), dtype=np.float32)
            assert data.size == int(np.prod(shape))
            np.testing.assert_array_equal(
                data.reshape(shape), np.asarray(arr)
            )

    def test_io_shapes_recorded(self, built):
        _, manifest = built
        pre = next(a for a in manifest["artifacts"]
                   if a["name"] == "prefill_b1_s8")
        assert pre["inputs"][0] == {
            "name": "tokens", "shape": [1, 8], "dtype": "i32"}
        assert pre["outputs"][0]["shape"] == [1, SMALL.vocab]
        dec = next(a for a in manifest["artifacts"] if a["name"] == "decode_b1")
        # decode outputs caches with the same shape it consumed
        assert dec["inputs"][1]["shape"] == dec["outputs"][1]["shape"]


def test_repo_artifacts_manifest_consistent():
    """If `make artifacts` has run, sanity-check the real manifest."""
    path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    manifest = json.load(open(path))
    assert manifest["model"]["name"] == M.TINY.name
    names = {a["name"] for a in manifest["artifacts"]}
    assert "kernel_fastattn_causal" in names
    assert "kernel_standard_causal" in names
    for b in manifest["prefill_batches"]:
        for s in manifest["prefill_seqs"]:
            assert f"prefill_b{b}_s{s}" in names
