"""Tiling-mask generator properties (paper §4.1, Figure 3).

Proves the (2M)x(2M) M-mask shift generator produces exactly the B-mask a
direct computation would, for every block offset and size b <= M — i.e. the
memory saving (256 KiB vs 8 GiB at S=64K, M=512) is free of semantic cost.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.maskgen import (
    b_mask_direct,
    b_mask_from_m,
    classify_block,
    m_mask,
)


class TestMMask:
    def test_shape_and_triangularity(self):
        mm = m_mask(4)
        assert mm.shape == (8, 8)
        assert np.array_equal(mm, np.tril(np.ones((8, 8))))

    def test_memory_claim(self):
        # Paper: M=512 M-mask is 256 KiB in uint8/fp8-like storage vs
        # 8 GiB for the S=64K fp16 full mask.
        m = 512
        assert m_mask(m).size == (2 * m) ** 2 == 1024 * 1024  # 1 MiB int8
        full = 64 * 1024
        assert full * full * 2 == 8 * 1024**3  # 8 GiB fp16


class TestBMaskExtraction:
    @pytest.mark.parametrize("m,b", [(3, 3), (4, 2), (8, 8), (8, 5)])
    def test_exhaustive_small(self, m, b):
        mm = m_mask(m)
        for row0 in range(0, 4 * m, 1):
            for col0 in range(0, 4 * m, 1):
                got = b_mask_from_m(mm, row0, col0, b)
                want = b_mask_direct(row0, col0, b)
                assert np.array_equal(got, want), (row0, col0, b)

    def test_figure3_case(self):
        # Paper figure: M=3, b=3 — all 6 distinct B-masks extractable.
        mm = m_mask(3)
        seen = set()
        for row0 in range(0, 12, 3):
            for col0 in range(0, 12, 3):
                bm = b_mask_from_m(mm, row0, col0, 3)
                seen.add(bm.tobytes())
        # distinct diagonals producing distinct patterns: full, zero, and
        # the partial ones
        assert len(seen) >= 3

    def test_b_greater_than_m_rejected(self):
        with pytest.raises(ValueError):
            b_mask_from_m(m_mask(2), 0, 0, 3)


class TestClassification:
    def test_zero_block(self):
        assert classify_block(0, 8, 4) == "zero"

    def test_full_block(self):
        assert classify_block(8, 0, 4) == "full"

    def test_diagonal_block_partial(self):
        assert classify_block(4, 4, 4) == "partial"

    @given(st.integers(0, 200), st.integers(0, 200), st.integers(1, 16))
    @settings(max_examples=200, deadline=None)
    def test_classification_matches_mask_content(self, row0, col0, b):
        bm = b_mask_direct(row0, col0, b)
        cls = classify_block(row0, col0, b)
        if cls == "zero":
            assert bm.sum() == 0
        elif cls == "full":
            assert bm.sum() == b * b
        else:
            assert 0 < bm.sum() < b * b

    def test_causal_skip_fraction_approaches_half(self):
        # The "~50% Cube saving": fraction of zero blocks over the S/b grid.
        b, s = 16, 1024
        n = s // b
        zero = sum(
            classify_block(i * b, j * b, b) == "zero"
            for i in range(n)
            for j in range(n)
        )
        frac = zero / (n * n)
        assert 0.4 < frac < 0.5


@given(st.integers(0, 500), st.integers(0, 500),
       st.integers(1, 12), st.integers(12, 24))
@settings(max_examples=300, deadline=None)
def test_hypothesis_shift_equivalence(row0, col0, b, m):
    got = b_mask_from_m(m_mask(m), row0, col0, b)
    assert np.array_equal(got, b_mask_direct(row0, col0, b))
