"""L2 model checks: shapes, prefill/decode consistency, oracle agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(
    name="test-tiny",
    vocab=64,
    n_layers=2,
    d_model=32,
    n_heads=2,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    max_seq=24,
    block_q=8,
    block_k1=8,
    block_k2=4,
)

GQA_CFG = M.ModelConfig(
    name="test-gqa",
    vocab=64,
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    head_dim=8,
    d_ff=64,
    max_seq=24,
    block_q=8,
    block_k1=8,
    block_k2=4,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


def _tokens(b, s, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, CFG.vocab, (b, s)), jnp.int32
    )


class TestParamSpecs:
    def test_count_matches_init(self, params):
        assert len(params) == len(M.param_specs(CFG))

    def test_shapes_match(self, params):
        for (name, shape, _), arr in zip(M.param_specs(CFG), params):
            assert arr.shape == tuple(shape), name

    def test_n_params(self):
        total = sum(int(np.prod(s)) for _, s, _ in M.param_specs(CFG))
        assert CFG.n_params == total

    def test_order_is_stable(self):
        a = [n for n, _, _ in M.param_specs(CFG)]
        b = [n for n, _, _ in M.param_specs(CFG)]
        assert a == b
        assert a[0] == "tok_embed" and a[-1] == "lm_head"


class TestPrefill:
    def test_output_shapes(self, params):
        logits, kc, vc = M.prefill(CFG, params, _tokens(2, 8))
        assert logits.shape == (2, CFG.vocab)
        assert kc.shape == (CFG.n_layers, 2, CFG.n_kv_heads, CFG.max_seq,
                            CFG.head_dim)
        assert vc.shape == kc.shape

    def test_matches_reference_attention(self, params):
        tokens = _tokens(2, 8)
        logits, _, _ = M.prefill(CFG, params, tokens)
        ref = M.prefill_reference(CFG, params, tokens)
        assert float(jnp.max(jnp.abs(logits - ref))) < 5e-5

    def test_cache_tail_is_padding(self, params):
        _, kc, vc = M.prefill(CFG, params, _tokens(1, 8))
        assert float(jnp.max(jnp.abs(kc[:, :, :, 8:, :]))) == 0.0
        assert float(jnp.max(jnp.abs(vc[:, :, :, 8:, :]))) == 0.0

    def test_batch_rows_independent(self, params):
        t2 = _tokens(2, 8)
        logits2, _, _ = M.prefill(CFG, params, t2)
        logits1, _, _ = M.prefill(CFG, params, t2[:1])
        assert float(jnp.max(jnp.abs(logits2[0] - logits1[0]))) < 1e-4


class TestDecode:
    def test_matches_prefill(self, params):
        tokens = _tokens(2, 8)
        _, kc, vc = M.prefill(CFG, params, tokens)
        nxt = _tokens(2, 1, seed=7)
        d_logits, kc2, vc2 = M.decode(CFG, params, nxt, kc, vc, jnp.int32(8))
        p_logits, _, _ = M.prefill(
            CFG, params, jnp.concatenate([tokens, nxt], axis=1)
        )
        assert float(jnp.max(jnp.abs(d_logits - p_logits))) < 1e-3

    def test_multi_step_chain(self, params):
        tokens = _tokens(1, 4)
        _, kc, vc = M.prefill(CFG, params, tokens)
        seq = tokens
        for step in range(3):
            nxt = _tokens(1, 1, seed=100 + step)
            d_logits, kc, vc = M.decode(
                CFG, params, nxt, kc, vc, jnp.int32(4 + step)
            )
            seq = jnp.concatenate([seq, nxt], axis=1)
        p_logits, _, _ = M.prefill(CFG, params, seq)
        assert float(jnp.max(jnp.abs(d_logits - p_logits))) < 1e-3

    def test_cache_updated_in_place(self, params):
        tokens = _tokens(1, 4)
        _, kc, vc = M.prefill(CFG, params, tokens)
        nxt = _tokens(1, 1, seed=5)
        _, kc2, _ = M.decode(CFG, params, nxt, kc, vc, jnp.int32(4))
        # prefix preserved, slot 4 written
        assert float(jnp.max(jnp.abs(kc2[:, :, :, :4] - kc[:, :, :, :4]))) == 0
        assert float(jnp.max(jnp.abs(kc2[:, :, :, 4]))) > 0


class TestContinuousBatching:
    """Per-row lengths/positions — the coordinator's ragged batches."""

    def test_ragged_prefill_matches_single(self, params):
        toks = _tokens(2, 8, seed=21)
        lengths = jnp.array([5, 8], jnp.int32)
        logits, _, _ = M.prefill(CFG, params, toks, lengths)
        solo, _, _ = M.prefill(CFG, params, toks[:1, :5])
        assert float(jnp.max(jnp.abs(logits[0] - solo[0]))) < 1e-4

    def test_ragged_decode_rows_independent(self, params):
        toks = _tokens(2, 8, seed=22)
        lengths = jnp.array([5, 8], jnp.int32)
        _, kc, vc = M.prefill(CFG, params, toks, lengths)
        nxt = _tokens(2, 1, seed=23)
        d_logits, _, _ = M.decode(
            CFG, params, nxt, kc, vc, jnp.array([5, 8], jnp.int32)
        )
        # row 0: equivalent to prefill over its true 6-token sequence
        p0, _, _ = M.prefill(
            CFG, params, jnp.concatenate([toks[:1, :5], nxt[:1]], axis=1)
        )
        p1, _, _ = M.prefill(
            CFG, params, jnp.concatenate([toks[1:], nxt[1:]], axis=1)
        )
        assert float(jnp.max(jnp.abs(d_logits[0] - p0[0]))) < 1e-3
        assert float(jnp.max(jnp.abs(d_logits[1] - p1[0]))) < 1e-3

    def test_padded_slot_is_harmless(self, params):
        # a dummy slot (zero cache, pos 0) must not disturb the real row
        toks = _tokens(2, 8, seed=24)
        _, kc, vc = M.prefill(CFG, params, toks)
        nxt = _tokens(2, 1, seed=25)
        # slot 1 is "dummy": zeroed cache, pos 0
        kc_d = kc.at[:, 1:].set(0.0)
        vc_d = vc.at[:, 1:].set(0.0)
        a, _, _ = M.decode(CFG, params, nxt, kc_d, vc_d,
                           jnp.array([8, 0], jnp.int32))
        b, _, _ = M.decode(CFG, params, nxt, kc, vc,
                           jnp.array([8, 8], jnp.int32))
        assert float(jnp.max(jnp.abs(a[0] - b[0]))) < 1e-4


class TestGQAModel:
    def test_prefill_decode_consistency(self):
        params = M.init_params(GQA_CFG, 3)
        tokens = _tokens(1, 8, seed=9)
        _, kc, vc = M.prefill(GQA_CFG, params, tokens)
        assert kc.shape[2] == GQA_CFG.n_kv_heads
        nxt = _tokens(1, 1, seed=11)
        d_logits, _, _ = M.decode(GQA_CFG, params, nxt, kc, vc, jnp.int32(8))
        p_logits, _, _ = M.prefill(
            GQA_CFG, params, jnp.concatenate([tokens, nxt], axis=1)
        )
        assert float(jnp.max(jnp.abs(d_logits - p_logits))) < 1e-3


class TestConfigs:
    def test_tiny_config_param_count(self):
        assert 3_000_000 < M.TINY.n_params < 4_000_000

    def test_small_100m_class(self):
        # ~124M params, GPT-2-small-shaped — used by the memory model tests.
        assert 100_000_000 < M.SMALL_100M.n_params < 200_000_000

    def test_wrong_param_count_raises(self, params):
        with pytest.raises(ValueError):
            M.prefill(CFG, params[:-1], _tokens(1, 8))
