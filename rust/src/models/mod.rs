//! Model zoo: the paper's evaluation models (Table 1) as shape configs.
//!
//! Weights never matter for the reproduced numbers — every latency /
//! throughput / memory figure in the paper is a function of the shapes
//! (B, S, N, D, L, H1, H2, V) — so the zoo stores shapes only.  The real
//! weights for the end-to-end serving example come from the AOT artifact
//! bundle (`artifacts/weights/`).

mod zoo;

pub use zoo::*;

/// Transformer shape parameters (paper Appendix C notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelShape {
    /// Human-readable name, e.g. "PanGu-38B".
    pub name: &'static str,
    /// Total parameter count (informational, in billions × 10⁹).
    pub params: u64,
    /// Number of transformer layers, `L`.
    pub layers: u32,
    /// Number of attention heads, `N`.
    pub heads: u32,
    /// Number of KV heads, `N_kv ≤ N` (grouped-query attention; `== N`
    /// for the classic multi-head models of Table 1).
    pub kv_heads: u32,
    /// Head dimension, `D`.
    pub head_dim: u32,
    /// FFN hidden size, `H2`.
    pub ffn: u32,
    /// Vocabulary size, `V`.
    pub vocab: u32,
}

impl ModelShape {
    /// Attention hidden dimension `H1 = N * D`.
    pub fn hidden(&self) -> u64 {
        self.heads as u64 * self.head_dim as u64
    }

    /// KV hidden dimension `N_kv * D` (equals `H1` for MHA models).
    pub fn kv_hidden(&self) -> u64 {
        self.kv_heads as u64 * self.head_dim as u64
    }

    /// Query heads sharing each KV head (GQA group size).
    pub fn group_size(&self) -> u32 {
        self.heads / self.kv_heads.max(1)
    }

    /// Heads resident on one device under `n`-way tensor parallelism.
    pub fn heads_per_device(&self, n: u32) -> u32 {
        (self.heads + n - 1) / n
    }

    /// FLOPs of one full attention forward (paper §5.2.3 formula):
    /// `4 · seqlen² · head_dim · heads` per batch element (both GEMMs).
    pub fn attention_flops(&self, batch: u64, seq: u64) -> f64 {
        4.0 * (seq as f64) * (seq as f64)
            * self.head_dim as f64
            * self.heads as f64
            * batch as f64
    }

    /// FLOPs of one decode-step attention (`seq_q = 1`) over a KV of
    /// length `kv`.
    pub fn decode_attention_flops(&self, batch: u64, kv: u64) -> f64 {
        4.0 * kv as f64 * self.head_dim as f64 * self.heads as f64 * batch as f64
    }

    /// Per-layer GEMM FLOPs for a prefill of `seq` tokens (QKV + O + MLP).
    pub fn layer_gemm_flops(&self, batch: u64, seq: u64) -> f64 {
        let h1 = self.hidden() as f64;
        let h2 = self.ffn as f64;
        let tok = (batch * seq) as f64;
        // 4 projections H1×H1 plus 2 MLP GEMMs H1×H2, 2 FLOPs per MAC.
        2.0 * tok * (4.0 * h1 * h1 + 2.0 * h1 * h2)
    }

    /// Model weight bytes in fp16 (paper eq. 17):
    /// `M_w = L (8 H1² + 4 H1 H2)`.
    pub fn weight_bytes_fp16(&self) -> u64 {
        let h1 = self.hidden();
        let h2 = self.ffn as u64;
        self.layers as u64 * (8 * h1 * h1 + 4 * h1 * h2)
    }

    /// One layer's KV-cache bytes per device in fp16 (paper eq. 18,
    /// generalized to GQA): `M_kv = 4 B N_kv D (S + O) / n`.  For the
    /// paper's MHA models `N_kv D == H1`, recovering eq. 18 exactly.
    pub fn kv_bytes_per_layer_fp16(&self, batch: u64, s_plus_o: u64, n: u32) -> u64 {
        4 * batch * self.kv_hidden() * s_plus_o / n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hidden_dims_match_table1() {
        assert_eq!(PANGU_38B.hidden(), 5120);
        assert_eq!(LLAMA2_7B.hidden(), 4096);
        assert_eq!(LLAMA2_70B.hidden(), 8192);
        assert_eq!(OPT_30B.hidden(), 7168);
        assert_eq!(LLAMA_65B.hidden(), 8192);
    }

    #[test]
    fn heads_per_device_8way() {
        assert_eq!(PANGU_38B.heads_per_device(8), 5); // paper §5.2.1: N=5
        assert_eq!(PANGU_71B.heads_per_device(8), 4); // paper §5.2.1: N=4
    }

    #[test]
    fn attention_flops_formula() {
        // paper formula: 4 · seqlen² · head_dim · heads
        let f = PANGU_38B.attention_flops(1, 2048);
        assert_eq!(f, 4.0 * 2048.0 * 2048.0 * 128.0 * 40.0);
    }

    #[test]
    fn weight_bytes_eq17_on_table1_config() {
        // eq. 17 over Table 1's PanGu-38B config: 40·(8·5120² + 4·5120·
        // 20480) ≈ 25 GB.  (The table's config understates the 38 B name;
        // the memory planner uses 2·params instead — see sim::memory.)
        let w = PANGU_38B.weight_bytes_fp16() as f64 / 1e9;
        assert!(w > 23.0 && w < 28.0, "got {w} GB");
    }

    #[test]
    fn gqa_shrinks_kv_not_hidden() {
        assert_eq!(LLAMA2_70B_GQA.hidden(), LLAMA2_70B.hidden());
        assert_eq!(LLAMA2_70B_GQA.group_size(), 8);
        assert_eq!(LLAMA2_70B_GQA.kv_hidden() * 8, LLAMA2_70B.kv_hidden());
        // KV cache shrinks by the group factor
        let mha = LLAMA2_70B.kv_bytes_per_layer_fp16(1, 4096, 1);
        let gqa = LLAMA2_70B_GQA.kv_bytes_per_layer_fp16(1, 4096, 1);
        assert_eq!(mha, 8 * gqa);
        // MHA models keep eq. 18 exactly
        assert_eq!(
            PANGU_38B.kv_bytes_per_layer_fp16(1, 1024, 1),
            4 * PANGU_38B.hidden() * 1024
        );
    }

    #[test]
    fn kv_bytes_match_table3_transfer_sizes() {
        // Table 3 @16K: one layer's per-GPU KV on 8 V100s for PanGu-38B
        // uploads in 3.58 ms at ~11.7 GB/s -> ~41.9 MB.
        let kv = PANGU_38B.kv_bytes_per_layer_fp16(1, 16 * 1024, 8);
        assert_eq!(kv, 4 * 16384 * 5120 / 8);
        let mb = kv as f64 / 1e6;
        assert!(mb > 40.0 && mb < 43.0, "got {mb} MB");
    }
}
