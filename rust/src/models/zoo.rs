//! The concrete model configurations used across the paper's evaluation.
//!
//! Table 1 plus the models that appear only in the text (PanGu-71B, the
//! DeiT/ViT family of Appendix D) and the tiny end-to-end serving model
//! matching `python/compile/model.py::TINY`.

use super::ModelShape;

/// PanGu-38B (Table 1): 40 layers, 40 heads, D=128, FFN 20480.
pub const PANGU_38B: ModelShape = ModelShape {
    name: "PanGu-38B",
    params: 38_000_000_000,
    layers: 40,
    heads: 40,
    kv_heads: 40,
    head_dim: 128,
    ffn: 20480,
    vocab: 100_000,
};

/// PanGu-71B — not in Table 1; §5.2.1 gives 4 heads per NPU on 8 devices
/// (=> 32 heads total) with D=128.  Layer count/FFN estimated from the
/// 71B parameter budget (64 layers, FFN 4·H1).
pub const PANGU_71B: ModelShape = ModelShape {
    name: "PanGu-71B",
    params: 71_000_000_000,
    layers: 64,
    heads: 32,
    kv_heads: 32,
    head_dim: 128,
    ffn: 16384,
    vocab: 100_000,
};

/// OPT-30B (Table 1): 48 layers, 56 heads, D=128, FFN 28672.
pub const OPT_30B: ModelShape = ModelShape {
    name: "OPT-30B",
    params: 30_000_000_000,
    layers: 48,
    heads: 56,
    kv_heads: 56,
    head_dim: 128,
    ffn: 28672,
    vocab: 50_272,
};

/// LLaMA2-7B (Table 1): 32 layers, 32 heads, D=128, FFN 11008.
pub const LLAMA2_7B: ModelShape = ModelShape {
    name: "LLaMA2-7B",
    params: 7_000_000_000,
    layers: 32,
    heads: 32,
    kv_heads: 32,
    head_dim: 128,
    ffn: 11008,
    vocab: 32_000,
};

/// LLaMA2-70B (Table 1): 80 layers, 64 heads, D=128, FFN 28672.
pub const LLAMA2_70B: ModelShape = ModelShape {
    name: "LLaMA2-70B",
    params: 70_000_000_000,
    layers: 80,
    heads: 64,
    kv_heads: 64,
    head_dim: 128,
    ffn: 28672,
    vocab: 32_000,
};

/// LLaMA-65B (Table 1): 80 layers, 64 heads, D=128, FFN 22016.
pub const LLAMA_65B: ModelShape = ModelShape {
    name: "LLaMA-65B",
    params: 65_000_000_000,
    layers: 80,
    heads: 64,
    kv_heads: 64,
    head_dim: 128,
    ffn: 22016,
    vocab: 32_000,
};

/// DeiT-B (Appendix D, Table 8): ViT-Base shape, S=197 tokens.
pub const DEIT_B: ModelShape = ModelShape {
    name: "DeiT-B",
    params: 86_000_000,
    layers: 12,
    heads: 12,
    kv_heads: 12,
    head_dim: 64,
    ffn: 3072,
    vocab: 1000,
};

/// ViT-B (Appendix D, Table 7).
pub const VIT_B: ModelShape = DEIT_B_WITH_NAME("ViT-B");
/// DeiT-S (Appendix D, Table 7): 6 heads, H1=384.
pub const DEIT_S: ModelShape = ModelShape {
    name: "DeiT-S",
    params: 22_000_000,
    layers: 12,
    heads: 6,
    kv_heads: 6,
    head_dim: 64,
    ffn: 1536,
    vocab: 1000,
};
/// DeiT-Ti (Appendix D, Table 7): 3 heads, H1=192.
pub const DEIT_TI: ModelShape = ModelShape {
    name: "DeiT-Ti",
    params: 5_700_000,
    layers: 12,
    heads: 3,
    kv_heads: 3,
    head_dim: 64,
    ffn: 768,
    vocab: 1000,
};

#[allow(non_snake_case)]
const fn DEIT_B_WITH_NAME(name: &'static str) -> ModelShape {
    ModelShape {
        name,
        params: 86_000_000,
        layers: 12,
        heads: 12,
        kv_heads: 12,
        head_dim: 64,
        ffn: 3072,
        vocab: 1000,
    }
}

/// LLaMA2-70B with its production grouped-query attention config
/// (8 KV heads — Touvron et al., 2023).  Table 1 lists the MHA shape the
/// paper benchmarked ([`LLAMA2_70B`]); this variant is what the batched
/// GQA decode path serves, with an 8× smaller KV cache.
pub const LLAMA2_70B_GQA: ModelShape = ModelShape {
    name: "LLaMA2-70B-GQA",
    params: 70_000_000_000,
    layers: 80,
    heads: 64,
    kv_heads: 8,
    head_dim: 128,
    ffn: 28672,
    vocab: 32_000,
};

/// Mistral-7B (Jiang et al., 2023): the canonical small GQA server
/// shape — 32 query heads over 8 KV heads, D=128, FFN 14336.
pub const MISTRAL_7B: ModelShape = ModelShape {
    name: "Mistral-7B",
    params: 7_300_000_000,
    layers: 32,
    heads: 32,
    kv_heads: 8,
    head_dim: 128,
    ffn: 14336,
    vocab: 32_000,
};

/// The tiny GQA serving shape the host-model backend and the batched
/// decode benches exercise end-to-end (2 query heads per KV head).
pub const TINY_GQA: ModelShape = ModelShape {
    name: "tiny-3m-gqa",
    params: 3_000_000,
    layers: 4,
    heads: 4,
    kv_heads: 2,
    head_dim: 64,
    ffn: 1024,
    vocab: 512,
};

/// The tiny end-to-end serving model — must match
/// `python/compile/model.py::TINY` (checked against the artifact manifest
/// at load time).
pub const TINY: ModelShape = ModelShape {
    name: "tiny-3m",
    params: 3_451_136,
    layers: 4,
    heads: 4,
    kv_heads: 4,
    head_dim: 64,
    ffn: 1024,
    vocab: 512,
};

/// Look up a model by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<ModelShape> {
    let all = [
        PANGU_38B, PANGU_71B, OPT_30B, LLAMA2_7B, LLAMA2_70B, LLAMA_65B,
        LLAMA2_70B_GQA, MISTRAL_7B, DEIT_B, DEIT_S, DEIT_TI, TINY, TINY_GQA,
    ];
    all.into_iter()
        .find(|m| m.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_finds_models() {
        assert_eq!(by_name("pangu-38b").unwrap().name, "PanGu-38B");
        assert_eq!(by_name("LLaMA2-70B").unwrap().heads, 64);
        assert_eq!(by_name("llama2-70b-gqa").unwrap().kv_heads, 8);
        assert_eq!(by_name("mistral-7b").unwrap().group_size(), 4);
        assert!(by_name("gpt-5").is_none());
    }

    #[test]
    fn gqa_shapes_are_well_formed() {
        for m in [LLAMA2_70B_GQA, MISTRAL_7B, TINY_GQA] {
            assert!(m.kv_heads >= 1 && m.kv_heads <= m.heads, "{}", m.name);
            assert_eq!(m.heads % m.kv_heads, 0, "{}", m.name);
        }
    }

    #[test]
    fn tiny_matches_python_model() {
        assert_eq!(TINY.hidden(), 256);
        assert_eq!(TINY.layers, 4);
        assert_eq!(TINY.vocab, 512);
    }
}
