//! Model-free speculative drafting: prompt-lookup / n-gram proposal.
//!
//! The draft–verify loop needs a proposer that is much cheaper than a
//! forward pass; the classic model-free choice (prompt-lookup decoding,
//! as popularized by assisted generation) is to suffix-match the
//! *generated context* against everything the sequence has already
//! seen — prompt plus emitted tokens — and propose the continuation of
//! the most recent prior occurrence.  Greedy decode on small models
//! loves short cycles, and serving prompts repeat structure (code,
//! templates, retrieved documents), so this trivial drafter gets real
//! acceptance rates without a second model.
//!
//! The drafter is **pure**: proposals never influence the accepted
//! output (the engine verifies every draft against the real model and
//! rolls rejected KV back with `BlockTable::truncate`), so any
//! proposal quality is *safe* — a bad drafter only costs wasted verify
//! rows, never wrong tokens.  That contract is what the
//! `prop_spec_decode_equals_vanilla_greedy` acceptance property pins.

/// Drafting knobs: `depth` draft tokens proposed per decode step
/// (`EngineConfig::speculate`), matched against suffixes of up to
/// `max_ngram` tokens.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecConfig {
    /// Maximum draft tokens per proposal (`k`); 0 disables drafting.
    pub depth: usize,
    /// Longest context suffix tried for the n-gram match (longer
    /// matches are tried first — they predict better continuations).
    pub max_ngram: usize,
}

impl SpecConfig {
    /// The engine's default shape for a given depth.
    pub fn with_depth(depth: usize) -> Self {
        Self { depth, max_ngram: 4 }
    }
}

/// Propose up to `k` draft tokens by prompt lookup over `context`
/// (prompt followed by all emitted tokens, oldest first).
///
/// The longest context suffix of `n <= max_ngram` tokens that re-occurs
/// earlier in the context wins, most recent prior occurrence first; the
/// proposal is the run of tokens that followed that occurrence.  Longer
/// suffixes are preferred over more recent shorter ones (an exact
/// longer match is stronger evidence of a repeated pattern).  Returns
/// an empty proposal when nothing matches — the engine then runs a
/// plain decode step, so drafting can never stall generation.
pub fn propose(context: &[i32], k: usize, max_ngram: usize) -> Vec<i32> {
    if k == 0 || context.len() < 2 {
        return Vec::new();
    }
    let n_max = max_ngram.min(context.len() - 1).max(1);
    for n in (1..=n_max).rev() {
        let suffix = &context[context.len() - n..];
        // candidate match starts, most recent first; `end` excludes the
        // suffix matching itself (start == end), but overlapping
        // matches are fine — a period-p cycle matches at end - p.
        let end = context.len() - n;
        for start in (0..end).rev() {
            if &context[start..start + n] == suffix {
                let cont = &context[start + n..];
                let take = cont.len().min(k);
                debug_assert!(take >= 1, "match before the suffix implies a continuation");
                return cont[..take].to_vec();
            }
        }
    }
    Vec::new()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_disabled_propose_nothing() {
        assert!(propose(&[], 4, 4).is_empty());
        assert!(propose(&[7], 4, 4).is_empty());
        assert!(propose(&[1, 2, 3], 0, 4).is_empty());
    }

    #[test]
    fn no_repetition_proposes_nothing() {
        assert!(propose(&[1, 2, 3, 4, 5, 6], 4, 4).is_empty());
    }

    #[test]
    fn repeated_ngram_proposes_its_continuation() {
        // ... 1 2 3 9 8 ... 1 2 3 |  → the last occurrence of suffix
        // [1,2,3] earlier in the context was followed by 9 8
        let ctx = [5, 1, 2, 3, 9, 8, 4, 1, 2, 3];
        assert_eq!(propose(&ctx, 2, 4), vec![9, 8]);
        // k caps the proposal length
        assert_eq!(propose(&ctx, 1, 4), vec![9]);
    }

    #[test]
    fn longest_suffix_wins_over_more_recent_short_match() {
        // suffix [2,3] occurs at position 1 (→ 7) while the shorter
        // suffix [3] also occurs at position 5 (→ 9); the 2-gram match
        // must win even though the 1-gram match is more recent.
        let ctx = [1, 2, 3, 7, 4, 3, 9, 2, 3];
        assert_eq!(propose(&ctx, 1, 4), vec![7]);
    }

    #[test]
    fn most_recent_occurrence_wins_within_a_length() {
        // [9] occurs twice; the later one (followed by 5) wins
        let ctx = [9, 4, 9, 5, 6, 9];
        assert_eq!(propose(&ctx, 1, 1), vec![5]);
    }

    #[test]
    fn cycle_is_predicted_through_overlap() {
        // a period-2 tail: ... a b a b a b — the drafter must extend
        // the cycle (overlapping matches allowed; the 4-gram suffix
        // [1,2,1,2] re-occurs one period earlier, continuation [1,2])
        let ctx = [7, 1, 2, 1, 2, 1, 2];
        assert_eq!(propose(&ctx, 4, 4), vec![1, 2]);
    }

    #[test]
    fn proposal_never_exceeds_available_continuation_or_k() {
        let ctx = [1, 2, 3, 1, 2, 3];
        // suffix [1,2,3] matched at start 0, continuation is [1,2,3]
        let p = propose(&ctx, 8, 4);
        assert!(!p.is_empty() && p.len() <= 8);
        for w in [1usize, 2, 3] {
            assert!(propose(&ctx, w, 4).len() <= w);
        }
    }

    #[test]
    fn determinism() {
        let ctx = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 1, 4];
        let a = propose(&ctx, 4, 4);
        let b = propose(&ctx, 4, 4);
        assert_eq!(a, b);
    }
}
