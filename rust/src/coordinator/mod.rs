//! Layer 3 — the serving coordinator (the paper's system integration).
//!
//! A vLLM-router-style engine over the AOT artifacts:
//!
//! * [`backend`]   — the execution backends behind the engine: the PJRT
//!   artifact path and the pure-rust host model whose decode attention
//!   runs through the batched parallel path (`attention::batch`);
//! * [`request`]   — request/response types;
//! * [`batcher`]   — continuous batcher over the artifact bucket grid;
//! * [`scheduler`] — prefill/decode policy (decode-priority + fairness
//!   quantum);
//! * [`kv_cache`]  — per-sequence KV caches, ragged batch packing, tiered
//!   (device/host) capacity pool;
//! * [`engine`]    — the synchronous execution core over the PJRT
//!   runtime: ragged prefill (per-row lengths), ragged decode (per-row
//!   positions), greedy sampling;
//! * [`server`]    — threaded front-end (PJRT handles stay on one
//!   thread; clients use channels);
//! * [`allreduce`] — the paper's tiling-AllReduce (§4.2) as a real
//!   multi-worker ring with per-block overlap;
//! * [`offload`]   — the CPU–GPU cooperative strategy (§4.4): eq. 15–20
//!   planner + classical-vs-cooperative executor with a *measured* host
//!   FlashAttention2 path.

pub mod allreduce;
pub mod backend;
pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod offload;
pub mod request;
pub mod scheduler;
pub mod server;

pub use backend::{
    ArtifactBackend, Backend, BucketGrid, HostModelBackend, HostModelConfig, StepOut,
};
pub use engine::{Engine, EngineConfig};
pub use request::{GenParams, Request, RequestId, Response};
pub use server::Server;
