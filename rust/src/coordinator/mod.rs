//! Layer 3 — the serving coordinator (the paper's system integration).
//!
//! A vLLM-router-style engine over the AOT artifacts:
//!
//! * [`backend`]   — the execution backends behind the engine: the PJRT
//!   artifact path and the pure-rust host model whose decode attention
//!   runs through the batched parallel path (`attention::batch`);
//! * [`request`]   — request/response types;
//! * [`batcher`]   — continuous batcher over the artifact bucket grid,
//!   with typed admission ([`batcher::AdmitError`]) and chunked-prefill
//!   admission of prompts longer than any bucket;
//! * [`scheduler`] — prefill/chunked/decode policy (decode-priority +
//!   fairness quantum; chunk continuation beats new admission);
//! * [`speculate`] — model-free prompt-lookup (n-gram) drafting for
//!   the draft–verify speculative decode loop; rejected draft KV is
//!   rolled back in O(1) by `BlockTable::truncate`;
//! * [`kv_cache`]  — the two-tier paged KV cache (`TieredPagePool`:
//!   device + host `PagePool`s behind per-sequence `BlockTable`s with
//!   per-block tier tags, cold-block migration over a modeled
//!   `PcieLink`), cross-sequence prompt-prefix sharing
//!   (`PrefixIndex`: content-addressed shared page runs with
//!   copy-on-write block splits), plus the contiguous per-sequence
//!   caches, ragged batch packing and the legacy layer-granularity
//!   capacity pool of the artifact path;
//! * [`reclaim`]   — the KV reclamation policy module: pluggable
//!   victim selection ([`reclaim::ReclaimPolicy`]: youngest /
//!   fewest-pages-lost / closest-to-done) and the per-victim
//!   recompute-vs-swap cost model that decides whether a preempted
//!   sequence's pages are parked on the host tier or replayed;
//! * [`engine`]    — the synchronous execution core: tiered paged
//!   decode and chunked prefill with a four-rung reclamation ladder
//!   (evict idle prefix runs → migrate cold blocks → swap out →
//!   recompute) over a paged-capable backend, or ragged plane
//!   prefill/decode over the PJRT runtime; greedy sampling either way;
//! * [`server`]    — the continuous-batching request plane: a threaded
//!   front-end (PJRT handles stay on one thread) with token-budget
//!   admission, per-request streaming channels, bounded command drain,
//!   concurrency-limit backpressure, and typed end-to-end error paths
//!   (no client ever hangs without a reason);
//! * [`allreduce`] — the paper's tiling-AllReduce (§4.2) as a real
//!   multi-worker ring with per-block overlap;
//! * [`sharded`]   — the tensor-parallel serving backend: N per-device
//!   host models sharded by KV head over per-shard page pools, partial
//!   attention outputs combined per tile through the ring with modeled
//!   tiling-AllReduce timing;
//! * [`offload`]   — the CPU–GPU cooperative strategy (§4.4): eq. 15–20
//!   planner + classical-vs-cooperative executor with a *measured* host
//!   FlashAttention2 path.

pub mod allreduce;
pub mod backend;
pub mod batcher;
pub mod engine;
pub mod kv_cache;
pub mod offload;
pub mod reclaim;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod sharded;
pub mod speculate;

pub use backend::{
    AllReduceStats, ArtifactBackend, Backend, BucketGrid, ChunkRun, HostModelBackend,
    HostModelConfig, PagedRow, ShardedRow, StepOut,
};
pub use sharded::{ShardedBackend, ShardedConfig};
pub use batcher::AdmitError;
pub use engine::{Engine, EngineConfig, KvLayout, TokenEvent};
pub use kv_cache::{
    BlockTable, CacheShape, MigrationStats, PageAllocError, PageCodec, PagePool, PcieLink,
    PrefixIndex, QuantStore, ShardedTable, Tier, TieredPagePool,
};
pub use reclaim::{PreemptMode, ReclaimPolicy, RecomputeVsSwap, VictimPolicy};
pub use request::{GenParams, Request, RequestId, Response};
pub use server::{ResponseStream, ServeError, Server, ServerConfig, StreamEvent};
pub use speculate::SpecConfig;
