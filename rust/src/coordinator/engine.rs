//! The serving engine: owns an execution [`Backend`] and all sequence
//! state, executes prefill/decode batches chosen by the scheduler.
//!
//! Single-threaded by design — PJRT handles are kept on one engine thread
//! (see [`super::server`] for the threaded front-end); the engine API is
//! synchronous and fully deterministic, which is what the integration
//! tests and benches drive.  Parallelism lives *inside* a step: the
//! batched decode-attention path fans (sequence × head) work across a
//! scoped thread pool sized by [`EngineConfig::parallel`], and
//! `threads = 1` is bit-identical to the multithreaded result.
//!
//! ## KV layouts
//!
//! The engine serves from one of two cache layouts:
//!
//! * **Paged** (the default whenever the backend `supports_paged`, e.g.
//!   [`HostModelBackend`](super::backend::HostModelBackend)): a
//!   two-tier [`TieredPagePool`] block allocator plus a per-sequence
//!   [`BlockTable`] with per-block tier tags.  Sequences hold only the
//!   pages their tokens occupy; decode reads and writes rows in place
//!   (no pack/unpack memcpy), gathering across the device and host
//!   stores when blocks have been offloaded; prompts longer than any
//!   prefill bucket are admitted and **chunk-prefilled** (`max_chunk`
//!   tokens per step, interleaved with decodes by the scheduler's
//!   `Chunked` step).  On device-page exhaustion the engine runs the
//!   **four-rung reclamation ladder** (policy in
//!   [`super::reclaim`]): evict idle prefix-cache runs, **migrate cold
//!   blocks to the host tier** (§4.4 at page granularity — coldest
//!   blocks of the longest sequences, batched across sequences into
//!   one move over the modeled [`PcieLink`]), **swap out** a victim
//!   (its whole block table parks on the host tier and resumes —
//!   before any new admission — with its KV intact), and only as the
//!   last resort **recompute-preempt** it (request back to the head of
//!   the waiting queue).  The victim is chosen by the pluggable
//!   [`ReclaimPolicy`](super::reclaim::ReclaimPolicy) in
//!   [`EngineConfig::victim_policy`], and swap-vs-recompute is a
//!   per-victim cost decision (pages over the link twice vs prompt
//!   replay).  When device pressure clears, the hottest host blocks
//!   promote back so long-lived sequences recover full device gather
//!   speed.  Admission is gated on worst-case page demand across both
//!   tiers — and the oldest live sequence is never victimized unless
//!   alone — so the oldest sequence always completes and the system
//!   cannot livelock.
//!   Requests that opt into `share_prefix` additionally go through the
//!   [`PrefixIndex`]: a prompt whose prefix was already prefilled
//!   adopts the cached page run (ref-counted, copy-on-write on the
//!   first divergent write) and chunked prefill resumes at the first
//!   unshared token.  Shared pages are pinned to the device tier until
//!   their ref count drops back to 1.
//! * **Contiguous** (artifact/PJRT backends): fixed `[L,1,Nkv,S,D]`
//!   per-sequence slabs packed into `[L,B,Nkv,S,D]` batch planes — the
//!   AOT wire format — with the device/host `CachePool` tiering.
//!
//! Both layouts produce bit-identical tokens: paged attention gathers
//! the same rows through the block table (see `attention::flash::KvView`).

#![warn(missing_docs)]

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{ArtifactBackend, Backend, PagedRow, ShardedRow};
use super::batcher::{AdmitError, Batcher, BatcherConfig, DecodeBatch, PrefillBatch};
use super::kv_cache::{
    kv_page_bytes_codec, pack_batch, unpack_batch, BlockTable, CachePool, CacheShape,
    PageAllocError, PageCodec, PcieLink, PrefixIndex, SeqCache, ShardedTable, Tier, TieredPagePool,
};
use super::reclaim::{
    PreemptMode, ReclaimDecision, Reclaimer, RecomputeVsSwap, VictimCandidate, VictimPolicy,
};
use super::request::{GenParams, Phase, Request, RequestId, Response};
use super::scheduler::{Policy, Scheduler, Step};
use super::speculate;
use crate::attention::batch::{CascadeGroup, ParallelConfig};
use crate::metrics::EngineMetrics;
use crate::runtime::Runtime;

/// Where a live sequence's KV rows are stored.
enum SeqStore {
    /// A contiguous `[L,1,Nkv,S,D]` slab in the tiered cache pool.
    Contig { cache: SeqCache, tier: Tier },
    /// Per-shard block tables (one per simulated device, mirrored in
    /// lockstep) naming pages in the engine's per-shard page pools.
    Paged { table: ShardedTable },
}

/// A live sequence.
struct SeqState {
    id: RequestId,
    /// The full prompt — kept for chunked prefill and for
    /// recompute-style preemption requeue.
    prompt: Vec<i32>,
    /// Generated tokens (first comes from prefill logits).
    tokens: Vec<i32>,
    store: SeqStore,
    params: GenParams,
    phase: Phase,
    /// Prompt tokens whose KV is already cached (equals `prompt.len()`
    /// once prefill — bucketed or chunked — completes).
    prefilled: usize,
    submitted_at: Instant,
    first_token_at: Option<Instant>,
}

impl SeqState {
    /// Cache position of the *latest* generated token (where the next
    /// decode step writes it).
    fn pos(&self) -> usize {
        self.prompt.len() + self.tokens.len() - 1
    }

    fn last_token(&self) -> i32 {
        *self.tokens.last().expect("sequence has a token after prefill")
    }
}

/// Which KV layout the engine serves from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvLayout {
    /// Paged when the backend supports it, contiguous otherwise.
    Auto,
    /// Force contiguous per-sequence slabs (the artifact wire format).
    Contiguous,
    /// Force the paged path; panics at engine build if the backend
    /// cannot execute against paged KV.
    Paged,
}

/// Engine configuration knobs.
pub struct EngineConfig {
    /// Prefill/decode scheduling policy.
    pub policy: Policy,
    /// Device KV budget in bytes: sizes the device page pool (paged
    /// layout) or drives CachePool tiering (contiguous layout).
    pub device_kv_budget: usize,
    /// Host-tier KV budget in bytes (paged layout): capacity for cold
    /// pages migrated off-device (§4.4 at page granularity) and for
    /// swap-out-suspended block tables.  `0` disables the host tier —
    /// page exhaustion then falls straight through to recompute
    /// preemption.
    pub host_kv_budget: usize,
    /// Modeled host↔device link that cold-page migrations are charged
    /// to (`EngineMetrics::pcie_modeled_s`).
    pub pcie: PcieLink,
    /// Cap on concurrently live sequences (decoding + chunk-prefilling).
    pub max_active: usize,
    /// Intra-step parallelism for backends that honor it (the host
    /// batched-attention path); `threads = 1` is the sequential
    /// fallback, bit-identical to any `threads = N`.
    pub parallel: ParallelConfig,
    /// KV cache layout selection.
    pub kv_layout: KvLayout,
    /// Tokens per KV page (paged layout).
    pub page_size: usize,
    /// Cap on prefix-cache block entries (paged layout): how many
    /// shared prompt-prefix blocks the [`PrefixIndex`] may retain for
    /// requests that opt into `share_prefix`.  Past the cap (and under
    /// device-page pressure) least-recently-used idle runs are evicted.
    pub prefix_cache_entries: usize,
    /// Victim-selection policy when the reclamation ladder must
    /// preempt: FCFS-compatible evict-youngest (the default), fewest
    /// pages lost, or closest to done.  Whatever the policy, the
    /// oldest live sequence is never offered unless it is alone, so
    /// the no-livelock induction holds.
    pub victim_policy: VictimPolicy,
    /// How a victim's pages are reclaimed: a per-victim
    /// recompute-vs-swap cost decision (the default), forced swap-out
    /// (host-tier save/restore), or forced recompute (the pre-swap
    /// behavior; also what `host_kv_budget: 0` degenerates to).
    pub preempt_mode: PreemptMode,
    /// Promote the hottest host-resident blocks back to the device
    /// tier when pressure clears (one block group per step, and only
    /// with two groups of slack).  Placement only — tokens are
    /// bit-identical wherever rows live.
    pub promote: bool,
    /// On-page KV encoding (paged layout).  [`PageCodec::F32`] is the
    /// bit-identical default; [`PageCodec::Int8`] stores rows as int8
    /// with a per-row scale — ~4× fewer bytes through both tiers, with
    /// dequantization fused into the attention gather.
    pub kv_codec: PageCodec,
    /// Token budget for one batched prefill step (paged layout): chunk
    /// rows of several admitting/chunking sequences pack into one
    /// forward pass until their combined token count reaches this
    /// budget.  `0` (the default) resolves to one `max_chunk` — the
    /// largest prefill bucket — preserving the one-chunk-per-step
    /// compute shape while still packing short admissions together.
    pub max_batch_prefill_tokens: usize,
    /// Cap on total *committed* tokens (prompt + full generation
    /// budget) across live sequences: admission defers once the next
    /// candidate would push the sum past it.  `0` = unbounded.
    pub max_batch_total_tokens: usize,
    /// Anti-starvation ratio for SLO-aware deferral: when `waiting ≥
    /// ratio × live`, the backlog has outgrown the running batch and
    /// prefill proceeds even with TPOT over its objective.
    pub waiting_served_ratio: f64,
    /// Optional TPOT service-level objective in seconds: when the mean
    /// decode-step wall time over a sliding window exceeds it, new
    /// prefill admissions defer to decode (counted in
    /// `EngineMetrics::slo_deferrals`), unless the waiting queue is
    /// starved per `waiting_served_ratio`.  `None` disables deferral.
    pub tpot_slo_s: Option<f64>,
    /// Cascade decode over shared-prefix pages (paged layout): rows of
    /// a decode batch whose block tables open with the same adopted
    /// shared run are attended in two phases — one multi-query pass
    /// over the shared tiles for the whole group, then per-row suffix
    /// passes folded through the kernel's LSE merge — so the shared KV
    /// is gathered once per batch instead of once per sequence.
    /// Bit-identical to the per-sequence gather (see
    /// `attention::batch::cascade_batch_decode_attention`); gated, like
    /// prefix sharing, to single-shard engines.  Default off.
    pub cascade: bool,
    /// Speculative decoding draft depth (paged layout): each decode
    /// step for a sequence proposes up to this many draft tokens by
    /// prompt lookup (`coordinator::speculate`), scores them together
    /// with the committed last token in ONE batched verify pass
    /// (`Backend::verify_step` — the chunked-prefill multi-position
    /// path), keeps the longest prefix that matches greedy argmax, and
    /// rolls rejected draft KV back with `BlockTable::truncate`.
    /// Output is token-for-token identical to vanilla greedy decode at
    /// any depth; a step emits 1..=depth+1 tokens.  `0` (the default)
    /// disables speculation; gated, like prefix sharing, to
    /// single-shard paged engines on verify-capable backends, and
    /// mutually exclusive with `cascade` (composition is a ROADMAP
    /// follow-up — cascade wins when both are set).
    pub speculate: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            policy: Policy::Fair { quantum: 4 },
            device_kv_budget: 64 << 20,
            host_kv_budget: 0,
            pcie: PcieLink::default(),
            max_active: 16,
            parallel: ParallelConfig::default(),
            kv_layout: KvLayout::Auto,
            page_size: 16,
            prefix_cache_entries: 256,
            victim_policy: VictimPolicy::Youngest,
            preempt_mode: PreemptMode::Auto,
            promote: true,
            kv_codec: PageCodec::F32,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
            tpot_slo_s: None,
            cascade: false,
            speculate: 0,
        }
    }
}

/// A streamed token: request `id` produced `token` as its `index`-th
/// generated token.  Drained via [`Engine::take_token_events`]; under
/// recompute preemption a replayed sequence re-emits its tokens with
/// the same indices (greedy decode is deterministic), so consumers
/// deduplicate by `(id, index)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TokenEvent {
    /// The request that generated the token.
    pub id: RequestId,
    /// 0-based position in the request's generated-token sequence.
    pub index: usize,
    /// The generated token.
    pub token: i32,
}

/// The engine's KV backing.
enum EngineKv {
    Contig(CachePool),
    /// One tiered pool per shard (a single pool on single-device
    /// backends).  Shards mirror page occupancy in lockstep, so
    /// capacity gates consult `pools[0]` and ladder ops run on all.
    Paged(Vec<TieredPagePool>),
}

/// The serving engine: submit prompts, step the scheduler, drain
/// responses.
///
/// ```
/// use fastattn::coordinator::{
///     Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig,
/// };
///
/// let mut engine = Engine::with_backend(
///     Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
///     EngineConfig::default(),
/// );
/// let id = engine
///     .submit(vec![1, 2, 3], GenParams { max_new_tokens: 4, ..GenParams::default() })
///     .unwrap();
/// let done = engine.run_until_idle().unwrap();
/// assert_eq!(done[0].id, id);
/// assert_eq!(done[0].tokens.len(), 4);
/// ```
pub struct Engine {
    backend: Box<dyn Backend>,
    shape: CacheShape,
    /// Per-shard cache shape: `shape` with `kv_heads / n_shards`.
    /// Equal to `shape` on single-device backends.
    shard_shape: CacheShape,
    /// Simulated tensor-parallel devices behind the backend (1 =
    /// single device).
    n_shards: usize,
    batcher: Batcher,
    scheduler: Scheduler,
    kv: EngineKv,
    /// Cross-sequence prompt-prefix cache (paged layout only):
    /// content-addressed shared page runs for `share_prefix` requests.
    prefix: Option<PrefixIndex>,
    active: Vec<RequestId>,
    /// Sequences mid chunked-prefill, oldest first.
    chunking: VecDeque<RequestId>,
    /// Swap-out-suspended sequences, ascending id (oldest resumes
    /// first, before any new admission).
    suspended: Vec<RequestId>,
    seqs: HashMap<RequestId, SeqState>,
    finished: Vec<Response>,
    next_id: RequestId,
    /// Largest prefill seq bucket — the chunk size of chunked prefill.
    max_chunk: usize,
    page_size: usize,
    /// Victim selection + recompute-vs-swap cost model (the policy
    /// half of the reclamation ladder — see [`super::reclaim`]).
    reclaim: Reclaimer,
    /// Promote hot host blocks when device pressure clears.
    promote: bool,
    /// On-page KV encoding of the paged pools — drives the analytic
    /// gather-bandwidth accounting in [`EngineMetrics`].
    kv_codec: PageCodec,
    /// Monotonic clock stamped onto block tables at every attention
    /// pass — ranks host blocks by heat for promotion.
    gather_clock: u64,
    /// Cascade decode over shared-prefix pages — resolved at build to
    /// `cfg.cascade && paged && n_shards == 1` (same gate as the
    /// prefix index, which is what creates adoptable shared runs).
    cascade: bool,
    /// Speculative draft depth — resolved at build to `cfg.speculate`
    /// on single-shard paged engines whose backend implements
    /// `verify_step` (and with cascade off), else 0.  The vanilla
    /// decode path is untouched when 0.
    speculate: usize,
    /// TPOT objective driving SLO-aware prefill deferral (`None` off).
    tpot_slo_s: Option<f64>,
    /// Sliding window of recent decode-step wall times (the TPOT
    /// proxy the SLO deferral gate consults).
    decode_window: VecDeque<f64>,
    /// Tokens generated since the last [`Engine::take_token_events`]
    /// drain, in generation order — the streaming feed.
    token_events: Vec<TokenEvent>,
    /// Live serving counters (steps, tokens, pages, migrations,
    /// prefix sharing) — see [`EngineMetrics`].
    pub metrics: EngineMetrics,
}

impl Engine {
    /// Build an engine over a loaded PJRT runtime (the AOT-artifact
    /// backend).
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Self {
        Self::with_backend(Box::new(ArtifactBackend::new(rt)), cfg)
    }

    /// Build an engine over any execution backend.
    pub fn with_backend(mut backend: Box<dyn Backend>, cfg: EngineConfig) -> Self {
        backend.set_parallel(cfg.parallel);
        let n_shards = backend.shard_count().max(1);
        let m = backend.model();
        let shape = CacheShape {
            layers: m.n_layers,
            kv_heads: m.n_kv_heads,
            max_seq: m.max_seq,
            head_dim: m.head_dim,
        };
        assert_eq!(
            shape.kv_heads % n_shards,
            0,
            "{} kv heads not divisible across {n_shards} shards",
            shape.kv_heads
        );
        let shard_shape = CacheShape { kv_heads: shape.kv_heads / n_shards, ..shape };
        let paged = match cfg.kv_layout {
            KvLayout::Auto => backend.supports_paged(),
            KvLayout::Contiguous => false,
            KvLayout::Paged => {
                assert!(
                    backend.supports_paged(),
                    "KvLayout::Paged requires a paged-capable backend"
                );
                true
            }
        };
        let buckets = backend.buckets();
        let max_chunk = buckets
            .prefill_seqs
            .iter()
            .copied()
            .max()
            .unwrap_or(shape.max_seq)
            .max(1);
        let batcher = Batcher::new(BatcherConfig {
            prefill_batches: buckets.prefill_batches,
            prefill_seqs: buckets.prefill_seqs,
            decode_batches: buckets.decode_batches,
            max_active: cfg.max_active,
            max_seq_tokens: shape.max_seq,
            allow_chunked: paged,
            max_batch_prefill_tokens: cfg.max_batch_prefill_tokens,
            max_batch_total_tokens: cfg.max_batch_total_tokens,
            waiting_served_ratio: cfg.waiting_served_ratio,
        });
        // one pool per shard, each sized to its device's full budget
        // (per-device memory: adding shards adds capacity, it does not
        // split one budget); `shard_shape` keeps per-shard page demand
        // and block-group size consistent with the sharded KV heads.
        let kv = if paged {
            EngineKv::Paged(
                (0..n_shards)
                    .map(|_| {
                        TieredPagePool::for_budget_codec(
                            shard_shape,
                            cfg.page_size,
                            cfg.device_kv_budget,
                            cfg.host_kv_budget,
                            cfg.pcie,
                            cfg.kv_codec,
                        )
                    })
                    .collect(),
            )
        } else {
            EngineKv::Contig(CachePool::new(shape, cfg.device_kv_budget))
        };
        // prefix sharing stays single-device: shared runs live in one
        // pool and the sharded path never adopts them.
        let prefix = (paged && n_shards == 1)
            .then(|| PrefixIndex::new(shard_shape, cfg.page_size, cfg.prefix_cache_entries));
        let reclaim = Reclaimer::new(
            cfg.victim_policy,
            cfg.preempt_mode,
            RecomputeVsSwap::new(
                cfg.pcie,
                kv_page_bytes_codec(cfg.page_size, shard_shape.head_dim, cfg.kv_codec),
                shard_shape.layers,
                m.n_heads / n_shards,
                shard_shape.head_dim,
                shard_shape.max_seq / 2,
            ),
        );
        let verify_capable = backend.supports_verify();
        Self {
            backend,
            shape,
            shard_shape,
            n_shards,
            batcher,
            scheduler: Scheduler::new(cfg.policy),
            kv,
            prefix,
            active: Vec::new(),
            chunking: VecDeque::new(),
            suspended: Vec::new(),
            seqs: HashMap::new(),
            finished: Vec::new(),
            next_id: 1,
            max_chunk,
            page_size: cfg.page_size,
            reclaim,
            promote: cfg.promote,
            kv_codec: cfg.kv_codec,
            gather_clock: 0,
            cascade: cfg.cascade && paged && n_shards == 1,
            speculate: if paged && n_shards == 1 && !cfg.cascade && verify_capable {
                cfg.speculate
            } else {
                0
            },
            tpot_slo_s: cfg.tpot_slo_s,
            decode_window: VecDeque::new(),
            token_events: Vec::new(),
            metrics: EngineMetrics::default(),
        }
    }

    /// True when the engine serves from the paged KV cache.
    pub fn is_paged(&self) -> bool {
        matches!(self.kv, EngineKv::Paged(_))
    }

    /// Pages the paged engine can actually place, rounded to block
    /// groups per tier: new blocks allocate whole groups on the device,
    /// cold blocks migrate as whole groups to the host, so a tier's
    /// trailing partial group is dead capacity.  This is what makes the
    /// no-livelock induction go through — the oldest sequence alone can
    /// always grow to `usable_pages` by migrating its own cold blocks.
    fn usable_pages(&self, pool: &TieredPagePool) -> usize {
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        (pool.device().num_pages() / group + pool.host().num_pages() / group) * group
    }

    /// Submit a prompt; returns its request id, or a typed
    /// [`AdmitError`] naming exactly why the request can never (or
    /// cannot currently) be served — the request-plane contract is
    /// that rejection is always a value, never a hang or a panic.
    pub fn submit(
        &mut self,
        prompt: Vec<i32>,
        params: GenParams,
    ) -> Result<RequestId, AdmitError> {
        if let EngineKv::Paged(pools) = &self.kv {
            let group = self.shard_shape.layers * self.shard_shape.kv_heads;
            if pools[0].device().num_pages() < group {
                return Err(AdmitError::PoolTooSmall {
                    pages: pools[0].device().num_pages(),
                    group,
                });
            }
            // shards mirror occupancy, so shard 0's per-shard demand
            // and capacity gate admission for the whole group
            let tokens = prompt.len() + params.max_new_tokens;
            let need = BlockTable::pages_needed(self.shard_shape, self.page_size, tokens);
            let usable = self.usable_pages(&pools[0]);
            if need > usable {
                return Err(AdmitError::ExceedsKvPages { need, usable, tokens });
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, params);
        self.batcher.push(req)?;
        Ok(id)
    }

    /// Sequences currently decoding.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Sequences mid chunked-prefill.
    pub fn chunking_count(&self) -> usize {
        self.chunking.len()
    }

    /// Sequences swap-out-suspended (KV parked on the host tier).
    pub fn suspended_count(&self) -> usize {
        self.suspended.len()
    }

    /// Run one scheduling step.  Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        // memory pressure: the device tier cannot place even one block
        // group, so admitting (or resuming) a sequence would only
        // bounce off the allocator — prefer draining work that frees
        // pages.
        let pressure = match &self.kv {
            EngineKv::Paged(pools) => {
                let group = self.shard_shape.layers * self.shard_shape.kv_heads;
                pools[0].device().free_pages() < group
            }
            EngineKv::Contig(_) => false,
        };
        // SLO-aware admission: with TPOT over its objective, new
        // prefill defers to decode — unless the waiting queue has
        // outgrown the running batch (then admission must proceed or
        // the backlog starves).
        let live = self.active.len() + self.chunking.len() + self.suspended.len();
        let slo_defer = self.tpot_slo_s.is_some_and(|slo| {
            self.decode_window.len() >= 4
                && self.decode_window.iter().sum::<f64>()
                    / self.decode_window.len() as f64
                    > slo
        }) && !self.batcher.starved(live);
        let (step, deferred) = self.scheduler.next_step_serving(
            &self.batcher,
            self.active.len(),
            self.chunking.len(),
            self.suspended.len(),
            pressure,
            slo_defer,
        );
        if deferred {
            self.metrics.slo_deferrals += 1;
        }
        match step {
            Step::Idle => return Ok(false),
            Step::Prefill => {
                let admitted = if self.is_paged() {
                    self.admit_chunked()?
                } else if let Some(batch) = self.batcher.next_prefill(self.active.len()) {
                    self.run_prefill(batch)?;
                    true
                } else {
                    false
                };
                if !admitted && !self.active.is_empty() {
                    // capacity-blocked: fall back to decode
                    if let Some(batch) = self.batcher.next_decode(&self.active) {
                        self.run_decode(batch)?;
                    }
                }
            }
            Step::Chunked => {
                if !self.chunking.is_empty() {
                    self.run_chunk_batch()?;
                } else if let Some(batch) = self.batcher.next_decode(&self.active) {
                    self.run_decode(batch)?;
                }
            }
            Step::Resume => self.resume_suspended()?,
            Step::Decode => {
                if let Some(batch) = self.batcher.next_decode(&self.active) {
                    self.run_decode(batch)?;
                }
            }
        }
        self.maybe_promote();
        Ok(true)
    }

    /// Drive until every submitted request completes; drain responses.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.step()? {}
        Ok(std::mem::take(&mut self.finished))
    }

    /// Drain any already-finished responses without stepping.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    /// Drain the tokens generated since the last drain, in generation
    /// order — the per-request streaming feed.  Replayed tokens (after
    /// recompute preemption) carry their original indices; consumers
    /// deduplicate by `(id, index)`.
    pub fn take_token_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.token_events)
    }

    /// Client-initiated abort: drop request `id` wherever it currently
    /// lives — still waiting, chunk-prefilling, decoding, or swap-out
    /// suspended — releasing every page it holds (both tiers; adopted
    /// shared blocks just drop their reference) immediately rather
    /// than running generation to completion.  No [`Response`] is
    /// produced and no token events are emitted past the drain point;
    /// the request plane terminates the client stream with
    /// `StreamEvent::Error(Aborted)`.  Returns false when `id` is
    /// unknown or already finished — cancelling twice is a no-op.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if self.batcher.remove(id) {
            return true; // never admitted: no KV to release
        }
        let Some(mut state) = self.seqs.remove(&id) else {
            return false;
        };
        self.active.retain(|&a| a != id);
        self.chunking.retain(|&c| c != id);
        self.suspended.retain(|&s| s != id);
        match &mut state.store {
            SeqStore::Contig { tier, .. } => {
                if let EngineKv::Contig(pool) = &mut self.kv {
                    pool.release(*tier);
                }
            }
            SeqStore::Paged { table } => {
                if let EngineKv::Paged(pools) = &mut self.kv {
                    table.release_all_tiered(pools);
                }
            }
        }
        self.update_page_metrics();
        true
    }

    // -----------------------------------------------------------------
    // Contiguous (plane) path
    // -----------------------------------------------------------------

    fn run_prefill(&mut self, batch: PrefillBatch) -> Result<()> {
        let t0 = Instant::now();
        let b = batch.batch_bucket;
        let s = batch.seq_bucket;

        // tokens [B, S] (right-padded), lengths [B] (dummy rows: 1).
        let mut tokens = vec![0i32; b * s];
        let mut lengths = vec![1i32; b];
        for (i, req) in batch.requests.iter().enumerate() {
            tokens[i * s..][..req.prompt.len()].copy_from_slice(&req.prompt);
            lengths[i] = req.prompt.len() as i32;
        }
        let out = self
            .backend
            .prefill(b, s, &tokens, &lengths)
            .with_context(|| format!("prefill step b{b}_s{s}"))?;
        let (logits, kc, vc) = (&out.logits, &out.k_plane, &out.v_plane);
        let vocab = self.backend.model().vocab;

        for (i, req) in batch.requests.into_iter().enumerate() {
            let row = &logits[i * vocab..][..vocab];
            let first = argmax(row) as i32;
            self.token_events.push(TokenEvent { id: req.id, index: 0, token: first });
            let (mut cache, tier) = match &mut self.kv {
                EngineKv::Contig(pool) => pool.allocate(),
                EngineKv::Paged(_) => bail!("bucketed prefill on a paged engine"),
            };
            unpack_batch(self.shape, b, kc, &mut [(i, &mut cache.k)])?;
            unpack_batch(self.shape, b, vc, &mut [(i, &mut cache.v)])?;
            let prompt_len = req.prompt.len();
            let state = SeqState {
                id: req.id,
                prompt: req.prompt,
                tokens: vec![first],
                store: SeqStore::Contig { cache, tier },
                params: req.params,
                phase: Phase::Decoding,
                prefilled: prompt_len,
                submitted_at: req.submitted_at,
                first_token_at: Some(Instant::now()),
            };
            self.metrics.prefilled_tokens += prompt_len as u64;
            // done already? (max_new_tokens == 1 or instant EOS)
            if state.tokens.len() >= state.params.max_new_tokens
                || state.params.eos_token == Some(first)
            {
                self.finish(state);
            } else {
                self.active.push(req.id);
                self.seqs.insert(req.id, state);
            }
        }
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn run_decode_plane(&mut self, batch: DecodeBatch) -> Result<()> {
        let t0 = Instant::now();
        let b = batch.batch_bucket;

        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut packs: Vec<(usize, &[f32])> = Vec::with_capacity(batch.seq_ids.len());
        let mut packs_v: Vec<(usize, &[f32])> = Vec::with_capacity(batch.seq_ids.len());
        for (slot, id) in batch.seq_ids.iter().enumerate() {
            let s = self.seqs.get(id).context("active seq missing")?;
            let SeqStore::Contig { cache, .. } = &s.store else {
                bail!("plane decode on a paged sequence");
            };
            token[slot] = s.last_token();
            pos[slot] = s.pos() as i32;
            packs.push((slot, &cache.k));
            packs_v.push((slot, &cache.v));
        }
        let k_plane = pack_batch(self.shape, b, &packs)?;
        let v_plane = pack_batch(self.shape, b, &packs_v)?;
        drop(packs);
        drop(packs_v);

        let out = self
            .backend
            .decode(b, &token, k_plane, v_plane, &pos)
            .with_context(|| format!("decode step b{b}"))?;
        let (logits, kc, vc) = (&out.logits, &out.k_plane, &out.v_plane);
        let vocab = self.backend.model().vocab;

        let mut done: Vec<RequestId> = Vec::new();
        for (slot, id) in batch.seq_ids.iter().enumerate() {
            let s = self.seqs.get_mut(id).unwrap();
            let SeqStore::Contig { cache, .. } = &mut s.store else {
                bail!("plane decode on a paged sequence");
            };
            unpack_batch(self.shape, b, kc, &mut [(slot, &mut cache.k)])?;
            unpack_batch(self.shape, b, vc, &mut [(slot, &mut cache.v)])?;
            let next = argmax(&logits[slot * vocab..][..vocab]) as i32;
            s.tokens.push(next);
            let index = s.tokens.len() - 1;
            self.token_events.push(TokenEvent { id: *id, index, token: next });
            self.metrics.decoded_tokens += 1;
            let finished = s.tokens.len() >= s.params.max_new_tokens
                || s.params.eos_token == Some(next)
                || s.pos() + 1 >= self.shape.max_seq;
            if finished {
                done.push(*id);
            }
        }
        for id in done {
            let state = self.seqs.remove(&id).unwrap();
            self.active.retain(|&a| a != id);
            self.finish(state);
        }
        self.metrics.decode_steps += 1;
        self.record_decode_step(t0.elapsed().as_secs_f64());
        Ok(())
    }

    // -----------------------------------------------------------------
    // Paged path
    // -----------------------------------------------------------------

    /// Admit waiting requests onto the paged cache — as many as the
    /// prefill-token budget, the total-token budget, `max_active`, and
    /// the page gate allow — then run one batched prefill step over
    /// everything mid-chunk.  Admission is gated on worst-case page
    /// demand (prompt + full generation budget): an admitted sequence
    /// can always finish by preempting only younger sequences, so the
    /// oldest always completes and admission cannot livelock.  Pages
    /// pinned only by idle prefix-cache runs don't block admission —
    /// they are evicted until the gate passes or nothing idle remains.
    /// Each admission additionally *reserves* its first chunk's pages
    /// against the free-page gate for later candidates in the same
    /// step, so packing admissions cannot over-commit pages the first
    /// batched chunk is about to allocate.
    ///
    /// A `share_prefix` request additionally consults the
    /// [`PrefixIndex`]: on a hit it adopts the shared page run and its
    /// chunked prefill resumes at the first unshared token.  Such a
    /// request never packs *behind* another admission in the same step
    /// — it waits until runs registered by the earlier admissions'
    /// prefill are visible, so adoptable prefixes are never missed.
    fn admit_chunked(&mut self) -> Result<bool> {
        if !matches!(self.kv, EngineKv::Paged(_)) {
            bail!("chunked admission on a contiguous engine");
        }
        // same group rounding as the submit gate: a tier's partial
        // trailing group is dead capacity and must not admit anyone.
        // Shard 0 stands for all shards — occupancy mirrors.
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        let budget = self.batcher.prefill_token_budget(self.max_chunk);
        // budget already spoken for by sequences mid-chunk (they pack
        // ahead of new admissions in the batched step below)
        let mut budget_left = budget;
        for &cid in &self.chunking {
            if let Some(s) = self.seqs.get(&cid) {
                budget_left = budget_left
                    .saturating_sub((s.prompt.len() - s.prefilled).min(self.max_chunk));
            }
        }
        let mut reserved = 0usize;
        let mut admitted_any = false;
        'admit: loop {
            // pop under the max_active budget first: when no admission
            // can happen anyway, the capacity gate below must not evict
            // reusable prefix-cache runs for nothing.  Suspended
            // sequences keep their slot — they hold KV and will resume.
            let live = self.active.len() + self.chunking.len() + self.suspended.len();
            {
                let Some(head) = self.batcher.peek() else { break 'admit };
                if admitted_any {
                    if head.params.share_prefix {
                        break 'admit; // adopt next step, once new runs register
                    }
                    if head.prompt.len().min(self.max_chunk) > budget_left {
                        break 'admit; // first chunk would bust the prefill budget
                    }
                }
                let committed: usize = self
                    .seqs
                    .values()
                    .map(|s| s.prompt.len() + s.params.max_new_tokens)
                    .sum();
                let need_tokens = head.prompt.len() + head.params.max_new_tokens;
                if !self.batcher.fits_total_budget(committed, need_tokens) {
                    break 'admit;
                }
            }
            let Some(req) = self.batcher.next_request(live) else { break 'admit };
            let need = BlockTable::pages_needed(
                self.shard_shape,
                self.page_size,
                req.prompt.len() + req.params.max_new_tokens,
            );
            let EngineKv::Paged(pools) = &mut self.kv else { unreachable!() };
            loop {
                let usable_free = (pools[0].device().free_pages() / group
                    + pools[0].host().free_pages() / group)
                    * group;
                if usable_free.saturating_sub(reserved) >= need {
                    break;
                }
                let freed = match &mut self.prefix {
                    Some(ix) => ix.evict_idle(pools[0].device_mut()),
                    None => 0,
                };
                if freed == 0 {
                    // wait for capacity; decode keeps draining.  The head
                    // request goes back where it came from (FCFS preserved).
                    self.batcher.requeue_front(req);
                    break 'admit;
                }
            }
            let id = req.id;
            let mut table = ShardedTable::new(self.shard_shape, self.n_shards, self.page_size);
            let mut shared_tokens = 0;
            if req.params.share_prefix {
                // the index exists only on single-device engines, where
                // the primary table is the whole sequence
                if let Some(ix) = &mut self.prefix {
                    shared_tokens =
                        ix.adopt(&req.prompt, table.primary_mut(), pools[0].device_mut());
                }
            }
            if shared_tokens > 0 {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_tokens_saved += shared_tokens as u64;
            }
            // reserve the pages this admission's first chunk is about
            // to allocate (beyond any adopted blocks), so the page gate
            // for the *next* candidate sees them as spoken for
            let first_end = (shared_tokens + self.max_chunk).min(req.prompt.len());
            reserved += BlockTable::pages_needed(self.shard_shape, self.page_size, first_end)
                .saturating_sub(BlockTable::pages_needed(
                    self.shard_shape,
                    self.page_size,
                    shared_tokens,
                ));
            budget_left = budget_left.saturating_sub(first_end - shared_tokens);
            let state = SeqState {
                id,
                prompt: req.prompt,
                tokens: Vec::new(),
                store: SeqStore::Paged { table },
                params: req.params,
                phase: Phase::Chunking,
                prefilled: shared_tokens,
                submitted_at: req.submitted_at,
                first_token_at: None,
            };
            self.seqs.insert(id, state);
            self.chunking.push_back(id);
            admitted_any = true;
        }
        if admitted_any {
            self.run_chunk_batch()?;
        }
        Ok(admitted_any)
    }

    /// Run one batched prefill step: pack the next chunk rows of the
    /// sequences mid chunked-prefill — oldest first, the front always
    /// getting its full chunk, later ones (possibly truncated) while
    /// the prefill-token budget lasts — into ONE backend forward pass.
    /// Sequences whose chunk completes the prompt are promoted to
    /// decoding with their first generated token.
    fn run_chunk_batch(&mut self) -> Result<()> {
        let t0 = Instant::now();
        let budget = self.batcher.prefill_token_budget(self.max_chunk);
        let mut plan: Vec<(RequestId, usize, usize)> = Vec::new();
        let mut used = 0usize;
        for &id in &self.chunking {
            let Some(s) = self.seqs.get(&id) else { continue };
            if s.phase != Phase::Chunking {
                continue;
            }
            let start = s.prefilled;
            let full = (start + self.max_chunk).min(s.prompt.len());
            debug_assert!(full > start, "chunk queue holds only partial sequences");
            // the front sequence always runs its full chunk — the
            // budget shapes packing, it must not starve the oldest
            let take =
                if plan.is_empty() { full - start } else { (full - start).min(budget - used) };
            if take == 0 {
                break;
            }
            plan.push((id, start, start + take));
            used += take;
            if used >= budget {
                break;
            }
        }
        if plan.is_empty() {
            return Ok(());
        }
        // grow/CoW-split each table for its rows; the reclamation
        // ladder may preempt *other* planned sequences, so survivors
        // are re-checked afterwards
        for &(id, start, end) in plan.clone().iter() {
            if self.steppable(id) {
                self.ensure_writable(id, end, start)?;
            }
        }
        plan.retain(|&(id, start, _)| {
            self.seqs
                .get(&id)
                .is_some_and(|s| s.phase == Phase::Chunking && s.prefilled == start)
        });
        if plan.is_empty() {
            return Ok(());
        }
        let results = {
            let EngineKv::Paged(pools) = &mut self.kv else {
                bail!("chunked sequence without a page pool");
            };
            let seqs = &self.seqs;
            let chunks: Vec<super::backend::ChunkRun<'_>> = plan
                .iter()
                .map(|&(id, start, end)| {
                    let s = &seqs[&id];
                    let SeqStore::Paged { table } = &s.store else {
                        unreachable!("paged engine tracks paged sequences");
                    };
                    super::backend::ChunkRun {
                        tokens: &s.prompt[start..end],
                        start_pos: start,
                        tables: table.tables(),
                    }
                })
                .collect();
            self.backend
                .prefill_chunks_sharded(&chunks, pools)
                .with_context(|| format!("batched prefill of {} chunk rows", plan.len()))?
        };
        self.gather_clock += 1;
        let clock = self.gather_clock;
        let tri = |n: usize| n as u64 * (n as u64 + 1) / 2;
        let mut gathered_positions: u64 = 0;
        for (&(id, start, end), logits) in plan.iter().zip(&results) {
            let s = self.seqs.get_mut(&id).expect("survived backend step");
            if let SeqStore::Paged { table } = &mut s.store {
                table.mark_gathered(clock);
            }
            s.prefilled = end;
            self.metrics.prefilled_tokens += (end - start) as u64;
            self.metrics.chunk_rows += 1;
            if end == s.prompt.len() {
                // prompt fully cached: publish its page run for future
                // `share_prefix` requests before decoding mutates anything
                if s.params.share_prefix {
                    if let (Some(ix), EngineKv::Paged(pools), SeqStore::Paged { table }) =
                        (&mut self.prefix, &mut self.kv, &s.store)
                    {
                        ix.register(&s.prompt, table.primary(), pools[0].device_mut());
                    }
                }
                // first generated token from the last chunk's logits
                let first = argmax(logits) as i32;
                s.tokens.push(first);
                self.token_events.push(TokenEvent { id, index: 0, token: first });
                s.first_token_at = Some(Instant::now());
                s.phase = Phase::Decoding;
                let done = s.tokens.len() >= s.params.max_new_tokens
                    || s.params.eos_token == Some(first);
                self.chunking.retain(|&c| c != id);
                if done {
                    let state = self.seqs.remove(&id).unwrap();
                    self.finish(state);
                } else {
                    self.active.push(id);
                }
            }
            // each chunk position p attends to its p+1-token causal prefix
            gathered_positions += tri(end) - tri(start);
        }
        self.metrics.chunk_steps += 1;
        self.count_gather(gathered_positions);
        self.metrics.prefill_s += t0.elapsed().as_secs_f64();
        self.update_page_metrics();
        Ok(())
    }

    /// Analytic gather-bandwidth accounting: `positions` KV positions
    /// just streamed through paged attention — each touches every
    /// layer and kv head, K and V both, at the codec's row encoding.
    fn count_gather(&mut self, positions: u64) {
        let kv_rows =
            positions * self.shape.layers as u64 * self.shape.kv_heads as u64 * 2;
        self.metrics.kv_bytes_gathered +=
            kv_rows * self.kv_codec.row_bytes(self.shape.head_dim) as u64;
        if self.kv_codec == PageCodec::Int8 {
            self.metrics.dequant_rows += kv_rows;
        }
    }

    fn run_decode_paged(&mut self, batch: DecodeBatch) -> Result<()> {
        let t0 = Instant::now();
        // grow each table for the row it writes this step; allocation
        // failure runs the reclamation ladder instead of panicking.
        for id in batch.seq_ids.iter().copied() {
            if !self.steppable(id) {
                continue; // preempted or swapped by an earlier row's allocation
            }
            let need = self.seqs[&id].pos() + 1;
            self.ensure_writable(id, need, need - 1)?;
        }
        let ids: Vec<RequestId> = batch
            .seq_ids
            .iter()
            .copied()
            .filter(|&id| self.steppable(id))
            .collect();
        if ids.is_empty() {
            return Ok(());
        }
        let logits = if self.cascade {
            // cascade is resolved to single-shard engines at build, so
            // each row's primary table is its full KV view
            let rows: Vec<PagedRow<'_>> = ids
                .iter()
                .map(|id| {
                    let s = &self.seqs[id];
                    let SeqStore::Paged { table } = &s.store else {
                        unreachable!("paged engine tracks paged sequences");
                    };
                    PagedRow { table: table.primary(), token: s.last_token(), pos: s.pos() }
                })
                .collect();
            let groups = cascade_groups(&rows);
            let EngineKv::Paged(pools) = &mut self.kv else {
                bail!("paged decode on a contiguous engine");
            };
            self.backend
                .decode_paged_cascade(&rows, &groups, &mut pools[0])
                .with_context(|| format!("cascade decode step b{}", ids.len()))?
        } else {
            let rows: Vec<ShardedRow<'_>> = ids
                .iter()
                .map(|id| {
                    let s = &self.seqs[id];
                    let SeqStore::Paged { table } = &s.store else {
                        unreachable!("paged engine tracks paged sequences");
                    };
                    ShardedRow { tables: table.tables(), token: s.last_token(), pos: s.pos() }
                })
                .collect();
            let EngineKv::Paged(pools) = &mut self.kv else {
                bail!("paged decode on a contiguous engine");
            };
            self.backend
                .decode_paged_sharded(&rows, pools)
                .with_context(|| format!("paged decode step b{}", ids.len()))?
        };
        let vocab = self.backend.model().vocab;

        // every row's whole history just streamed through attention —
        // stamp its blocks for the promotion heat ranking
        self.gather_clock += 1;
        let clock = self.gather_clock;
        let mut done: Vec<RequestId> = Vec::new();
        let mut gathered_positions: u64 = 0;
        for (i, id) in ids.iter().enumerate() {
            let s = self.seqs.get_mut(id).unwrap();
            if let SeqStore::Paged { table } = &mut s.store {
                table.mark_gathered(clock);
            }
            // this row's decode step streamed its whole pos+1 history
            gathered_positions += s.pos() as u64 + 1;
            let next = argmax(&logits[i * vocab..][..vocab]) as i32;
            s.tokens.push(next);
            let index = s.tokens.len() - 1;
            self.token_events.push(TokenEvent { id: *id, index, token: next });
            self.metrics.decoded_tokens += 1;
            let finished = s.tokens.len() >= s.params.max_new_tokens
                || s.params.eos_token == Some(next)
                || s.pos() + 1 >= self.shape.max_seq;
            if finished {
                done.push(*id);
            }
        }
        for id in done {
            let state = self.seqs.remove(&id).unwrap();
            self.active.retain(|&a| a != id);
            self.finish(state);
        }
        self.count_gather(gathered_positions);
        if self.cascade {
            let cs = self.backend.take_cascade_stats();
            self.metrics.cascade_passes += cs.passes;
            self.metrics.shared_rows_saved += cs.rows_saved;
            // the saved rows were counted by `count_gather` above but
            // never actually streamed — settle the analytic accounting
            let saved = cs.rows_saved * self.kv_codec.row_bytes(self.shape.head_dim) as u64;
            self.metrics.kv_bytes_gathered =
                self.metrics.kv_bytes_gathered.saturating_sub(saved);
        }
        self.metrics.decode_steps += 1;
        self.record_decode_step(t0.elapsed().as_secs_f64());
        self.update_page_metrics();
        Ok(())
    }

    /// One speculative decode step over the batch: per sequence,
    /// propose up to `speculate` draft tokens by prompt lookup, write
    /// their KV speculatively, score the committed last token plus all
    /// drafts in ONE `verify_step` pass (the chunked-prefill
    /// multi-position path, whose chunk-boundary causal mask makes row
    /// `t` attend exactly its `pos+t+1`-token prefix — bit-identical to
    /// `t` successive vanilla decode steps), accept the longest prefix
    /// where each draft matches the greedy argmax of the row before it,
    /// and roll rejected draft KV back with `BlockTable::truncate`.
    ///
    /// Parity argument (the `prop_spec_decode_equals_vanilla_greedy`
    /// contract): an accepted draft row's K/V equals what vanilla would
    /// have written — same committed prefix, same hidden states, same
    /// quantization under `Int8` — and a rejected row is truncated (or
    /// overwritten by the next step's write at the same position)
    /// before any later attention reads it, so no speculative state
    /// ever leaks into committed output.  Drafting is model-free and
    /// pure, so a bad proposal costs wasted verify rows, never wrong
    /// tokens.
    fn run_decode_spec(&mut self, batch: DecodeBatch) -> Result<()> {
        let t0 = Instant::now();
        let k = self.speculate;
        let vocab = self.backend.model().vocab;
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        self.gather_clock += 1;
        let clock = self.gather_clock;
        let mut done: Vec<RequestId> = Vec::new();
        let mut gathered_positions: u64 = 0;
        let tri = |n: usize| n as u64 * (n as u64 + 1) / 2;
        for id in batch.seq_ids.iter().copied() {
            if !self.steppable(id) {
                continue; // preempted or swapped by an earlier row's allocation
            }
            // Draft: verify consumes the committed last token plus up
            // to m-1 proposals, capped so every emitted token would
            // also have been emitted by vanilla decode (generation
            // budget) and every written row stays inside max_seq.
            let (pos, toks) = {
                let s = &self.seqs[&id];
                let pos = s.pos();
                let remaining = s.params.max_new_tokens - s.tokens.len();
                let m = (k + 1).min(remaining).min(self.shape.max_seq - pos);
                debug_assert!(m >= 1, "active sequences have budget and room");
                let mut context = Vec::with_capacity(s.prompt.len() + s.tokens.len());
                context.extend_from_slice(&s.prompt);
                context.extend_from_slice(&s.tokens);
                let spec = speculate::SpecConfig::with_depth(m - 1);
                let drafts = speculate::propose(&context, spec.depth, spec.max_ngram);
                let mut toks = Vec::with_capacity(1 + drafts.len());
                toks.push(s.last_token());
                toks.extend_from_slice(&drafts);
                (pos, toks)
            };
            // Grow + CoW-unshare for every row the verify pass writes
            // (pos..pos+toks.len()); rejected-row blocks are therefore
            // never shared when truncate pops them.
            if !self.ensure_writable(id, pos + toks.len(), pos)? {
                continue; // the sequence itself was the reclamation victim
            }
            // pages allocated beyond what a vanilla single-token step
            // would have needed — the speculative write footprint
            let blocks_full = (pos + toks.len()).div_ceil(self.page_size);
            let blocks_vanilla = (pos + 1).div_ceil(self.page_size);
            let spec_written = (blocks_full - blocks_vanilla) * group;
            // Verify: all rows in one pass.  Single-shard by the build
            // gate, so the primary table is the whole KV view.
            let logits = {
                let EngineKv::Paged(pools) = &mut self.kv else {
                    bail!("paged decode on a contiguous engine");
                };
                let s = &self.seqs[&id];
                let SeqStore::Paged { table } = &s.store else {
                    unreachable!("paged engine tracks paged sequences");
                };
                self.backend
                    .verify_step(&toks, pos, table.primary(), &mut pools[0])
                    .with_context(|| format!("verify step of {} rows", toks.len()))?
            };
            // row t streamed its pos+t+1-token causal prefix
            gathered_positions += tri(pos + toks.len()) - tri(pos);
            // Accept: row t's argmax is the true next token after
            // toks[..=t]; it commits, and scoring continues into row
            // t+1 only while it equals the draft toks[t+1] that row was
            // computed from.  Finish conditions run per emitted token,
            // in vanilla order, so budget/EOS/max_seq cut identically.
            let s = self.seqs.get_mut(&id).unwrap();
            let mut emitted = 0usize;
            let mut finished = false;
            for (t, row) in logits.chunks_exact(vocab).enumerate() {
                let next = argmax(row) as i32;
                s.tokens.push(next);
                let index = s.tokens.len() - 1;
                self.token_events.push(TokenEvent { id, index, token: next });
                self.metrics.decoded_tokens += 1;
                emitted += 1;
                finished = s.tokens.len() >= s.params.max_new_tokens
                    || s.params.eos_token == Some(next)
                    || s.pos() + 1 >= self.shape.max_seq;
                if finished || (t + 1 < toks.len() && next != toks[t + 1]) {
                    break;
                }
            }
            // Rollback: rows pos..pos+emitted-1 hold committed-token KV
            // (row pos is the old last token; each kept draft row was
            // confirmed equal to the token the model emitted at its
            // position); everything past them pops back to the free
            // list.  The stale partial tail row, if any, sits at the
            // next write position and is overwritten before it is ever
            // attended.
            let popped = {
                let EngineKv::Paged(pools) = &mut self.kv else {
                    bail!("paged decode on a contiguous engine");
                };
                let s = self.seqs.get_mut(&id).unwrap();
                let SeqStore::Paged { table } = &mut s.store else {
                    unreachable!("paged engine tracks paged sequences");
                };
                table.mark_gathered(clock);
                table
                    .truncate(pos + emitted, pools.as_mut_slice())
                    .with_context(|| format!("speculative rollback to {} rows", pos + emitted))?
            };
            // exact rollback accounting: pages popped == pages written
            // speculatively minus pages the accepted rows kept
            debug_assert_eq!(
                popped,
                spec_written
                    - (pos + emitted).div_ceil(self.page_size).saturating_sub(blocks_vanilla)
                        * group,
                "rollback accounting identity"
            );
            self.metrics.draft_proposed += (toks.len() - 1) as u64;
            self.metrics.draft_accepted += (emitted - 1) as u64;
            if self.metrics.accept_len_hist.len() < emitted {
                self.metrics.accept_len_hist.resize(emitted, 0);
            }
            self.metrics.accept_len_hist[emitted - 1] += 1;
            self.metrics.spec_pages_written += spec_written as u64;
            self.metrics.spec_rollback_pages += popped as u64;
            if finished {
                done.push(id);
            }
        }
        for id in done {
            let state = self.seqs.remove(&id).unwrap();
            self.active.retain(|&a| a != id);
            self.finish(state);
        }
        self.count_gather(gathered_positions);
        self.metrics.decode_steps += 1;
        self.record_decode_step(t0.elapsed().as_secs_f64());
        self.update_page_metrics();
        Ok(())
    }

    /// Record one decode step's wall time: total decode seconds plus
    /// the sliding window the SLO deferral gate reads as a TPOT proxy.
    fn record_decode_step(&mut self, secs: f64) {
        self.metrics.decode_s += secs;
        self.decode_window.push_back(secs);
        if self.decode_window.len() > 32 {
            self.decode_window.pop_front();
        }
    }

    fn run_decode(&mut self, batch: DecodeBatch) -> Result<()> {
        match self.kv {
            EngineKv::Paged(_) if self.speculate > 0 => self.run_decode_spec(batch),
            EngineKv::Paged(_) => self.run_decode_paged(batch),
            EngineKv::Contig(_) => self.run_decode_plane(batch),
        }
    }

    /// True when `id` is tracked and not swap-out-suspended — i.e. the
    /// engine may run a step for it right now.
    fn steppable(&self, id: RequestId) -> bool {
        self.seqs.get(&id).is_some_and(|s| s.phase != Phase::Suspended)
    }

    /// Make `id` ready for a write of token rows `[write_from, tokens)`:
    /// grow its block table to hold `tokens` rows **and**
    /// copy-on-write-split any still-shared block the write range
    /// overlaps (a divergent write must never mutate pages a sibling
    /// sequence or the prefix index still reads).  On device-pool
    /// exhaustion the engine runs the four-rung reclamation ladder in
    /// cost order — evict idle prefix-cache runs (no computed work
    /// lost), migrate cold pages to the host tier (§4.4 at page
    /// granularity, batched across sequences), swap out a victim
    /// (pages parked, resumed later), or recompute-preempt it (pages
    /// freed, prompt replayed) — with the victim chosen by the
    /// configured [`ReclaimPolicy`](super::reclaim::ReclaimPolicy);
    /// returns `Ok(false)` when the sequence *itself* was the victim.
    fn ensure_writable(&mut self, id: RequestId, tokens: usize, write_from: usize) -> Result<bool> {
        loop {
            {
                let EngineKv::Paged(pools) = &mut self.kv else {
                    bail!("ensure_writable on a contiguous engine");
                };
                let Some(s) = self.seqs.get_mut(&id) else {
                    return Ok(false);
                };
                if s.phase == Phase::Suspended {
                    return Ok(false); // swapped out by an earlier reclamation
                }
                let SeqStore::Paged { table } = &mut s.store else {
                    bail!("ensure_writable on a contiguous sequence");
                };
                let mut res = table.ensure_capacity(tokens, pools.as_mut_slice()).map(|()| 0);
                if res.is_ok() {
                    res = table.cow_unshare(write_from, tokens, pools.as_mut_slice());
                }
                match res {
                    Ok(splits) => {
                        self.metrics.cow_splits += splits as u64;
                        return Ok(true);
                    }
                    Err(PageAllocError::ExceedsMaxSeq) => {
                        bail!("sequence {id} exceeds max_seq {}", self.shape.max_seq)
                    }
                    Err(_) => {
                        self.metrics.alloc_failures += 1;
                    }
                }
            }
            // cheapest reclamation first: idle prefix-cache runs cost
            // nothing to drop (their KV can be recomputed by whoever
            // misses), migration preserves computed KV on the slower
            // tier, swap-out preserves it at two link transfers, and
            // recompute throws it away.  Each rung makes strict
            // progress — evicting shrinks the finite index, migrating
            // and swapping consume finite host free pages, preempting
            // removes a live sequence — so the loop terminates.
            //
            // One ordering subtlety: when the live sequences are
            // *over-committed* (their combined worst-case growth cannot
            // fit the free pages of both tiers), some victim must
            // eventually be preempted no matter how much is migrated —
            // and every migration eats the host space a swap-out would
            // need.  So under over-commitment the engine migrates only
            // while the host tier retains room to park the largest
            // victim afterwards, and otherwise preempts *now*, while
            // the swap is still feasible (the "swap reservations are
            // gated like migrations" invariant).  Worst-case demand is
            // a loose bound for early-EOS workloads, so the
            // reservation check — not over-commitment alone — decides:
            // with an ample host tier the engine keeps every sequence
            // live exactly as the pre-swap ladder did.
            if self.evict_idle_prefix() {
                continue;
            }
            let live = self.active.len() + self.chunking.len();
            let migrate_first = live <= 1
                || !self.overcommitted()
                || self.migration_preserves_swap_reservation();
            if migrate_first && self.migrate_cold_blocks() {
                continue;
            }
            match self.preempt_victim()? {
                Some(victim) if victim == id => return Ok(false),
                Some(_) => {}
                None => bail!("KV page pool exhausted with nothing to preempt"),
            }
        }
    }

    /// Drop one least-recently-used idle prefix-cache run, freeing its
    /// device pages.  False when the index is absent or nothing idle
    /// remains.
    fn evict_idle_prefix(&mut self) -> bool {
        let Some(ix) = &mut self.prefix else {
            return false;
        };
        let EngineKv::Paged(pools) = &mut self.kv else {
            return false;
        };
        ix.evict_idle(pools[0].device_mut()) > 0
    }

    /// True when the host tier could still park the largest live
    /// victim's device pages even after another folded migration —
    /// migrating then cannot strand the swap rung, so the ladder
    /// prefers it (migration keeps every sequence live, and worst-case
    /// over-commitment may never materialize for early-EOS workloads).
    fn migration_preserves_swap_reservation(&self) -> bool {
        let EngineKv::Paged(pools) = &self.kv else {
            return true;
        };
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        let reserve = self
            .active
            .iter()
            .chain(self.chunking.iter())
            .map(|id| match &self.seqs[id].store {
                SeqStore::Paged { table } => table.device_blocks() * group,
                SeqStore::Contig { .. } => 0,
            })
            .max()
            .unwrap_or(0);
        pools[0].host().free_pages() >= reserve + Self::MIGRATION_FOLD * group
    }

    /// Rung 2: move cold blocks to the host tier — the lowest-index
    /// device block (oldest token positions) of the longest live
    /// sequence, plus (under multi-sequence pressure) the coldest
    /// block of the next-longest sequence, all folded into **one**
    /// batched PCIe move so the link setup latency is paid once.  The
    /// hot tail block of each sequence is spared unless nothing else
    /// qualifies (a device tier too small for two blocks), and blocks
    /// pinned by sharing are judged by their *current* ref count — an
    /// idle prefix run evicted earlier in the ladder unpins its blocks
    /// immediately, stale `shared` flags notwithstanding.  Returns
    /// false when the host tier is absent/full or no migratable device
    /// block exists — the caller falls back to swap/preemption.
    ///
    /// Termination: every migration consumes host free pages, every
    /// preemption removes a live sequence, and neither is undone within
    /// one `ensure_writable` call — the exhaustion loop cannot cycle.
    fn migrate_cold_blocks(&mut self) -> bool {
        let EngineKv::Paged(pools) = &mut self.kv else {
            return false;
        };
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        if pools[0].host().free_pages() < group {
            return false;
        }
        // longest cached sequence first; deterministic id tie-break
        // (active/chunking vectors, not HashMap order).  Suspended
        // sequences hold no device blocks and are not scanned.
        let mut order: Vec<(usize, RequestId)> = self
            .active
            .iter()
            .chain(self.chunking.iter())
            .map(|&sid| {
                let blocks = match &self.seqs[&sid].store {
                    SeqStore::Paged { table } => table.blocks(),
                    SeqStore::Contig { .. } => 0,
                };
                (blocks, sid)
            })
            .collect();
        order.sort_by_key(|&(blocks, sid)| (std::cmp::Reverse(blocks), sid));
        for include_tail in [false, true] {
            let mut folded = 0;
            for p in pools.iter_mut() {
                p.begin_batched_transfer();
            }
            for &(_, sid) in &order {
                if folded == Self::MIGRATION_FOLD || pools[0].host().free_pages() < group {
                    break;
                }
                let Some(s) = self.seqs.get_mut(&sid) else { continue };
                let SeqStore::Paged { table } = &mut s.store else { continue };
                // shared blocks are pinned to the device tier until
                // their ref count drops to 1 — a sibling's table (or
                // the prefix index) would keep indexing the device
                // store if their pages moved.
                let Some(b) = table.coldest_migratable_block(include_tail, pools.as_slice())
                else {
                    continue;
                };
                if table.migrate_block_to_host(b, pools.as_mut_slice()).is_ok() {
                    folded += 1;
                }
            }
            for p in pools.iter_mut() {
                p.commit_batched_transfer();
            }
            if folded > 0 {
                return true;
            }
        }
        false
    }

    /// Block groups (one per sequence) folded into a single batched
    /// migration transfer: the group the failed allocation needs plus
    /// one prefetched from the next-coldest sequence — amortizing the
    /// link setup latency without over-draining the device tier.
    const MIGRATION_FOLD: usize = 2;

    /// True when the live sequences (suspended included — they resume
    /// and keep growing) cannot all reach their worst-case page demand
    /// within the usable free pages of both tiers.  Over-commitment
    /// means some victim must eventually be preempted; detecting it
    /// early lets the ladder swap the victim out while the host tier
    /// still has room, instead of recomputing it after migrations have
    /// consumed that room.  The per-request admission gate bounds each
    /// sequence individually, so over-commitment only arises from
    /// sequences growing *concurrently* — exactly the case cascaded
    /// preemption exists for.
    fn overcommitted(&self) -> bool {
        let EngineKv::Paged(pools) = &self.kv else {
            return false;
        };
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        let mut future = 0usize;
        for id in self
            .active
            .iter()
            .chain(self.chunking.iter())
            .chain(self.suspended.iter())
        {
            let s = &self.seqs[id];
            let total = BlockTable::pages_needed(
                self.shard_shape,
                self.page_size,
                s.prompt.len() + s.params.max_new_tokens,
            );
            let held = match &s.store {
                SeqStore::Paged { table } => table.pages_held(),
                SeqStore::Contig { .. } => 0,
            };
            future += total.saturating_sub(held);
        }
        let usable_free = (pools[0].device().free_pages() / group
            + pools[0].host().free_pages() / group)
            * group;
        future > usable_free
    }

    /// Rungs 3–4: choose a victim via the configured
    /// [`ReclaimPolicy`](super::reclaim::ReclaimPolicy) and reclaim its
    /// pages — swap-out (table parked on the host tier, resumed before
    /// any new admission) or recompute (pages freed, request back at
    /// the head of the waiting queue), per the per-victim
    /// [`RecomputeVsSwap`] decision.  The oldest live sequence is
    /// never offered unless it is alone — that exclusion is what keeps
    /// the no-livelock induction independent of the policy.  Returns
    /// the victim id, or `None` with nothing to preempt.
    fn preempt_victim(&mut self) -> Result<Option<RequestId>> {
        let mut ids: Vec<RequestId> = self
            .active
            .iter()
            .chain(self.chunking.iter())
            .copied()
            .collect();
        if ids.is_empty() {
            return Ok(None);
        }
        ids.sort_unstable();
        if ids.len() > 1 {
            ids.remove(0); // the oldest is protected
        }
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        let (decision, victim) = {
            let EngineKv::Paged(pools) = &self.kv else {
                bail!("preemption on a contiguous engine");
            };
            let candidates: Vec<VictimCandidate> = ids
                .iter()
                .map(|&sid| {
                    let s = &self.seqs[&sid];
                    let (pages_held, device_pages, swappable) = match &s.store {
                        SeqStore::Paged { table } => (
                            table.pages_held(),
                            table.device_blocks() * group,
                            table.suspendable_pages(pools).is_some(),
                        ),
                        SeqStore::Contig { .. } => (0, 0, false),
                    };
                    VictimCandidate {
                        id: sid,
                        pages_held,
                        device_pages,
                        tokens_cached: s.prefilled + s.tokens.len(),
                        tokens_remaining: (s.prompt.len() - s.prefilled)
                            + s.params.max_new_tokens.saturating_sub(s.tokens.len()),
                        swappable,
                    }
                })
                .collect();
            let victim = *self.reclaim.select(&candidates);
            let decision = self.reclaim.decide(&victim, pools[0].host().free_pages());
            (decision, victim.id)
        };
        match decision {
            ReclaimDecision::Swap => self.swap_out(victim),
            ReclaimDecision::Recompute => self.preempt_recompute(victim),
        }
        Ok(Some(victim))
    }

    /// Rung 3: park `victim`'s whole block table on the host tier as
    /// one batched transfer and mark it [`Phase::Suspended`]; the
    /// scheduler resumes it (with its KV intact) before any new
    /// admission.  Falls back to recompute preemption if the transfer
    /// refuses — the cost decision pre-checked feasibility, so this is
    /// purely defensive.
    fn swap_out(&mut self, victim: RequestId) {
        let parked = match (&mut self.kv, self.seqs.get_mut(&victim)) {
            (EngineKv::Paged(pools), Some(s)) => match &mut s.store {
                SeqStore::Paged { table } => table.suspend_to_host(pools).is_ok(),
                SeqStore::Contig { .. } => false,
            },
            _ => false,
        };
        if !parked {
            self.preempt_recompute(victim);
            return;
        }
        let s = self.seqs.get_mut(&victim).expect("victim is tracked");
        s.phase = Phase::Suspended;
        self.active.retain(|&a| a != victim);
        self.chunking.retain(|&c| c != victim);
        let at = self
            .suspended
            .binary_search(&victim)
            .expect_err("victim cannot already be suspended");
        self.suspended.insert(at, victim);
        self.metrics.preemptions += 1;
        self.metrics.swaps_out += 1;
        self.update_page_metrics();
    }

    /// Rung 4: recompute-style preemption — free `victim`'s pages and
    /// put its request back at the head of the waiting queue (FCFS
    /// preserved: it was admitted before everything still waiting).
    fn preempt_recompute(&mut self, victim: RequestId) {
        let mut state = self.seqs.remove(&victim).expect("victim is tracked");
        self.active.retain(|&a| a != victim);
        self.chunking.retain(|&c| c != victim);
        if let (SeqStore::Paged { table }, EngineKv::Paged(pools)) =
            (&mut state.store, &mut self.kv)
        {
            table.release_all_tiered(pools);
        }
        self.batcher.requeue_front(Request {
            id: victim,
            prompt: std::mem::take(&mut state.prompt),
            params: state.params,
            submitted_at: state.submitted_at,
        });
        self.metrics.preemptions += 1;
    }

    /// Resume the oldest suspended sequence: restore its table to the
    /// device tier when there is room for all of it plus one block
    /// group of headroom (so the restore cannot immediately re-trigger
    /// the pressure that suspended it), then put it back on its run
    /// queue.  With no device room the sequence still resumes — decode
    /// gathers its rows from the host store bit-identically and the
    /// promotion pass brings blocks back as capacity appears.
    fn resume_suspended(&mut self) -> Result<()> {
        if self.suspended.is_empty() {
            return Ok(());
        }
        let id = self.suspended.remove(0);
        let group = self.shard_shape.layers * self.shard_shape.kv_heads;
        {
            let EngineKv::Paged(pools) = &mut self.kv else {
                bail!("suspended sequence on a contiguous engine");
            };
            let s = self.seqs.get_mut(&id).context("suspended seq missing")?;
            let SeqStore::Paged { table } = &mut s.store else {
                bail!("suspended sequence without a block table");
            };
            let host_pages = table.host_blocks() * group;
            if host_pages > 0 && pools[0].device().free_pages() >= host_pages + group {
                let _ = table.resume_from_host(pools);
            }
        }
        let s = self.seqs.get_mut(&id).expect("resumed seq tracked");
        self.metrics.swaps_in += 1;
        self.metrics.recompute_tokens_avoided += (s.prefilled + s.tokens.len()) as u64;
        if s.tokens.is_empty() {
            s.phase = Phase::Chunking;
            self.chunking.push_back(id);
        } else {
            s.phase = Phase::Decoding;
            self.active.push(id);
        }
        self.update_page_metrics();
        Ok(())
    }

    /// Host→device promotion: when the device tier has at least two
    /// block groups of slack, move the hottest (most-recently-gathered)
    /// host block of any *running* sequence back so long-lived
    /// sequences recover full device gather speed (suspended tables
    /// stay parked — promoting them would undo the swap they just paid
    /// for).  One block group per engine step — promotion must never
    /// cause the pressure it relieves, and the one-group headroom left
    /// behind keeps the next allocation from immediately re-migrating.
    /// Placement only: tokens are bit-identical wherever rows live.
    fn maybe_promote(&mut self) {
        if !self.promote {
            return;
        }
        let promoted = {
            let EngineKv::Paged(pools) = &mut self.kv else { return };
            let group = self.shard_shape.layers * self.shard_shape.kv_heads;
            if pools[0].device().free_pages() < 2 * group {
                return;
            }
            // hottest host block across every *running* table.
            // Suspended sequences are skipped: their whole table was
            // just paid for to park host-side, they take no steps, and
            // nothing in the ladder could reclaim device pages handed
            // to them — restoring a parked table is `resume_from_host`'s
            // job at resume time.  Ties resolved by (stamp, id, block)
            // so HashMap iteration order cannot leak into placement.
            let mut best: Option<(u64, RequestId, usize)> = None;
            for (&sid, s) in &self.seqs {
                if s.phase == Phase::Suspended {
                    continue;
                }
                let SeqStore::Paged { table } = &s.store else { continue };
                if let Some((stamp, b)) = table.hottest_host_block() {
                    let cand = (stamp, sid, b);
                    if best.map_or(true, |x| cand > x) {
                        best = Some(cand);
                    }
                }
            }
            let Some((_, sid, b)) = best else { return };
            let Some(s) = self.seqs.get_mut(&sid) else { return };
            let SeqStore::Paged { table } = &mut s.store else { return };
            table.promote_block_to_device(b, pools.as_mut_slice()).is_ok()
        };
        if promoted {
            self.update_page_metrics();
        }
    }

    fn update_page_metrics(&mut self) {
        if let EngineKv::Paged(pools) = &self.kv {
            // page and migration counters sum across the shard pools
            // (a single pool on single-device engines)
            self.metrics.pages_used =
                pools.iter().map(|p| p.device().used_pages() as u64).sum();
            self.metrics.pages_total =
                pools.iter().map(|p| p.device().num_pages() as u64).sum();
            self.metrics.peak_pages_used =
                self.metrics.peak_pages_used.max(self.metrics.pages_used);
            self.metrics.host_pages_used =
                pools.iter().map(|p| p.host().used_pages() as u64).sum();
            self.metrics.host_pages_total =
                pools.iter().map(|p| p.host().num_pages() as u64).sum();
            self.metrics.pages_migrated = pools.iter().map(|p| p.stats().pages_moved).sum();
            self.metrics.migrations = pools.iter().map(|p| p.stats().batches).sum();
            self.metrics.migrated_bytes = pools.iter().map(|p| p.stats().bytes_moved).sum();
            self.metrics.pcie_modeled_s = pools.iter().map(|p| p.stats().modeled_s).sum();
            self.metrics.promotions = pools.iter().map(|p| p.stats().promotions).sum();
            self.metrics.promoted_pages = pools.iter().map(|p| p.stats().pages_promoted).sum();
            self.metrics.grouped_transfers =
                pools.iter().map(|p| p.stats().grouped_transfers).sum();
            self.metrics.shared_pages =
                self.prefix.as_ref().map_or(0, |ix| ix.pages_held() as u64);
        }
        // tensor-parallel combine accounting (zero on single-device
        // backends, which keep the default AllReduceStats)
        let c = self.backend.comm_stats();
        self.metrics.allreduce_tiles = c.tiles;
        self.metrics.allreduce_bytes = c.bytes;
        self.metrics.allreduce_modeled_s = c.modeled_s;
        self.metrics.allreduce_hidden_s = c.hidden_s;
        self.metrics.allreduce_makespan_s = c.makespan_s;
        self.metrics.allreduce_serial_s = c.serial_makespan_s;
    }

    fn finish(&mut self, mut state: SeqState) {
        state.phase = Phase::Finished;
        match &mut state.store {
            SeqStore::Contig { tier, .. } => {
                if let EngineKv::Contig(pool) = &mut self.kv {
                    pool.release(*tier);
                }
            }
            SeqStore::Paged { table } => {
                if let EngineKv::Paged(pools) = &mut self.kv {
                    table.release_all_tiered(pools);
                }
            }
        }
        self.update_page_metrics();
        let now = Instant::now();
        let ttft = state
            .first_token_at
            .map(|t| (t - state.submitted_at).as_secs_f64())
            .unwrap_or(0.0);
        let total = (now - state.submitted_at).as_secs_f64();
        self.metrics.completed += 1;
        self.metrics.ttft.record(ttft);
        if state.tokens.len() > 1 && total > ttft {
            // time-per-output-token over the generation phase
            self.metrics
                .tpot
                .record((total - ttft) / (state.tokens.len() - 1) as f64);
        }
        self.finished.push(Response {
            id: state.id,
            prompt_len: state.prompt.len(),
            tokens: state.tokens,
            ttft_s: ttft,
            total_s: total,
        });
    }
}

/// Group a decode batch's rows into cascade groups by their leading
/// shared-block run: rows whose tables open with the same chain of
/// adopted page groups (still marked `block_shared`, i.e. not yet
/// split by copy-on-write) attend those pages together.  `shared_rows`
/// is the chain's token span clamped to the shortest member's visible
/// history; the kernel additionally rounds it down to whole KV tiles
/// and re-verifies page identity, so a group is a *hint*, never a
/// correctness obligation.  Groups are emitted in first-member order
/// for deterministic accounting.
fn cascade_groups(rows: &[PagedRow<'_>]) -> Vec<CascadeGroup> {
    let mut by_key: HashMap<Vec<u32>, Vec<usize>> = HashMap::new();
    for (i, r) in rows.iter().enumerate() {
        let t = r.table;
        let mut run = 0;
        while run < t.blocks() && t.block_shared(run) {
            run += 1;
        }
        if run == 0 {
            continue;
        }
        let mut key = Vec::with_capacity(1 + run * t.layers() * t.kv_heads());
        key.push(run as u32);
        for b in 0..run {
            key.extend(t.block_group(b));
        }
        by_key.entry(key).or_default().push(i);
    }
    let mut groups: Vec<CascadeGroup> = by_key
        .into_iter()
        .filter(|(_, members)| members.len() >= 2)
        .map(|(key, members)| {
            let run = key[0] as usize;
            let min_len =
                members.iter().map(|&i| rows[i].pos + 1).min().expect("non-empty group");
            let shared_rows = (run * rows[members[0]].table.page_size()).min(min_len);
            CascadeGroup { members, shared_rows }
        })
        .collect();
    groups.sort_by_key(|g| g.members[0]);
    groups
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{HostModelBackend, HostModelConfig};

    fn host_engine(threads: usize) -> Engine {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads, min_work_per_thread: 0 },
            ..EngineConfig::default()
        };
        Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        )
    }

    fn host_engine_with_layout(threads: usize, layout: KvLayout) -> Engine {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads, min_work_per_thread: 0 },
            kv_layout: layout,
            ..EngineConfig::default()
        };
        Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        )
    }

    fn host_engine_tiered(device_groups: usize, host_groups: usize) -> Engine {
        host_engine_reclaim(device_groups, host_groups, PreemptMode::Auto, VictimPolicy::Youngest)
    }

    fn host_engine_reclaim(
        device_groups: usize,
        host_groups: usize,
        preempt_mode: PreemptMode,
        victim_policy: VictimPolicy,
    ) -> Engine {
        // tiny_gqa: a block group is layers 2 × kv_heads 2 = 4 pages of
        // 2·4·16·8 B = 1 KiB each.
        let group_bytes = 4 * 1024;
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
            kv_layout: KvLayout::Paged,
            device_kv_budget: device_groups * group_bytes,
            host_kv_budget: host_groups * group_bytes,
            page_size: 16,
            preempt_mode,
            victim_policy,
            ..EngineConfig::default()
        };
        Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        )
    }

    #[test]
    fn tiered_offload_matches_device_only() {
        // 8 + 40 = 48 tokens = 3 blocks = 12 pages; the device tier
        // holds only 2 block groups, so the third block forces a
        // cold-page migration — with nothing younger to evict, only the
        // migrate-before-preempt path can make room.
        let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let prompt = vec![5i32; 8];
        let mut big = host_engine_with_layout(1, KvLayout::Paged);
        big.submit(prompt.clone(), p).unwrap();
        let want = big.run_until_idle().unwrap();
        assert_eq!(big.metrics.pages_migrated, 0, "unconstrained run never migrates");

        let mut tiered = host_engine_tiered(2, 4);
        tiered.submit(prompt, p).unwrap();
        let got = tiered.run_until_idle().unwrap();
        assert_eq!(got[0].tokens, want[0].tokens, "offload must not change tokens");
        assert!(
            tiered.metrics.pages_migrated >= 4,
            "the cold block group must have moved, migrated {}",
            tiered.metrics.pages_migrated
        );
        assert_eq!(tiered.metrics.preemptions, 0, "migration covers a solo sequence");
        assert!(tiered.metrics.migrations >= 1);
        assert!(tiered.metrics.pcie_modeled_s > 0.0);
        assert_eq!(
            tiered.metrics.migrated_bytes,
            tiered.metrics.pages_migrated * 1024
        );
        assert_eq!(tiered.metrics.pages_used, 0, "device tier drained at idle");
        assert_eq!(tiered.metrics.host_pages_used, 0, "host tier drained at idle");
        assert_eq!(tiered.metrics.host_pages_total, 16);
    }

    #[test]
    fn submit_gate_counts_both_tiers() {
        // device alone (2 groups) cannot hold 3 blocks, device+host can
        let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let mut no_host = host_engine_tiered(2, 0);
        assert!(no_host.submit(vec![5; 8], p).is_err());
        let mut tiered = host_engine_tiered(2, 4);
        assert!(tiered.submit(vec![5; 8], p).is_ok());
    }

    #[test]
    fn host_backend_single_request_completes() {
        let mut e = host_engine(1);
        assert!(e.is_paged(), "host backend defaults to the paged layout");
        let id = e
            .submit(vec![1, 2, 3, 4, 5], GenParams { max_new_tokens: 4, ..GenParams::default() })
            .unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        let vocab = 64;
        assert!(out[0].tokens.iter().all(|&t| t >= 0 && t < vocab));
        // pages reported and fully released at idle
        assert!(e.metrics.pages_total > 0);
        assert_eq!(e.metrics.pages_used, 0);
        assert!(e.metrics.peak_pages_used > 0);
    }

    #[test]
    fn host_backend_batched_equals_solo() {
        let p = GenParams { max_new_tokens: 5, eos_token: None, share_prefix: false };
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 2, 3], vec![10, 20, 30, 40, 50, 60], vec![7; 12], vec![3, 1]];
        let mut batched = host_engine(2);
        let mut ids = Vec::new();
        for pr in &prompts {
            ids.push(batched.submit(pr.clone(), p).unwrap());
        }
        let mut out = batched.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), prompts.len());

        for (pr, want) in prompts.iter().zip(&out) {
            let mut solo = host_engine(2);
            solo.submit(pr.clone(), p).unwrap();
            let got = solo.run_until_idle().unwrap();
            assert_eq!(got[0].tokens, want.tokens, "prompt {pr:?}");
        }
    }

    #[test]
    fn host_backend_parallel_matches_sequential() {
        let p = GenParams { max_new_tokens: 6, eos_token: None, share_prefix: false };
        let prompts: Vec<Vec<i32>> =
            vec![vec![5, 4, 3, 2, 1], vec![11; 9], vec![2, 4, 6, 8]];
        let run = |threads: usize| {
            let mut e = host_engine(threads);
            for pr in &prompts {
                e.submit(pr.clone(), p).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "threads must not change greedy tokens");
    }

    #[test]
    fn paged_engine_matches_contiguous_engine() {
        // the paged path must be token-identical to the plane path
        let p = GenParams { max_new_tokens: 6, eos_token: None, share_prefix: false };
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 2, 3], vec![9; 17], vec![4, 5], vec![30, 20, 10, 5, 2, 1, 7]];
        let run = |layout: KvLayout| {
            let mut e = host_engine_with_layout(2, layout);
            for pr in &prompts {
                e.submit(pr.clone(), p).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        let contig = run(KvLayout::Contiguous);
        let paged = run(KvLayout::Paged);
        assert_eq!(contig, paged, "KV layout must not change greedy tokens");
    }

    #[test]
    fn contiguous_layout_rejects_unbucketed_prompt() {
        // tiny_gqa's largest prefill bucket is 32: without chunked
        // prefill a 40-token prompt is refused, with it it completes.
        let mut contig = host_engine_with_layout(1, KvLayout::Contiguous);
        assert!(contig.submit(vec![3; 40], GenParams::default()).is_err());
        let mut paged = host_engine_with_layout(1, KvLayout::Paged);
        let id = paged
            .submit(vec![3; 40], GenParams { max_new_tokens: 3, ..GenParams::default() })
            .unwrap();
        let out = paged.run_until_idle().unwrap();
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 3);
        assert!(paged.metrics.chunk_steps >= 2, "40 tokens need >1 chunk of 32");
    }

    // --- prefix sharing ----------------------------------------------

    #[test]
    fn shared_prefix_decode_matches_unshared() {
        // four prompts with a 24-token common "system prefix": the
        // shared run covers one 16-token block, so requests 2..4 skip
        // that block's prefill — tokens must not change.
        let system = vec![9i32; 24];
        let prompts: Vec<Vec<i32>> = (0..4)
            .map(|i| {
                let mut p = system.clone();
                p.extend(vec![i as i32 + 1; 4 + i]);
                p
            })
            .collect();
        let run = |share: bool| {
            let mut e = host_engine(1);
            let gp = GenParams {
                max_new_tokens: 6,
                eos_token: None,
                share_prefix: share,
            };
            for pr in &prompts {
                e.submit(pr.clone(), gp).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            let toks: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
            (toks, e.metrics.clone())
        };
        let (base, bm) = run(false);
        let (shared, sm) = run(true);
        assert_eq!(base, shared, "prefix sharing must not change tokens");
        assert_eq!(bm.prefix_hits, 0);
        assert_eq!(bm.shared_pages, 0);
        assert!(sm.prefix_hits >= 3, "later prompts must hit, got {}", sm.prefix_hits);
        assert!(
            sm.prefix_tokens_saved >= 3 * 16,
            "one block per hit, saved {}",
            sm.prefix_tokens_saved
        );
        assert!(
            sm.prefilled_tokens < bm.prefilled_tokens,
            "sharing must shrink prefill work"
        );
        assert!(sm.shared_pages > 0, "the index retains registered runs");
    }

    #[test]
    fn cow_split_preserves_sibling_tokens() {
        // identical prompts: the second adopts the first's run
        // including the partially filled tail block, then diverges by
        // recomputing the last prompt token — the copy-on-write split
        // must leave both sequences' outputs identical to a solo run.
        let prompt = vec![7i32; 20]; // one full 16-token block + 4-row tail
        let solo_gp = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: false };
        let mut solo = host_engine(1);
        solo.submit(prompt.clone(), solo_gp).unwrap();
        let want = solo.run_until_idle().unwrap()[0].tokens.clone();

        let gp = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: true };
        let mut e = host_engine(1);
        e.submit(prompt.clone(), gp).unwrap();
        e.submit(prompt.clone(), gp).unwrap();
        let mut out = e.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].tokens, want);
        assert_eq!(out[1].tokens, want, "COW split must not corrupt either sequence");
        assert!(e.metrics.prefix_hits >= 1);
        assert!(e.metrics.cow_splits >= 1, "tail divergence must split a block");
        assert!(e.metrics.prefix_tokens_saved >= 19);
    }

    #[test]
    fn idle_prefix_runs_evict_under_page_pressure() {
        // device tier: 4 block groups, no host tier.  A share_prefix
        // request registers 2 groups that stay pinned after it
        // finishes; the next request needs 3 groups, which only fit if
        // the engine evicts idle prefix-cache runs instead of failing.
        let mut e = host_engine_tiered(4, 0);
        let gp = GenParams { max_new_tokens: 8, eos_token: None, share_prefix: true };
        e.submit(vec![3i32; 20], gp).unwrap();
        let first = e.run_until_idle().unwrap();
        assert_eq!(first.len(), 1);
        assert_eq!(e.metrics.shared_pages, 8, "two registered block groups");

        let gp2 = GenParams { max_new_tokens: 20, eos_token: None, share_prefix: false };
        e.submit(vec![5i32; 20], gp2).unwrap();
        let second = e.run_until_idle().unwrap();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].tokens.len(), 20);
        assert!(
            e.metrics.shared_pages < 8,
            "admission had to evict an idle prefix run, still holds {}",
            e.metrics.shared_pages
        );
        assert_eq!(e.metrics.preemptions, 0, "eviction made preemption unnecessary");
    }

    #[test]
    fn preempted_share_prefix_request_readopts_its_run() {
        // a preempted sequence's pages are released, but the prefix run
        // registered for its prompt survives in the index (the sibling
        // sequence keeps it busy) — the recompute replay adopts it and
        // skips most of the prompt.  Device tier: 5 block groups, so
        // the second sequence admits (worst case 3 groups vs 3 free at
        // the first quantum) and the pair then collides while growing.
        let mut e = host_engine_tiered(5, 0);
        let gp = GenParams { max_new_tokens: 30, eos_token: None, share_prefix: true };
        // identical prompts: 16 tokens + 30 generated = 46 = 3 blocks
        e.submit(vec![4i32; 16], gp).unwrap();
        e.submit(vec![4i32; 16], gp).unwrap();
        let mut out = e.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.tokens.len() == 30));
        assert!(e.metrics.preemptions >= 1, "capacity forces preemption");
        assert!(
            e.metrics.prefix_hits >= 2,
            "admission and the replay both adopt, hits = {}",
            e.metrics.prefix_hits
        );
        assert!(e.metrics.cow_splits >= 1, "block-aligned tail must split on write");

        // parity with an unconstrained, unshared engine
        let mut big = host_engine(1);
        let plain = GenParams { max_new_tokens: 30, eos_token: None, share_prefix: false };
        big.submit(vec![4i32; 16], plain).unwrap();
        big.submit(vec![4i32; 16], plain).unwrap();
        let mut want = big.run_until_idle().unwrap();
        want.sort_by_key(|r| r.id);
        for (a, b) in out.iter().zip(&want) {
            assert_eq!(a.tokens, b.tokens, "preemption + sharing must not change tokens");
        }
    }

    // --- reclamation: swap-out, resume, promotion, victim policies ----

    #[test]
    fn swap_out_preserves_tokens_and_avoids_replay() {
        // two 48-token sequences over a 2+2-group cache cannot coexist
        // (future demand 4 groups > 2 usable free at the collision), so
        // the ladder preempts the youngest while the host tier still
        // has room — in Swap mode its table parks and resumes, so *no
        // prompt token is ever prefilled twice*.
        let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let prompts = [vec![1i32; 8], vec![2i32; 8]];

        let mut base = host_engine_with_layout(1, KvLayout::Paged);
        for pr in &prompts {
            base.submit(pr.clone(), p).unwrap();
        }
        let mut want = base.run_until_idle().unwrap();
        want.sort_by_key(|r| r.id);

        let mut e = host_engine_reclaim(2, 2, PreemptMode::Swap, VictimPolicy::Youngest);
        for pr in &prompts {
            e.submit(pr.clone(), p).unwrap();
        }
        let mut got = e.run_until_idle().unwrap();
        got.sort_by_key(|r| r.id);
        assert_eq!(got.len(), 2);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.tokens, b.tokens, "swap-out must not change request {} tokens", a.id);
        }
        let m = &e.metrics;
        assert!(m.swaps_out >= 1, "the squeeze must swap the youngest out");
        assert_eq!(m.swaps_in, m.swaps_out, "every swap resumed");
        assert!(m.swaps_out <= m.preemptions);
        assert!(m.recompute_tokens_avoided > 0);
        assert!(
            m.promotions >= 1,
            "the swap-in restore must promote the parked table back"
        );
        assert_eq!(
            m.prefilled_tokens, 16,
            "swap-out preserves cached KV: no prompt token prefills twice"
        );
        assert_eq!(m.pages_used, 0, "device tier drained at idle");
        assert_eq!(m.host_pages_used, 0, "host tier drained at idle");

        // the same squeeze in Recompute mode replays the victim's
        // prompt — strictly more prefill work, identical tokens
        let mut r = host_engine_reclaim(2, 2, PreemptMode::Recompute, VictimPolicy::Youngest);
        for pr in &prompts {
            r.submit(pr.clone(), p).unwrap();
        }
        let mut rec = r.run_until_idle().unwrap();
        rec.sort_by_key(|x| x.id);
        for (a, b) in rec.iter().zip(&want) {
            assert_eq!(a.tokens, b.tokens, "recompute must not change request {} tokens", a.id);
        }
        assert_eq!(r.metrics.swaps_out, 0);
        assert!(r.metrics.preemptions >= 1);
        assert!(
            r.metrics.prefilled_tokens > e.metrics.prefilled_tokens,
            "recompute replays prefill work that swap-out avoids: {} !> {}",
            r.metrics.prefilled_tokens,
            e.metrics.prefilled_tokens
        );
    }

    #[test]
    fn swap_infeasible_without_host_tier_falls_back_to_recompute() {
        // no host tier: even forced Swap mode must degrade to the
        // recompute path (swap reservations are gated like migrations)
        let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let mut e = host_engine_reclaim(5, 0, PreemptMode::Swap, VictimPolicy::Youngest);
        e.submit(vec![1; 8], p).unwrap();
        e.submit(vec![2; 8], p).unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|r| r.tokens.len() == 40));
        assert!(e.metrics.preemptions >= 1);
        assert_eq!(e.metrics.swaps_out, 0, "nothing can park on an absent host tier");
        assert_eq!(e.metrics.swaps_in, 0);
    }

    #[test]
    fn victim_policies_all_terminate_with_identical_tokens() {
        let p = GenParams { max_new_tokens: 24, eos_token: None, share_prefix: false };
        let prompts = [vec![3i32; 8], vec![4i32; 20], vec![5i32; 4]];
        let mut base = host_engine_with_layout(1, KvLayout::Paged);
        for pr in &prompts {
            base.submit(pr.clone(), p).unwrap();
        }
        let mut want = base.run_until_idle().unwrap();
        want.sort_by_key(|r| r.id);

        for policy in
            [VictimPolicy::Youngest, VictimPolicy::FewestPagesLost, VictimPolicy::ClosestToDone]
        {
            let mut e = host_engine_reclaim(2, 3, PreemptMode::Auto, policy);
            for pr in &prompts {
                e.submit(pr.clone(), p).unwrap();
            }
            let mut got = e.run_until_idle().unwrap();
            got.sort_by_key(|r| r.id);
            assert_eq!(got.len(), want.len(), "{policy:?} lost a request");
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.tokens, b.tokens, "{policy:?} changed request {} tokens", a.id);
            }
            assert_eq!(e.metrics.pages_used, 0, "{policy:?} leaked device pages");
            assert_eq!(e.metrics.host_pages_used, 0, "{policy:?} leaked host pages");
        }
    }

    #[test]
    fn promotion_recovers_device_residency_and_folds_migrations() {
        // two 48-token sequences (20-token prompts = 2 blocks up
        // front, 3 blocks total each) over a 4+4-group cache: both
        // prompts prefill onto the device (4 groups, full), so the
        // first third-block allocation migrates BOTH sequences' cold
        // blocks in ONE folded transfer; when the older sequence
        // finishes, the freed device groups promote the survivor's
        // hottest host block back.
        let p = GenParams { max_new_tokens: 28, eos_token: None, share_prefix: false };
        let prompts = [vec![7i32; 20], vec![9i32; 20]];
        let mut base = host_engine_with_layout(1, KvLayout::Paged);
        for pr in &prompts {
            base.submit(pr.clone(), p).unwrap();
        }
        let mut want = base.run_until_idle().unwrap();
        want.sort_by_key(|r| r.id);

        let mut e = host_engine_tiered(4, 4);
        for pr in &prompts {
            e.submit(pr.clone(), p).unwrap();
        }
        let mut got = e.run_until_idle().unwrap();
        got.sort_by_key(|r| r.id);
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x.tokens, y.tokens, "promotion must not change request {} tokens", x.id);
        }
        let m = &e.metrics;
        assert!(
            m.pages_migrated >= 8,
            "both sequences' cold blocks must migrate, moved {}",
            m.pages_migrated
        );
        assert!(
            m.grouped_transfers >= 1,
            "the two cold groups must fold into one link transfer"
        );
        assert!(m.promotions >= 1, "freed device groups must pull hot blocks back");
        assert!(m.promoted_pages >= 4);
        assert_eq!(m.preemptions, 0, "migration + promotion cover this workload");
        assert_eq!(m.pages_used, 0);
        assert_eq!(m.host_pages_used, 0);
    }

    #[test]
    fn suspended_sequence_resumes_before_new_admissions() {
        // A, B, C in FCFS order over a 2+2-group cache: C's admission
        // defers on capacity, B swaps out under the squeeze, and B must
        // come back and finish before C is admitted.
        let p = GenParams { max_new_tokens: 40, eos_token: None, share_prefix: false };
        let mut e = host_engine_reclaim(2, 2, PreemptMode::Swap, VictimPolicy::Youngest);
        let ida = e.submit(vec![1; 8], p).unwrap();
        let idb = e.submit(vec![2; 8], p).unwrap();
        let idc = e.submit(vec![3; 8], p).unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.tokens.len() == 40));
        // completion order == finish-push order: A, then the resumed
        // B, then the late-admitted C
        let order: Vec<_> = out.iter().map(|r| r.id).collect();
        assert_eq!(order, vec![ida, idb, idc], "resume must outrank new admission");
        assert!(e.metrics.swaps_out >= 1, "B was parked, not replayed");
    }

    fn engine() -> Option<Engine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return None;
        }
        let rt = Runtime::load(dir).expect("runtime loads");
        Some(Engine::new(rt, EngineConfig::default()))
    }

    #[test]
    fn single_request_completes() {
        let Some(mut e) = engine() else { return };
        let id = e
            .submit(vec![1, 2, 3, 4, 5], GenParams { max_new_tokens: 4, ..GenParams::default() })
            .unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        assert!(out[0].ttft_s > 0.0);
        assert!(out[0].total_s >= out[0].ttft_s);
    }

    #[test]
    fn generation_is_deterministic() {
        let Some(mut e1) = engine() else { return };
        let Some(mut e2) = engine() else { return };
        let p = GenParams { max_new_tokens: 6, eos_token: None, share_prefix: false };
        e1.submit(vec![7, 8, 9], p).unwrap();
        e2.submit(vec![7, 8, 9], p).unwrap();
        let a = e1.run_until_idle().unwrap();
        let b = e2.run_until_idle().unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn batched_equals_solo() {
        // The continuous batcher must not change any request's output.
        let Some(mut batched) = engine() else { return };
        let p = GenParams { max_new_tokens: 5, eos_token: None, share_prefix: false };
        let prompts: Vec<Vec<i32>> = vec![
            vec![1, 2, 3],
            vec![10, 20, 30, 40, 50, 60],
            vec![100, 200],
            vec![5; 20],
        ];
        let mut ids = Vec::new();
        for pr in &prompts {
            ids.push(batched.submit(pr.clone(), p).unwrap());
        }
        let mut out = batched.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);

        for (pr, want) in prompts.iter().zip(&out) {
            let Some(mut solo) = engine() else { return };
            solo.submit(pr.clone(), p).unwrap();
            let got = solo.run_until_idle().unwrap();
            assert_eq!(got[0].tokens, want.tokens, "prompt {pr:?}");
        }
    }

    #[test]
    fn rejects_over_capacity() {
        let Some(mut e) = engine() else { return };
        let max_seq = 160;
        assert!(e
            .submit(vec![1; 120], GenParams { max_new_tokens: 100, ..GenParams::default() })
            .is_err());
        assert!(e
            .submit(vec![1; max_seq + 1], GenParams { max_new_tokens: 1, ..GenParams::default() })
            .is_err());
    }

    #[test]
    fn eos_stops_generation() {
        let Some(mut e) = engine() else { return };
        // run once to learn the greedy continuation, then set eos to the
        // second generated token and expect early stop.
        e.submit(vec![3, 1, 4, 1, 5], GenParams { max_new_tokens: 6, ..GenParams::default() })
            .unwrap();
        let full = e.run_until_idle().unwrap();
        let second = full[0].tokens[1];

        let Some(mut e2) = engine() else { return };
        e2.submit(
            vec![3, 1, 4, 1, 5],
            GenParams { max_new_tokens: 6, eos_token: Some(second), share_prefix: false },
        )
        .unwrap();
        let stopped = e2.run_until_idle().unwrap();
        assert_eq!(stopped[0].tokens.len(), 2);
        assert_eq!(*stopped[0].tokens.last().unwrap(), second);
    }

    #[test]
    fn many_requests_all_complete() {
        let Some(mut e) = engine() else { return };
        let p = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
        for i in 0..10 {
            e.submit(vec![i as i32 + 1; (i % 7) + 1], p).unwrap();
        }
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|r| r.tokens.len() == 3));
        assert_eq!(e.metrics.completed, 10);
        assert!(e.metrics.decode_steps > 0);
        assert!(e.metrics.prefill_steps > 0);
    }
}
