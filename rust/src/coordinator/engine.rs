//! The serving engine: owns an execution [`Backend`] and all sequence
//! state, executes prefill/decode batches chosen by the scheduler.
//!
//! Single-threaded by design — PJRT handles are kept on one engine thread
//! (see [`super::server`] for the threaded front-end); the engine API is
//! synchronous and fully deterministic, which is what the integration
//! tests and benches drive.  Parallelism lives *inside* a step: the
//! batched decode-attention path fans (sequence × head) work across a
//! scoped thread pool sized by [`EngineConfig::parallel`], and
//! `threads = 1` is bit-identical to the multithreaded result.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{ArtifactBackend, Backend};
use super::batcher::{Batcher, BatcherConfig, DecodeBatch, PrefillBatch};
use super::kv_cache::{pack_batch, unpack_batch, CachePool, CacheShape, SeqCache, Tier};
use super::request::{GenParams, Phase, Request, RequestId, Response};
use super::scheduler::{Policy, Scheduler, Step};
use crate::attention::batch::ParallelConfig;
use crate::metrics::EngineMetrics;
use crate::runtime::Runtime;

/// A live sequence.
struct SeqState {
    id: RequestId,
    prompt_len: usize,
    /// Generated tokens (first comes from prefill logits).
    tokens: Vec<i32>,
    cache: SeqCache,
    tier: Tier,
    params: GenParams,
    phase: Phase,
    submitted_at: Instant,
    first_token_at: Option<Instant>,
}

impl SeqState {
    /// Cache position of the *latest* generated token (where the next
    /// decode step writes it).
    fn pos(&self) -> usize {
        self.prompt_len + self.tokens.len() - 1
    }

    fn last_token(&self) -> i32 {
        *self.tokens.last().expect("sequence has a token after prefill")
    }
}

/// Engine configuration knobs.
pub struct EngineConfig {
    pub policy: Policy,
    /// Device KV budget in bytes (drives CachePool tiering).
    pub device_kv_budget: usize,
    /// Cap on concurrently decoding sequences.
    pub max_active: usize,
    /// Intra-step parallelism for backends that honor it (the host
    /// batched-attention path); `threads = 1` is the sequential
    /// fallback, bit-identical to any `threads = N`.
    pub parallel: ParallelConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            policy: Policy::Fair { quantum: 4 },
            device_kv_budget: 64 << 20,
            max_active: 16,
            parallel: ParallelConfig::default(),
        }
    }
}

/// The engine.
pub struct Engine {
    backend: Box<dyn Backend>,
    shape: CacheShape,
    batcher: Batcher,
    scheduler: Scheduler,
    pool: CachePool,
    active: Vec<RequestId>,
    seqs: HashMap<RequestId, SeqState>,
    finished: Vec<Response>,
    next_id: RequestId,
    pub metrics: EngineMetrics,
}

impl Engine {
    /// Build an engine over a loaded PJRT runtime (the AOT-artifact
    /// backend).
    pub fn new(rt: Runtime, cfg: EngineConfig) -> Self {
        Self::with_backend(Box::new(ArtifactBackend::new(rt)), cfg)
    }

    /// Build an engine over any execution backend.
    pub fn with_backend(mut backend: Box<dyn Backend>, cfg: EngineConfig) -> Self {
        backend.set_parallel(cfg.parallel);
        let m = backend.model();
        let shape = CacheShape {
            layers: m.n_layers,
            kv_heads: m.n_kv_heads,
            max_seq: m.max_seq,
            head_dim: m.head_dim,
        };
        let buckets = backend.buckets();
        let batcher = Batcher::new(BatcherConfig {
            prefill_batches: buckets.prefill_batches,
            prefill_seqs: buckets.prefill_seqs,
            decode_batches: buckets.decode_batches,
            max_active: cfg.max_active,
        });
        Self {
            backend,
            shape,
            batcher,
            scheduler: Scheduler::new(cfg.policy),
            pool: CachePool::new(shape, cfg.device_kv_budget),
            active: Vec::new(),
            seqs: HashMap::new(),
            finished: Vec::new(),
            next_id: 1,
            metrics: EngineMetrics::default(),
        }
    }

    /// Submit a prompt; returns its request id.
    pub fn submit(&mut self, prompt: Vec<i32>, params: GenParams) -> Result<RequestId> {
        let max_seq = self.shape.max_seq;
        if prompt.len() + params.max_new_tokens > max_seq {
            bail!(
                "prompt {} + max_new_tokens {} exceeds cache capacity {max_seq}",
                prompt.len(),
                params.max_new_tokens
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::new(id, prompt, params);
        self.batcher
            .push(req)
            .map_err(|r| anyhow::anyhow!("prompt of {} tokens fits no bucket", r.prompt.len()))?;
        Ok(id)
    }

    /// Sequences currently decoding.
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Run one scheduling step.  Returns false when idle.
    pub fn step(&mut self) -> Result<bool> {
        match self.scheduler.next_step(&self.batcher, self.active.len()) {
            Step::Idle => Ok(false),
            Step::Prefill => {
                if let Some(batch) = self.batcher.next_prefill(self.active.len()) {
                    self.run_prefill(batch)?;
                } else if !self.active.is_empty() {
                    // capacity-blocked: fall back to decode
                    if let Some(batch) = self.batcher.next_decode(&self.active) {
                        self.run_decode(batch)?;
                    }
                }
                Ok(true)
            }
            Step::Decode => {
                if let Some(batch) = self.batcher.next_decode(&self.active) {
                    self.run_decode(batch)?;
                }
                Ok(true)
            }
        }
    }

    /// Drive until every submitted request completes; drain responses.
    pub fn run_until_idle(&mut self) -> Result<Vec<Response>> {
        while self.step()? {}
        Ok(std::mem::take(&mut self.finished))
    }

    /// Drain any already-finished responses without stepping.
    pub fn take_finished(&mut self) -> Vec<Response> {
        std::mem::take(&mut self.finished)
    }

    fn run_prefill(&mut self, batch: PrefillBatch) -> Result<()> {
        let t0 = Instant::now();
        let b = batch.batch_bucket;
        let s = batch.seq_bucket;

        // tokens [B, S] (right-padded), lengths [B] (dummy rows: 1).
        let mut tokens = vec![0i32; b * s];
        let mut lengths = vec![1i32; b];
        for (i, req) in batch.requests.iter().enumerate() {
            tokens[i * s..][..req.prompt.len()].copy_from_slice(&req.prompt);
            lengths[i] = req.prompt.len() as i32;
        }
        let out = self
            .backend
            .prefill(b, s, &tokens, &lengths)
            .with_context(|| format!("prefill step b{b}_s{s}"))?;
        let (logits, kc, vc) = (&out.logits, &out.k_plane, &out.v_plane);
        let vocab = self.backend.model().vocab;

        for (i, req) in batch.requests.into_iter().enumerate() {
            let row = &logits[i * vocab..][..vocab];
            let first = argmax(row) as i32;
            let (mut cache, tier) = self.pool.allocate();
            unpack_batch(self.shape, b, kc, &mut [(i, &mut cache.k)])?;
            unpack_batch(self.shape, b, vc, &mut [(i, &mut cache.v)])?;
            let state = SeqState {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: vec![first],
                cache,
                tier,
                params: req.params,
                phase: Phase::Decoding,
                submitted_at: req.submitted_at,
                first_token_at: Some(Instant::now()),
            };
            self.metrics.prefilled_tokens += req.prompt.len() as u64;
            // done already? (max_new_tokens == 1 or instant EOS)
            if state.tokens.len() >= state.params.max_new_tokens
                || state.params.eos_token == Some(first)
            {
                self.finish(state);
            } else {
                self.active.push(req.id);
                self.seqs.insert(req.id, state);
            }
        }
        self.metrics.prefill_steps += 1;
        self.metrics.prefill_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn run_decode(&mut self, batch: DecodeBatch) -> Result<()> {
        let t0 = Instant::now();
        let b = batch.batch_bucket;

        let mut token = vec![0i32; b];
        let mut pos = vec![0i32; b];
        let mut packs: Vec<(usize, &[f32])> = Vec::with_capacity(batch.seq_ids.len());
        let mut packs_v: Vec<(usize, &[f32])> = Vec::with_capacity(batch.seq_ids.len());
        for (slot, id) in batch.seq_ids.iter().enumerate() {
            let s = self.seqs.get(id).context("active seq missing")?;
            token[slot] = s.last_token();
            pos[slot] = s.pos() as i32;
            packs.push((slot, &s.cache.k));
            packs_v.push((slot, &s.cache.v));
        }
        let k_plane = pack_batch(self.shape, b, &packs)?;
        let v_plane = pack_batch(self.shape, b, &packs_v)?;
        drop(packs);
        drop(packs_v);

        let out = self
            .backend
            .decode(b, &token, k_plane, v_plane, &pos)
            .with_context(|| format!("decode step b{b}"))?;
        let (logits, kc, vc) = (&out.logits, &out.k_plane, &out.v_plane);
        let vocab = self.backend.model().vocab;

        let mut done: Vec<RequestId> = Vec::new();
        for (slot, id) in batch.seq_ids.iter().enumerate() {
            let s = self.seqs.get_mut(id).unwrap();
            unpack_batch(self.shape, b, kc, &mut [(slot, &mut s.cache.k)])?;
            unpack_batch(self.shape, b, vc, &mut [(slot, &mut s.cache.v)])?;
            let next = argmax(&logits[slot * vocab..][..vocab]) as i32;
            s.tokens.push(next);
            self.metrics.decoded_tokens += 1;
            let finished = s.tokens.len() >= s.params.max_new_tokens
                || s.params.eos_token == Some(next)
                || s.pos() + 1 >= self.shape.max_seq;
            if finished {
                done.push(*id);
            }
        }
        for id in done {
            let state = self.seqs.remove(&id).unwrap();
            self.active.retain(|&a| a != id);
            self.finish(state);
        }
        self.metrics.decode_steps += 1;
        self.metrics.decode_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    fn finish(&mut self, mut state: SeqState) {
        state.phase = Phase::Finished;
        self.pool.release(state.tier);
        let now = Instant::now();
        let ttft = state
            .first_token_at
            .map(|t| (t - state.submitted_at).as_secs_f64())
            .unwrap_or(0.0);
        self.metrics.completed += 1;
        self.finished.push(Response {
            id: state.id,
            prompt_len: state.prompt_len,
            tokens: state.tokens,
            ttft_s: ttft,
            total_s: (now - state.submitted_at).as_secs_f64(),
        });
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::{HostModelBackend, HostModelConfig};

    fn host_engine(threads: usize) -> Engine {
        let cfg = EngineConfig {
            parallel: ParallelConfig { threads, min_work_per_thread: 0 },
            ..EngineConfig::default()
        };
        Engine::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            cfg,
        )
    }

    #[test]
    fn host_backend_single_request_completes() {
        let mut e = host_engine(1);
        let id = e
            .submit(vec![1, 2, 3, 4, 5], GenParams { max_new_tokens: 4, eos_token: None })
            .unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        let vocab = 64;
        assert!(out[0].tokens.iter().all(|&t| t >= 0 && t < vocab));
    }

    #[test]
    fn host_backend_batched_equals_solo() {
        let p = GenParams { max_new_tokens: 5, eos_token: None };
        let prompts: Vec<Vec<i32>> =
            vec![vec![1, 2, 3], vec![10, 20, 30, 40, 50, 60], vec![7; 12], vec![3, 1]];
        let mut batched = host_engine(2);
        let mut ids = Vec::new();
        for pr in &prompts {
            ids.push(batched.submit(pr.clone(), p).unwrap());
        }
        let mut out = batched.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);
        assert_eq!(out.len(), prompts.len());

        for (pr, want) in prompts.iter().zip(&out) {
            let mut solo = host_engine(2);
            solo.submit(pr.clone(), p).unwrap();
            let got = solo.run_until_idle().unwrap();
            assert_eq!(got[0].tokens, want.tokens, "prompt {pr:?}");
        }
    }

    #[test]
    fn host_backend_parallel_matches_sequential() {
        let p = GenParams { max_new_tokens: 6, eos_token: None };
        let prompts: Vec<Vec<i32>> =
            vec![vec![5, 4, 3, 2, 1], vec![11; 9], vec![2, 4, 6, 8]];
        let run = |threads: usize| {
            let mut e = host_engine(threads);
            for pr in &prompts {
                e.submit(pr.clone(), p).unwrap();
            }
            let mut out = e.run_until_idle().unwrap();
            out.sort_by_key(|r| r.id);
            out.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "threads must not change greedy tokens");
    }

    fn engine() -> Option<Engine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return None;
        }
        let rt = Runtime::load(dir).expect("runtime loads");
        Some(Engine::new(rt, EngineConfig::default()))
    }

    #[test]
    fn single_request_completes() {
        let Some(mut e) = engine() else { return };
        let id = e
            .submit(vec![1, 2, 3, 4, 5], GenParams { max_new_tokens: 4, eos_token: None })
            .unwrap();
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].tokens.len(), 4);
        assert!(out[0].ttft_s > 0.0);
        assert!(out[0].total_s >= out[0].ttft_s);
    }

    #[test]
    fn generation_is_deterministic() {
        let Some(mut e1) = engine() else { return };
        let Some(mut e2) = engine() else { return };
        let p = GenParams { max_new_tokens: 6, eos_token: None };
        e1.submit(vec![7, 8, 9], p).unwrap();
        e2.submit(vec![7, 8, 9], p).unwrap();
        let a = e1.run_until_idle().unwrap();
        let b = e2.run_until_idle().unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn batched_equals_solo() {
        // The continuous batcher must not change any request's output.
        let Some(mut batched) = engine() else { return };
        let p = GenParams { max_new_tokens: 5, eos_token: None };
        let prompts: Vec<Vec<i32>> = vec![
            vec![1, 2, 3],
            vec![10, 20, 30, 40, 50, 60],
            vec![100, 200],
            vec![5; 20],
        ];
        let mut ids = Vec::new();
        for pr in &prompts {
            ids.push(batched.submit(pr.clone(), p).unwrap());
        }
        let mut out = batched.run_until_idle().unwrap();
        out.sort_by_key(|r| r.id);

        for (pr, want) in prompts.iter().zip(&out) {
            let Some(mut solo) = engine() else { return };
            solo.submit(pr.clone(), p).unwrap();
            let got = solo.run_until_idle().unwrap();
            assert_eq!(got[0].tokens, want.tokens, "prompt {pr:?}");
        }
    }

    #[test]
    fn rejects_over_capacity() {
        let Some(mut e) = engine() else { return };
        let max_seq = 160;
        assert!(e
            .submit(vec![1; 120], GenParams { max_new_tokens: 100, eos_token: None })
            .is_err());
        assert!(e
            .submit(vec![1; max_seq + 1], GenParams { max_new_tokens: 1, eos_token: None })
            .is_err());
    }

    #[test]
    fn eos_stops_generation() {
        let Some(mut e) = engine() else { return };
        // run once to learn the greedy continuation, then set eos to the
        // second generated token and expect early stop.
        e.submit(vec![3, 1, 4, 1, 5], GenParams { max_new_tokens: 6, eos_token: None })
            .unwrap();
        let full = e.run_until_idle().unwrap();
        let second = full[0].tokens[1];

        let Some(mut e2) = engine() else { return };
        e2.submit(
            vec![3, 1, 4, 1, 5],
            GenParams { max_new_tokens: 6, eos_token: Some(second) },
        )
        .unwrap();
        let stopped = e2.run_until_idle().unwrap();
        assert_eq!(stopped[0].tokens.len(), 2);
        assert_eq!(*stopped[0].tokens.last().unwrap(), second);
    }

    #[test]
    fn many_requests_all_complete() {
        let Some(mut e) = engine() else { return };
        let p = GenParams { max_new_tokens: 3, eos_token: None };
        for i in 0..10 {
            e.submit(vec![i as i32 + 1; (i % 7) + 1], p).unwrap();
        }
        let out = e.run_until_idle().unwrap();
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|r| r.tokens.len() == 3));
        assert_eq!(e.metrics.completed, 10);
        assert!(e.metrics.decode_steps > 0);
        assert!(e.metrics.prefill_steps > 0);
    }
}
