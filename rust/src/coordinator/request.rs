//! Request/response types for the serving engine.

use std::time::Instant;

/// Monotonic request identifier.
pub type RequestId = u64;

/// Generation parameters (greedy sampling; the tiny model's decode path).
#[derive(Debug, Clone, Copy)]
pub struct GenParams {
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
    /// Stop early on this token id, if any.
    pub eos_token: Option<i32>,
    /// Opt into cross-sequence prompt-prefix sharing (paged engines
    /// only): adopt the cached KV pages of a matching prompt prefix
    /// instead of re-prefilling it, and register this prompt's pages
    /// for later requests.  Off by default — shared pages are pinned to
    /// the device tier while referenced.  Tokens are unchanged either
    /// way (sharing reuses bit-identical KV rows).
    pub share_prefix: bool,
}

impl Default for GenParams {
    fn default() -> Self {
        Self { max_new_tokens: 16, eos_token: None, share_prefix: false }
    }
}

impl GenParams {
    /// `self` with prefix sharing switched on — the request-path opt-in.
    pub fn with_shared_prefix(mut self) -> Self {
        self.share_prefix = true;
        self
    }
}

/// An inference request as submitted to the router.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Prompt token ids (tokenization is out of scope — the tiny model
    /// has a synthetic vocabulary).
    pub prompt: Vec<i32>,
    pub params: GenParams,
    /// Submission timestamp (for queueing-latency metrics).
    pub submitted_at: Instant,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, params: GenParams) -> Self {
        Self { id, prompt, params, submitted_at: Instant::now() }
    }
}

/// Lifecycle phase of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Queued, not yet prefilled.
    Waiting,
    /// Admitted; prompt KV being built chunk by chunk (chunked prefill
    /// over the paged cache).
    Chunking,
    /// Prefilled; generating tokens.
    Decoding,
    /// Swap-out preempted: the sequence's whole block table is parked
    /// on the host tier and it takes no steps until the scheduler
    /// resumes it (before any new admission) — its cached KV survives,
    /// so resume continues exactly where it stopped instead of
    /// replaying the prompt.
    Suspended,
    /// Done (budget exhausted or EOS).
    Finished,
}

/// Completed response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: RequestId,
    pub prompt_len: usize,
    pub tokens: Vec<i32>,
    /// Seconds from submission to first generated token.
    pub ttft_s: f64,
    /// Seconds from submission to completion.
    pub total_s: f64,
}

impl Response {
    /// Decode throughput over the generation phase, tokens/second.
    pub fn decode_tps(&self) -> f64 {
        if self.tokens.len() <= 1 || self.total_s <= self.ttft_s {
            return 0.0;
        }
        (self.tokens.len() - 1) as f64 / (self.total_s - self.ttft_s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_tps_accounts_post_first_token() {
        let r = Response {
            id: 1,
            prompt_len: 4,
            tokens: vec![1, 2, 3, 4, 5],
            ttft_s: 0.5,
            total_s: 1.5,
        };
        assert!((r.decode_tps() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn decode_tps_degenerate_cases() {
        let r = Response {
            id: 1,
            prompt_len: 4,
            tokens: vec![1],
            ttft_s: 0.5,
            total_s: 0.5,
        };
        assert_eq!(r.decode_tps(), 0.0);
    }
}
