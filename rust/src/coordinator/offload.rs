//! The CPU–GPU cooperative strategy (§4.4): planner + executor.
//!
//! Planner: eq. 15–20 (via `sim::memory`) decide the L_CPU/L_GPU layer
//! split.  Executor: for each decode step,
//!
//! * **classical offloading** uploads the layer's KV cache over PCIe and
//!   computes attention on the GPU;
//! * **cooperative** keeps pre-L_CPU layers' KV host-resident, ships the
//!   one-token QKV down, runs attention *on the host CPU* (the real
//!   FlashAttention2 kernel in `attention::flash`), and uploads only the
//!   fixed-size result.
//!
//! Device-side timings come from the Volta model (no V100 here — repro
//! band 0); the host attention is executed for real and *measured*, so
//! Table 3's CPU_Calc column has a live counterpart.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::attention::flash::{flash_attention, FlashParams};
use crate::coordinator::kv_cache::{kv_page_bytes_codec, CacheShape, PageCodec, PcieLink};
use crate::models::ModelShape;
use crate::sim::memory::Deployment;
use crate::sim::volta::VoltaSpec;

/// Where a layer's KV lives and what executes its decode attention.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerPlacement {
    /// KV on host; attention on host CPU; result uploaded (cooperative).
    HostCompute,
    /// KV on device; attention on device.
    DeviceCompute,
}

/// The per-layer plan for a deployment.
#[derive(Debug, Clone)]
pub struct OffloadPlan {
    pub l_cpu: u32,
    pub l_gpu: u32,
    pub placements: Vec<LayerPlacement>,
    /// Whether any offload is needed at all (Table 3's '-' rows).
    pub offload_needed: bool,
}

/// Build the plan for a deployment (§4.4 steps 1–2).
pub fn plan(dep: &Deployment) -> OffloadPlan {
    let breakdown = dep.plan();
    let l = dep.model.layers;
    if breakdown.fits_without_offload {
        return OffloadPlan {
            l_cpu: 0,
            l_gpu: l,
            placements: vec![LayerPlacement::DeviceCompute; l as usize],
            offload_needed: false,
        };
    }
    let mut placements = Vec::with_capacity(l as usize);
    for i in 0..l {
        if i < breakdown.l_cpu {
            placements.push(LayerPlacement::HostCompute);
        } else {
            placements.push(LayerPlacement::DeviceCompute);
        }
    }
    OffloadPlan {
        l_cpu: breakdown.l_cpu,
        l_gpu: breakdown.l_gpu,
        placements,
        offload_needed: true,
    }
}

/// Latency breakdown of one layer's decode attention (Table 3 columns).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerLatency {
    /// Classical: KV upload over PCIe, seconds.
    pub upload_s: f64,
    /// GPU attention compute, seconds.
    pub gpu_calc_s: f64,
    /// Cooperative: host attention compute, seconds.
    pub cpu_calc_s: f64,
    /// Cooperative: QKV down + result up, seconds.
    pub off_upload_s: f64,
}

impl LayerLatency {
    /// Total under classical offloading.
    pub fn classical_total(&self) -> f64 {
        self.upload_s + self.gpu_calc_s
    }

    /// Total under the cooperative strategy (host-compute layer).
    pub fn coop_total(&self) -> f64 {
        self.cpu_calc_s + self.off_upload_s
    }
}

/// Model-driven layer latencies for a host-resident layer at `seq` KV
/// length (PanGu-38B Table 3 geometry: per-GPU shard of heads).
pub fn layer_latency_model(
    spec: &VoltaSpec,
    model: &ModelShape,
    n_gpus: u32,
    batch: u64,
    seq: u64,
) -> LayerLatency {
    let kv_bytes = model.kv_bytes_per_layer_fp16(batch, seq, n_gpus);
    let h1_shard = model.hidden() / n_gpus as u64;
    let qkv_bytes = 3 * 2 * batch * h1_shard; // one token, fp16
    let out_bytes = 2 * batch * h1_shard;
    LayerLatency {
        upload_s: spec.pcie_transfer(kv_bytes),
        gpu_calc_s: spec.decode_attention_gpu(kv_bytes),
        cpu_calc_s: spec.decode_attention_cpu(kv_bytes),
        off_upload_s: spec.offload_roundtrip(qkv_bytes, out_bytes),
    }
}

/// Measured host attention for one decode step over `seq` cached tokens
/// (live CPU_Calc).  heads/head_dim are the per-GPU shard.
///
/// Measurements are cached per `(heads, seq, head_dim)` for the life of
/// the process: a planner consulting the same geometry twice sees one
/// number — deterministic within a run — instead of re-timing the
/// kernel (and paying its cost) on every call.
pub fn measured_cpu_attention(heads: usize, seq: usize, head_dim: usize) -> f64 {
    static CACHE: OnceLock<Mutex<HashMap<(usize, usize, usize), f64>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(&t) = cache.lock().unwrap().get(&(heads, seq, head_dim)) {
        return t;
    }
    let t = time_cpu_attention(heads, seq, head_dim);
    // a racing thread's earlier insert wins, keeping all callers
    // consistent within the run
    *cache
        .lock()
        .unwrap()
        .entry((heads, seq, head_dim))
        .or_insert(t)
}

/// One uncached timing of the host FlashAttention2 decode kernel.
fn time_cpu_attention(heads: usize, seq: usize, head_dim: usize) -> f64 {
    let q = vec![0.01f32; heads * head_dim];
    let k = vec![0.02f32; heads * seq * head_dim];
    let v = vec![0.03f32; heads * seq * head_dim];
    let mut out = vec![0.0f32; heads * head_dim];
    let t0 = Instant::now();
    flash_attention(&q, &k, &v, &mut out, &FlashParams::decode(heads, seq, head_dim));
    t0.elapsed().as_secs_f64()
}

/// The modeled PCIe link of a Volta deployment — ties the §4.4 cost
/// model to the tiered paged cache's migration accounting
/// (`TieredPagePool` charges `PcieLink::transfer_s` per batched move).
pub fn pcie_link(spec: &VoltaSpec) -> PcieLink {
    PcieLink::new(spec.pcie_bw, spec.pcie_latency_s)
}

/// Modeled seconds to replay **one** token of a preempted sequence's
/// cached KV: one measured host decode-attention step at `typical_kv`
/// cached rows, per layer.  This is the prompt-replay FLOPs side of
/// the recompute-vs-swap decision
/// ([`crate::coordinator::reclaim::RecomputeVsSwap`]): the engine
/// weighs `tokens × this` against shipping the victim's pages over the
/// PCIe link twice.  Deterministic within a run
/// ([`measured_cpu_attention`] caches per geometry).
pub fn replay_token_cost_s(
    layers: usize,
    heads: usize,
    head_dim: usize,
    typical_kv: usize,
) -> f64 {
    layers.max(1) as f64
        * measured_cpu_attention(heads.max(1), typical_kv.max(1), head_dim.max(1))
}

/// Modeled seconds to replay `tokens` cached tokens of a preempted
/// sequence (chunked prefill of its prompt plus re-decode of its
/// generated tokens), using the mean KV length `tokens / 2` as the
/// per-step attention span.
pub fn replay_cost_s(layers: usize, heads: usize, head_dim: usize, tokens: usize) -> f64 {
    tokens as f64 * replay_token_cost_s(layers, heads, head_dim, (tokens / 2).max(1))
}

/// Page-granularity placement for the tiered paged KV cache — the §4.4
/// cache accounting redone at the `PagePool` unit instead of whole
/// layers: how many blocks of a `seq`-token sequence fit under the
/// device budget, how many spill to the host tier, and the modeled
/// batched-PCIe cost of getting them there.  (The layer-granularity
/// planner above is kept for the Table 3 reproduction; the serving
/// engine's placement is this one.)
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PagePlan {
    /// Blocks the sequence occupies in total.
    pub total_blocks: usize,
    /// Blocks that fit on the device under the budget.
    pub device_blocks: usize,
    /// Cold blocks spilled to the host tier.
    pub host_blocks: usize,
    /// Bytes migrated device→host for the spilled blocks.
    pub offload_bytes: usize,
    /// Modeled migration time: one batched transfer per spilled block.
    pub offload_s: f64,
}

impl PagePlan {
    /// Whether the whole sequence is device-resident.
    pub fn fits_on_device(&self) -> bool {
        self.host_blocks == 0
    }
}

/// Place a `seq`-token sequence's KV blocks across the two tiers.  A
/// block allocates one page per (layer, kv-head) plane, so the device
/// capacity is counted in whole block groups.
pub fn plan_pages(
    shape: CacheShape,
    page_size: usize,
    seq: usize,
    device_budget_bytes: usize,
    link: &PcieLink,
) -> PagePlan {
    plan_pages_codec(shape, page_size, seq, device_budget_bytes, link, PageCodec::F32)
}

/// [`plan_pages`] at an explicit on-page encoding: int8 pages quarter
/// every term of the plan — more blocks fit under the same device
/// budget, and each spilled block costs ~4× less link time.
pub fn plan_pages_codec(
    shape: CacheShape,
    page_size: usize,
    seq: usize,
    device_budget_bytes: usize,
    link: &PcieLink,
    codec: PageCodec,
) -> PagePlan {
    let group = shape.layers * shape.kv_heads;
    let page_bytes = kv_page_bytes_codec(page_size, shape.head_dim, codec);
    let group_bytes = (group * page_bytes).max(1);
    let total_blocks = seq.div_ceil(page_size.max(1));
    let device_blocks = total_blocks.min(device_budget_bytes / group_bytes);
    let host_blocks = total_blocks - device_blocks;
    PagePlan {
        total_blocks,
        device_blocks,
        host_blocks,
        offload_bytes: host_blocks * group * page_bytes,
        offload_s: host_blocks as f64 * link.transfer_s(group * page_bytes),
    }
}

/// Full-model decode-step attention latency under each strategy, with
/// per-layer placements applied (the Fig 11 / Table 3 aggregate).
#[derive(Debug, Clone, Copy)]
pub struct StepLatency {
    pub classical_s: f64,
    pub cooperative_s: f64,
}

pub fn step_latency(
    spec: &VoltaSpec,
    dep: &Deployment,
    plan: &OffloadPlan,
) -> StepLatency {
    let per = layer_latency_model(spec, &dep.model, dep.n_gpus, dep.batch, dep.seq);
    let mut classical = 0.0;
    let mut coop = 0.0;
    for p in &plan.placements {
        match p {
            LayerPlacement::HostCompute => {
                // classical must upload this layer's KV every step
                classical += per.classical_total();
                coop += per.coop_total();
            }
            LayerPlacement::DeviceCompute => {
                classical += per.gpu_calc_s;
                coop += per.gpu_calc_s;
            }
        }
    }
    StepLatency { classical_s: classical, cooperative_s: coop }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::PANGU_38B;

    fn dep(seq: u64) -> Deployment {
        Deployment::v100_node(PANGU_38B, seq, 50)
    }

    #[test]
    fn no_offload_for_short_seqs() {
        for s in [1024, 4096, 8192] {
            let p = plan(&dep(s));
            assert!(!p.offload_needed, "S={s}");
            assert_eq!(p.l_cpu, 0);
        }
    }

    #[test]
    fn offload_plan_prefix_layers_on_host() {
        let p = plan(&dep(256 * 1024));
        assert!(p.offload_needed);
        assert!(p.l_cpu > 0);
        assert_eq!(p.placements.len(), PANGU_38B.layers as usize);
        // host layers form a prefix (the paper's "pre-L_CPU layers")
        let first_dev = p
            .placements
            .iter()
            .position(|&x| x == LayerPlacement::DeviceCompute)
            .unwrap_or(p.placements.len());
        assert!(p.placements[..first_dev]
            .iter()
            .all(|&x| x == LayerPlacement::HostCompute));
        assert!(p.placements[first_dev..]
            .iter()
            .all(|&x| x == LayerPlacement::DeviceCompute));
    }

    #[test]
    fn cooperative_beats_classical_on_host_layers() {
        // Table 3: 1.27–1.48× per host-resident layer at 16K–256K.
        let spec = VoltaSpec::default();
        for s in [16 * 1024u64, 64 * 1024, 256 * 1024] {
            let per = layer_latency_model(&spec, &PANGU_38B, 8, 1, s);
            let speedup = per.classical_total() / per.coop_total();
            assert!(
                speedup > 1.2 && speedup < 1.7,
                "S={s}: speedup {speedup:.2}"
            );
        }
    }

    #[test]
    fn off_upload_roughly_constant() {
        let spec = VoltaSpec::default();
        let a = layer_latency_model(&spec, &PANGU_38B, 8, 1, 16 * 1024).off_upload_s;
        let b = layer_latency_model(&spec, &PANGU_38B, 8, 1, 256 * 1024).off_upload_s;
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }

    #[test]
    fn step_latency_aggregates() {
        let spec = VoltaSpec::default();
        let d = dep(128 * 1024);
        let p = plan(&d);
        let st = step_latency(&spec, &d, &p);
        assert!(st.cooperative_s < st.classical_s);
        assert!(st.cooperative_s > 0.0);
    }

    #[test]
    fn measured_cpu_attention_positive_and_scales() {
        let t1 = measured_cpu_attention(5, 2048, 128);
        let t2 = measured_cpu_attention(5, 8192, 128);
        assert!(t1 > 0.0);
        assert!(t2 > t1, "{t2} !> {t1}");
    }

    #[test]
    fn measured_cpu_attention_is_cached_per_shape() {
        // same geometry → bitwise-identical answer within a run, so the
        // planner is deterministic (and doesn't pay the kernel twice)
        let a = measured_cpu_attention(3, 1024, 64);
        let b = measured_cpu_attention(3, 1024, 64);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn replay_cost_scales_with_tokens_and_layers() {
        let a = replay_cost_s(2, 4, 8, 16);
        let b = replay_cost_s(2, 4, 8, 64);
        assert!(a > 0.0);
        assert!(b > a, "more cached tokens cost more to replay: {b} !> {a}");
        let deep = replay_token_cost_s(4, 4, 8, 32);
        let shallow = replay_token_cost_s(2, 4, 8, 32);
        assert!((deep - 2.0 * shallow).abs() < 1e-12, "per-token cost is linear in layers");
        assert_eq!(replay_cost_s(2, 4, 8, 0), 0.0);
    }

    #[test]
    fn page_plan_splits_blocks_and_costs() {
        let shape = CacheShape { layers: 2, kv_heads: 2, max_seq: 4096, head_dim: 8 };
        let link = PcieLink::default();
        let page_size = 16;
        // group = 4 pages of 2·4·16·8 = 1 KiB → 4 KiB per block group
        let group_bytes = 4 * 1024;

        // ample budget: everything device-resident, no modeled cost
        let p = plan_pages(shape, page_size, 160, 100 * group_bytes, &link);
        assert_eq!(p.total_blocks, 10);
        assert!(p.fits_on_device());
        assert_eq!(p.offload_bytes, 0);
        assert_eq!(p.offload_s, 0.0);

        // 3-group budget: 10 blocks → 3 device + 7 host
        let p = plan_pages(shape, page_size, 160, 3 * group_bytes, &link);
        assert_eq!((p.device_blocks, p.host_blocks), (3, 7));
        assert_eq!(p.offload_bytes, 7 * group_bytes);
        assert!((p.offload_s - 7.0 * link.transfer_s(group_bytes)).abs() < 1e-12);

        // spill grows monotonically with sequence length
        let shorter = plan_pages(shape, page_size, 96, 3 * group_bytes, &link);
        assert!(shorter.host_blocks < p.host_blocks);
    }

    #[test]
    fn page_plan_int8_shrinks_spill_and_link_cost() {
        let shape = CacheShape { layers: 2, kv_heads: 2, max_seq: 4096, head_dim: 8 };
        let link = PcieLink::default();
        let page_size = 16;
        let group_bytes = 4 * 1024; // f32 block group (see above)

        // same 3-group f32 budget, int8 pages: 384 B/page vs 1 KiB →
        // 8 block groups fit on device where 3 did, so far less spills
        let f32_plan = plan_pages(shape, page_size, 160, 3 * group_bytes, &link);
        let i8_plan = plan_pages_codec(
            shape,
            page_size,
            160,
            3 * group_bytes,
            &link,
            PageCodec::Int8,
        );
        assert_eq!(i8_plan.total_blocks, f32_plan.total_blocks);
        assert_eq!((i8_plan.device_blocks, i8_plan.host_blocks), (8, 2));
        assert!(i8_plan.host_blocks < f32_plan.host_blocks);

        // force the same split under a proportionally tighter budget:
        // spilled bytes and modeled seconds shrink by the codec ratio
        let i8_group = 2 * 2 * kv_page_bytes_codec(page_size, shape.head_dim, PageCodec::Int8);
        let tight = plan_pages_codec(shape, page_size, 160, 3 * i8_group, &link, PageCodec::Int8);
        assert_eq!((tight.device_blocks, tight.host_blocks), (3, 7));
        assert_eq!(tight.offload_bytes, 7 * i8_group);
        assert!(tight.offload_bytes < f32_plan.offload_bytes);
        assert!(tight.offload_s < f32_plan.offload_s);

        // the f32 delegate is the codec plan at PageCodec::F32
        let via_codec =
            plan_pages_codec(shape, page_size, 160, 3 * group_bytes, &link, PageCodec::F32);
        assert_eq!(via_codec, f32_plan);
    }

    #[test]
    fn pcie_link_matches_volta_spec() {
        let spec = VoltaSpec::default();
        let link = pcie_link(&spec);
        assert_eq!(link.bandwidth_bps, spec.pcie_bw);
        assert_eq!(link.latency_s, spec.pcie_latency_s);
        // the kv_cache default is the same Table 3 calibration
        assert_eq!(link, PcieLink::default());
    }
}
