//! Threaded serving front-end: the continuous-batching request plane.
//!
//! PJRT handles live on a single engine thread (they are not `Send`);
//! clients talk to it over channels.  [`Server::submit`] is
//! non-blocking and returns a [`ResponseStream`] whose channel yields
//! tokens *as each engine step lands* and terminates with either the
//! finished [`Response`] or a typed [`ServeError`].
//!
//! ## The no-hang contract
//!
//! Every submitted request terminates with tokens or a typed error —
//! never a bare hung channel:
//!
//! * **rejection** is a value ([`ServeError::Rejected`] /
//!   [`ServeError::Overloaded`]) returned from `submit` itself;
//! * **engine-step failure** broadcasts
//!   [`ServeError::EngineFailed`] to every outstanding stream (and to
//!   submissions still queued in the command channel) before the
//!   thread exits;
//! * **server drop / shutdown** delivers [`ServeError::Aborted`] to
//!   every in-flight stream before the thread joins;
//! * **client cancel** ([`Server::cancel`]) frees the request's KV
//!   pages immediately — wherever the sequence lives — and terminates
//!   its stream with [`ServeError::Aborted`]; dropping a
//!   [`ResponseStream`] alone never cancels.
//!
//! The serve loop drains at most [`ServerConfig::max_cmds_per_step`]
//! commands between engine steps, so a sustained submit flood cannot
//! starve decode progress, and admits at most
//! [`ServerConfig::max_pending`] concurrent requests — past that,
//! submission fails fast with `Overloaded` backpressure instead of
//! growing the queue without bound.

use std::collections::HashMap;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{Context, Result};

use super::backend::Backend;
use super::batcher::AdmitError;
use super::engine::{Engine, EngineConfig};
use super::request::{GenParams, RequestId, Response};
use crate::metrics::EngineMetrics;
use crate::runtime::Runtime;

/// Why a request could not be (or stopped being) served.  The request
/// plane's error paths are typed end-to-end: every variant reaches the
/// client as a value, never as a silently dropped channel.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// The engine can never serve this request (validation failed).
    Rejected(AdmitError),
    /// Backpressure: the server already tracks `limit` in-flight
    /// requests; retry after some complete.
    Overloaded {
        /// The configured [`ServerConfig::max_pending`] ceiling.
        limit: usize,
    },
    /// The engine thread died mid-serve; the message carries the
    /// step error it died with.
    EngineFailed(String),
    /// The server shut down (or its thread disappeared) with this
    /// request still in flight.
    Aborted,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Rejected(e) => write!(f, "request rejected: {e}"),
            Self::Overloaded { limit } => {
                write!(f, "server at capacity ({limit} requests in flight)")
            }
            Self::EngineFailed(msg) => write!(f, "engine failed: {msg}"),
            Self::Aborted => write!(f, "request aborted by server shutdown"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One event on a request's stream.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// The request's `index`-th generated token (0-based, gap-free:
    /// index `n` is always preceded by `n-1`).
    Token {
        /// 0-based position in the generated sequence.
        index: usize,
        /// The generated token.
        token: i32,
    },
    /// Generation finished; the response's `tokens` equal the streamed
    /// tokens exactly.
    Done(Response),
    /// The request will produce nothing further — the typed reason.
    Error(ServeError),
}

/// Client handle to one in-flight request: a stream of
/// [`StreamEvent`]s ending in `Done` or `Error`.
pub struct ResponseStream {
    id: RequestId,
    rx: Receiver<StreamEvent>,
}

impl ResponseStream {
    /// The request id assigned at admission.
    pub fn id(&self) -> RequestId {
        self.id
    }

    /// Block for the next event.  A receive error means the serving
    /// thread vanished without its exit broadcast — surfaced as
    /// [`ServeError::Aborted`] so the caller still gets a typed reason.
    pub fn recv(&self) -> StreamEvent {
        self.rx.recv().unwrap_or(StreamEvent::Error(ServeError::Aborted))
    }

    /// Like [`ResponseStream::recv`] with a timeout; `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<StreamEvent> {
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => Some(ev),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(StreamEvent::Error(ServeError::Aborted))
            }
        }
    }

    /// Non-blocking poll; `None` when no event is ready.
    pub fn try_recv(&self) -> Option<StreamEvent> {
        match self.rx.try_recv() {
            Ok(ev) => Some(ev),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                Some(StreamEvent::Error(ServeError::Aborted))
            }
        }
    }

    /// Drain the stream to completion and return the final response —
    /// the whole-completion convenience over the streaming API.
    pub fn wait(self) -> Result<Response, ServeError> {
        loop {
            match self.recv() {
                StreamEvent::Token { .. } => continue,
                StreamEvent::Done(resp) => return Ok(resp),
                StreamEvent::Error(e) => return Err(e),
            }
        }
    }
}

/// Request-plane knobs (the engine's own scheduling/admission knobs
/// live in [`EngineConfig`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Concurrency limit: in-flight requests (queued + running) past
    /// which submission fails fast with [`ServeError::Overloaded`].
    pub max_pending: usize,
    /// Commands drained from the channel per serve-loop iteration —
    /// the bound that keeps a submit flood from starving decode steps.
    pub max_cmds_per_step: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { max_pending: 256, max_cmds_per_step: 32 }
    }
}

enum Cmd {
    Submit {
        prompt: Vec<i32>,
        params: GenParams,
        reply: Sender<Result<RequestId, ServeError>>,
        events: Sender<StreamEvent>,
    },
    Metrics {
        reply: Sender<EngineMetrics>,
    },
    Cancel {
        id: RequestId,
        reply: Sender<bool>,
    },
    Shutdown,
}

/// Server-side record of one in-flight stream: its channel plus the
/// next token index the client expects.  `next_index` is what makes
/// streaming exactly-once under recompute preemption — a replayed
/// sequence re-emits tokens it already streamed (bit-identical, greedy
/// decode is deterministic), and those duplicates are dropped here.
struct Waiter {
    events: Sender<StreamEvent>,
    next_index: usize,
}

/// Handle to the engine thread.
pub struct Server {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the engine thread over the artifact directory, with
    /// default request-plane limits.
    pub fn start(artifact_dir: String, cfg: EngineConfig) -> Result<Self> {
        Self::start_with(artifact_dir, cfg, ServerConfig::default())
    }

    /// Start the engine thread over the artifact directory.
    pub fn start_with(
        artifact_dir: String,
        cfg: EngineConfig,
        scfg: ServerConfig,
    ) -> Result<Self> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = thread::spawn(move || {
            let rt = match Runtime::load(&artifact_dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            serve(Engine::new(rt, cfg), scfg, rx);
        });
        ready_rx
            .recv()
            .context("engine thread died before ready")?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Start the engine thread over any `Send` execution backend —
    /// what lets the full request plane run (and be tested) without an
    /// artifact bundle, e.g. against
    /// [`HostModelBackend`](super::backend::HostModelBackend).
    pub fn with_backend(
        backend: Box<dyn Backend + Send>,
        cfg: EngineConfig,
        scfg: ServerConfig,
    ) -> Self {
        let (tx, rx) = channel::<Cmd>();
        let handle = thread::spawn(move || {
            serve(Engine::with_backend(backend, cfg), scfg, rx);
        });
        Self { tx, handle: Some(handle) }
    }

    /// Submit a prompt.  Non-blocking with respect to generation: on
    /// admission it returns a [`ResponseStream`] immediately; tokens
    /// arrive on the stream as decode steps land.  On rejection or
    /// backpressure the typed error comes back instead — this call
    /// never silently drops a request.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
    ) -> Result<ResponseStream, ServeError> {
        let (reply_tx, reply_rx) = channel();
        let (ev_tx, ev_rx) = channel();
        self.tx
            .send(Cmd::Submit { prompt, params, reply: reply_tx, events: ev_tx })
            .map_err(|_| ServeError::Aborted)?;
        let id = reply_rx.recv().map_err(|_| ServeError::Aborted)??;
        Ok(ResponseStream { id, rx: ev_rx })
    }

    /// Snapshot engine metrics.
    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Metrics { reply: tx }).context("engine thread gone")?;
        rx.recv().context("engine thread gone")
    }

    /// Cancel an in-flight request: the engine frees its KV pages
    /// immediately (waiting, prefilling, decoding, or swapped out —
    /// wherever it lives) and its stream terminates with
    /// [`StreamEvent::Error`]`(`[`ServeError::Aborted`]`)` instead of
    /// `Done`.  Returns `Ok(true)` when the request was found live,
    /// `Ok(false)` when it was unknown or had already finished (its
    /// stream then carries the normal `Done`) — cancelling twice is a
    /// harmless no-op.  Dropping a [`ResponseStream`] alone never
    /// cancels: explicit abort is the only way to reclaim a running
    /// request's pages early.
    pub fn cancel(&self, id: RequestId) -> Result<bool> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Cancel { id, reply: tx }).context("engine thread gone")?;
        rx.recv().context("engine thread gone")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // the serve loop's exit path delivers `Aborted` to every
        // stream still in flight before the thread returns, so this
        // join cannot leave a client hanging
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Apply one command.  Returns `false` on `Shutdown`.
fn handle_cmd(
    engine: &mut Engine,
    scfg: &ServerConfig,
    waiters: &mut HashMap<RequestId, Waiter>,
    cmd: Cmd,
) -> bool {
    match cmd {
        Cmd::Submit { prompt, params, reply, events } => {
            if waiters.len() >= scfg.max_pending {
                let _ = reply.send(Err(ServeError::Overloaded { limit: scfg.max_pending }));
                return true;
            }
            match engine.submit(prompt, params) {
                Ok(id) => {
                    waiters.insert(id, Waiter { events, next_index: 0 });
                    let _ = reply.send(Ok(id));
                }
                Err(e) => {
                    let _ = reply.send(Err(ServeError::Rejected(e)));
                }
            }
            true
        }
        Cmd::Metrics { reply } => {
            let _ = reply.send(engine.metrics.clone());
            true
        }
        Cmd::Cancel { id, reply } => {
            // deliver tokens already generated before the abort marker
            // so the stream stays gap-free up to its termination
            deliver(engine, waiters);
            let live = engine.cancel(id);
            if let Some(w) = waiters.remove(&id) {
                let _ = w.events.send(StreamEvent::Error(ServeError::Aborted));
            }
            let _ = reply.send(live);
            true
        }
        Cmd::Shutdown => false,
    }
}

/// Forward this step's tokens and completions to their streams.
/// Token events are deduplicated by index (see [`Waiter`]); at `Done`
/// any trailing tokens the event feed missed are backfilled from the
/// response itself, so the streamed sequence always equals
/// `Response.tokens` exactly.
fn deliver(engine: &mut Engine, waiters: &mut HashMap<RequestId, Waiter>) {
    for ev in engine.take_token_events() {
        if let Some(w) = waiters.get_mut(&ev.id) {
            if ev.index == w.next_index {
                let _ = w.events.send(StreamEvent::Token { index: ev.index, token: ev.token });
                w.next_index += 1;
            }
        }
    }
    for resp in engine.take_finished() {
        if let Some(mut w) = waiters.remove(&resp.id) {
            for (i, &tok) in resp.tokens.iter().enumerate().skip(w.next_index) {
                let _ = w.events.send(StreamEvent::Token { index: i, token: tok });
            }
            w.next_index = resp.tokens.len();
            let _ = w.events.send(StreamEvent::Done(resp));
        }
    }
}

/// The background batching loop: drain a bounded number of commands,
/// run one engine step, stream out what it produced — repeat.  On any
/// exit (shutdown, client disconnect, engine failure) every
/// outstanding stream and still-queued submission receives a typed
/// error before the thread returns.
fn serve(mut engine: Engine, scfg: ServerConfig, rx: Receiver<Cmd>) {
    let mut waiters: HashMap<RequestId, Waiter> = HashMap::new();
    let exit: ServeError = 'run: loop {
        let mut budget = scfg.max_cmds_per_step.max(1);
        // nothing in flight: block instead of spinning on try_recv
        if waiters.is_empty() {
            match rx.recv() {
                Ok(cmd) => {
                    if !handle_cmd(&mut engine, &scfg, &mut waiters, cmd) {
                        break 'run ServeError::Aborted;
                    }
                    budget -= 1;
                }
                Err(_) => break 'run ServeError::Aborted,
            }
        }
        // bounded drain: a submit flood fills at most `budget` slots
        // before the engine steps again, so decode always progresses
        while budget > 0 {
            match rx.try_recv() {
                Ok(cmd) => {
                    if !handle_cmd(&mut engine, &scfg, &mut waiters, cmd) {
                        break 'run ServeError::Aborted;
                    }
                    budget -= 1;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'run ServeError::Aborted,
            }
        }
        if let Err(e) = engine.step() {
            break 'run ServeError::EngineFailed(format!("{e:#}"));
        }
        deliver(&mut engine, &mut waiters);
    };
    // the no-hang contract: every outstanding stream learns why it
    // ended, and submissions still queued in the channel get a typed
    // reply instead of a dead reply channel
    deliver(&mut engine, &mut waiters);
    for (_, w) in waiters.drain() {
        let _ = w.events.send(StreamEvent::Error(exit.clone()));
    }
    while let Ok(cmd) = rx.try_recv() {
        match cmd {
            Cmd::Submit { reply, .. } => {
                let _ = reply.send(Err(exit.clone()));
            }
            Cmd::Metrics { reply } => {
                let _ = reply.send(engine.metrics.clone());
            }
            Cmd::Cancel { reply, .. } => {
                let _ = reply.send(false);
            }
            Cmd::Shutdown => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::batch::ParallelConfig;
    use crate::coordinator::backend::{
        BucketGrid, HostModelBackend, HostModelConfig, ModelInfo, PagedRow, StepOut,
    };
    use crate::coordinator::kv_cache::{BlockTable, TieredPagePool};
    use anyhow::bail;
    use std::time::Duration;

    const WAIT: Duration = Duration::from_secs(60);

    fn host_server(scfg: ServerConfig) -> Server {
        Server::with_backend(
            Box::new(HostModelBackend::new(HostModelConfig::tiny_gqa())),
            EngineConfig::default(),
            scfg,
        )
    }

    /// Delegates to a host backend until `calls_left` paged steps have
    /// run, then every step fails — the engine-death injection rig.
    struct FailingBackend {
        inner: HostModelBackend,
        calls_left: usize,
    }

    impl FailingBackend {
        fn new(calls_left: usize) -> Self {
            Self { inner: HostModelBackend::new(HostModelConfig::tiny_gqa()), calls_left }
        }

        fn tick(&mut self) -> anyhow::Result<()> {
            if self.calls_left == 0 {
                bail!("injected backend failure");
            }
            self.calls_left -= 1;
            Ok(())
        }
    }

    impl Backend for FailingBackend {
        fn model(&self) -> &ModelInfo {
            self.inner.model()
        }
        fn buckets(&self) -> BucketGrid {
            self.inner.buckets()
        }
        fn set_parallel(&mut self, cfg: ParallelConfig) {
            self.inner.set_parallel(cfg)
        }
        fn prefill(
            &mut self,
            batch: usize,
            seq: usize,
            tokens: &[i32],
            lengths: &[i32],
        ) -> anyhow::Result<StepOut> {
            self.tick()?;
            self.inner.prefill(batch, seq, tokens, lengths)
        }
        fn decode(
            &mut self,
            batch: usize,
            tokens: &[i32],
            k_plane: Vec<f32>,
            v_plane: Vec<f32>,
            pos: &[i32],
        ) -> anyhow::Result<StepOut> {
            self.tick()?;
            self.inner.decode(batch, tokens, k_plane, v_plane, pos)
        }
        fn supports_paged(&self) -> bool {
            true
        }
        fn decode_paged(
            &mut self,
            rows: &[PagedRow<'_>],
            pools: &mut TieredPagePool,
        ) -> anyhow::Result<Vec<f32>> {
            self.tick()?;
            self.inner.decode_paged(rows, pools)
        }
        fn prefill_chunk(
            &mut self,
            tokens: &[i32],
            start_pos: usize,
            table: &BlockTable,
            pools: &mut TieredPagePool,
        ) -> anyhow::Result<Vec<f32>> {
            self.tick()?;
            self.inner.prefill_chunk(tokens, start_pos, table, pools)
        }
    }

    #[test]
    fn serves_concurrent_clients_on_host_backend() {
        let server = host_server(ServerConfig::default());
        let p = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
        let waits: Vec<_> = (0..6)
            .map(|i| {
                let prompt = vec![(i % 50) as i32 + 1; (i % 9) + 1];
                server.submit(prompt, p).unwrap()
            })
            .collect();
        for stream in waits {
            let id = stream.id();
            let resp = stream.wait().unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
        }
        let m = server.metrics().unwrap();
        assert_eq!(m.completed, 6);
    }

    #[test]
    fn streamed_tokens_match_final_response() {
        let server = host_server(ServerConfig::default());
        let stream = server
            .submit(vec![1, 2, 3, 4, 5], GenParams { max_new_tokens: 8, ..GenParams::default() })
            .unwrap();
        let mut streamed = Vec::new();
        loop {
            match stream.recv() {
                StreamEvent::Token { index, token } => {
                    assert_eq!(index, streamed.len(), "token indices are gap-free");
                    streamed.push(token);
                }
                StreamEvent::Done(resp) => {
                    assert_eq!(streamed, resp.tokens, "stream equals final response");
                    break;
                }
                StreamEvent::Error(e) => panic!("unexpected stream error: {e}"),
            }
        }
        assert_eq!(streamed.len(), 8);
    }

    #[test]
    fn rejects_bad_prompt_with_typed_error_without_killing_engine() {
        let server = host_server(ServerConfig::default());
        let err = server.submit(vec![1; 1000], GenParams::default());
        assert!(matches!(err, Err(ServeError::Rejected(_))), "got {err:?}");
        // engine still alive and serving
        let stream = server
            .submit(vec![1, 2, 3], GenParams { max_new_tokens: 2, ..GenParams::default() })
            .unwrap();
        assert_eq!(stream.wait().unwrap().tokens.len(), 2);
    }

    #[test]
    fn engine_failure_reaches_every_waiter() {
        // enough successful steps to admit everyone, then the backend
        // dies mid-decode
        let server = Server::with_backend(
            Box::new(FailingBackend::new(6)),
            EngineConfig::default(),
            ServerConfig::default(),
        );
        let p = GenParams { max_new_tokens: 12, ..GenParams::default() };
        let streams: Vec<_> =
            (0..3).map(|i| server.submit(vec![i + 1; 4], p).unwrap()).collect();
        for stream in streams {
            // every waiter must terminate — with the typed engine
            // failure, never a hang or a bare disconnect
            loop {
                match stream.recv_timeout(WAIT).expect("no-hang contract") {
                    StreamEvent::Token { .. } => continue,
                    StreamEvent::Done(_) => panic!("backend dies before 12 tokens"),
                    StreamEvent::Error(ServeError::EngineFailed(msg)) => {
                        assert!(msg.contains("injected backend failure"), "got: {msg}");
                        break;
                    }
                    StreamEvent::Error(e) => panic!("wrong error: {e}"),
                }
            }
        }
        // submissions after death get a typed error too
        let late = server.submit(vec![1, 2], GenParams::default());
        assert!(late.is_err());
    }

    #[test]
    fn drop_while_busy_delivers_typed_abort() {
        let server = host_server(ServerConfig::default());
        let p = GenParams { max_new_tokens: 64, ..GenParams::default() };
        let streams: Vec<_> =
            (0..4).map(|i| server.submit(vec![i + 1; 6], p).unwrap()).collect();
        drop(server); // shutdown with requests almost certainly mid-flight
        for stream in streams {
            // each stream must still terminate: Done if it won the
            // race, else a typed Aborted — never a hang
            loop {
                match stream.recv_timeout(WAIT).expect("no-hang contract") {
                    StreamEvent::Token { .. } => continue,
                    StreamEvent::Done(_) | StreamEvent::Error(ServeError::Aborted) => break,
                    StreamEvent::Error(e) => panic!("wrong error: {e}"),
                }
            }
        }
    }

    #[test]
    fn cancel_mid_generation_frees_pages_and_aborts_stream() {
        let server = host_server(ServerConfig::default());
        let p = GenParams { max_new_tokens: 64, ..GenParams::default() };
        let victim = server.submit(vec![1, 2, 3, 4], p).unwrap();
        assert!(server.cancel(victim.id()).unwrap(), "in-flight request is live");
        // the stream terminates with the typed abort (possibly after
        // tokens generated before the cancel landed), never Done
        loop {
            match victim.recv_timeout(WAIT).expect("no-hang contract") {
                StreamEvent::Token { .. } => continue,
                StreamEvent::Error(ServeError::Aborted) => break,
                ev => panic!("cancelled stream ended with {ev:?}"),
            }
        }
        // its pages are free again (no prefix sharing here) and the
        // engine is still serving
        let m = server.metrics().unwrap();
        assert_eq!(m.completed, 0);
        assert_eq!(m.pages_used, 0, "cancel released the victim's pages");
        let after = server
            .submit(vec![5, 6, 7], GenParams { max_new_tokens: 2, ..GenParams::default() })
            .unwrap();
        assert_eq!(after.wait().unwrap().tokens.len(), 2);
    }

    #[test]
    fn cancel_unknown_or_finished_is_noop() {
        let server = host_server(ServerConfig::default());
        assert!(!server.cancel(999).unwrap(), "unknown id");
        let stream = server
            .submit(vec![1, 2, 3], GenParams { max_new_tokens: 2, ..GenParams::default() })
            .unwrap();
        let id = stream.id();
        let resp = stream.wait().unwrap();
        assert_eq!(resp.tokens.len(), 2);
        assert!(!server.cancel(id).unwrap(), "finished request cancels as a no-op");
    }

    #[test]
    fn overload_returns_typed_backpressure() {
        let server = host_server(ServerConfig { max_pending: 1, max_cmds_per_step: 32 });
        let p = GenParams { max_new_tokens: 48, ..GenParams::default() };
        let first = server.submit(vec![1, 2, 3, 4], p).unwrap();
        // the first request needs ~50 engine steps; this submit lands
        // long before that, while the waiter table is full
        let second = server.submit(vec![5, 6, 7], p);
        assert!(
            matches!(second, Err(ServeError::Overloaded { limit: 1 })),
            "got {second:?}"
        );
        first.wait().unwrap();
    }

    #[test]
    fn submit_flood_does_not_starve_decode() {
        let server = std::sync::Arc::new(host_server(ServerConfig {
            max_pending: 4,
            max_cmds_per_step: 4,
        }));
        let probe = server
            .submit(vec![7, 8, 9], GenParams { max_new_tokens: 16, ..GenParams::default() })
            .unwrap();
        // sustained flood from another thread: every submission past
        // the pending cap bounces with Overloaded, but the bounded
        // drain keeps decode stepping underneath
        let flooder = {
            let server = std::sync::Arc::clone(&server);
            let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
            let flag = std::sync::Arc::clone(&stop);
            let h = thread::spawn(move || {
                let mut extra = Vec::new();
                while !flag.load(std::sync::atomic::Ordering::Relaxed) {
                    match server.submit(vec![1, 2], GenParams::default()) {
                        Ok(s) => extra.push(s),
                        Err(ServeError::Overloaded { .. }) => {}
                        Err(e) => panic!("flood submit failed oddly: {e}"),
                    }
                }
                extra
            });
            (stop, h)
        };
        let resp = probe.wait().expect("decode progresses under continuous submission");
        assert_eq!(resp.tokens.len(), 16);
        flooder.0.store(true, std::sync::atomic::Ordering::Relaxed);
        // every admitted flood request still terminates cleanly
        for s in flooder.1.join().unwrap() {
            s.wait().expect("flood stream completes");
        }
    }

    fn artifact_dir() -> String {
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
    }

    #[test]
    #[ignore = "requires artifacts/ bundle (build with python/compile/aot.py)"]
    fn serves_concurrent_clients_from_artifacts() {
        let server = Server::start(artifact_dir(), EngineConfig::default()).unwrap();
        let p = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
        let waits: Vec<_> = (0..6)
            .map(|i| {
                let prompt = vec![(i % 50) as i32 + 1; (i % 9) + 1];
                server.submit(prompt, p).unwrap()
            })
            .collect();
        for stream in waits {
            let resp = stream.wait().unwrap();
            assert_eq!(resp.tokens.len(), 3);
        }
        let m = server.metrics().unwrap();
        assert_eq!(m.completed, 6);
    }

    #[test]
    #[ignore = "requires artifacts/ bundle (build with python/compile/aot.py)"]
    fn rejects_bad_prompt_from_artifacts() {
        let server = Server::start(artifact_dir(), EngineConfig::default()).unwrap();
        let err = server.submit(vec![1; 1000], GenParams::default());
        assert!(err.is_err());
        let stream = server
            .submit(vec![1, 2, 3], GenParams { max_new_tokens: 2, ..GenParams::default() })
            .unwrap();
        assert_eq!(stream.wait().unwrap().tokens.len(), 2);
    }
}
