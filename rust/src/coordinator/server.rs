//! Threaded serving front-end.
//!
//! PJRT handles live on a single engine thread (they are not `Send`);
//! clients talk to it over channels.  `Server::submit` is non-blocking
//! and returns a receiver that yields the finished [`Response`].

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::thread::{self, JoinHandle};

use anyhow::{Context, Result};

use super::engine::{Engine, EngineConfig};
use super::request::{GenParams, RequestId, Response};
use crate::metrics::EngineMetrics;
use crate::runtime::Runtime;

enum Cmd {
    Submit {
        prompt: Vec<i32>,
        params: GenParams,
        reply: Sender<Result<RequestId, String>>,
        done: Sender<Response>,
    },
    Metrics {
        reply: Sender<EngineMetrics>,
    },
    Shutdown,
}

/// Handle to the engine thread.
pub struct Server {
    tx: Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

impl Server {
    /// Start the engine thread over the artifact directory.
    pub fn start(artifact_dir: String, cfg: EngineConfig) -> Result<Self> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let handle = thread::spawn(move || {
            let rt = match Runtime::load(&artifact_dir) {
                Ok(rt) => {
                    let _ = ready_tx.send(Ok(()));
                    rt
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            let mut engine = Engine::new(rt, cfg);
            let mut waiters: HashMap<RequestId, Sender<Response>> = HashMap::new();
            loop {
                // Drain commands; block only when fully idle.
                let cmd = if engine.active_count() == 0 && waiters.is_empty() {
                    match rx.recv() {
                        Ok(c) => Some(c),
                        Err(_) => break,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(c) => Some(c),
                        Err(TryRecvError::Empty) => None,
                        Err(TryRecvError::Disconnected) => break,
                    }
                };
                match cmd {
                    Some(Cmd::Submit { prompt, params, reply, done }) => {
                        match engine.submit(prompt, params) {
                            Ok(id) => {
                                waiters.insert(id, done);
                                let _ = reply.send(Ok(id));
                            }
                            Err(e) => {
                                let _ = reply.send(Err(format!("{e:#}")));
                            }
                        }
                        continue; // keep draining submissions greedily
                    }
                    Some(Cmd::Metrics { reply }) => {
                        let _ = reply.send(engine.metrics.clone());
                        continue;
                    }
                    Some(Cmd::Shutdown) => break,
                    None => {}
                }
                // One scheduling step, then deliver whatever finished.
                match engine.step() {
                    Ok(_) => {}
                    Err(e) => {
                        eprintln!("engine step failed: {e:#}");
                        break;
                    }
                }
                for resp in engine.take_finished() {
                    if let Some(w) = waiters.remove(&resp.id) {
                        let _ = w.send(resp);
                    }
                }
            }
        });
        ready_rx
            .recv()
            .context("engine thread died before ready")?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok(Self { tx, handle: Some(handle) })
    }

    /// Submit a prompt; returns (request id, completion receiver).
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        params: GenParams,
    ) -> Result<(RequestId, Receiver<Response>)> {
        let (reply_tx, reply_rx) = channel();
        let (done_tx, done_rx) = channel();
        self.tx
            .send(Cmd::Submit { prompt, params, reply: reply_tx, done: done_tx })
            .context("engine thread gone")?;
        let id = reply_rx
            .recv()
            .context("engine thread gone")?
            .map_err(|e| anyhow::anyhow!(e))?;
        Ok((id, done_rx))
    }

    /// Snapshot engine metrics.
    pub fn metrics(&self) -> Result<EngineMetrics> {
        let (tx, rx) = channel();
        self.tx.send(Cmd::Metrics { reply: tx }).context("engine thread gone")?;
        rx.recv().context("engine thread gone")
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifact_dir() -> Option<String> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            Some(dir.to_string())
        } else {
            None
        }
    }

    #[test]
    fn serves_concurrent_clients() {
        let Some(dir) = artifact_dir() else { return };
        let server = Server::start(dir, EngineConfig::default()).unwrap();
        let p = GenParams { max_new_tokens: 3, eos_token: None, share_prefix: false };
        let waits: Vec<_> = (0..6)
            .map(|i| {
                let prompt = vec![(i % 50) as i32 + 1; (i % 9) + 1];
                server.submit(prompt, p).unwrap()
            })
            .collect();
        for (id, rx) in waits {
            let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.tokens.len(), 3);
        }
        let m = server.metrics().unwrap();
        assert_eq!(m.completed, 6);
    }

    #[test]
    fn rejects_bad_prompt_without_killing_engine() {
        let Some(dir) = artifact_dir() else { return };
        let server = Server::start(dir, EngineConfig::default()).unwrap();
        let err = server.submit(vec![1; 1000], GenParams::default());
        assert!(err.is_err());
        // engine still alive
        let (_, rx) = server
            .submit(vec![1, 2, 3], GenParams { max_new_tokens: 2, ..GenParams::default() })
            .unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.tokens.len(), 2);
    }
}
