//! KV-cache manager: paged block-table caches, contiguous per-sequence
//! caches, batch packing, and the host/device tier accounting the
//! CPU–GPU cooperative strategy uses.
//!
//! Two layouts coexist:
//!
//! * **Contiguous** — the AOT decode artifact consumes caches of shape
//!   `[L, B, Nkv, max_seq, D]` for a fixed batch bucket `B`.  Sequences
//!   own caches of shape `[L, 1, Nkv, max_seq, D]`; `pack_batch` /
//!   `unpack_batch` move any (≤ B)-subset of sequences in and out of the
//!   batch tensor — the memcpy boundary of continuous batching.
//! * **Paged** — [`PagePool`] owns fixed-size pages of `page_size` KV
//!   rows, one page per (layer, kv-head) block; a per-sequence
//!   [`BlockTable`] maps logical token blocks to pages.  Pages are
//!   ref-counted (prefix sharing keeps a page alive across sequences)
//!   and recycled through a free list, so a 16-token sequence holds one
//!   block instead of a `max_seq` slab.  Attention gathers rows through
//!   the table (`attention::flash::KvView`), bit-identically to the
//!   contiguous layout.
//! * **Shared** — [`PrefixIndex`] layers cross-sequence prompt-prefix
//!   sharing on top of the paged layout: identical prompt prefixes
//!   occupy one ref-counted physical page run, with copy-on-write
//!   splits ([`BlockTable::cow_unshare`]) isolating divergent writes.

#![warn(missing_docs)]

use anyhow::{bail, Result};

/// Cache geometry (from the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheShape {
    /// Transformer layers, `L`.
    pub layers: usize,
    /// KV heads per layer, `N_kv` (GQA: `≤` query heads).
    pub kv_heads: usize,
    /// Token capacity per sequence, `S`.
    pub max_seq: usize,
    /// Elements per head row, `D`.
    pub head_dim: usize,
}

impl CacheShape {
    /// f32 elements of one sequence's K (or V) cache.
    pub fn seq_elems(&self) -> usize {
        self.layers * self.kv_heads * self.max_seq * self.head_dim
    }

    /// Elements of one layer-row within a single-sequence cache
    /// (`[Nkv, S, D]` — also the per-(layer, slot) plane of a batch
    /// tensor, which is exactly what batched decode attention consumes).
    pub fn layer_elems(&self) -> usize {
        self.kv_heads * self.max_seq * self.head_dim
    }

    /// Bytes of one sequence's full KV (K + V) cache.
    pub fn seq_bytes(&self) -> usize {
        2 * 4 * self.seq_elems()
    }

    /// Flat offset of `(layer, slot)` inside a `[L, B, Nkv, S, D]` batch
    /// plane — the start of that sequence's `[Nkv, S, D]` sub-plane.
    pub fn batch_slot_offset(&self, batch: usize, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < batch);
        (layer * batch + slot) * self.layer_elems()
    }

    /// Flat offset of `(layer, slot, kv_head, row)` inside a batch plane
    /// — where a decode step writes the new token's K/V row.
    pub fn batch_row_offset(
        &self,
        batch: usize,
        layer: usize,
        slot: usize,
        kv_head: usize,
        row: usize,
    ) -> usize {
        debug_assert!(kv_head < self.kv_heads && row < self.max_seq);
        self.batch_slot_offset(batch, layer, slot)
            + (kv_head * self.max_seq + row) * self.head_dim
    }
}

/// One sequence's KV cache (K and V planes, flat f32, `[L,1,Nkv,S,D]`).
#[derive(Debug, Clone)]
pub struct SeqCache {
    /// Geometry of both planes.
    pub shape: CacheShape,
    /// K plane, flat f32.
    pub k: Vec<f32>,
    /// V plane, flat f32.
    pub v: Vec<f32>,
}

impl SeqCache {
    /// Zero-initialized cache (a fresh slot).
    pub fn zeros(shape: CacheShape) -> Self {
        let n = shape.seq_elems();
        Self { shape, k: vec![0.0; n], v: vec![0.0; n] }
    }
}

/// Pack `seqs` (each `[L,1,Nkv,S,D]`) into a `[L,B,Nkv,S,D]` batch plane.
/// Unused slots stay zero.  Returns the flat batch tensor.
pub fn pack_batch(
    shape: CacheShape,
    batch: usize,
    seqs: &[(usize, &[f32])],
) -> Result<Vec<f32>> {
    let le = shape.layer_elems();
    let mut out = vec![0.0f32; shape.layers * batch * le];
    for &(slot, data) in seqs {
        if slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &data[layer * le..][..le];
            let dst = &mut out[(layer * batch + slot) * le..][..le];
            dst.copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Scatter a `[L,B,Nkv,S,D]` batch plane back into per-sequence caches.
pub fn unpack_batch(
    shape: CacheShape,
    batch: usize,
    plane: &[f32],
    seqs: &mut [(usize, &mut [f32])],
) -> Result<()> {
    let le = shape.layer_elems();
    if plane.len() != shape.layers * batch * le {
        bail!("batch plane has {} elems, expected {}", plane.len(), shape.layers * batch * le);
    }
    for (slot, data) in seqs.iter_mut() {
        if *slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &plane[(layer * batch + *slot) * le..][..le];
            data[layer * le..][..le].copy_from_slice(src);
        }
    }
    Ok(())
}

/// Placement tier for KV memory (§4.4): a whole contiguous layer cache
/// under the legacy [`CachePool`], or a single page/block under the
/// tiered paged cache ([`TieredPagePool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Device (GPU/NPU) resident.
    Device,
    /// Host (CPU) resident — the cooperative strategy's pre-L_CPU layers.
    Host,
}

/// Capacity-tracking cache pool with per-tier accounting.
#[derive(Debug)]
pub struct CachePool {
    /// Per-sequence cache geometry the pool hands out.
    pub shape: CacheShape,
    device_budget_bytes: usize,
    device_used_bytes: usize,
    host_used_bytes: usize,
    active: usize,
}

impl CachePool {
    /// An empty pool over `device_budget_bytes` of device memory.
    pub fn new(shape: CacheShape, device_budget_bytes: usize) -> Self {
        Self {
            shape,
            device_budget_bytes,
            device_used_bytes: 0,
            host_used_bytes: 0,
            active: 0,
        }
    }

    /// Can another sequence's cache be placed on-device?
    pub fn has_device_room(&self) -> bool {
        self.device_used_bytes + self.shape.seq_bytes() <= self.device_budget_bytes
    }

    /// Allocate a cache; spills to Host when the device is full (the
    /// engine treats Host-tier caches via the cooperative path).
    pub fn allocate(&mut self) -> (SeqCache, Tier) {
        let tier = if self.has_device_room() { Tier::Device } else { Tier::Host };
        match tier {
            Tier::Device => self.device_used_bytes += self.shape.seq_bytes(),
            Tier::Host => self.host_used_bytes += self.shape.seq_bytes(),
        }
        self.active += 1;
        (SeqCache::zeros(self.shape), tier)
    }

    /// Release a cache allocated at `tier`.
    pub fn release(&mut self, tier: Tier) {
        match tier {
            Tier::Device => {
                self.device_used_bytes =
                    self.device_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
            Tier::Host => {
                self.host_used_bytes =
                    self.host_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
        }
        self.active = self.active.saturating_sub(1);
    }

    /// Live caches (both tiers).
    pub fn active(&self) -> usize {
        self.active
    }

    /// Bytes currently placed on the device tier.
    pub fn device_used_bytes(&self) -> usize {
        self.device_used_bytes
    }

    /// Bytes spilled to the host tier.
    pub fn host_used_bytes(&self) -> usize {
        self.host_used_bytes
    }
}

// ---------------------------------------------------------------------
// Paged KV: PagePool + BlockTable
// ---------------------------------------------------------------------

/// Marker for an unallocated block-table slot.
pub const NO_PAGE: u32 = u32::MAX;

/// How KV rows are encoded inside a page — the page pool's element
/// codec.  Both tiers of a [`TieredPagePool`] share one codec (pages
/// migrate by memcpy, never transcoding), and the gather kernels select
/// the matching fused path from the view variant
/// (`attention::flash::KvView`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PageCodec {
    /// 4-byte floats — bit-identical to the pre-codec layout.
    #[default]
    F32,
    /// Symmetric per-row int8: each K/V row stores `head_dim` bytes
    /// plus one f32 scale (`max|x| / 127`), quartering the row payload
    /// at large `head_dim`.  Dequantization is fused into the gather —
    /// a decoded f32 row is never materialized.
    Int8,
}

impl PageCodec {
    /// Bytes of one encoded K or V row, scale side-channel included.
    pub fn row_bytes(self, head_dim: usize) -> usize {
        match self {
            PageCodec::F32 => 4 * head_dim,
            PageCodec::Int8 => head_dim + 4,
        }
    }
}

/// Bytes of one KV page (K + V rows) under `codec` — the single source
/// of truth for page sizing: pool budgets, migration accounting and the
/// offload page planner all go through it.
pub fn kv_page_bytes_codec(page_size: usize, head_dim: usize, codec: PageCodec) -> usize {
    2 * page_size * codec.row_bytes(head_dim)
}

/// Bytes of one f32 KV page — [`kv_page_bytes_codec`] at
/// [`PageCodec::F32`], kept as the legacy spelling.
pub fn kv_page_bytes(page_size: usize, head_dim: usize) -> usize {
    kv_page_bytes_codec(page_size, head_dim, PageCodec::F32)
}

/// One int8 row store with its per-row scale side-channel: `q` is
/// `[num_pages, page_size, head_dim]` flat i8 and `scales` is
/// `[num_pages, page_size]` — one f32 per encoded row.  Row `r` of page
/// `p` decodes as `q[(p*page_size + r)*head_dim + t] as f32 *
/// scales[p*page_size + r]`.
#[derive(Debug, Clone, Copy)]
pub struct QuantStore<'a> {
    /// Quantized rows, `[num_pages, page_size, head_dim]` flat.
    pub q: &'a [i8],
    /// Per-row dequantization scales, `[num_pages, page_size]` flat.
    pub scales: &'a [f32],
}

/// Symmetric per-row int8 quantization: `scale = max|x| / 127` (1.0 for
/// an all-zero row), `q = round(x / scale)` clamped to ±127.  Returns
/// the scale; worst-case dequantization error is `scale / 2 =
/// max|x| / 254` per element.
pub fn quantize_row_i8(row: &[f32], q: &mut [i8]) -> f32 {
    let mut max_abs = 0.0f32;
    for &x in row {
        max_abs = max_abs.max(x.abs());
    }
    let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for (qi, &x) in q.iter_mut().zip(row) {
        *qi = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// Why a page allocation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageAllocError {
    /// The pool's free list is empty — the caller should preempt a
    /// sequence (or shed load) and retry.
    OutOfPages,
    /// The sequence would exceed its `max_seq` block budget.
    ExceedsMaxSeq,
    /// The block's pages are shared (ref count > 1): shared pages are
    /// pinned to the device tier until the count drops to 1, because
    /// every other holder's table would keep indexing the device store.
    SharedPage,
}

impl std::fmt::Display for PageAllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::OutOfPages => write!(f, "KV page pool exhausted"),
            Self::ExceedsMaxSeq => write!(f, "sequence exceeds max_seq block budget"),
            Self::SharedPage => write!(f, "page is shared (ref count > 1) and pinned to device"),
        }
    }
}

impl std::error::Error for PageAllocError {}

/// A fixed-size page allocator for KV rows.
///
/// One page holds `page_size` rows of `head_dim` f32 for K and the same
/// for V, and belongs to exactly one (layer, kv-head) plane of one
/// sequence block (ownership is the [`BlockTable`]'s — the pool only
/// tracks ref counts).  `refs == 0` pages sit on the free list.
///
/// ```
/// use fastattn::coordinator::kv_cache::PagePool;
///
/// let mut pool = PagePool::new(16, 8, 4); // 4 pages × 16 rows × d = 8
/// let page = pool.alloc().unwrap();
/// pool.retain(page); // a second holder — prefix sharing
/// pool.release(page);
/// assert_eq!(pool.used_pages(), 1, "still referenced by one holder");
/// pool.release(page);
/// assert_eq!(pool.free_pages(), 4);
/// ```
#[derive(Debug)]
pub struct PagePool {
    page_size: usize,
    head_dim: usize,
    codec: PageCodec,
    /// `[num_pages, page_size, head_dim]` flat K rows (`F32` codec;
    /// empty under `Int8`).
    k: Vec<f32>,
    /// Same shape, V rows.
    v: Vec<f32>,
    /// `[num_pages, page_size, head_dim]` flat int8 K rows (`Int8`
    /// codec; empty under `F32`).
    kq: Vec<i8>,
    /// Same shape, int8 V rows.
    vq: Vec<i8>,
    /// `[num_pages, page_size]` per-row K scales (`Int8` codec).
    k_scale: Vec<f32>,
    /// Same shape, V scales.
    v_scale: Vec<f32>,
    refs: Vec<u32>,
    free: Vec<u32>,
}

impl PagePool {
    /// A pool of `num_pages` zeroed f32 pages of `page_size` rows ×
    /// `head_dim` — [`Self::with_codec`] at [`PageCodec::F32`].
    pub fn new(page_size: usize, head_dim: usize, num_pages: usize) -> Self {
        Self::with_codec(page_size, head_dim, num_pages, PageCodec::F32)
    }

    /// A pool of `num_pages` zeroed pages encoded with `codec`.
    pub fn with_codec(
        page_size: usize,
        head_dim: usize,
        num_pages: usize,
        codec: PageCodec,
    ) -> Self {
        assert!(page_size >= 1, "page_size must be >= 1");
        assert!(head_dim >= 1, "head_dim must be >= 1");
        assert!(num_pages <= NO_PAGE as usize, "num_pages overflows page id space");
        let elems = num_pages * page_size * head_dim;
        let rows = num_pages * page_size;
        let (f32_elems, i8_elems, scale_elems) = match codec {
            PageCodec::F32 => (elems, 0, 0),
            PageCodec::Int8 => (0, elems, rows),
        };
        Self {
            page_size,
            head_dim,
            codec,
            k: vec![0.0; f32_elems],
            v: vec![0.0; f32_elems],
            kq: vec![0; i8_elems],
            vq: vec![0; i8_elems],
            k_scale: vec![1.0; scale_elems],
            v_scale: vec![1.0; scale_elems],
            refs: vec![0; num_pages],
            // LIFO free list, lowest ids on top.
            free: (0..num_pages as u32).rev().collect(),
        }
    }

    /// Size the pool for a device budget: as many pages as
    /// `budget_bytes` holds at f32 K+V rows (at least one).
    pub fn for_budget(shape: CacheShape, page_size: usize, budget_bytes: usize) -> Self {
        Self::for_budget_codec(shape, page_size, budget_bytes, PageCodec::F32)
    }

    /// Size the pool for a device budget under `codec`: the smaller
    /// int8 pages mean the same byte budget holds ~4× the tokens.
    pub fn for_budget_codec(
        shape: CacheShape,
        page_size: usize,
        budget_bytes: usize,
        codec: PageCodec,
    ) -> Self {
        let page_bytes = kv_page_bytes_codec(page_size, shape.head_dim, codec);
        let num_pages = (budget_bytes / page_bytes.max(1)).max(1);
        Self::with_codec(page_size, shape.head_dim, num_pages, codec)
    }

    /// The pool's element codec.
    pub fn codec(&self) -> PageCodec {
        self.codec
    }

    /// Token rows per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Elements per row.
    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Total pages in the pool.
    pub fn num_pages(&self) -> usize {
        self.refs.len()
    }

    /// Pages on the free list.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages with at least one reference.
    pub fn used_pages(&self) -> usize {
        self.num_pages() - self.free_pages()
    }

    /// Fraction of pages in use, 0.0 ..= 1.0.
    pub fn occupancy(&self) -> f64 {
        if self.refs.is_empty() {
            return 0.0;
        }
        self.used_pages() as f64 / self.num_pages() as f64
    }

    /// Bytes of one page (K + V, scale side-channel included).
    pub fn page_bytes(&self) -> usize {
        kv_page_bytes_codec(self.page_size, self.head_dim, self.codec)
    }

    /// Allocate one page (`refs = 1`).  Page contents are stale — the
    /// paged attention contract is that rows `< kv_len` are written
    /// before they are read, and rows `>= kv_len` are never read.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        self.refs[id as usize] = 1;
        Some(id)
    }

    /// Bump a page's ref count (prefix sharing across sequences).
    pub fn retain(&mut self, id: u32) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "retain of free page {id}");
        *r += 1;
    }

    /// Drop one reference; the page returns to the free list at zero.
    pub fn release(&mut self, id: u32) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "release of free page {id}");
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
        }
    }

    /// Reference count of a page (0 = free).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs[id as usize]
    }

    /// Allocate a fresh page and copy `src`'s full contents into it —
    /// the copy-on-write split primitive.  The clone starts at
    /// `refs = 1`; `src` keeps its own count.  `None` when the pool is
    /// exhausted.
    pub fn clone_page(&mut self, src: u32) -> Option<u32> {
        debug_assert!(self.refs[src as usize] > 0, "clone of free page {src}");
        let dst = self.alloc()?;
        let n = self.page_size * self.head_dim;
        let (s, d) = (src as usize * n, dst as usize * n);
        match self.codec {
            PageCodec::F32 => {
                self.k.copy_within(s..s + n, d);
                self.v.copy_within(s..s + n, d);
            }
            PageCodec::Int8 => {
                self.kq.copy_within(s..s + n, d);
                self.vq.copy_within(s..s + n, d);
                let m = self.page_size;
                let (ss, sd) = (src as usize * m, dst as usize * m);
                self.k_scale.copy_within(ss..ss + m, sd);
                self.v_scale.copy_within(ss..ss + m, sd);
            }
        }
        Some(dst)
    }

    /// The flat K row store (`[num_pages, page_size, head_dim]`) —
    /// what `KvView::Paged` gathers from.  Empty under the `Int8`
    /// codec; int8 pools gather through [`Self::k_quant_store`].
    pub fn k_store(&self) -> &[f32] {
        &self.k
    }

    /// The flat V row store, same shape.
    pub fn v_store(&self) -> &[f32] {
        &self.v
    }

    /// The int8 K row store with its scale side-channel — what
    /// `KvView::PagedI8` gathers from.  Empty under the `F32` codec.
    pub fn k_quant_store(&self) -> QuantStore<'_> {
        QuantStore { q: &self.kq, scales: &self.k_scale }
    }

    /// The int8 V row store with its scale side-channel, same shape.
    pub fn v_quant_store(&self) -> QuantStore<'_> {
        QuantStore { q: &self.vq, scales: &self.v_scale }
    }

    /// Write one token's K and V rows into `slot` of `page`, encoding
    /// through the pool codec (quantize-on-append for `Int8`).
    pub fn write_row(&mut self, page: u32, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size, "slot {slot} out of page");
        debug_assert!(self.refs[page as usize] > 0, "write to free page {page}");
        let d = self.head_dim;
        let row = page as usize * self.page_size + slot;
        let at = row * d;
        match self.codec {
            PageCodec::F32 => {
                self.k[at..at + d].copy_from_slice(&k_row[..d]);
                self.v[at..at + d].copy_from_slice(&v_row[..d]);
            }
            PageCodec::Int8 => {
                self.k_scale[row] = quantize_row_i8(&k_row[..d], &mut self.kq[at..at + d]);
                self.v_scale[row] = quantize_row_i8(&v_row[..d], &mut self.vq[at..at + d]);
            }
        }
    }

    /// Decode one K row back to f32 — a test/diagnostic path (the hot
    /// gather streams the stores directly through `KvView`).
    pub fn k_row_f32(&self, page: u32, slot: usize) -> Vec<f32> {
        self.row_f32(&self.k, &self.kq, &self.k_scale, page, slot)
    }

    /// Decode one V row back to f32, same contract.
    pub fn v_row_f32(&self, page: u32, slot: usize) -> Vec<f32> {
        self.row_f32(&self.v, &self.vq, &self.v_scale, page, slot)
    }

    fn row_f32(
        &self,
        f: &[f32],
        q: &[i8],
        scales: &[f32],
        page: u32,
        slot: usize,
    ) -> Vec<f32> {
        let d = self.head_dim;
        let row = page as usize * self.page_size + slot;
        match self.codec {
            PageCodec::F32 => f[row * d..][..d].to_vec(),
            PageCodec::Int8 => {
                let s = scales[row];
                q[row * d..][..d].iter().map(|&x| x as f32 * s).collect()
            }
        }
    }
}

// ---------------------------------------------------------------------
// Tiered paged KV: PcieLink + TieredPagePool
// ---------------------------------------------------------------------

/// Modeled host↔device interconnect that cold-page migration is charged
/// to: a fixed per-transfer setup latency plus bytes over an effective
/// bandwidth.  Batched moves (one block group = `layers × kv_heads`
/// pages) pay the latency once, which is why the engine migrates whole
/// blocks rather than single pages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcieLink {
    /// Effective bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-transfer setup latency, seconds.
    pub latency_s: f64,
}

impl Default for PcieLink {
    /// PCIe 3.0 ×16 as calibrated from the paper's Table 3 — the same
    /// ~11.7 GB/s effective bandwidth and 22 µs setup latency that
    /// `sim::volta::VoltaSpec` uses (see `coordinator::offload`).
    fn default() -> Self {
        Self { bandwidth_bps: 11.7e9, latency_s: 22e-6 }
    }
}

impl PcieLink {
    /// A link with the given effective bandwidth and setup latency.
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        Self { bandwidth_bps, latency_s }
    }

    /// Modeled seconds to move `bytes` as one batched transfer.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps.max(1.0)
    }
}

/// Cumulative migration accounting of a [`TieredPagePool`], both
/// directions: cold-page offload and swap-out run device→host,
/// promotion and swap-in restore run host→device.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct MigrationStats {
    /// Pages moved device→host.
    pub pages_moved: u64,
    /// Batched device→host transfers (one link charge each).
    pub batches: u64,
    /// Bytes moved device→host over the modeled link.
    pub bytes_moved: u64,
    /// Modeled link seconds charged (`PcieLink::transfer_s` per batched
    /// transfer, both directions).
    pub modeled_s: f64,
    /// Pages moved host→device (promotion / swap-in restore).
    pub pages_promoted: u64,
    /// Batched host→device transfers (one link charge each).
    pub promotions: u64,
    /// Bytes moved host→device over the modeled link.
    pub promoted_bytes: u64,
    /// Transfers (either direction) that folded two or more block
    /// groups — possibly from several sequences — into one link charge
    /// (the cross-sequence batching that amortizes setup latency).
    pub grouped_transfers: u64,
}

/// Page moves accumulated between [`TieredPagePool::begin_batched_transfer`]
/// and [`TieredPagePool::commit_batched_transfer`], per direction, so a
/// multi-block (even multi-sequence) move pays the link setup latency
/// once.
#[derive(Debug, Default, Clone, Copy)]
struct PendingTransfer {
    /// Device→host pages and the block-group charges folded in.
    out_pages: usize,
    out_groups: usize,
    /// Host→device pages and the block-group charges folded in.
    in_pages: usize,
    in_groups: usize,
}

/// The two-tier paged KV cache: a device-resident [`PagePool`] that all
/// new blocks allocate from, plus a host-resident pool that cold pages
/// migrate to over the modeled [`PcieLink`].  Page ids are per-pool; a
/// [`BlockTable`]'s per-entry [`Tier`] tag says which pool an id indexes.
///
/// A `host_pages == 0` pool degenerates to the single-tier behavior:
/// migration always refuses and callers fall back to preemption.
#[derive(Debug)]
pub struct TieredPagePool {
    device: PagePool,
    host: PagePool,
    link: PcieLink,
    stats: MigrationStats,
    /// `Some` while a batched transfer is open: per-page charges fold
    /// into it instead of paying their own link setup latency.
    pending: Option<PendingTransfer>,
}

impl TieredPagePool {
    /// Device and host pools of `device_pages` / `host_pages` pages
    /// joined by the modeled `link`.
    pub fn new(
        page_size: usize,
        head_dim: usize,
        device_pages: usize,
        host_pages: usize,
        link: PcieLink,
    ) -> Self {
        Self::new_with_codec(page_size, head_dim, device_pages, host_pages, link, PageCodec::F32)
    }

    /// Device and host pools sharing one page `codec` — migration moves
    /// encoded bytes verbatim, so the host tier inherits the int8
    /// compression for free (every swap/offload moves ~4× fewer bytes).
    pub fn new_with_codec(
        page_size: usize,
        head_dim: usize,
        device_pages: usize,
        host_pages: usize,
        link: PcieLink,
        codec: PageCodec,
    ) -> Self {
        Self {
            device: PagePool::with_codec(page_size, head_dim, device_pages, codec),
            host: PagePool::with_codec(page_size, head_dim, host_pages, codec),
            link,
            stats: MigrationStats::default(),
            pending: None,
        }
    }

    /// Size both tiers from byte budgets.  The device tier always holds
    /// at least one page; `host_budget_bytes` smaller than a page means
    /// no host tier at all.
    pub fn for_budget(
        shape: CacheShape,
        page_size: usize,
        device_budget_bytes: usize,
        host_budget_bytes: usize,
        link: PcieLink,
    ) -> Self {
        Self::for_budget_codec(
            shape,
            page_size,
            device_budget_bytes,
            host_budget_bytes,
            link,
            PageCodec::F32,
        )
    }

    /// [`Self::for_budget`] with an explicit page codec: the same byte
    /// budgets hold ~4× the pages under [`PageCodec::Int8`].
    pub fn for_budget_codec(
        shape: CacheShape,
        page_size: usize,
        device_budget_bytes: usize,
        host_budget_bytes: usize,
        link: PcieLink,
        codec: PageCodec,
    ) -> Self {
        let page_bytes = kv_page_bytes_codec(page_size, shape.head_dim, codec);
        let device_pages = (device_budget_bytes / page_bytes.max(1)).max(1);
        let host_pages = host_budget_bytes / page_bytes.max(1);
        Self::new_with_codec(page_size, shape.head_dim, device_pages, host_pages, link, codec)
    }

    /// The element codec shared by both tiers.
    pub fn codec(&self) -> PageCodec {
        self.device.codec
    }

    /// The device-tier pool.
    pub fn device(&self) -> &PagePool {
        &self.device
    }

    /// The device pool — what [`BlockTable::ensure_capacity`] allocates
    /// new blocks from (fresh rows are always written device-side).
    pub fn device_mut(&mut self) -> &mut PagePool {
        &mut self.device
    }

    /// The host-tier pool (cold pages).
    pub fn host(&self) -> &PagePool {
        &self.host
    }

    /// The pool backing `tier`.
    pub fn pool(&self, tier: Tier) -> &PagePool {
        match tier {
            Tier::Device => &self.device,
            Tier::Host => &self.host,
        }
    }

    fn pool_mut(&mut self, tier: Tier) -> &mut PagePool {
        match tier {
            Tier::Device => &mut self.device,
            Tier::Host => &mut self.host,
        }
    }

    /// Token rows per page, identical in both tiers.
    pub fn page_size(&self) -> usize {
        self.device.page_size()
    }

    /// Elements per row, identical in both tiers.
    pub fn head_dim(&self) -> usize {
        self.device.head_dim()
    }

    /// Bytes of one page (K + V), identical in both tiers.
    pub fn page_bytes(&self) -> usize {
        self.device.page_bytes()
    }

    /// Pages across both tiers.
    pub fn total_pages(&self) -> usize {
        self.device.num_pages() + self.host.num_pages()
    }

    /// Free pages across both tiers.
    pub fn free_pages_total(&self) -> usize {
        self.device.free_pages() + self.host.free_pages()
    }

    /// The modeled host↔device interconnect.
    pub fn link(&self) -> PcieLink {
        self.link
    }

    /// Cumulative migration accounting.
    pub fn stats(&self) -> MigrationStats {
        self.stats
    }

    /// K row store of one tier (`[num_pages, page_size, head_dim]`).
    pub fn k_store(&self, tier: Tier) -> &[f32] {
        self.pool(tier).k_store()
    }

    /// V row store of one tier, same shape.
    pub fn v_store(&self, tier: Tier) -> &[f32] {
        self.pool(tier).v_store()
    }

    /// Int8 K row store + scales of one tier (`Int8` codec).
    pub fn k_quant_store(&self, tier: Tier) -> QuantStore<'_> {
        self.pool(tier).k_quant_store()
    }

    /// Int8 V row store + scales of one tier, same shape.
    pub fn v_quant_store(&self, tier: Tier) -> QuantStore<'_> {
        self.pool(tier).v_quant_store()
    }

    /// Write one token's K/V rows into `slot` of `page` on `tier`.
    /// Fresh blocks live device-side, but writes into already-migrated
    /// blocks (a chunked prefill filling a cold tail) land on host.
    pub fn write_row(&mut self, tier: Tier, page: u32, slot: usize, k_row: &[f32], v_row: &[f32]) {
        self.pool_mut(tier).write_row(page, slot, k_row, v_row);
    }

    /// Move one device page's rows onto a freshly allocated host page;
    /// the device page returns to its free list.  Accounting is the
    /// caller's ([`Self::charge_batch`]) so a multi-page block move is
    /// charged one PCIe setup latency.
    fn offload_page(&mut self, device_page: u32) -> Option<u32> {
        debug_assert_eq!(
            self.device.ref_count(device_page),
            1,
            "migrating a shared page would break the other holder's mapping"
        );
        let host_page = self.host.alloc()?;
        let n = self.device.page_size * self.device.head_dim;
        let src = device_page as usize * n;
        let dst = host_page as usize * n;
        match self.device.codec {
            PageCodec::F32 => {
                self.host.k[dst..dst + n].copy_from_slice(&self.device.k[src..src + n]);
                self.host.v[dst..dst + n].copy_from_slice(&self.device.v[src..src + n]);
            }
            PageCodec::Int8 => {
                self.host.kq[dst..dst + n].copy_from_slice(&self.device.kq[src..src + n]);
                self.host.vq[dst..dst + n].copy_from_slice(&self.device.vq[src..src + n]);
                let m = self.device.page_size;
                let (ss, sd) = (device_page as usize * m, host_page as usize * m);
                self.host.k_scale[sd..sd + m]
                    .copy_from_slice(&self.device.k_scale[ss..ss + m]);
                self.host.v_scale[sd..sd + m]
                    .copy_from_slice(&self.device.v_scale[ss..ss + m]);
            }
        }
        self.device.release(device_page);
        Some(host_page)
    }

    /// Move one host page's rows onto a freshly allocated device page
    /// (the reverse of [`Self::offload_page`]): promotion and swap-in
    /// restore.  The host page returns to its free list.  Accounting is
    /// the caller's ([`Self::charge_promotion`]).
    fn promote_page(&mut self, host_page: u32) -> Option<u32> {
        debug_assert_eq!(
            self.host.ref_count(host_page),
            1,
            "host pages are never shared — promotion expects a sole holder"
        );
        let device_page = self.device.alloc()?;
        let n = self.device.page_size * self.device.head_dim;
        let src = host_page as usize * n;
        let dst = device_page as usize * n;
        match self.device.codec {
            PageCodec::F32 => {
                self.device.k[dst..dst + n].copy_from_slice(&self.host.k[src..src + n]);
                self.device.v[dst..dst + n].copy_from_slice(&self.host.v[src..src + n]);
            }
            PageCodec::Int8 => {
                self.device.kq[dst..dst + n].copy_from_slice(&self.host.kq[src..src + n]);
                self.device.vq[dst..dst + n].copy_from_slice(&self.host.vq[src..src + n]);
                let m = self.device.page_size;
                let (ss, sd) = (host_page as usize * m, device_page as usize * m);
                self.device.k_scale[sd..sd + m]
                    .copy_from_slice(&self.host.k_scale[ss..ss + m]);
                self.device.v_scale[sd..sd + m]
                    .copy_from_slice(&self.host.v_scale[ss..ss + m]);
            }
        }
        self.host.release(host_page);
        Some(device_page)
    }

    /// Open a batched transfer: until [`Self::commit_batched_transfer`],
    /// per-block charges (either direction) accumulate instead of each
    /// paying the link setup latency — one multi-block move, possibly
    /// spanning several sequences, is then charged as one transfer per
    /// direction.
    pub fn begin_batched_transfer(&mut self) {
        debug_assert!(self.pending.is_none(), "nested batched transfer");
        self.pending = Some(PendingTransfer::default());
    }

    /// Close the open batched transfer and charge everything
    /// accumulated since [`Self::begin_batched_transfer`] as one link
    /// transfer per direction.  A no-op when nothing is open or nothing
    /// moved.
    pub fn commit_batched_transfer(&mut self) {
        let Some(p) = self.pending.take() else { return };
        if p.out_pages > 0 {
            self.charge_out(p.out_pages, p.out_groups);
        }
        if p.in_pages > 0 {
            self.charge_in(p.in_pages, p.in_groups);
        }
    }

    /// Charge one batched `pages`-page device→host move to the link
    /// model, or fold it into the open batched transfer.
    fn charge_batch(&mut self, pages: usize) {
        if pages == 0 {
            return;
        }
        if let Some(p) = &mut self.pending {
            p.out_pages += pages;
            p.out_groups += 1;
            return;
        }
        self.charge_out(pages, 1);
    }

    /// Charge one batched `pages`-page host→device move to the link
    /// model, or fold it into the open batched transfer.
    fn charge_promotion(&mut self, pages: usize) {
        if pages == 0 {
            return;
        }
        if let Some(p) = &mut self.pending {
            p.in_pages += pages;
            p.in_groups += 1;
            return;
        }
        self.charge_in(pages, 1);
    }

    fn charge_out(&mut self, pages: usize, groups: usize) {
        let bytes = pages * self.page_bytes();
        self.stats.pages_moved += pages as u64;
        self.stats.batches += 1;
        self.stats.bytes_moved += bytes as u64;
        self.stats.modeled_s += self.link.transfer_s(bytes);
        if groups >= 2 {
            self.stats.grouped_transfers += 1;
        }
    }

    fn charge_in(&mut self, pages: usize, groups: usize) {
        let bytes = pages * self.page_bytes();
        self.stats.pages_promoted += pages as u64;
        self.stats.promotions += 1;
        self.stats.promoted_bytes += bytes as u64;
        self.stats.modeled_s += self.link.transfer_s(bytes);
        if groups >= 2 {
            self.stats.grouped_transfers += 1;
        }
    }
}

/// A sequence's logical-block → page mapping: `[layers, kv_heads,
/// max_blocks]` page ids, where block `b` covers token rows
/// `[b*page_size, (b+1)*page_size)`.  Blocks allocate as a group — one
/// page per (layer, kv-head) — so a sequence always has the same number
/// of blocks in every plane.
#[derive(Debug, Clone)]
pub struct BlockTable {
    layers: usize,
    kv_heads: usize,
    page_size: usize,
    max_blocks: usize,
    /// Allocated logical blocks (all planes).
    blocks: usize,
    table: Vec<u32>,
    /// Per-entry placement tag (parallel to `table`).  Blocks migrate
    /// as a group, so every plane of one block shares a tier.
    tiers: Vec<Tier>,
    /// Per-*block* sharing flag (`[max_blocks]`): `true` while block
    /// `b` was adopted from a shared prefix run and has not been
    /// copy-on-write-split yet.  Shared blocks are read-only for this
    /// sequence — [`Self::cow_unshare`] must run before any write lands
    /// in them.
    shared: Vec<bool>,
    /// Per-block last-gather stamp (`[max_blocks]`): the engine's
    /// monotonic gather clock at the most recent attention pass that
    /// streamed the block's rows.  Host→device promotion uses it to
    /// pick the hottest (most-recently-gathered) host blocks first.
    stamps: Vec<u64>,
}

impl BlockTable {
    /// An empty table for caches of `shape` at `page_size`-row pages.
    pub fn new(shape: CacheShape, page_size: usize) -> Self {
        assert!(page_size >= 1, "page_size must be >= 1");
        let max_blocks = shape.max_seq.div_ceil(page_size);
        Self {
            layers: shape.layers,
            kv_heads: shape.kv_heads,
            page_size,
            max_blocks,
            blocks: 0,
            table: vec![NO_PAGE; shape.layers * shape.kv_heads * max_blocks],
            tiers: vec![Tier::Device; shape.layers * shape.kv_heads * max_blocks],
            shared: vec![false; max_blocks],
            stamps: vec![0; max_blocks],
        }
    }

    /// Flat index of plane (`l`, `g`) of block `b` inside `table` /
    /// `tiers` (the `[layers, kv_heads, max_blocks]` layout's one rule).
    fn plane_at(&self, l: usize, g: usize, b: usize) -> usize {
        (l * self.kv_heads + g) * self.max_blocks + b
    }

    /// Pages a sequence of `tokens` tokens needs in total under `shape`.
    pub fn pages_needed(shape: CacheShape, page_size: usize, tokens: usize) -> usize {
        shape.layers * shape.kv_heads * tokens.div_ceil(page_size.max(1))
    }

    /// Transformer layers the table spans.
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// KV heads per layer.
    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    /// Token rows per page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Block capacity (`max_seq` rounded up to whole pages).
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Logical blocks currently allocated (uniform across planes).
    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Token rows the allocated blocks can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.blocks * self.page_size
    }

    /// Pages currently held (all planes).
    pub fn pages_held(&self) -> usize {
        self.blocks * self.layers * self.kv_heads
    }

    /// Grow until `tokens` rows fit, allocating one page per
    /// (layer, kv-head) per new block.  All-or-nothing per block: a
    /// partial group is rolled back before `OutOfPages` is returned, so
    /// a failed call never leaks pages.
    pub fn ensure_capacity(
        &mut self,
        tokens: usize,
        pool: &mut PagePool,
    ) -> std::result::Result<(), PageAllocError> {
        debug_assert_eq!(pool.page_size(), self.page_size, "pool/table page_size");
        while self.capacity_tokens() < tokens {
            if self.blocks == self.max_blocks {
                return Err(PageAllocError::ExceedsMaxSeq);
            }
            let group = self.layers * self.kv_heads;
            let mut got: Vec<u32> = Vec::with_capacity(group);
            for _ in 0..group {
                match pool.alloc() {
                    Some(p) => got.push(p),
                    None => {
                        for p in got {
                            pool.release(p);
                        }
                        return Err(PageAllocError::OutOfPages);
                    }
                }
            }
            let b = self.blocks;
            let mut it = got.into_iter();
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = self.plane_at(l, g, b);
                    self.table[at] = it.next().expect("group sized to planes");
                    self.tiers[at] = Tier::Device;
                }
            }
            self.shared[b] = false;
            self.stamps[b] = 0;
            self.blocks += 1;
        }
        Ok(())
    }

    /// Append one block adopted from a shared prefix run: `group` pages
    /// (plane-major `[layers * kv_heads]`, all device-resident) are
    /// retained in `pool` and become this table's next logical block,
    /// flagged shared — read-only until [`Self::cow_unshare`] splits it.
    pub fn push_shared_block(&mut self, group: &[u32], pool: &mut PagePool) {
        assert!(self.blocks < self.max_blocks, "shared block beyond max_seq budget");
        assert_eq!(group.len(), self.layers * self.kv_heads, "group sized to planes");
        let b = self.blocks;
        let mut it = group.iter();
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                let at = self.plane_at(l, g, b);
                let page = *it.next().expect("group sized to planes");
                pool.retain(page);
                self.table[at] = page;
                self.tiers[at] = Tier::Device;
            }
        }
        self.shared[b] = true;
        self.stamps[b] = 0;
        self.blocks += 1;
    }

    /// The page group of block `b`, plane-major `[layers * kv_heads]` —
    /// the unit the prefix index registers and adopts.
    pub fn block_group(&self, b: usize) -> Vec<u32> {
        assert!(b < self.blocks, "group of unallocated block {b}");
        let mut out = Vec::with_capacity(self.layers * self.kv_heads);
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                out.push(self.table[self.plane_at(l, g, b)]);
            }
        }
        out
    }

    /// True while block `b` is an unsplit adoption from a shared run.
    pub fn block_shared(&self, b: usize) -> bool {
        debug_assert!(b < self.blocks, "shared flag of unallocated block {b}");
        self.shared[b]
    }

    /// Blocks currently shared (adopted and not yet split).
    pub fn shared_blocks(&self) -> usize {
        (0..self.blocks).filter(|&b| self.shared[b]).count()
    }

    /// True when block `b` must stay device-resident: some *other*
    /// holder (a sibling table or the prefix index) still references
    /// its pages, so moving them would break that holder's mapping.
    /// Ref counts are uniform across a block's planes (every sharing
    /// operation acts on whole groups), so the first plane's page
    /// stands for the group.
    pub fn block_pinned(&self, b: usize, device: &PagePool) -> bool {
        debug_assert!(b < self.blocks, "pin check of unallocated block {b}");
        self.block_tier(b) == Tier::Device && device.ref_count(self.table[b]) > 1
    }

    /// Copy-on-write for a write of token rows `[first_row, last_row)`:
    /// every still-shared block the range overlaps is split — its page
    /// group is cloned into freshly allocated device pages (old
    /// references released) — so the write cannot mutate pages a
    /// sibling sequence or the prefix index still reads.  A block whose
    /// pages this table holds the only reference to is unshared without
    /// copying.  All-or-nothing per block (a partial clone group is
    /// rolled back before `OutOfPages` is returned).  Returns the
    /// number of blocks actually copied.
    pub fn cow_unshare(
        &mut self,
        first_row: usize,
        last_row: usize,
        pool: &mut PagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        if first_row >= last_row || self.blocks == 0 {
            return Ok(0);
        }
        let b0 = first_row / self.page_size;
        let b1 = ((last_row - 1) / self.page_size).min(self.blocks - 1);
        let mut splits = 0;
        for b in b0..=b1 {
            if !self.shared[b] {
                continue;
            }
            debug_assert_eq!(self.block_tier(b), Tier::Device, "shared blocks are device-pinned");
            let group = self.layers * self.kv_heads;
            let sole = (0..self.layers).all(|l| {
                (0..self.kv_heads).all(|g| {
                    let at = self.plane_at(l, g, b);
                    pool.ref_count(self.table[at]) == 1
                })
            });
            if sole {
                // every other holder is gone — this table owns the
                // pages outright; sharing ends without a copy.
                self.shared[b] = false;
                continue;
            }
            let mut got: Vec<u32> = Vec::with_capacity(group);
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = self.plane_at(l, g, b);
                    match pool.clone_page(self.table[at]) {
                        Some(p) => got.push(p),
                        None => {
                            for p in got {
                                pool.release(p);
                            }
                            return Err(PageAllocError::OutOfPages);
                        }
                    }
                }
            }
            let mut it = got.into_iter();
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = self.plane_at(l, g, b);
                    pool.release(self.table[at]);
                    self.table[at] = it.next().expect("group sized to planes");
                }
            }
            self.shared[b] = false;
            splits += 1;
        }
        Ok(splits)
    }

    /// The (tier, page, in-page slot) holding token row `row` of
    /// (`layer`, `kv_head`).  The block must be allocated.
    pub fn locate_tiered(&self, layer: usize, kv_head: usize, row: usize) -> (Tier, u32, usize) {
        let b = row / self.page_size;
        debug_assert!(b < self.blocks, "row {row} beyond allocated blocks");
        let at = self.plane_at(layer, kv_head, b);
        debug_assert_ne!(self.table[at], NO_PAGE, "unallocated block {b}");
        (self.tiers[at], self.table[at], row % self.page_size)
    }

    /// The (page, in-page slot) holding token row `row` of
    /// (`layer`, `kv_head`) — single-pool callers that never migrate.
    pub fn locate(&self, layer: usize, kv_head: usize, row: usize) -> (u32, usize) {
        let (_, page, slot) = self.locate_tiered(layer, kv_head, row);
        (page, slot)
    }

    /// One layer's `[kv_heads, max_blocks]` page-id plane — the gather
    /// table paged attention consumes.
    pub fn layer_pages(&self, layer: usize) -> &[u32] {
        let n = self.kv_heads * self.max_blocks;
        &self.table[layer * n..][..n]
    }

    /// One layer's `[kv_heads, max_blocks]` tier-tag plane, parallel to
    /// [`Self::layer_pages`] — selects the store each page id indexes.
    pub fn layer_tiers(&self, layer: usize) -> &[Tier] {
        let n = self.kv_heads * self.max_blocks;
        &self.tiers[layer * n..][..n]
    }

    /// Tier of block `b` (uniform across planes — blocks migrate as a
    /// group).
    pub fn block_tier(&self, b: usize) -> Tier {
        debug_assert!(b < self.blocks, "tier of unallocated block {b}");
        self.tiers[b] // entry (layer 0, kv_head 0, b)
    }

    /// Device-resident blocks.
    pub fn device_blocks(&self) -> usize {
        (0..self.blocks).filter(|&b| self.block_tier(b) == Tier::Device).count()
    }

    /// Host-resident blocks.
    pub fn host_blocks(&self) -> usize {
        (0..self.blocks).filter(|&b| self.block_tier(b) == Tier::Host).count()
    }

    /// Stamp every allocated block as gathered at `clock` — called by
    /// the engine after an attention pass streamed this sequence's rows
    /// (decode reads the whole history, so all blocks heat together).
    pub fn mark_gathered(&mut self, clock: u64) {
        self.stamps[..self.blocks].fill(clock);
    }

    /// The hottest host-resident block — the one with the highest
    /// last-gather stamp, ties broken toward the highest block index
    /// (later token positions) — or `None` with nothing host-resident.
    /// Returns `(stamp, block)` so callers can rank across sequences.
    pub fn hottest_host_block(&self) -> Option<(u64, usize)> {
        (0..self.blocks)
            .filter(|&b| self.block_tier(b) == Tier::Host)
            .map(|b| (self.stamps[b], b))
            .max()
    }

    /// The coldest migratable block: the lowest-index device-tier block
    /// (lowest token positions = oldest data).  `include_tail: false`
    /// spares the hot tail — the last allocated block, where fresh rows
    /// usually land; `true` considers every block (the last resort when
    /// the device tier cannot even hold two blocks of one sequence).
    pub fn coldest_device_block(&self, include_tail: bool) -> Option<usize> {
        let lim = if include_tail { self.blocks } else { self.blocks.saturating_sub(1) };
        (0..lim).find(|&b| self.block_tier(b) == Tier::Device)
    }

    /// Like [`Self::coldest_device_block`], but additionally skips
    /// blocks pinned by prefix sharing ([`Self::block_pinned`]): a page
    /// referenced by another holder must not leave the device store.
    pub fn coldest_migratable_block(
        &self,
        include_tail: bool,
        device: &PagePool,
    ) -> Option<usize> {
        let lim = if include_tail { self.blocks } else { self.blocks.saturating_sub(1) };
        (0..lim)
            .find(|&b| self.block_tier(b) == Tier::Device && !self.block_pinned(b, device))
    }

    /// Migrate block `b` (one page per plane) from the device tier to
    /// the host tier as one batched PCIe move.  All-or-nothing: host
    /// capacity for the whole group is checked up front, so a failed
    /// call changes nothing.  Returns the pages moved.
    ///
    /// Shared pages (ref count > 1) must not migrate — the other
    /// holder's table (or the prefix index) would keep indexing the
    /// device store; the call refuses with
    /// [`PageAllocError::SharedPage`] until this table owns every page
    /// of the block outright.
    pub fn migrate_block_to_host(
        &mut self,
        b: usize,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        assert!(b < self.blocks, "migrate of unallocated block {b}");
        assert_eq!(self.block_tier(b), Tier::Device, "block {b} already host-resident");
        debug_assert_eq!(pools.page_size(), self.page_size, "pool/table page_size");
        let group = self.layers * self.kv_heads;
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                let at = self.plane_at(l, g, b);
                if pools.device().ref_count(self.table[at]) > 1 {
                    return Err(PageAllocError::SharedPage);
                }
            }
        }
        if pools.host().free_pages() < group {
            return Err(PageAllocError::OutOfPages);
        }
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                let at = self.plane_at(l, g, b);
                let host_page = pools
                    .offload_page(self.table[at])
                    .expect("host capacity checked above");
                self.table[at] = host_page;
                self.tiers[at] = Tier::Host;
            }
        }
        // sole ownership was just proven — if the block was ever
        // adopted from a shared run, sharing has ended.
        self.shared[b] = false;
        pools.charge_batch(group);
        Ok(group)
    }

    /// Migrate block `b` from the host tier back to the device tier
    /// (promotion / swap-in restore), one page per plane, charged as
    /// one batched move.  All-or-nothing: device capacity for the whole
    /// group is checked up front.  Returns the pages moved.
    pub fn promote_block_to_device(
        &mut self,
        b: usize,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        assert!(b < self.blocks, "promote of unallocated block {b}");
        assert_eq!(self.block_tier(b), Tier::Host, "block {b} already device-resident");
        debug_assert_eq!(pools.page_size(), self.page_size, "pool/table page_size");
        let group = self.layers * self.kv_heads;
        if pools.device().free_pages() < group {
            return Err(PageAllocError::OutOfPages);
        }
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                let at = self.plane_at(l, g, b);
                let device_page = pools
                    .promote_page(self.table[at])
                    .expect("device capacity checked above");
                self.table[at] = device_page;
                self.tiers[at] = Tier::Device;
            }
        }
        pools.charge_promotion(group);
        Ok(group)
    }

    /// Device pages this table could park on the host tier, or `None`
    /// when any device block's pages are shared (ref count > 1) — a
    /// sibling table or the prefix index would keep indexing the device
    /// store, so the sequence is not swappable.
    pub fn suspendable_pages(&self, pools: &TieredPagePool) -> Option<usize> {
        let group = self.layers * self.kv_heads;
        let mut pages = 0;
        for b in 0..self.blocks {
            if self.block_tier(b) != Tier::Device {
                continue;
            }
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = self.plane_at(l, g, b);
                    if pools.device().ref_count(self.table[at]) > 1 {
                        return None;
                    }
                }
            }
            pages += group;
        }
        Some(pages)
    }

    /// Park the whole table on the host tier (swap-out preemption):
    /// every device-resident block migrates to host as **one** batched
    /// link transfer, so a suspended sequence's KV survives preemption
    /// instead of being recomputed.  All-or-nothing: shared pages
    /// ([`PageAllocError::SharedPage`]) and insufficient host capacity
    /// ([`PageAllocError::OutOfPages`]) are detected up front and the
    /// table is left untouched.  Returns the pages moved.
    pub fn suspend_to_host(
        &mut self,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        let Some(pages) = self.suspendable_pages(pools) else {
            return Err(PageAllocError::SharedPage);
        };
        if pages == 0 {
            return Ok(0);
        }
        if pools.host().free_pages() < pages {
            return Err(PageAllocError::OutOfPages);
        }
        pools.begin_batched_transfer();
        for b in 0..self.blocks {
            if self.block_tier(b) == Tier::Device {
                self.migrate_block_to_host(b, pools)
                    .expect("sharing and capacity checked above");
            }
        }
        pools.commit_batched_transfer();
        Ok(pages)
    }

    /// Bring a suspended table fully back to the device tier (swap-in
    /// restore): every host-resident block promotes as **one** batched
    /// link transfer.  All-or-nothing on device capacity; a failed call
    /// changes nothing and the sequence keeps gathering from the host
    /// store until capacity appears.  Returns the pages moved.
    pub fn resume_from_host(
        &mut self,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        let group = self.layers * self.kv_heads;
        let pages = self.host_blocks() * group;
        if pages == 0 {
            return Ok(0);
        }
        if pools.device().free_pages() < pages {
            return Err(PageAllocError::OutOfPages);
        }
        pools.begin_batched_transfer();
        for b in 0..self.blocks {
            if self.block_tier(b) == Tier::Host {
                self.promote_block_to_device(b, pools)
                    .expect("device capacity checked above");
            }
        }
        pools.commit_batched_transfer();
        Ok(pages)
    }

    /// Release every held page back to `pool` and reset to empty — the
    /// single-pool path; every block must still be device-resident.
    pub fn release_all(&mut self, pool: &mut PagePool) {
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                for b in 0..self.blocks {
                    let at = self.plane_at(l, g, b);
                    debug_assert_eq!(
                        self.tiers[at],
                        Tier::Device,
                        "release_all on a migrated table — use release_all_tiered"
                    );
                    pool.release(self.table[at]);
                    self.table[at] = NO_PAGE;
                }
            }
        }
        self.shared.fill(false);
        self.stamps.fill(0);
        self.blocks = 0;
    }

    /// Release every held page into its own tier's pool and reset to
    /// empty.
    pub fn release_all_tiered(&mut self, pools: &mut TieredPagePool) {
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                for b in 0..self.blocks {
                    let at = self.plane_at(l, g, b);
                    pools.pool_mut(self.tiers[at]).release(self.table[at]);
                    self.table[at] = NO_PAGE;
                    self.tiers[at] = Tier::Device;
                }
            }
        }
        self.shared.fill(false);
        self.stamps.fill(0);
        self.blocks = 0;
    }

    /// O(1)-per-page speculative rollback: shrink the table to exactly
    /// the blocks `tokens` rows need, popping every whole trailing
    /// block back to its own tier's free list.  The partial tail-row
    /// rewind is purely logical — the paged-attention contract (rows
    /// `>= kv_len` are never read) makes stale rows, and under the
    /// `Int8` codec their stale per-row scale side-channel entries,
    /// unreachable until the next append overwrites them — so rollback
    /// costs page-id bookkeeping only, never store traffic.
    ///
    /// A still-shared (adopted) block in the pop range is refused with
    /// [`PageAllocError::SharedPage`] *before any page moves*: popping
    /// it in place would drop a reference the prefix index or a sibling
    /// table still counts on; callers split such blocks first
    /// ([`Self::cow_unshare`]) or keep them.  (The speculative decode
    /// path never hits this: draft rows are only ever written past
    /// `cow_unshare`d blocks.)  Truncation never grows: `tokens` beyond
    /// [`Self::capacity_tokens`] panics.  Returns the pages released
    /// (all planes).
    pub fn truncate(
        &mut self,
        tokens: usize,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        let keep = tokens.div_ceil(self.page_size.max(1));
        assert!(
            keep <= self.blocks,
            "truncate to {tokens} rows ({keep} blocks) beyond allocated {}",
            self.blocks
        );
        // all-or-nothing like the grow paths: refuse before mutating
        for b in keep..self.blocks {
            if self.shared[b] {
                return Err(PageAllocError::SharedPage);
            }
        }
        let mut pages = 0;
        for b in keep..self.blocks {
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = self.plane_at(l, g, b);
                    pools.pool_mut(self.tiers[at]).release(self.table[at]);
                    self.table[at] = NO_PAGE;
                    self.tiers[at] = Tier::Device;
                    pages += 1;
                }
            }
            self.stamps[b] = 0;
        }
        self.blocks = keep;
        Ok(pages)
    }
}

// ---------------------------------------------------------------------
// Tensor-parallel KV: ShardedTable
// ---------------------------------------------------------------------

/// One sequence's block tables across N tensor-parallel KV shards,
/// mutated in lockstep: every capacity/migration/swap operation runs on
/// all shards, so a sequence's pages migrate, swap out and resume on
/// every simulated device together — the cross-shard reclamation
/// invariant the engine's four-rung ladder relies on.
///
/// Shard `s`'s table pairs with `pools[s]` of the engine's per-shard
/// [`TieredPagePool`]s.  All shards see the same geometry (the *shard*
/// cache shape: `kv_heads / n_shards` heads) and the same operation
/// sequence, so their page occupancy is always identical; shard 0 is
/// the *primary* whose state answers every read (block counts,
/// victim-selection inputs, coldest/hottest block choices).  A mirrored
/// operation failing on a non-primary shard after succeeding on the
/// primary would mean the shards diverged — that is a bug, and the
/// mirror panics rather than limping on with inconsistent KV.
#[derive(Debug)]
pub struct ShardedTable {
    tables: Vec<BlockTable>,
}

impl ShardedTable {
    /// Empty tables on `n_shards` shards, each of the per-shard
    /// geometry `shard_shape` (`kv_heads` already divided by the shard
    /// count).
    pub fn new(shard_shape: CacheShape, n_shards: usize, page_size: usize) -> Self {
        assert!(n_shards >= 1, "at least one shard");
        Self {
            tables: (0..n_shards).map(|_| BlockTable::new(shard_shape, page_size)).collect(),
        }
    }

    /// Number of KV shards.
    pub fn n_shards(&self) -> usize {
        self.tables.len()
    }

    /// Shard 0's table — the authority for reads and the only shard the
    /// single-device prefix index ever sees.
    pub fn primary(&self) -> &BlockTable {
        &self.tables[0]
    }

    /// Mutable access to shard 0's table (prefix adoption; `n == 1`).
    pub fn primary_mut(&mut self) -> &mut BlockTable {
        &mut self.tables[0]
    }

    /// All shards' tables, index-aligned with the engine's pools — what
    /// the sharded backend reads per shard.
    pub fn tables(&self) -> &[BlockTable] {
        &self.tables
    }

    /// Logical blocks currently allocated (identical on every shard).
    pub fn blocks(&self) -> usize {
        self.primary().blocks()
    }

    /// Token rows the allocated blocks can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.primary().capacity_tokens()
    }

    /// Pages held **per shard** (the engine's budgets and victim
    /// accounting are per-device, so per-shard counts are the right
    /// unit — each shard's pool sees exactly this many pages).
    pub fn pages_held(&self) -> usize {
        self.primary().pages_held()
    }

    /// Device-resident blocks (identical on every shard).
    pub fn device_blocks(&self) -> usize {
        self.primary().device_blocks()
    }

    /// Host-resident blocks (identical on every shard).
    pub fn host_blocks(&self) -> usize {
        self.primary().host_blocks()
    }

    /// The hottest host-resident block, from the primary (stamps are
    /// mirrored, so every shard would agree).
    pub fn hottest_host_block(&self) -> Option<(u64, usize)> {
        self.primary().hottest_host_block()
    }

    /// Stamp every allocated block as gathered at `clock`, on all
    /// shards.
    pub fn mark_gathered(&mut self, clock: u64) {
        for t in &mut self.tables {
            t.mark_gathered(clock);
        }
    }

    /// Grow every shard's table until `tokens` rows fit, allocating
    /// from each shard's own device pool.  Per-shard growth is
    /// idempotent, so a partial failure (only possible if the pools
    /// were asymmetric) leaves already-grown shards ahead; the engine's
    /// reclamation ladder frees pages on **all** shards and retries,
    /// which tops up exactly the shards that fell short.
    pub fn ensure_capacity(
        &mut self,
        tokens: usize,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<(), PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()) {
            t.ensure_capacity(tokens, p.device_mut())?;
        }
        Ok(())
    }

    /// Copy-on-write split of `[first_row, last_row)` on every shard.
    /// Returns the primary's split count (sharing only exists under
    /// `n == 1`, where primary == the only shard; mirrored shards
    /// without shared blocks split nothing and return 0).
    pub fn cow_unshare(
        &mut self,
        first_row: usize,
        last_row: usize,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let mut primary_splits = 0;
        for (s, (t, p)) in self.tables.iter_mut().zip(pools.iter_mut()).enumerate() {
            let splits = t.cow_unshare(first_row, last_row, p.device_mut())?;
            if s == 0 {
                primary_splits = splits;
            }
        }
        Ok(primary_splits)
    }

    /// The coldest migratable block, judged on the primary shard
    /// against `pools[0]` (occupancy and pins mirror, so the choice is
    /// valid on every shard).
    pub fn coldest_migratable_block(
        &self,
        include_tail: bool,
        pools: &[TieredPagePool],
    ) -> Option<usize> {
        self.primary().coldest_migratable_block(include_tail, pools[0].device())
    }

    /// Migrate block `b` to the host tier on every shard.  The primary
    /// decides feasibility (`?`); mirrored shards cannot fail after it
    /// succeeded unless the shards diverged, which panics.  Returns the
    /// primary's pages moved (per shard).
    pub fn migrate_block_to_host(
        &mut self,
        b: usize,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let pages = self.tables[0].migrate_block_to_host(b, &mut pools[0])?;
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()).skip(1) {
            t.migrate_block_to_host(b, p)
                .expect("mirrored shard diverged on cold-block migration");
        }
        Ok(pages)
    }

    /// Promote block `b` back to the device tier on every shard (same
    /// primary-decides contract as migration).  Returns the primary's
    /// pages moved (per shard).
    pub fn promote_block_to_device(
        &mut self,
        b: usize,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let pages = self.tables[0].promote_block_to_device(b, &mut pools[0])?;
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()).skip(1) {
            t.promote_block_to_device(b, p)
                .expect("mirrored shard diverged on block promotion");
        }
        Ok(pages)
    }

    /// Device pages the primary shard could park on its host tier
    /// (`None` = pinned by sharing); per-shard counts mirror, so this
    /// answers swappability for the whole group.
    pub fn suspendable_pages(&self, pools: &[TieredPagePool]) -> Option<usize> {
        self.primary().suspendable_pages(&pools[0])
    }

    /// Swap the whole sequence out on every shard (one batched link
    /// transfer per shard).  Primary decides feasibility; a mirrored
    /// shard failing afterwards panics.  Returns the primary's pages
    /// moved (per shard).
    pub fn suspend_to_host(
        &mut self,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let pages = self.tables[0].suspend_to_host(&mut pools[0])?;
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()).skip(1) {
            t.suspend_to_host(p).expect("mirrored shard diverged on swap-out");
        }
        Ok(pages)
    }

    /// Restore a suspended sequence to the device tier on every shard.
    /// Primary decides feasibility; a mirrored shard failing afterwards
    /// panics.  Returns the primary's pages moved (per shard).
    pub fn resume_from_host(
        &mut self,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let pages = self.tables[0].resume_from_host(&mut pools[0])?;
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()).skip(1) {
            t.resume_from_host(p).expect("mirrored shard diverged on swap-in");
        }
        Ok(pages)
    }

    /// Release every shard's pages into its own pool and reset empty.
    pub fn release_all_tiered(&mut self, pools: &mut [TieredPagePool]) {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()) {
            t.release_all_tiered(p);
        }
    }

    /// Truncate every shard's table to `tokens` rows — the speculative
    /// rollback, mirrored in lockstep (same primary-decides contract as
    /// migration: a mirrored shard failing after the primary succeeded
    /// means the shards diverged, which panics).  Returns the primary's
    /// pages released (per shard).
    pub fn truncate(
        &mut self,
        tokens: usize,
        pools: &mut [TieredPagePool],
    ) -> std::result::Result<usize, PageAllocError> {
        debug_assert_eq!(self.tables.len(), pools.len(), "one pool per shard");
        let pages = self.tables[0].truncate(tokens, &mut pools[0])?;
        for (t, p) in self.tables.iter_mut().zip(pools.iter_mut()).skip(1) {
            t.truncate(tokens, p).expect("mirrored shard diverged on truncation");
        }
        Ok(pages)
    }
}

// ---------------------------------------------------------------------
// Cross-sequence prefix sharing: PrefixIndex
// ---------------------------------------------------------------------

/// One registered block of shared prompt-prefix KV.
#[derive(Debug, Clone)]
struct PrefixEntry {
    /// One page per (layer, kv-head) plane, plane-major
    /// (`[layers * kv_heads]`), all device-resident; the index holds
    /// one reference on each so the run outlives the sequence that
    /// prefilled it.
    pages: Vec<u32>,
    /// Valid token rows in the block: `page_size` for chain blocks,
    /// `1..page_size` for a partially filled tail block.
    rows: usize,
    /// LRU stamp (unique; bumped on every registration and hit).
    stamp: u64,
}

/// The cross-sequence prompt-prefix cache of a paged engine
/// (system-prompt caching): content-addressed page runs that let a new
/// sequence *adopt* the KV pages of a previously prefilled prompt
/// prefix instead of recomputing them.
///
/// Entries are **block-granular**, keyed by the exact token prefix they
/// cover: a chain entry's key is the prompt's first `k · page_size`
/// tokens and its value is block `k-1`'s page group; a *tail* entry
/// (the partially filled last block of a prompt whose length is not a
/// page multiple) is keyed by the whole prompt.  Lookup walks the chain
/// greedily — block `k` can only hit if blocks `0..k` hit — so two
/// prompts share exactly the page runs of their common block-aligned
/// prefix, plus the tail when the prompts are identical.
///
/// The index retains every registered page, pinning it to the device
/// tier; [`Self::evict_idle`] drops least-recently-used runs no live
/// sequence references when the engine needs the pages back.
/// Divergent writes into adopted blocks are handled by
/// [`BlockTable::cow_unshare`] — the index's copy is never mutated.
///
/// ```
/// use fastattn::coordinator::kv_cache::{BlockTable, CacheShape, PagePool, PrefixIndex};
///
/// let shape = CacheShape { layers: 1, kv_heads: 1, max_seq: 8, head_dim: 2 };
/// let mut pool = PagePool::new(2, shape.head_dim, 16);
/// let mut index = PrefixIndex::new(shape, 2, 64);
///
/// // sequence A prefills a 4-token prompt and registers it
/// let prompt = [7i32, 8, 9, 10];
/// let mut a = BlockTable::new(shape, 2);
/// a.ensure_capacity(prompt.len(), &mut pool).unwrap();
/// assert_eq!(index.register(&prompt, &a, &mut pool), 2);
///
/// // sequence B with the same prompt adopts the shared run: its
/// // prefill resumes at the last prompt token instead of token 0
/// let mut b = BlockTable::new(shape, 2);
/// let adopted = index.adopt(&prompt, &mut b, &mut pool);
/// assert_eq!(adopted, prompt.len() - 1);
/// assert_eq!(b.shared_blocks(), 2);
///
/// // B's first write into the shared tail block copy-on-write-splits
/// // it, so A's pages (and the index's) are never mutated
/// let splits = b.cow_unshare(3, 4, &mut pool).unwrap();
/// assert_eq!(splits, 1);
/// b.release_all(&mut pool);
/// a.release_all(&mut pool);
/// ```
#[derive(Debug)]
pub struct PrefixIndex {
    layers: usize,
    kv_heads: usize,
    page_size: usize,
    /// Cap on registered entries (LRU-evicted past it).
    max_entries: usize,
    entries: std::collections::HashMap<Vec<i32>, PrefixEntry>,
    /// Monotonic LRU clock; every stamp it hands out is unique, so
    /// eviction order is deterministic.
    clock: u64,
}

impl PrefixIndex {
    /// An empty index for caches of `shape` at `page_size`, holding at
    /// most `max_entries` block entries.
    pub fn new(shape: CacheShape, page_size: usize, max_entries: usize) -> Self {
        assert!(page_size >= 1, "page_size must be >= 1");
        Self {
            layers: shape.layers,
            kv_heads: shape.kv_heads,
            page_size,
            max_entries: max_entries.max(1),
            entries: std::collections::HashMap::new(),
            clock: 0,
        }
    }

    /// Registered block entries.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Pages currently retained by the index (each pinned to device).
    pub fn pages_held(&self) -> usize {
        self.entries.values().map(|e| e.pages.len()).sum()
    }

    /// Register the prompt-prefix KV of a fully prefilled sequence for
    /// future sharing: one chain entry per whole block the table owns
    /// outright (computed here, device-resident, not itself an unsplit
    /// adoption), plus a tail entry for a partially filled last block,
    /// keyed by the whole prompt.  Every registered page is retained.
    /// Returns the entries added (0 when everything was already
    /// registered or nothing qualifies).
    pub fn register(&mut self, prompt: &[i32], table: &BlockTable, pool: &mut PagePool) -> usize {
        assert_eq!(table.layers(), self.layers, "table/index layers");
        assert_eq!(table.kv_heads(), self.kv_heads, "table/index kv_heads");
        assert_eq!(table.page_size(), self.page_size, "table/index page_size");
        let ps = self.page_size;
        let full = prompt.len() / ps;
        let tail_rows = prompt.len() % ps;
        let mut added = 0;
        for b in 0..full.min(table.blocks()) {
            let key = &prompt[..(b + 1) * ps];
            if table.block_shared(b)
                || table.block_tier(b) != Tier::Device
                || self.entries.contains_key(key)
                || !self.make_room(pool)
            {
                continue;
            }
            added += self.insert(key, table.block_group(b), ps, pool);
        }
        if tail_rows != 0 && full < table.blocks() {
            let b = full;
            if !table.block_shared(b)
                && table.block_tier(b) == Tier::Device
                && !self.entries.contains_key(prompt)
                && self.make_room(pool)
            {
                added += self.insert(prompt, table.block_group(b), tail_rows, pool);
            }
        }
        added
    }

    fn insert(&mut self, key: &[i32], pages: Vec<u32>, rows: usize, pool: &mut PagePool) -> usize {
        for &p in &pages {
            pool.retain(p);
        }
        self.clock += 1;
        self.entries
            .insert(key.to_vec(), PrefixEntry { pages, rows, stamp: self.clock });
        1
    }

    /// Evict (at most one entry) until there is room for one more.
    fn make_room(&mut self, pool: &mut PagePool) -> bool {
        if self.entries.len() < self.max_entries {
            return true;
        }
        self.evict_idle(pool) > 0 && self.entries.len() < self.max_entries
    }

    /// Adopt the longest registered run matching a prefix of `prompt`
    /// into `table` (which must be empty): chain blocks first, then —
    /// on an exact full-prompt hit — the partially filled tail block.
    /// At most `prompt.len() - 1` tokens are adopted, so prefill always
    /// recomputes at least the final prompt token (its logits seed the
    /// first generated token); the recomputed rows land in adopted
    /// blocks only after a copy-on-write split.  Returns the tokens
    /// adopted (0 = miss).
    pub fn adopt(&mut self, prompt: &[i32], table: &mut BlockTable, pool: &mut PagePool) -> usize {
        assert_eq!(table.blocks(), 0, "adopt into a non-empty table");
        assert_eq!(table.layers(), self.layers, "table/index layers");
        assert_eq!(table.kv_heads(), self.kv_heads, "table/index kv_heads");
        assert_eq!(table.page_size(), self.page_size, "table/index page_size");
        let ps = self.page_size;
        let max_tokens = prompt.len().saturating_sub(1);
        if max_tokens == 0 {
            return 0;
        }
        let full = prompt.len() / ps;
        let mut chain = 0;
        while chain < full && self.entries.contains_key(&prompt[..(chain + 1) * ps]) {
            chain += 1;
        }
        // the tail block only helps when it contributes adoptable rows
        let tail = chain == full
            && prompt.len() % ps != 0
            && chain * ps < max_tokens
            && self.entries.contains_key(prompt);
        if chain == 0 && !tail {
            return 0;
        }
        let mut tokens = 0;
        for b in 0..chain {
            let (pages, rows) = self.touch(&prompt[..(b + 1) * ps]);
            table.push_shared_block(&pages, pool);
            tokens += rows;
        }
        if tail {
            let (pages, rows) = self.touch(prompt);
            table.push_shared_block(&pages, pool);
            tokens += rows;
        }
        tokens.min(max_tokens)
    }

    /// Bump an entry's LRU stamp and clone its page group.
    fn touch(&mut self, key: &[i32]) -> (Vec<u32>, usize) {
        self.clock += 1;
        let e = self.entries.get_mut(key).expect("probed key present");
        e.stamp = self.clock;
        (e.pages.clone(), e.rows)
    }

    /// Evict the least-recently-used *idle* entry — one whose pages no
    /// live table references (the index holds the only reference on
    /// each) — releasing its pages back to the free list.  Returns the
    /// pages freed (0 when every entry is still in use).
    pub fn evict_idle(&mut self, pool: &mut PagePool) -> usize {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| e.pages.iter().all(|&p| pool.ref_count(p) == 1))
            .min_by_key(|(_, e)| e.stamp)
            .map(|(k, _)| k.clone());
        let Some(key) = victim else { return 0 };
        let e = self.entries.remove(&key).expect("victim key present");
        for &p in &e.pages {
            pool.release(p);
        }
        e.pages.len()
    }

    /// Release every retained page and forget all entries (engine
    /// shutdown / tests).  Pages still shared with live tables survive
    /// under those tables' references.
    pub fn clear(&mut self, pool: &mut PagePool) {
        for e in self.entries.values() {
            for &p in &e.pages {
                pool.release(p);
            }
        }
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> CacheShape {
        CacheShape { layers: 2, kv_heads: 3, max_seq: 4, head_dim: 2 }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let sh = shape();
        let n = sh.seq_elems();
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let plane = pack_batch(sh, 4, &[(0, &a), (2, &b)]).unwrap();
        assert_eq!(plane.len(), sh.layers * 4 * sh.seq_elems() / sh.layers);

        let mut a2 = vec![0.0; n];
        let mut b2 = vec![0.0; n];
        unpack_batch(sh, 4, &plane, &mut [(0, &mut a2), (2, &mut b2)]).unwrap();
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn unused_slots_zero() {
        let sh = shape();
        let n = sh.seq_elems();
        let a = vec![1.0f32; n];
        let plane = pack_batch(sh, 3, &[(1, &a)]).unwrap();
        // slot 0 of layer 0 must be all zeros
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        assert!(plane[..le].iter().all(|&x| x == 0.0));
        assert!(plane[le..2 * le].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_interleaving_correct() {
        // value at [layer, slot] must land at plane[(layer*B + slot)*le]
        let sh = shape();
        let n = sh.seq_elems();
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        let mut a = vec![0.0f32; n];
        a[0] = 7.0; // layer 0 first elem
        a[le] = 9.0; // layer 1 first elem
        let plane = pack_batch(sh, 2, &[(1, &a)]).unwrap();
        assert_eq!(plane[(0 * 2 + 1) * le], 7.0);
        assert_eq!(plane[(1 * 2 + 1) * le], 9.0);
    }

    #[test]
    fn batch_offsets_match_pack_layout() {
        // a value written at (layer, slot, kv_head, row) in a sequence
        // cache must land at batch_row_offset after pack_batch.
        let sh = shape();
        let (layer, kv_head, row, t) = (1usize, 2usize, 3usize, 1usize);
        let mut a = vec![0.0f32; sh.seq_elems()];
        let seq_idx = layer * sh.layer_elems()
            + (kv_head * sh.max_seq + row) * sh.head_dim
            + t;
        a[seq_idx] = 5.5;
        let b = 3;
        let slot = 2;
        let plane = pack_batch(sh, b, &[(slot, &a)]).unwrap();
        assert_eq!(plane[sh.batch_row_offset(b, layer, slot, kv_head, row) + t], 5.5);
        assert_eq!(
            sh.batch_slot_offset(b, layer, slot),
            (layer * b + slot) * sh.layer_elems()
        );
    }

    #[test]
    fn bad_slot_rejected() {
        let sh = shape();
        let a = vec![0.0f32; sh.seq_elems()];
        assert!(pack_batch(sh, 2, &[(2, &a)]).is_err());
    }

    #[test]
    fn pool_spills_to_host() {
        let sh = shape();
        let mut pool = CachePool::new(sh, sh.seq_bytes() * 2);
        let (_, t1) = pool.allocate();
        let (_, t2) = pool.allocate();
        let (_, t3) = pool.allocate();
        assert_eq!(t1, Tier::Device);
        assert_eq!(t2, Tier::Device);
        assert_eq!(t3, Tier::Host);
        assert_eq!(pool.active(), 3);
        pool.release(t1);
        assert!(pool.has_device_room());
    }

    // --- paged KV -----------------------------------------------------

    #[test]
    fn page_pool_alloc_release_reuse() {
        let mut pool = PagePool::new(4, 2, 3);
        assert_eq!(pool.num_pages(), 3);
        assert_eq!(pool.free_pages(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.alloc(), None);
        assert_eq!(pool.used_pages(), 3);
        assert!((pool.occupancy() - 1.0).abs() < 1e-12);
        pool.release(b);
        assert_eq!(pool.free_pages(), 1);
        // LIFO reuse of the freed page
        assert_eq!(pool.alloc(), Some(b));
        pool.release(a);
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.occupancy(), 0.0);
    }

    #[test]
    fn page_refcounts_keep_shared_pages_alive() {
        let mut pool = PagePool::new(4, 2, 2);
        let p = pool.alloc().unwrap();
        pool.retain(p); // a second sequence shares the prefix
        pool.release(p);
        assert_eq!(pool.ref_count(p), 1);
        assert_eq!(pool.used_pages(), 1, "shared page must stay allocated");
        pool.release(p);
        assert_eq!(pool.ref_count(p), 0);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn page_rows_roundtrip() {
        let mut pool = PagePool::new(4, 2, 2);
        let p = pool.alloc().unwrap();
        pool.write_row(p, 3, &[1.0, 2.0], &[3.0, 4.0]);
        let at = (p as usize * 4 + 3) * 2;
        assert_eq!(&pool.k_store()[at..at + 2], &[1.0, 2.0]);
        assert_eq!(&pool.v_store()[at..at + 2], &[3.0, 4.0]);
    }

    #[test]
    fn block_table_grows_and_locates() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let mut pool = PagePool::new(2, sh.head_dim, 32);
        let mut t = BlockTable::new(sh, 2);
        assert_eq!(t.max_blocks(), 2);
        assert_eq!(t.capacity_tokens(), 0);
        t.ensure_capacity(1, &mut pool).unwrap();
        assert_eq!(t.blocks(), 1);
        assert_eq!(t.capacity_tokens(), 2);
        assert_eq!(t.pages_held(), 6); // layers * kv_heads
        assert_eq!(pool.used_pages(), 6);
        // growing within capacity is a no-op
        t.ensure_capacity(2, &mut pool).unwrap();
        assert_eq!(t.blocks(), 1);
        t.ensure_capacity(4, &mut pool).unwrap();
        assert_eq!(t.blocks(), 2);

        // every (layer, kv_head) plane has distinct pages; row 3 lives in
        // block 1 slot 1
        let (p0, s0) = t.locate(0, 0, 3);
        let (p1, s1) = t.locate(1, 2, 3);
        assert_eq!(s0, 1);
        assert_eq!(s1, 1);
        assert_ne!(p0, p1);
        let lp = t.layer_pages(1);
        assert_eq!(lp.len(), sh.kv_heads * t.max_blocks());
        assert_eq!(lp[2 * t.max_blocks() + 1], p1);

        t.ensure_capacity(5, &mut pool)
            .expect_err("beyond max_seq must fail");
        t.release_all(&mut pool);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(t.blocks(), 0);
    }

    #[test]
    fn block_table_rolls_back_partial_groups() {
        let sh = shape(); // group = 6 pages per block
        let mut pool = PagePool::new(2, sh.head_dim, 4);
        let mut t = BlockTable::new(sh, 2);
        assert_eq!(
            t.ensure_capacity(1, &mut pool),
            Err(PageAllocError::OutOfPages)
        );
        // the partial group was rolled back — nothing leaked
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(t.blocks(), 0);
    }

    // --- tiered paged KV ----------------------------------------------

    #[test]
    fn pcie_link_batched_moves_amortize_latency() {
        let link = PcieLink::new(10e9, 20e-6);
        let pb = 4096usize;
        let one = link.transfer_s(pb);
        assert!((one - (20e-6 + 4096.0 / 10e9)).abs() < 1e-12);
        // one batched 10-page move beats ten single-page moves
        assert!(link.transfer_s(10 * pb) < 10.0 * one);
    }

    #[test]
    fn migrate_block_preserves_rows_and_frees_device_pages() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.blocks(), 2);
        assert_eq!(t.device_blocks(), 2);
        // distinct rows everywhere
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    assert_eq!(tier, Tier::Device);
                    pools.write_row(tier, page, slot, &[base, base + 0.5], &[-base, -base - 0.5]);
                }
            }
        }
        assert_eq!(pools.device().used_pages(), 2 * group);

        let moved = t.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(moved, group);
        assert_eq!(t.block_tier(0), Tier::Host);
        assert_eq!(t.block_tier(1), Tier::Device);
        assert_eq!(t.device_blocks(), 1);
        assert_eq!(pools.device().used_pages(), group, "block 0 device pages freed");
        assert_eq!(pools.host().used_pages(), group);

        // every row reads back identically through its (possibly new) tier
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    assert_eq!(tier, if r < 2 { Tier::Host } else { Tier::Device });
                    let at = (page as usize * 2 + slot) * sh.head_dim;
                    assert_eq!(&pools.k_store(tier)[at..at + 2], &[base, base + 0.5]);
                    assert_eq!(&pools.v_store(tier)[at..at + 2], &[-base, -base - 0.5]);
                }
            }
        }

        // accounting: one batch of `group` pages at page_bytes each
        let st = pools.stats();
        assert_eq!(st.pages_moved, group as u64);
        assert_eq!(st.batches, 1);
        assert_eq!(st.bytes_moved, (group * pools.page_bytes()) as u64);
        assert!(st.modeled_s > 0.0);

        // release drains both tiers
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.device().used_pages(), 0);
        assert_eq!(pools.host().used_pages(), 0);
        assert_eq!(t.blocks(), 0);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn migrate_refuses_without_host_capacity() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        // host tier holds less than one block group
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, group - 1, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(2, pools.device_mut()).unwrap();
        assert_eq!(
            t.migrate_block_to_host(0, &mut pools),
            Err(PageAllocError::OutOfPages)
        );
        // nothing changed
        assert_eq!(t.block_tier(0), Tier::Device);
        assert_eq!(pools.host().used_pages(), 0);
        assert_eq!(pools.stats(), MigrationStats::default());
    }

    #[test]
    fn coldest_block_policy_spares_the_tail() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(2, pools.device_mut()).unwrap(); // one block
        assert_eq!(t.coldest_device_block(false), None, "lone block is the hot tail");
        assert_eq!(t.coldest_device_block(true), Some(0));
        t.ensure_capacity(4, pools.device_mut()).unwrap(); // two blocks
        assert_eq!(t.coldest_device_block(false), Some(0));
        t.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(t.coldest_device_block(false), None, "only the tail is left on device");
        assert_eq!(t.coldest_device_block(true), Some(1));
        t.release_all_tiered(&mut pools);
    }

    /// Write a distinct row pattern into every (layer, head, row) slot.
    fn fill_rows(t: &BlockTable, pools: &mut TieredPagePool, sh: CacheShape, rows: usize) {
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..rows {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    pools.write_row(tier, page, slot, &[base, base + 0.5], &[-base, -base - 0.5]);
                }
            }
        }
    }

    /// Every row reads back the `fill_rows` pattern through its tier.
    fn check_rows(t: &BlockTable, pools: &TieredPagePool, sh: CacheShape, rows: usize) {
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..rows {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    let at = (page as usize * 2 + slot) * sh.head_dim;
                    assert_eq!(&pools.k_store(tier)[at..at + 2], &[base, base + 0.5]);
                    assert_eq!(&pools.v_store(tier)[at..at + 2], &[-base, -base - 0.5]);
                }
            }
        }
    }

    #[test]
    fn promote_block_restores_rows_and_charges_link() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        fill_rows(&t, &mut pools, sh, 4);

        t.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(t.host_blocks(), 1);
        let moved = t.promote_block_to_device(0, &mut pools).unwrap();
        assert_eq!(moved, group);
        assert_eq!(t.block_tier(0), Tier::Device);
        assert_eq!(t.host_blocks(), 0);
        assert_eq!(pools.host().used_pages(), 0, "host pages recycled on promotion");
        check_rows(&t, &pools, sh, 4);

        let st = pools.stats();
        assert_eq!(st.pages_moved, group as u64);
        assert_eq!(st.pages_promoted, group as u64);
        assert_eq!(st.promotions, 1);
        assert_eq!(st.promoted_bytes, (group * pools.page_bytes()) as u64);
        assert_eq!(st.grouped_transfers, 0, "single-group moves are not grouped");
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn suspend_resume_roundtrip_is_one_batched_transfer_each_way() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 4 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap(); // 2 blocks
        fill_rows(&t, &mut pools, sh, 4);

        let parked = t.suspend_to_host(&mut pools).unwrap();
        assert_eq!(parked, 2 * group);
        assert_eq!(t.device_blocks(), 0);
        assert_eq!(t.host_blocks(), 2);
        assert_eq!(pools.device().used_pages(), 0, "swap-out frees the device tier");
        check_rows(&t, &pools, sh, 4);
        let st = pools.stats();
        assert_eq!(st.batches, 1, "both blocks fold into one outbound transfer");
        assert_eq!(st.pages_moved, 2 * group as u64);
        assert_eq!(st.grouped_transfers, 1, "a 2-group move is a grouped transfer");
        // one transfer of 2 groups beats two transfers of 1 group
        let link = pools.link();
        let gb = group * pools.page_bytes();
        assert!(st.modeled_s < 2.0 * link.transfer_s(gb));
        assert!((st.modeled_s - link.transfer_s(2 * gb)).abs() < 1e-12);

        let restored = t.resume_from_host(&mut pools).unwrap();
        assert_eq!(restored, 2 * group);
        assert_eq!(t.host_blocks(), 0);
        assert_eq!(pools.host().used_pages(), 0);
        check_rows(&t, &pools, sh, 4);
        let st = pools.stats();
        assert_eq!(st.promotions, 1, "both blocks fold into one inbound transfer");
        assert_eq!(st.pages_promoted, 2 * group as u64);
        assert_eq!(st.grouped_transfers, 2);
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn suspend_refuses_shared_pages_and_tight_host_tiers() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        // host holds only one group — a two-block suspend must refuse
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 4 * group, group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.suspend_to_host(&mut pools), Err(PageAllocError::OutOfPages));
        assert_eq!(t.device_blocks(), 2, "failed suspend changes nothing");
        assert_eq!(pools.stats(), MigrationStats::default());

        // a shared block makes the table unswappable outright
        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&t.block_group(0), pools.device_mut());
        assert_eq!(t.suspendable_pages(&pools), None);
        assert_eq!(t.suspend_to_host(&mut pools), Err(PageAllocError::SharedPage));
        adopter.release_all_tiered(&mut pools);
        assert_eq!(t.suspendable_pages(&pools), Some(2 * group));
        t.release_all_tiered(&mut pools);
    }

    #[test]
    fn stale_shared_flag_does_not_block_migration_after_release() {
        // Regression: a block adopted from a prefix run keeps its
        // `shared` flag after every other holder (sibling table or
        // index entry) releases — the reclamation scan must judge
        // migratability by the *current* ref count, not the stale flag,
        // so an eviction mid-ladder immediately unpins its candidates.
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 4 * group, 4 * group, PcieLink::default());
        let mut owner = BlockTable::new(sh, 2);
        owner.ensure_capacity(2, pools.device_mut()).unwrap();
        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&owner.block_group(0), pools.device_mut());
        assert!(adopter.block_shared(0));
        assert_eq!(adopter.coldest_migratable_block(true, pools.device()), None);

        // the other holder lets go (e.g. an idle prefix run evicted in
        // the reclamation loop): the flag is stale but the pin is gone
        owner.release_all_tiered(&mut pools);
        assert!(adopter.block_shared(0), "flag not yet recomputed");
        assert_eq!(
            adopter.coldest_migratable_block(true, pools.device()),
            Some(0),
            "refcount-based recheck must see the unpinned block"
        );
        assert_eq!(adopter.migrate_block_to_host(0, &mut pools), Ok(group));
        assert!(!adopter.block_shared(0), "migration proves sole ownership");
        adopter.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn gather_stamps_rank_host_blocks_by_heat() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.hottest_host_block(), None, "nothing host-resident yet");
        t.mark_gathered(7);
        t.migrate_block_to_host(0, &mut pools).unwrap();
        t.migrate_block_to_host(1, &mut pools).unwrap();
        // equal stamps: the higher block index (later tokens) wins
        assert_eq!(t.hottest_host_block(), Some((7, 1)));
        t.promote_block_to_device(1, &mut pools).unwrap();
        assert_eq!(t.hottest_host_block(), Some((7, 0)));
        t.release_all_tiered(&mut pools);
    }

    #[test]
    fn tiered_for_budget_zero_host_disables_the_tier() {
        let sh = shape();
        let pools = TieredPagePool::for_budget(sh, 2, 64 * 1024, 0, PcieLink::default());
        assert_eq!(pools.host().num_pages(), 0);
        assert!(pools.device().num_pages() > 0);
        assert_eq!(pools.total_pages(), pools.device().num_pages());
        // page geometry identical across tiers
        assert_eq!(pools.page_size(), 2);
        assert_eq!(pools.head_dim(), sh.head_dim);
        assert_eq!(pools.page_bytes(), 2 * 4 * 2 * sh.head_dim);
    }

    // --- prefix sharing: clone/COW/pinning/PrefixIndex ----------------

    #[test]
    fn clone_page_copies_rows_and_leaves_src() {
        let mut pool = PagePool::new(2, 2, 4);
        let src = pool.alloc().unwrap();
        pool.write_row(src, 0, &[1.0, 2.0], &[3.0, 4.0]);
        pool.write_row(src, 1, &[5.0, 6.0], &[7.0, 8.0]);
        let dst = pool.clone_page(src).unwrap();
        assert_ne!(src, dst);
        assert_eq!(pool.ref_count(src), 1);
        assert_eq!(pool.ref_count(dst), 1);
        let at = |p: u32, s: usize| (p as usize * 2 + s) * 2;
        assert_eq!(&pool.k_store()[at(dst, 0)..at(dst, 0) + 2], &[1.0, 2.0]);
        assert_eq!(&pool.v_store()[at(dst, 1)..at(dst, 1) + 2], &[7.0, 8.0]);
        // mutating the clone leaves the source untouched
        pool.write_row(dst, 0, &[9.0, 9.0], &[9.0, 9.0]);
        assert_eq!(&pool.k_store()[at(src, 0)..at(src, 0) + 2], &[1.0, 2.0]);
    }

    #[test]
    fn push_shared_block_retains_group() {
        let sh = shape(); // layers 2, kv_heads 3 → group 6
        let mut pool = PagePool::new(2, sh.head_dim, 32);
        let mut owner = BlockTable::new(sh, 2);
        owner.ensure_capacity(2, &mut pool).unwrap();
        assert!(!owner.block_shared(0));
        let group = owner.block_group(0);
        assert_eq!(group.len(), 6);

        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&group, &mut pool);
        assert_eq!(adopter.blocks(), 1);
        assert!(adopter.block_shared(0));
        assert_eq!(adopter.shared_blocks(), 1);
        assert_eq!(adopter.block_group(0), group);
        for &p in &group {
            assert_eq!(pool.ref_count(p), 2);
        }
        // both tables resolve the same physical rows
        assert_eq!(owner.locate(1, 2, 1), adopter.locate(1, 2, 1));

        adopter.release_all(&mut pool);
        for &p in &group {
            assert_eq!(pool.ref_count(p), 1, "owner keeps its reference");
        }
        owner.release_all(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn cow_unshare_splits_without_touching_sibling() {
        let sh = shape();
        let mut pool = PagePool::new(2, sh.head_dim, 64);
        let mut owner = BlockTable::new(sh, 2);
        owner.ensure_capacity(4, &mut pool).unwrap(); // 2 blocks
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (page, slot) = owner.locate(l, g, r);
                    pool.write_row(page, slot, &[base, base + 0.5], &[-base, -base - 0.5]);
                }
            }
        }
        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&owner.block_group(0), &mut pool);
        adopter.push_shared_block(&owner.block_group(1), &mut pool);

        // a write into rows 2..4 (block 1) splits only block 1
        let splits = adopter.cow_unshare(2, 4, &mut pool).unwrap();
        assert_eq!(splits, 1);
        assert!(adopter.block_shared(0));
        assert!(!adopter.block_shared(1));
        assert_ne!(owner.locate(0, 0, 2), adopter.locate(0, 0, 2));
        assert_eq!(owner.locate(0, 0, 0), adopter.locate(0, 0, 0));

        // the clone carried the rows; diverging leaves the owner intact
        let (op, os) = owner.locate(1, 1, 3);
        let (ap, asl) = adopter.locate(1, 1, 3);
        let at = |p: u32, s: usize| (p as usize * 2 + s) * sh.head_dim;
        assert_eq!(
            &pool.k_store()[at(op, os)..at(op, os) + 2].to_vec(),
            &pool.k_store()[at(ap, asl)..at(ap, asl) + 2].to_vec()
        );
        pool.write_row(ap, asl, &[99.0, 99.0], &[99.0, 99.0]);
        let base = 113.0f32; // (l * 10 + g) * 10 + r at (1, 1, 3)
        assert_eq!(
            &pool.k_store()[at(op, os)..at(op, os) + 2],
            &[base, base + 0.5],
            "COW split must never mutate the sibling's pages"
        );

        // sole owner: once the sibling releases, unsharing block 0 is
        // a flag flip, not a copy
        owner.release_all(&mut pool);
        let used = pool.used_pages();
        assert_eq!(adopter.cow_unshare(0, 2, &mut pool).unwrap(), 0);
        assert!(!adopter.block_shared(0));
        assert_eq!(pool.used_pages(), used, "sole-owner unshare allocates nothing");
        adopter.release_all(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn shared_blocks_pin_migration() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 4 * group, 4 * group, PcieLink::default());
        let mut owner = BlockTable::new(sh, 2);
        owner.ensure_capacity(4, pools.device_mut()).unwrap();
        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&owner.block_group(0), pools.device_mut());

        // block 0 is shared: pinned for both holders
        assert!(owner.block_pinned(0, pools.device()));
        assert!(!owner.block_pinned(1, pools.device()));
        assert_eq!(
            owner.migrate_block_to_host(0, &mut pools),
            Err(PageAllocError::SharedPage)
        );
        assert_eq!(owner.coldest_device_block(true), Some(0));
        assert_eq!(owner.coldest_migratable_block(true, pools.device()), Some(1));
        assert_eq!(adopter.coldest_migratable_block(true, pools.device()), None);

        // once the adopter lets go, the pin lifts
        adopter.release_all_tiered(&mut pools);
        assert!(!owner.block_pinned(0, pools.device()));
        owner.migrate_block_to_host(0, &mut pools).unwrap();
        owner.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    /// Index geometry for the prefix tests: single-plane cache, page
    /// size 4.
    fn ix_shape() -> CacheShape {
        CacheShape { layers: 1, kv_heads: 1, max_seq: 16, head_dim: 2 }
    }

    #[test]
    fn prefix_index_chain_and_tail_roundtrip() {
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 64);

        // register a 6-token prompt: one chain block + a 2-row tail
        let prompt = [1i32, 2, 3, 4, 5, 6];
        let mut owner = BlockTable::new(sh, ps);
        owner.ensure_capacity(prompt.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&prompt, &owner, &mut pool), 2);
        assert_eq!(ix.entries(), 2);
        assert_eq!(ix.pages_held(), 2);
        // double registration is a no-op
        assert_eq!(ix.register(&prompt, &owner, &mut pool), 0);

        // identical prompt: chain + tail adopt, capped at len - 1
        let mut same = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&prompt, &mut same, &mut pool), 5);
        assert_eq!(same.blocks(), 2);
        assert_eq!(same.shared_blocks(), 2);
        assert_eq!(same.locate(0, 0, 5), owner.locate(0, 0, 5));

        // longer prompt sharing the block-aligned prefix: chain only
        let longer = [1i32, 2, 3, 4, 9, 9, 9];
        let mut ext = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&longer, &mut ext, &mut pool), 4);
        assert_eq!(ext.blocks(), 1);
        assert_eq!(ext.locate(0, 0, 3), owner.locate(0, 0, 3));

        // divergent prompt: miss
        let other = [8i32, 8, 8, 8, 8];
        let mut miss = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&other, &mut miss, &mut pool), 0);
        assert_eq!(miss.blocks(), 0);

        same.release_all(&mut pool);
        ext.release_all(&mut pool);
        owner.release_all(&mut pool);
        ix.clear(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn prefix_index_skips_adopted_and_cold_blocks_on_register() {
        let sh = ix_shape();
        let ps = 4;
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(ps, sh.head_dim, 8 * group, 8 * group, PcieLink::default());
        let mut ix = PrefixIndex::new(sh, ps, 64);

        let prompt = [1i32, 2, 3, 4, 5, 6, 7, 8];
        let mut owner = BlockTable::new(sh, ps);
        owner.ensure_capacity(prompt.len(), pools.device_mut()).unwrap();
        assert_eq!(ix.register(&prompt, &owner, pools.device_mut()), 2);

        // an adopter that extends the prompt registers only the blocks
        // it computed itself (block 2), not the adopted shared ones
        let longer = [1i32, 2, 3, 4, 5, 6, 7, 8, 9, 10];
        let mut ext = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&longer, &mut ext, pools.device_mut()), 8);
        ext.ensure_capacity(longer.len(), pools.device_mut()).unwrap();
        assert_eq!(ix.register(&longer, &ext, pools.device_mut()), 1);
        assert_eq!(ix.entries(), 3);

        // a host-migrated block never registers
        let cold = [9i32, 9, 9, 9, 9];
        let mut c = BlockTable::new(sh, ps);
        c.ensure_capacity(cold.len(), pools.device_mut()).unwrap();
        c.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(ix.register(&cold, &c, pools.device_mut()), 1, "only the device tail");

        ext.release_all(pools.device_mut());
        owner.release_all(pools.device_mut());
        c.release_all_tiered(&mut pools);
        ix.clear(pools.device_mut());
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn prefix_index_evicts_only_idle_lru() {
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 64);

        let a = [1i32, 2, 3, 4];
        let mut ta = BlockTable::new(sh, ps);
        ta.ensure_capacity(a.len(), &mut pool).unwrap();
        ix.register(&a, &ta, &mut pool);
        let b = [5i32, 6, 7, 8];
        let mut tb = BlockTable::new(sh, ps);
        tb.ensure_capacity(b.len(), &mut pool).unwrap();
        ix.register(&b, &tb, &mut pool);
        assert_eq!(ix.entries(), 2);

        // both runs still referenced by their tables: nothing is idle
        assert_eq!(ix.evict_idle(&mut pool), 0);

        // a's table lets go → a is idle and LRU → evicted first
        ta.release_all(&mut pool);
        assert_eq!(ix.evict_idle(&mut pool), 1);
        assert_eq!(ix.entries(), 1);
        assert_eq!(ix.adopt(&a, &mut ta, &mut pool), 0, "a's run is gone");

        tb.release_all(&mut pool);
        assert_eq!(ix.evict_idle(&mut pool), 1);
        assert_eq!(ix.evict_idle(&mut pool), 0);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn prefix_index_cap_evicts_for_room() {
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 1); // room for one entry
        let a = [1i32, 2, 3, 4];
        let mut ta = BlockTable::new(sh, ps);
        ta.ensure_capacity(a.len(), &mut pool).unwrap();
        ix.register(&a, &ta, &mut pool);
        ta.release_all(&mut pool); // a idle

        let b = [5i32, 6, 7, 8];
        let mut tb = BlockTable::new(sh, ps);
        tb.ensure_capacity(b.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&b, &tb, &mut pool), 1, "cap evicts the idle run");
        assert_eq!(ix.entries(), 1);
        // with b's run busy (tb still holds it), nothing can make room
        let c = [9i32, 9, 9, 9];
        let mut tc = BlockTable::new(sh, ps);
        tc.ensure_capacity(c.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&c, &tc, &mut pool), 0, "no idle run to evict");

        tb.release_all(&mut pool);
        tc.release_all(&mut pool);
        ix.clear(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn prefix_index_eviction_order_follows_adoption_recency() {
        // `evict_idle` must pick the least-recently-*used* run, and a
        // hit (adopt) counts as use — registration order alone is not
        // the LRU order.
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 64);

        let a = [1i32, 2, 3, 4];
        let b = [5i32, 6, 7, 8];
        let c = [9i32, 10, 11, 12];
        let mut tables = Vec::new();
        for p in [&a[..], &b, &c] {
            let mut t = BlockTable::new(sh, ps);
            t.ensure_capacity(p.len(), &mut pool).unwrap();
            ix.register(p, &t, &mut pool);
            tables.push(t);
        }
        // touch a (oldest-registered) via adoption, then idle everything
        let mut ta = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&a, &mut ta, &mut pool), 3);
        ta.release_all(&mut pool);
        for t in &mut tables {
            t.release_all(&mut pool);
        }

        // eviction order is now b, c, a — not registration order a, b, c
        assert_eq!(ix.evict_idle(&mut pool), 1);
        let mut probe = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&b, &mut probe, &mut pool), 0, "b evicted first (LRU)");
        assert_eq!(ix.adopt(&a, &mut probe, &mut pool), 3, "a survives: its stamp was bumped");
        probe.release_all(&mut pool);

        assert_eq!(ix.evict_idle(&mut pool), 1);
        let mut probe2 = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&c, &mut probe2, &mut pool), 0, "c evicted second");
        assert_eq!(ix.adopt(&a, &mut probe2, &mut pool), 3, "a evicted last");
        probe2.release_all(&mut pool);

        ix.clear(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn prefix_index_chain_and_tail_keys_stay_disjoint() {
        // Chain keys are block-aligned prefixes (length ≡ 0 mod
        // page_size); tail keys are whole prompts with a partial last
        // block (length ≢ 0).  A 6-token prompt's tail entry must never
        // satisfy another prompt's chain probe, and a longer prompt's
        // chain entries must never masquerade as its tail.
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 64);

        // short: chain [1..4] + tail [1..6] (2 valid rows)
        let short = [1i32, 2, 3, 4, 5, 6];
        let mut ts = BlockTable::new(sh, ps);
        ts.ensure_capacity(short.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&short, &ts, &mut pool), 2);
        // long shares block 0: adds only the chain entry [1..8]
        let long = [1i32, 2, 3, 4, 5, 6, 7, 8];
        let mut tl = BlockTable::new(sh, ps);
        tl.ensure_capacity(long.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&long, &tl, &mut pool), 1);
        assert_eq!(ix.entries(), 3);

        // the long prompt adopts its two chain blocks — the short
        // prompt's 2-row tail at key [1..6] must not leak into the walk
        let mut al = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&long, &mut al, &mut pool), 7, "2 chain blocks, capped at len-1");
        assert_eq!(al.blocks(), 2);

        // the short prompt adopts chain + its own tail (rows = 2, not a
        // full block's 4): 4 + 2 = 6, capped at len - 1 = 5
        let mut ash = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&short, &mut ash, &mut pool), 5);
        assert_eq!(ash.blocks(), 2);
        assert_eq!(ash.locate(0, 0, 4), ts.locate(0, 0, 4), "tail pages are short's");

        // a 7-token prompt extending `short` matches no tail key
        // (entries hold [1..6], not [1..7]) and only block 0's chain
        let seven = [1i32, 2, 3, 4, 5, 6, 9];
        let mut a7 = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&seven, &mut a7, &mut pool), 4, "chain only — tail key differs");
        assert_eq!(a7.blocks(), 1);

        for t in [&mut ts, &mut tl, &mut al, &mut ash, &mut a7] {
            t.release_all(&mut pool);
        }
        ix.clear(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn prefix_index_reregisters_after_eviction() {
        // Eviction must fully retire a run: the key misses, the pages
        // return to the free list, and a fresh prefill of the same
        // prompt registers (and adopts) again from scratch.
        let sh = ix_shape();
        let ps = 4;
        let mut pool = PagePool::new(ps, sh.head_dim, 32);
        let mut ix = PrefixIndex::new(sh, ps, 64);

        let prompt = [1i32, 2, 3, 4, 5, 6];
        let mut t1 = BlockTable::new(sh, ps);
        t1.ensure_capacity(prompt.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&prompt, &t1, &mut pool), 2);
        t1.release_all(&mut pool);
        assert_eq!(ix.evict_idle(&mut pool), 1);
        assert_eq!(ix.evict_idle(&mut pool), 1);
        assert_eq!(ix.entries(), 0);
        assert_eq!(pool.used_pages(), 0, "evicted runs release their pages");

        let mut miss = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&prompt, &mut miss, &mut pool), 0, "evicted key misses");

        // a new owner prefills the same prompt: registration works again
        let mut t2 = BlockTable::new(sh, ps);
        t2.ensure_capacity(prompt.len(), &mut pool).unwrap();
        assert_eq!(ix.register(&prompt, &t2, &mut pool), 2);
        let mut adopter = BlockTable::new(sh, ps);
        assert_eq!(ix.adopt(&prompt, &mut adopter, &mut pool), 5);
        assert_eq!(adopter.locate(0, 0, 2), t2.locate(0, 0, 2), "fresh pages, shared again");

        adopter.release_all(&mut pool);
        t2.release_all(&mut pool);
        ix.clear(&mut pool);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn pages_needed_math() {
        let sh = shape();
        assert_eq!(BlockTable::pages_needed(sh, 2, 0), 0);
        assert_eq!(BlockTable::pages_needed(sh, 2, 1), 6);
        assert_eq!(BlockTable::pages_needed(sh, 2, 2), 6);
        assert_eq!(BlockTable::pages_needed(sh, 2, 3), 12);
        let pool = PagePool::for_budget(sh, 2, 6 * 2 * 4 * 2 * sh.head_dim);
        assert_eq!(pool.num_pages(), 6);
        assert_eq!(pool.page_bytes(), 2 * 4 * 2 * sh.head_dim);
    }

    #[test]
    fn sharded_table_mirrors_ladder_ops_across_shards() {
        // two shards, symmetric pools: every capacity/migrate/swap op
        // must leave identical occupancy on both shards' pools.
        let sh = shape(); // per-shard geometry
        let group = sh.layers * sh.kv_heads;
        let mut pools: Vec<TieredPagePool> = (0..2)
            .map(|_| TieredPagePool::new(2, sh.head_dim, 4 * group, 4 * group, PcieLink::default()))
            .collect();
        let mut st = ShardedTable::new(sh, 2, 2);
        assert_eq!(st.n_shards(), 2);

        st.ensure_capacity(4, &mut pools).unwrap();
        assert_eq!(st.blocks(), 2);
        assert_eq!(st.capacity_tokens(), 4);
        assert_eq!(st.pages_held(), 2 * group, "per-shard page count");
        assert_eq!(pools[0].device().used_pages(), pools[1].device().used_pages());

        // cold-block migration mirrors
        let b = st.coldest_migratable_block(false, &pools).unwrap();
        assert_eq!(st.migrate_block_to_host(b, &mut pools).unwrap(), group);
        assert_eq!(st.host_blocks(), 1);
        for p in &pools {
            assert_eq!(p.host().used_pages(), group);
            assert_eq!(p.stats().pages_moved, group as u64, "each shard charges its own link");
        }

        // swap-out / swap-in round trip mirrors
        let parked = st.suspend_to_host(&mut pools).unwrap();
        assert_eq!(parked, group, "one device block left to park per shard");
        assert_eq!(st.device_blocks(), 0);
        assert_eq!(st.suspendable_pages(&pools), Some(0));
        assert_eq!(st.resume_from_host(&mut pools).unwrap(), 2 * group);
        assert_eq!(st.host_blocks(), 0);
        for p in &pools {
            assert_eq!(p.host().used_pages(), 0);
            assert_eq!(p.device().used_pages(), 2 * group);
        }

        // promotion surface: nothing host-resident → no hottest block
        assert_eq!(st.hottest_host_block(), None);
        st.mark_gathered(7);

        st.release_all_tiered(&mut pools);
        for p in &pools {
            assert_eq!(p.free_pages_total(), p.total_pages());
        }
    }

    // --- page codec ---------------------------------------------------

    #[test]
    fn codec_row_and_page_bytes() {
        assert_eq!(PageCodec::F32.row_bytes(64), 256);
        assert_eq!(PageCodec::Int8.row_bytes(64), 68);
        assert_eq!(kv_page_bytes(16, 64), kv_page_bytes_codec(16, 64, PageCodec::F32));
        // int8 pages approach 4× smaller as head_dim grows
        assert!(kv_page_bytes_codec(16, 64, PageCodec::Int8) * 3 < kv_page_bytes(16, 64));
    }

    #[test]
    fn prop_quantize_roundtrip_within_half_scale() {
        use crate::proptest::check;
        check(200, |rng| {
            let d = rng.range(1, 96);
            // mix of magnitudes so scales vary case to case
            let amp = *rng.pick(&[1e-3f32, 1.0, 37.5, 2048.0]);
            let row: Vec<f32> = rng.f32_vec(d).iter().map(|x| x * amp).collect();
            let mut q = vec![0i8; d];
            let scale = quantize_row_i8(&row, &mut q);
            let max_abs = row.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            crate::prop_ensure!(scale > 0.0, "scale must stay positive, got {scale}");
            for (x, &qi) in row.iter().zip(&q) {
                let err = (x - qi as f32 * scale).abs();
                // symmetric rounding: worst case half a quantization
                // step, i.e. scale/2 = max|x|/254
                let bound = max_abs / 254.0 + max_abs * 1e-6 + f32::EPSILON;
                crate::prop_ensure!(
                    err <= bound,
                    "d={d} amp={amp}: err {err} > bound {bound} (x={x}, q={qi}, scale={scale})"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn quantize_zero_row_is_exact() {
        let row = [0.0f32; 8];
        let mut q = [7i8; 8];
        let scale = quantize_row_i8(&row, &mut q);
        assert_eq!(scale, 1.0, "all-zero rows take the neutral scale");
        assert!(q.iter().all(|&x| x == 0));
    }

    #[test]
    fn prop_int8_pool_accounting_no_leak() {
        use crate::proptest::check;
        check(60, |rng| {
            let page_size = rng.range(1, 6);
            let head_dim = rng.range(1, 17);
            let num_pages = rng.range(2, 10);
            let mut pool = PagePool::with_codec(page_size, head_dim, num_pages, PageCodec::Int8);
            // random alloc / clone / release walk, tracking live handles
            // (clones alias pages, so count every handle separately)
            let mut live: Vec<u32> = Vec::new();
            for _ in 0..rng.range(10, 60) {
                match rng.range(0, 3) {
                    0 => {
                        if let Some(p) = pool.alloc() {
                            let k = rng.f32_vec(head_dim);
                            let v = rng.f32_vec(head_dim);
                            pool.write_row(p, rng.range(0, page_size), &k, &v);
                            live.push(p);
                        }
                    }
                    1 => {
                        if !live.is_empty() {
                            let src = live[rng.range(0, live.len())];
                            if let Some(c) = pool.clone_page(src) {
                                live.push(c);
                            }
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.range(0, live.len());
                            pool.release(live.swap_remove(i));
                        }
                    }
                }
                crate::prop_ensure!(
                    pool.used_pages() + pool.free_pages() == pool.num_pages(),
                    "page accounting must always balance"
                );
            }
            for p in live.drain(..) {
                pool.release(p);
            }
            crate::prop_ensure!(
                pool.free_pages() == num_pages,
                "all pages must return to the free list: {} of {num_pages}",
                pool.free_pages()
            );
            Ok(())
        });
    }

    #[test]
    fn prop_int8_pool_rows_decode_within_tolerance() {
        use crate::proptest::check;
        check(60, |rng| {
            let page_size = rng.range(1, 8);
            let head_dim = rng.range(1, 33);
            let mut pool = PagePool::with_codec(page_size, head_dim, 2, PageCodec::Int8);
            let page = pool.alloc().unwrap();
            for slot in 0..page_size {
                let k = rng.f32_vec(head_dim);
                let v = rng.f32_vec(head_dim);
                pool.write_row(page, slot, &k, &v);
                let (kd, vd) = (pool.k_row_f32(page, slot), pool.v_row_f32(page, slot));
                let kmax = k.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let vmax = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                for t in 0..head_dim {
                    crate::prop_ensure!(
                        (k[t] - kd[t]).abs() <= kmax / 254.0 + 1e-6,
                        "k slot {slot} elem {t}: {} vs {}",
                        k[t],
                        kd[t]
                    );
                    crate::prop_ensure!(
                        (v[t] - vd[t]).abs() <= vmax / 254.0 + 1e-6,
                        "v slot {slot} elem {t}: {} vs {}",
                        v[t],
                        vd[t]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_offload_promote_roundtrip_preserves_rows() {
        // device→host→device migration must move quantized bytes and
        // scales together: decoded rows are bit-identical afterwards.
        let (page_size, head_dim) = (4, 8);
        let mut pools =
            TieredPagePool::new_with_codec(page_size, head_dim, 2, 2, PcieLink::default(), PageCodec::Int8);
        assert_eq!(pools.codec(), PageCodec::Int8);
        let mut rng = crate::proptest::Rng::new(11);
        let page = pools.device_mut().alloc().unwrap();
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..page_size).map(|_| (rng.f32_vec(head_dim), rng.f32_vec(head_dim))).collect();
        for (slot, (k, v)) in rows.iter().enumerate() {
            pools.write_row(Tier::Device, page, slot, k, v);
        }
        let before: Vec<(Vec<f32>, Vec<f32>)> = (0..page_size)
            .map(|s| (pools.device().k_row_f32(page, s), pools.device().v_row_f32(page, s)))
            .collect();
        let hp = pools.offload_page(page).unwrap();
        for (s, (k, v)) in before.iter().enumerate() {
            assert_eq!(&pools.host().k_row_f32(hp, s), k, "host K slot {s}");
            assert_eq!(&pools.host().v_row_f32(hp, s), v, "host V slot {s}");
        }
        let dp = pools.promote_page(hp).unwrap();
        for (s, (k, v)) in before.iter().enumerate() {
            assert_eq!(&pools.device().k_row_f32(dp, s), k, "promoted K slot {s}");
            assert_eq!(&pools.device().v_row_f32(dp, s), v, "promoted V slot {s}");
        }
    }

    // --- speculative rollback: truncate -------------------------------

    #[test]
    fn truncate_pops_trailing_blocks_to_free_list() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        fill_rows(&t, &mut pools, sh, 4);

        // rewinding within the last block is logical-only: no pages move
        assert_eq!(t.truncate(3, &mut pools).unwrap(), 0);
        assert_eq!(t.blocks(), 2);
        assert_eq!(pools.device().used_pages(), 2 * group);

        // dropping below the block boundary pops the whole trailing
        // group back to the device free list; kept rows stay intact
        assert_eq!(t.truncate(2, &mut pools).unwrap(), group);
        assert_eq!(t.blocks(), 1);
        assert_eq!(t.capacity_tokens(), 2);
        assert_eq!(pools.device().used_pages(), group);
        check_rows(&t, &pools, sh, 2);

        // regrowing reuses the freed pages; truncate to empty drains all
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.truncate(0, &mut pools).unwrap(), 2 * group);
        assert_eq!(t.blocks(), 0);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn truncate_releases_host_blocks_to_the_host_pool() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        fill_rows(&t, &mut pools, sh, 4);
        t.migrate_block_to_host(1, &mut pools).unwrap();
        assert_eq!(t.block_tier(1), Tier::Host);

        // the popped block was host-resident: its pages go back to the
        // host pool, the device pool is untouched
        let dev_used = pools.device().used_pages();
        assert_eq!(t.truncate(2, &mut pools).unwrap(), group);
        assert_eq!(pools.host().used_pages(), 0);
        assert_eq!(pools.device().used_pages(), dev_used);
        check_rows(&t, &pools, sh, 2);

        // a fresh block after rollback starts device-resident again
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.block_tier(1), Tier::Device);
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn truncate_refuses_shared_blocks_before_mutating() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 4 * group, 4 * group, PcieLink::default());
        let mut owner = BlockTable::new(sh, 2);
        owner.ensure_capacity(4, pools.device_mut()).unwrap();
        let mut adopter = BlockTable::new(sh, 2);
        adopter.push_shared_block(&owner.block_group(0), pools.device_mut());
        adopter.push_shared_block(&owner.block_group(1), pools.device_mut());

        // popping an adopted block would drop a reference the owner
        // still counts on: refused all-or-nothing, nothing moved
        let used = pools.device().used_pages();
        assert_eq!(adopter.truncate(0, &mut pools), Err(PageAllocError::SharedPage));
        assert_eq!(adopter.blocks(), 2);
        assert_eq!(pools.device().used_pages(), used);
        for &p in &owner.block_group(1) {
            assert_eq!(pools.device().ref_count(p), 2);
        }

        // after a COW split the tail block is private and pops cleanly;
        // the still-shared block 0 keeps refusing
        adopter.cow_unshare(2, 4, pools.device_mut()).unwrap();
        assert_eq!(adopter.truncate(2, &mut pools).unwrap(), group);
        for &p in &owner.block_group(1) {
            assert_eq!(pools.device().ref_count(p), 1, "owner keeps its tail block");
        }
        assert_eq!(adopter.truncate(0, &mut pools), Err(PageAllocError::SharedPage));

        adopter.release_all_tiered(&mut pools);
        owner.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn truncate_int8_keeps_scales_coherent() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools = TieredPagePool::new_with_codec(
            2,
            sh.head_dim,
            2 * group,
            2 * group,
            PcieLink::default(),
            PageCodec::Int8,
        );
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        let mut rng = crate::proptest::Rng::new(23);
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let (k, v) = (rng.f32_vec(sh.head_dim), rng.f32_vec(sh.head_dim));
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    pools.write_row(tier, page, slot, &k, &v);
                }
            }
        }
        let decoded = |t: &BlockTable, pools: &TieredPagePool, r: usize| -> Vec<Vec<f32>> {
            let mut out = Vec::new();
            for l in 0..sh.layers {
                for g in 0..sh.kv_heads {
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    out.push(pools.pool(tier).k_row_f32(page, slot));
                    out.push(pools.pool(tier).v_row_f32(page, slot));
                }
            }
            out
        };
        let (r0, r1) = (decoded(&t, &pools, 0), decoded(&t, &pools, 1));

        // rollback pops the quantized pages together with their scale
        // side-channel; kept rows decode bit-identically
        assert_eq!(t.truncate(2, &mut pools).unwrap(), group);
        assert_eq!(decoded(&t, &pools, 0), r0);
        assert_eq!(decoded(&t, &pools, 1), r1);

        // a regrown tail re-quantizes into fresh pages without
        // disturbing the survivors' scales
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 2..4 {
                    let (k, v) = (rng.f32_vec(sh.head_dim), rng.f32_vec(sh.head_dim));
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    pools.write_row(tier, page, slot, &k, &v);
                }
            }
        }
        assert_eq!(decoded(&t, &pools, 0), r0);
        assert_eq!(decoded(&t, &pools, 1), r1);
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn sharded_truncate_mirrors_across_shards() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools: Vec<TieredPagePool> = (0..2)
            .map(|_| {
                TieredPagePool::new(2, sh.head_dim, 4 * group, 4 * group, PcieLink::default())
            })
            .collect();
        let mut st = ShardedTable::new(sh, 2, 2);
        st.ensure_capacity(4, &mut pools).unwrap();
        st.migrate_block_to_host(0, &mut pools).unwrap();

        // the per-shard count is returned once; every shard's pools
        // move in lockstep, tier by tier
        assert_eq!(st.truncate(2, &mut pools).unwrap(), group);
        assert_eq!(st.blocks(), 1);
        for p in &pools {
            assert_eq!(p.device().used_pages(), 0, "device tail popped on every shard");
            assert_eq!(p.host().used_pages(), group, "host-resident block kept");
        }
        assert_eq!(st.truncate(0, &mut pools).unwrap(), group);
        for p in &pools {
            assert_eq!(p.free_pages_total(), p.total_pages());
        }
    }

    /// Random append/share/COW/offload/truncate schedules: truncation
    /// returns exactly `(blocks dropped) × group` pages, each popped
    /// page lands on its own tier's free list, shared (refcount > 1)
    /// blocks are refused without side effects, surviving rows keep
    /// decoding bit-identically (host-tier and Int8-scale coherence),
    /// and a full drain leaves zero leaked pages.
    #[test]
    fn prop_truncate_schedules_account_exactly() {
        use crate::proptest::check;
        check(40, |rng| {
            let sh = CacheShape { layers: 2, kv_heads: 2, max_seq: 16, head_dim: 4 };
            let group = sh.layers * sh.kv_heads;
            let ps = 2usize;
            let max_blocks = sh.max_seq / ps;
            let codec = *rng.pick(&[PageCodec::F32, PageCodec::Int8]);
            // device fits both tables fully unshared, host fits the
            // whole owner: growth and COW never fail for capacity
            let mut pools = TieredPagePool::new_with_codec(
                ps,
                sh.head_dim,
                2 * max_blocks * group,
                max_blocks * group,
                PcieLink::default(),
                codec,
            );
            let mut owner = BlockTable::new(sh, ps);
            let mut adopter = BlockTable::new(sh, ps);
            // decoded-row model of the owner: expected[r] holds one
            // (k, v) pair per (layer, head) plane, as read back through
            // the codec right after the write
            let mut expected: Vec<Vec<(Vec<f32>, Vec<f32>)>> = Vec::new();
            // highest owner block ever shared, plus one.  The engine
            // never rolls back into the shared prefix (`cow_unshare`
            // precedes every speculative write), so neither does the
            // schedule: below the floor the owner-side `shared` flags
            // cannot catch an adoption that happened via
            // `push_shared_block`, and popping would silently keep the
            // refcounted pages alive, breaking exact accounting.
            let mut floor_blocks = 0usize;
            for _ in 0..rng.range(20, 60) {
                match rng.below(6) {
                    // append: grow the owner and fill the new rows
                    0 => {
                        let cur = owner.capacity_tokens();
                        if cur < sh.max_seq {
                            let target = rng.range(cur + 1, sh.max_seq + 1);
                            owner.ensure_capacity(target, pools.device_mut()).unwrap();
                            for r in expected.len()..owner.capacity_tokens() {
                                let mut planes = Vec::new();
                                for l in 0..sh.layers {
                                    for g in 0..sh.kv_heads {
                                        let (k, v) =
                                            (rng.f32_vec(sh.head_dim), rng.f32_vec(sh.head_dim));
                                        let (tier, page, slot) = owner.locate_tiered(l, g, r);
                                        pools.write_row(tier, page, slot, &k, &v);
                                        planes.push((
                                            pools.pool(tier).k_row_f32(page, slot),
                                            pools.pool(tier).v_row_f32(page, slot),
                                        ));
                                    }
                                }
                                expected.push(planes);
                            }
                        }
                    }
                    // share: the adopter adopts the owner's next block
                    1 => {
                        let b = adopter.blocks();
                        if b < owner.blocks() && owner.block_tier(b) == Tier::Device {
                            adopter.push_shared_block(&owner.block_group(b), pools.device_mut());
                            floor_blocks = floor_blocks.max(b + 1);
                        }
                    }
                    // COW: split a random adopted row range
                    2 => {
                        if adopter.blocks() > 0 {
                            let cap = adopter.capacity_tokens();
                            let first = rng.range(0, cap);
                            let last = rng.range(first + 1, cap + 1);
                            adopter.cow_unshare(first, last, pools.device_mut()).unwrap();
                        }
                    }
                    // offload / promote a random owner block (shared
                    // blocks refuse via pinning — ignored here)
                    3 => {
                        if owner.blocks() > 0 {
                            let b = rng.range(0, owner.blocks());
                            match owner.block_tier(b) {
                                Tier::Device => {
                                    let _ = owner.migrate_block_to_host(b, &mut pools);
                                }
                                Tier::Host => {
                                    let _ = owner.promote_block_to_device(b, &mut pools);
                                }
                            }
                        }
                    }
                    // owner rollback: exact per-tier free-list accounting
                    4 => {
                        let floor = floor_blocks * ps;
                        if owner.capacity_tokens() > floor {
                            let tokens = rng.range(floor, owner.capacity_tokens() + 1);
                            let keep = tokens.div_ceil(ps);
                            let tiers: Vec<Tier> =
                                (keep..owner.blocks()).map(|b| owner.block_tier(b)).collect();
                            let (df, hf) =
                                (pools.device().free_pages(), pools.host().free_pages());
                            let before = owner.blocks();
                            let pages = owner
                                .truncate(tokens, &mut pools)
                                .map_err(|e| format!("owner truncate failed: {e:?}"))?;
                            crate::prop_ensure!(
                                pages == (before - keep) * group,
                                "owner popped {pages}, expected {} blocks × {group}",
                                before - keep
                            );
                            let dev =
                                tiers.iter().filter(|&&t| t == Tier::Device).count() * group;
                            let host =
                                tiers.iter().filter(|&&t| t == Tier::Host).count() * group;
                            crate::prop_ensure!(
                                pools.device().free_pages() == df + dev
                                    && pools.host().free_pages() == hf + host,
                                "popped pages must land on their own tier's free list"
                            );
                            crate::prop_ensure!(
                                owner.blocks() == keep && owner.capacity_tokens() == keep * ps,
                                "rollback geometry"
                            );
                            expected.truncate(owner.capacity_tokens());
                        }
                    }
                    // adopter rollback: shared blocks refuse in place
                    _ => {
                        if adopter.blocks() > 0 {
                            let tokens = rng.range(0, adopter.capacity_tokens() + 1);
                            let keep = tokens.div_ceil(ps);
                            let shared =
                                (keep..adopter.blocks()).any(|b| adopter.block_shared(b));
                            let before = adopter.blocks();
                            let free = pools.free_pages_total();
                            match adopter.truncate(tokens, &mut pools) {
                                Err(PageAllocError::SharedPage) => {
                                    crate::prop_ensure!(shared, "spurious SharedPage refusal");
                                    crate::prop_ensure!(
                                        adopter.blocks() == before
                                            && pools.free_pages_total() == free,
                                        "refusal must not mutate"
                                    );
                                }
                                Err(e) => return Err(format!("adopter truncate: {e:?}")),
                                Ok(pages) => {
                                    crate::prop_ensure!(
                                        !shared,
                                        "popped {pages} pages through a shared block"
                                    );
                                    crate::prop_ensure!(
                                        pages == (before - keep) * group
                                            && pools.free_pages_total() == free + pages,
                                        "adopter accounting: popped {pages} of {} blocks",
                                        before - keep
                                    );
                                }
                            }
                        }
                    }
                }
                // every surviving owner row still decodes to the value
                // observed at write time — across migrations, COW splits
                // elsewhere, and rollbacks (under Int8 the scale
                // side-channel travels with its page)
                for (r, planes) in expected.iter().enumerate() {
                    for l in 0..sh.layers {
                        for g in 0..sh.kv_heads {
                            let (tier, page, slot) = owner.locate_tiered(l, g, r);
                            let (ek, ev) = &planes[l * sh.kv_heads + g];
                            let pool = pools.pool(tier);
                            crate::prop_ensure!(
                                pool.k_row_f32(page, slot) == *ek
                                    && pool.v_row_f32(page, slot) == *ev,
                                "row {r} plane ({l},{g}) diverged ({codec:?})"
                            );
                        }
                    }
                }
            }
            owner.release_all_tiered(&mut pools);
            adopter.release_all_tiered(&mut pools);
            crate::prop_ensure!(
                pools.free_pages_total() == pools.total_pages(),
                "leak at drain: {} free of {}",
                pools.free_pages_total(),
                pools.total_pages()
            );
            Ok(())
        });
    }
}
