//! KV-cache manager: paged block-table caches, contiguous per-sequence
//! caches, batch packing, and the host/device tier accounting the
//! CPU–GPU cooperative strategy uses.
//!
//! Two layouts coexist:
//!
//! * **Contiguous** — the AOT decode artifact consumes caches of shape
//!   `[L, B, Nkv, max_seq, D]` for a fixed batch bucket `B`.  Sequences
//!   own caches of shape `[L, 1, Nkv, max_seq, D]`; `pack_batch` /
//!   `unpack_batch` move any (≤ B)-subset of sequences in and out of the
//!   batch tensor — the memcpy boundary of continuous batching.
//! * **Paged** — [`PagePool`] owns fixed-size pages of `page_size` KV
//!   rows, one page per (layer, kv-head) block; a per-sequence
//!   [`BlockTable`] maps logical token blocks to pages.  Pages are
//!   ref-counted (prefix sharing keeps a page alive across sequences)
//!   and recycled through a free list, so a 16-token sequence holds one
//!   block instead of a `max_seq` slab.  Attention gathers rows through
//!   the table (`attention::flash::KvView`), bit-identically to the
//!   contiguous layout.

use anyhow::{bail, Result};

/// Cache geometry (from the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheShape {
    pub layers: usize,
    pub kv_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl CacheShape {
    /// f32 elements of one sequence's K (or V) cache.
    pub fn seq_elems(&self) -> usize {
        self.layers * self.kv_heads * self.max_seq * self.head_dim
    }

    /// Elements of one layer-row within a single-sequence cache
    /// (`[Nkv, S, D]` — also the per-(layer, slot) plane of a batch
    /// tensor, which is exactly what batched decode attention consumes).
    pub fn layer_elems(&self) -> usize {
        self.kv_heads * self.max_seq * self.head_dim
    }

    /// Bytes of one sequence's full KV (K + V) cache.
    pub fn seq_bytes(&self) -> usize {
        2 * 4 * self.seq_elems()
    }

    /// Flat offset of `(layer, slot)` inside a `[L, B, Nkv, S, D]` batch
    /// plane — the start of that sequence's `[Nkv, S, D]` sub-plane.
    pub fn batch_slot_offset(&self, batch: usize, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < batch);
        (layer * batch + slot) * self.layer_elems()
    }

    /// Flat offset of `(layer, slot, kv_head, row)` inside a batch plane
    /// — where a decode step writes the new token's K/V row.
    pub fn batch_row_offset(
        &self,
        batch: usize,
        layer: usize,
        slot: usize,
        kv_head: usize,
        row: usize,
    ) -> usize {
        debug_assert!(kv_head < self.kv_heads && row < self.max_seq);
        self.batch_slot_offset(batch, layer, slot)
            + (kv_head * self.max_seq + row) * self.head_dim
    }
}

/// One sequence's KV cache (K and V planes, flat f32, `[L,1,Nkv,S,D]`).
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub shape: CacheShape,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl SeqCache {
    /// Zero-initialized cache (a fresh slot).
    pub fn zeros(shape: CacheShape) -> Self {
        let n = shape.seq_elems();
        Self { shape, k: vec![0.0; n], v: vec![0.0; n] }
    }
}

/// Pack `seqs` (each `[L,1,Nkv,S,D]`) into a `[L,B,Nkv,S,D]` batch plane.
/// Unused slots stay zero.  Returns the flat batch tensor.
pub fn pack_batch(
    shape: CacheShape,
    batch: usize,
    seqs: &[(usize, &[f32])],
) -> Result<Vec<f32>> {
    let le = shape.layer_elems();
    let mut out = vec![0.0f32; shape.layers * batch * le];
    for &(slot, data) in seqs {
        if slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &data[layer * le..][..le];
            let dst = &mut out[(layer * batch + slot) * le..][..le];
            dst.copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Scatter a `[L,B,Nkv,S,D]` batch plane back into per-sequence caches.
pub fn unpack_batch(
    shape: CacheShape,
    batch: usize,
    plane: &[f32],
    seqs: &mut [(usize, &mut [f32])],
) -> Result<()> {
    let le = shape.layer_elems();
    if plane.len() != shape.layers * batch * le {
        bail!("batch plane has {} elems, expected {}", plane.len(), shape.layers * batch * le);
    }
    for (slot, data) in seqs.iter_mut() {
        if *slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &plane[(layer * batch + *slot) * le..][..le];
            data[layer * le..][..le].copy_from_slice(src);
        }
    }
    Ok(())
}

/// Placement tier for KV memory (§4.4): a whole contiguous layer cache
/// under the legacy [`CachePool`], or a single page/block under the
/// tiered paged cache ([`TieredPagePool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Device (GPU/NPU) resident.
    Device,
    /// Host (CPU) resident — the cooperative strategy's pre-L_CPU layers.
    Host,
}

/// Capacity-tracking cache pool with per-tier accounting.
#[derive(Debug)]
pub struct CachePool {
    pub shape: CacheShape,
    device_budget_bytes: usize,
    device_used_bytes: usize,
    host_used_bytes: usize,
    active: usize,
}

impl CachePool {
    pub fn new(shape: CacheShape, device_budget_bytes: usize) -> Self {
        Self {
            shape,
            device_budget_bytes,
            device_used_bytes: 0,
            host_used_bytes: 0,
            active: 0,
        }
    }

    /// Can another sequence's cache be placed on-device?
    pub fn has_device_room(&self) -> bool {
        self.device_used_bytes + self.shape.seq_bytes() <= self.device_budget_bytes
    }

    /// Allocate a cache; spills to Host when the device is full (the
    /// engine treats Host-tier caches via the cooperative path).
    pub fn allocate(&mut self) -> (SeqCache, Tier) {
        let tier = if self.has_device_room() { Tier::Device } else { Tier::Host };
        match tier {
            Tier::Device => self.device_used_bytes += self.shape.seq_bytes(),
            Tier::Host => self.host_used_bytes += self.shape.seq_bytes(),
        }
        self.active += 1;
        (SeqCache::zeros(self.shape), tier)
    }

    /// Release a cache allocated at `tier`.
    pub fn release(&mut self, tier: Tier) {
        match tier {
            Tier::Device => {
                self.device_used_bytes =
                    self.device_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
            Tier::Host => {
                self.host_used_bytes =
                    self.host_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
        }
        self.active = self.active.saturating_sub(1);
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn device_used_bytes(&self) -> usize {
        self.device_used_bytes
    }

    pub fn host_used_bytes(&self) -> usize {
        self.host_used_bytes
    }
}

// ---------------------------------------------------------------------
// Paged KV: PagePool + BlockTable
// ---------------------------------------------------------------------

/// Marker for an unallocated block-table slot.
pub const NO_PAGE: u32 = u32::MAX;

/// Bytes of one KV page (K + V rows at f32) — the single source of
/// truth for page sizing: pool budgets, migration accounting and the
/// offload page planner all go through it.
pub fn kv_page_bytes(page_size: usize, head_dim: usize) -> usize {
    2 * 4 * page_size * head_dim
}

/// Why a page allocation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageAllocError {
    /// The pool's free list is empty — the caller should preempt a
    /// sequence (or shed load) and retry.
    OutOfPages,
    /// The sequence would exceed its `max_seq` block budget.
    ExceedsMaxSeq,
}

impl std::fmt::Display for PageAllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::OutOfPages => write!(f, "KV page pool exhausted"),
            Self::ExceedsMaxSeq => write!(f, "sequence exceeds max_seq block budget"),
        }
    }
}

impl std::error::Error for PageAllocError {}

/// A fixed-size page allocator for KV rows.
///
/// One page holds `page_size` rows of `head_dim` f32 for K and the same
/// for V, and belongs to exactly one (layer, kv-head) plane of one
/// sequence block (ownership is the [`BlockTable`]'s — the pool only
/// tracks ref counts).  `refs == 0` pages sit on the free list.
#[derive(Debug)]
pub struct PagePool {
    page_size: usize,
    head_dim: usize,
    /// `[num_pages, page_size, head_dim]` flat K rows.
    k: Vec<f32>,
    /// Same shape, V rows.
    v: Vec<f32>,
    refs: Vec<u32>,
    free: Vec<u32>,
}

impl PagePool {
    pub fn new(page_size: usize, head_dim: usize, num_pages: usize) -> Self {
        assert!(page_size >= 1, "page_size must be >= 1");
        assert!(head_dim >= 1, "head_dim must be >= 1");
        assert!(num_pages <= NO_PAGE as usize, "num_pages overflows page id space");
        let elems = num_pages * page_size * head_dim;
        Self {
            page_size,
            head_dim,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            refs: vec![0; num_pages],
            // LIFO free list, lowest ids on top.
            free: (0..num_pages as u32).rev().collect(),
        }
    }

    /// Size the pool for a device budget: as many pages as
    /// `budget_bytes` holds at f32 K+V rows (at least one).
    pub fn for_budget(shape: CacheShape, page_size: usize, budget_bytes: usize) -> Self {
        let page_bytes = kv_page_bytes(page_size, shape.head_dim);
        let num_pages = (budget_bytes / page_bytes.max(1)).max(1);
        Self::new(page_size, shape.head_dim, num_pages)
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn head_dim(&self) -> usize {
        self.head_dim
    }

    pub fn num_pages(&self) -> usize {
        self.refs.len()
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.num_pages() - self.free_pages()
    }

    /// Fraction of pages in use, 0.0 ..= 1.0.
    pub fn occupancy(&self) -> f64 {
        if self.refs.is_empty() {
            return 0.0;
        }
        self.used_pages() as f64 / self.num_pages() as f64
    }

    /// Bytes of one page (K + V).
    pub fn page_bytes(&self) -> usize {
        kv_page_bytes(self.page_size, self.head_dim)
    }

    /// Allocate one page (`refs = 1`).  Page contents are stale — the
    /// paged attention contract is that rows `< kv_len` are written
    /// before they are read, and rows `>= kv_len` are never read.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        self.refs[id as usize] = 1;
        Some(id)
    }

    /// Bump a page's ref count (prefix sharing across sequences).
    pub fn retain(&mut self, id: u32) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "retain of free page {id}");
        *r += 1;
    }

    /// Drop one reference; the page returns to the free list at zero.
    pub fn release(&mut self, id: u32) {
        let r = &mut self.refs[id as usize];
        assert!(*r > 0, "release of free page {id}");
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
        }
    }

    /// Reference count of a page (0 = free).
    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs[id as usize]
    }

    /// The flat K row store (`[num_pages, page_size, head_dim]`) —
    /// what `KvView::Paged` gathers from.
    pub fn k_store(&self) -> &[f32] {
        &self.k
    }

    /// The flat V row store, same shape.
    pub fn v_store(&self) -> &[f32] {
        &self.v
    }

    /// Write one token's K and V rows into `slot` of `page`.
    pub fn write_row(&mut self, page: u32, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(slot < self.page_size, "slot {slot} out of page");
        debug_assert!(self.refs[page as usize] > 0, "write to free page {page}");
        let d = self.head_dim;
        let at = (page as usize * self.page_size + slot) * d;
        self.k[at..at + d].copy_from_slice(&k_row[..d]);
        self.v[at..at + d].copy_from_slice(&v_row[..d]);
    }
}

// ---------------------------------------------------------------------
// Tiered paged KV: PcieLink + TieredPagePool
// ---------------------------------------------------------------------

/// Modeled host↔device interconnect that cold-page migration is charged
/// to: a fixed per-transfer setup latency plus bytes over an effective
/// bandwidth.  Batched moves (one block group = `layers × kv_heads`
/// pages) pay the latency once, which is why the engine migrates whole
/// blocks rather than single pages.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PcieLink {
    /// Effective bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-transfer setup latency, seconds.
    pub latency_s: f64,
}

impl Default for PcieLink {
    /// PCIe 3.0 ×16 as calibrated from the paper's Table 3 — the same
    /// ~11.7 GB/s effective bandwidth and 22 µs setup latency that
    /// `sim::volta::VoltaSpec` uses (see `coordinator::offload`).
    fn default() -> Self {
        Self { bandwidth_bps: 11.7e9, latency_s: 22e-6 }
    }
}

impl PcieLink {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        Self { bandwidth_bps, latency_s }
    }

    /// Modeled seconds to move `bytes` as one batched transfer.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps.max(1.0)
    }
}

/// Cumulative migration accounting of a [`TieredPagePool`].
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct MigrationStats {
    /// Pages moved device→host.
    pub pages_moved: u64,
    /// Batched transfers (one per migrated block group).
    pub batches: u64,
    /// Bytes moved over the modeled link.
    pub bytes_moved: u64,
    /// Modeled link seconds charged (`PcieLink::transfer_s` per batch).
    pub modeled_s: f64,
}

/// The two-tier paged KV cache: a device-resident [`PagePool`] that all
/// new blocks allocate from, plus a host-resident pool that cold pages
/// migrate to over the modeled [`PcieLink`].  Page ids are per-pool; a
/// [`BlockTable`]'s per-entry [`Tier`] tag says which pool an id indexes.
///
/// A `host_pages == 0` pool degenerates to the single-tier behavior:
/// migration always refuses and callers fall back to preemption.
#[derive(Debug)]
pub struct TieredPagePool {
    device: PagePool,
    host: PagePool,
    link: PcieLink,
    stats: MigrationStats,
}

impl TieredPagePool {
    pub fn new(
        page_size: usize,
        head_dim: usize,
        device_pages: usize,
        host_pages: usize,
        link: PcieLink,
    ) -> Self {
        Self {
            device: PagePool::new(page_size, head_dim, device_pages),
            host: PagePool::new(page_size, head_dim, host_pages),
            link,
            stats: MigrationStats::default(),
        }
    }

    /// Size both tiers from byte budgets.  The device tier always holds
    /// at least one page; `host_budget_bytes` smaller than a page means
    /// no host tier at all.
    pub fn for_budget(
        shape: CacheShape,
        page_size: usize,
        device_budget_bytes: usize,
        host_budget_bytes: usize,
        link: PcieLink,
    ) -> Self {
        let page_bytes = kv_page_bytes(page_size, shape.head_dim);
        let host_pages = host_budget_bytes / page_bytes.max(1);
        Self {
            device: PagePool::for_budget(shape, page_size, device_budget_bytes),
            host: PagePool::new(page_size, shape.head_dim, host_pages),
            link,
            stats: MigrationStats::default(),
        }
    }

    pub fn device(&self) -> &PagePool {
        &self.device
    }

    /// The device pool — what [`BlockTable::ensure_capacity`] allocates
    /// new blocks from (fresh rows are always written device-side).
    pub fn device_mut(&mut self) -> &mut PagePool {
        &mut self.device
    }

    pub fn host(&self) -> &PagePool {
        &self.host
    }

    pub fn pool(&self, tier: Tier) -> &PagePool {
        match tier {
            Tier::Device => &self.device,
            Tier::Host => &self.host,
        }
    }

    fn pool_mut(&mut self, tier: Tier) -> &mut PagePool {
        match tier {
            Tier::Device => &mut self.device,
            Tier::Host => &mut self.host,
        }
    }

    pub fn page_size(&self) -> usize {
        self.device.page_size()
    }

    pub fn head_dim(&self) -> usize {
        self.device.head_dim()
    }

    /// Bytes of one page (K + V), identical in both tiers.
    pub fn page_bytes(&self) -> usize {
        self.device.page_bytes()
    }

    pub fn total_pages(&self) -> usize {
        self.device.num_pages() + self.host.num_pages()
    }

    pub fn free_pages_total(&self) -> usize {
        self.device.free_pages() + self.host.free_pages()
    }

    pub fn link(&self) -> PcieLink {
        self.link
    }

    pub fn stats(&self) -> MigrationStats {
        self.stats
    }

    /// K row store of one tier (`[num_pages, page_size, head_dim]`).
    pub fn k_store(&self, tier: Tier) -> &[f32] {
        self.pool(tier).k_store()
    }

    /// V row store of one tier, same shape.
    pub fn v_store(&self, tier: Tier) -> &[f32] {
        self.pool(tier).v_store()
    }

    /// Write one token's K/V rows into `slot` of `page` on `tier`.
    /// Fresh blocks live device-side, but writes into already-migrated
    /// blocks (a chunked prefill filling a cold tail) land on host.
    pub fn write_row(&mut self, tier: Tier, page: u32, slot: usize, k_row: &[f32], v_row: &[f32]) {
        self.pool_mut(tier).write_row(page, slot, k_row, v_row);
    }

    /// Move one device page's rows onto a freshly allocated host page;
    /// the device page returns to its free list.  Accounting is the
    /// caller's ([`Self::charge_batch`]) so a multi-page block move is
    /// charged one PCIe setup latency.
    fn offload_page(&mut self, device_page: u32) -> Option<u32> {
        debug_assert_eq!(
            self.device.ref_count(device_page),
            1,
            "migrating a shared page would break the other holder's mapping"
        );
        let host_page = self.host.alloc()?;
        let n = self.device.page_size * self.device.head_dim;
        let src = device_page as usize * n;
        let dst = host_page as usize * n;
        self.host.k[dst..dst + n].copy_from_slice(&self.device.k[src..src + n]);
        self.host.v[dst..dst + n].copy_from_slice(&self.device.v[src..src + n]);
        self.device.release(device_page);
        Some(host_page)
    }

    /// Charge one batched `pages`-page move to the link model.
    fn charge_batch(&mut self, pages: usize) {
        if pages == 0 {
            return;
        }
        let bytes = pages * self.page_bytes();
        self.stats.pages_moved += pages as u64;
        self.stats.batches += 1;
        self.stats.bytes_moved += bytes as u64;
        self.stats.modeled_s += self.link.transfer_s(bytes);
    }
}

/// A sequence's logical-block → page mapping: `[layers, kv_heads,
/// max_blocks]` page ids, where block `b` covers token rows
/// `[b*page_size, (b+1)*page_size)`.  Blocks allocate as a group — one
/// page per (layer, kv-head) — so a sequence always has the same number
/// of blocks in every plane.
#[derive(Debug, Clone)]
pub struct BlockTable {
    layers: usize,
    kv_heads: usize,
    page_size: usize,
    max_blocks: usize,
    /// Allocated logical blocks (all planes).
    blocks: usize,
    table: Vec<u32>,
    /// Per-entry placement tag (parallel to `table`).  Blocks migrate
    /// as a group, so every plane of one block shares a tier.
    tiers: Vec<Tier>,
}

impl BlockTable {
    pub fn new(shape: CacheShape, page_size: usize) -> Self {
        assert!(page_size >= 1, "page_size must be >= 1");
        let max_blocks = shape.max_seq.div_ceil(page_size);
        Self {
            layers: shape.layers,
            kv_heads: shape.kv_heads,
            page_size,
            max_blocks,
            blocks: 0,
            table: vec![NO_PAGE; shape.layers * shape.kv_heads * max_blocks],
            tiers: vec![Tier::Device; shape.layers * shape.kv_heads * max_blocks],
        }
    }

    /// Pages a sequence of `tokens` tokens needs in total under `shape`.
    pub fn pages_needed(shape: CacheShape, page_size: usize, tokens: usize) -> usize {
        shape.layers * shape.kv_heads * tokens.div_ceil(page_size.max(1))
    }

    pub fn layers(&self) -> usize {
        self.layers
    }

    pub fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    pub fn blocks(&self) -> usize {
        self.blocks
    }

    /// Token rows the allocated blocks can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.blocks * self.page_size
    }

    /// Pages currently held (all planes).
    pub fn pages_held(&self) -> usize {
        self.blocks * self.layers * self.kv_heads
    }

    /// Grow until `tokens` rows fit, allocating one page per
    /// (layer, kv-head) per new block.  All-or-nothing per block: a
    /// partial group is rolled back before `OutOfPages` is returned, so
    /// a failed call never leaks pages.
    pub fn ensure_capacity(
        &mut self,
        tokens: usize,
        pool: &mut PagePool,
    ) -> std::result::Result<(), PageAllocError> {
        debug_assert_eq!(pool.page_size(), self.page_size, "pool/table page_size");
        while self.capacity_tokens() < tokens {
            if self.blocks == self.max_blocks {
                return Err(PageAllocError::ExceedsMaxSeq);
            }
            let group = self.layers * self.kv_heads;
            let mut got: Vec<u32> = Vec::with_capacity(group);
            for _ in 0..group {
                match pool.alloc() {
                    Some(p) => got.push(p),
                    None => {
                        for p in got {
                            pool.release(p);
                        }
                        return Err(PageAllocError::OutOfPages);
                    }
                }
            }
            let b = self.blocks;
            let mut it = got.into_iter();
            for l in 0..self.layers {
                for g in 0..self.kv_heads {
                    let at = (l * self.kv_heads + g) * self.max_blocks + b;
                    self.table[at] = it.next().expect("group sized to planes");
                    self.tiers[at] = Tier::Device;
                }
            }
            self.blocks += 1;
        }
        Ok(())
    }

    /// The (tier, page, in-page slot) holding token row `row` of
    /// (`layer`, `kv_head`).  The block must be allocated.
    pub fn locate_tiered(&self, layer: usize, kv_head: usize, row: usize) -> (Tier, u32, usize) {
        let b = row / self.page_size;
        debug_assert!(b < self.blocks, "row {row} beyond allocated blocks");
        let at = (layer * self.kv_heads + kv_head) * self.max_blocks + b;
        debug_assert_ne!(self.table[at], NO_PAGE, "unallocated block {b}");
        (self.tiers[at], self.table[at], row % self.page_size)
    }

    /// The (page, in-page slot) holding token row `row` of
    /// (`layer`, `kv_head`) — single-pool callers that never migrate.
    pub fn locate(&self, layer: usize, kv_head: usize, row: usize) -> (u32, usize) {
        let (_, page, slot) = self.locate_tiered(layer, kv_head, row);
        (page, slot)
    }

    /// One layer's `[kv_heads, max_blocks]` page-id plane — the gather
    /// table paged attention consumes.
    pub fn layer_pages(&self, layer: usize) -> &[u32] {
        let n = self.kv_heads * self.max_blocks;
        &self.table[layer * n..][..n]
    }

    /// One layer's `[kv_heads, max_blocks]` tier-tag plane, parallel to
    /// [`Self::layer_pages`] — selects the store each page id indexes.
    pub fn layer_tiers(&self, layer: usize) -> &[Tier] {
        let n = self.kv_heads * self.max_blocks;
        &self.tiers[layer * n..][..n]
    }

    /// Tier of block `b` (uniform across planes — blocks migrate as a
    /// group).
    pub fn block_tier(&self, b: usize) -> Tier {
        debug_assert!(b < self.blocks, "tier of unallocated block {b}");
        self.tiers[b] // entry (layer 0, kv_head 0, b)
    }

    /// Device-resident blocks.
    pub fn device_blocks(&self) -> usize {
        (0..self.blocks).filter(|&b| self.block_tier(b) == Tier::Device).count()
    }

    /// The coldest migratable block: the lowest-index device-tier block
    /// (lowest token positions = oldest data).  `include_tail: false`
    /// spares the hot tail — the last allocated block, where fresh rows
    /// usually land; `true` considers every block (the last resort when
    /// the device tier cannot even hold two blocks of one sequence).
    pub fn coldest_device_block(&self, include_tail: bool) -> Option<usize> {
        let lim = if include_tail { self.blocks } else { self.blocks.saturating_sub(1) };
        (0..lim).find(|&b| self.block_tier(b) == Tier::Device)
    }

    /// Migrate block `b` (one page per plane) from the device tier to
    /// the host tier as one batched PCIe move.  All-or-nothing: host
    /// capacity for the whole group is checked up front, so a failed
    /// call changes nothing.  Returns the pages moved.
    ///
    /// Shared pages (ref count > 1) must not migrate — the other
    /// holder's table would keep indexing the device store; this table
    /// must own every page of the block.
    pub fn migrate_block_to_host(
        &mut self,
        b: usize,
        pools: &mut TieredPagePool,
    ) -> std::result::Result<usize, PageAllocError> {
        assert!(b < self.blocks, "migrate of unallocated block {b}");
        assert_eq!(self.block_tier(b), Tier::Device, "block {b} already host-resident");
        debug_assert_eq!(pools.page_size(), self.page_size, "pool/table page_size");
        let group = self.layers * self.kv_heads;
        if pools.host().free_pages() < group {
            return Err(PageAllocError::OutOfPages);
        }
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                let at = (l * self.kv_heads + g) * self.max_blocks + b;
                let host_page = pools
                    .offload_page(self.table[at])
                    .expect("host capacity checked above");
                self.table[at] = host_page;
                self.tiers[at] = Tier::Host;
            }
        }
        pools.charge_batch(group);
        Ok(group)
    }

    /// Release every held page back to `pool` and reset to empty — the
    /// single-pool path; every block must still be device-resident.
    pub fn release_all(&mut self, pool: &mut PagePool) {
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                for b in 0..self.blocks {
                    let at = (l * self.kv_heads + g) * self.max_blocks + b;
                    debug_assert_eq!(
                        self.tiers[at],
                        Tier::Device,
                        "release_all on a migrated table — use release_all_tiered"
                    );
                    pool.release(self.table[at]);
                    self.table[at] = NO_PAGE;
                }
            }
        }
        self.blocks = 0;
    }

    /// Release every held page into its own tier's pool and reset to
    /// empty.
    pub fn release_all_tiered(&mut self, pools: &mut TieredPagePool) {
        for l in 0..self.layers {
            for g in 0..self.kv_heads {
                for b in 0..self.blocks {
                    let at = (l * self.kv_heads + g) * self.max_blocks + b;
                    pools.pool_mut(self.tiers[at]).release(self.table[at]);
                    self.table[at] = NO_PAGE;
                    self.tiers[at] = Tier::Device;
                }
            }
        }
        self.blocks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> CacheShape {
        CacheShape { layers: 2, kv_heads: 3, max_seq: 4, head_dim: 2 }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let sh = shape();
        let n = sh.seq_elems();
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let plane = pack_batch(sh, 4, &[(0, &a), (2, &b)]).unwrap();
        assert_eq!(plane.len(), sh.layers * 4 * sh.seq_elems() / sh.layers);

        let mut a2 = vec![0.0; n];
        let mut b2 = vec![0.0; n];
        unpack_batch(sh, 4, &plane, &mut [(0, &mut a2), (2, &mut b2)]).unwrap();
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn unused_slots_zero() {
        let sh = shape();
        let n = sh.seq_elems();
        let a = vec![1.0f32; n];
        let plane = pack_batch(sh, 3, &[(1, &a)]).unwrap();
        // slot 0 of layer 0 must be all zeros
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        assert!(plane[..le].iter().all(|&x| x == 0.0));
        assert!(plane[le..2 * le].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_interleaving_correct() {
        // value at [layer, slot] must land at plane[(layer*B + slot)*le]
        let sh = shape();
        let n = sh.seq_elems();
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        let mut a = vec![0.0f32; n];
        a[0] = 7.0; // layer 0 first elem
        a[le] = 9.0; // layer 1 first elem
        let plane = pack_batch(sh, 2, &[(1, &a)]).unwrap();
        assert_eq!(plane[(0 * 2 + 1) * le], 7.0);
        assert_eq!(plane[(1 * 2 + 1) * le], 9.0);
    }

    #[test]
    fn batch_offsets_match_pack_layout() {
        // a value written at (layer, slot, kv_head, row) in a sequence
        // cache must land at batch_row_offset after pack_batch.
        let sh = shape();
        let (layer, kv_head, row, t) = (1usize, 2usize, 3usize, 1usize);
        let mut a = vec![0.0f32; sh.seq_elems()];
        let seq_idx = layer * sh.layer_elems()
            + (kv_head * sh.max_seq + row) * sh.head_dim
            + t;
        a[seq_idx] = 5.5;
        let b = 3;
        let slot = 2;
        let plane = pack_batch(sh, b, &[(slot, &a)]).unwrap();
        assert_eq!(plane[sh.batch_row_offset(b, layer, slot, kv_head, row) + t], 5.5);
        assert_eq!(
            sh.batch_slot_offset(b, layer, slot),
            (layer * b + slot) * sh.layer_elems()
        );
    }

    #[test]
    fn bad_slot_rejected() {
        let sh = shape();
        let a = vec![0.0f32; sh.seq_elems()];
        assert!(pack_batch(sh, 2, &[(2, &a)]).is_err());
    }

    #[test]
    fn pool_spills_to_host() {
        let sh = shape();
        let mut pool = CachePool::new(sh, sh.seq_bytes() * 2);
        let (_, t1) = pool.allocate();
        let (_, t2) = pool.allocate();
        let (_, t3) = pool.allocate();
        assert_eq!(t1, Tier::Device);
        assert_eq!(t2, Tier::Device);
        assert_eq!(t3, Tier::Host);
        assert_eq!(pool.active(), 3);
        pool.release(t1);
        assert!(pool.has_device_room());
    }

    // --- paged KV -----------------------------------------------------

    #[test]
    fn page_pool_alloc_release_reuse() {
        let mut pool = PagePool::new(4, 2, 3);
        assert_eq!(pool.num_pages(), 3);
        assert_eq!(pool.free_pages(), 3);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert_eq!(pool.alloc(), None);
        assert_eq!(pool.used_pages(), 3);
        assert!((pool.occupancy() - 1.0).abs() < 1e-12);
        pool.release(b);
        assert_eq!(pool.free_pages(), 1);
        // LIFO reuse of the freed page
        assert_eq!(pool.alloc(), Some(b));
        pool.release(a);
        pool.release(b);
        pool.release(c);
        assert_eq!(pool.free_pages(), 3);
        assert_eq!(pool.occupancy(), 0.0);
    }

    #[test]
    fn page_refcounts_keep_shared_pages_alive() {
        let mut pool = PagePool::new(4, 2, 2);
        let p = pool.alloc().unwrap();
        pool.retain(p); // a second sequence shares the prefix
        pool.release(p);
        assert_eq!(pool.ref_count(p), 1);
        assert_eq!(pool.used_pages(), 1, "shared page must stay allocated");
        pool.release(p);
        assert_eq!(pool.ref_count(p), 0);
        assert_eq!(pool.used_pages(), 0);
    }

    #[test]
    fn page_rows_roundtrip() {
        let mut pool = PagePool::new(4, 2, 2);
        let p = pool.alloc().unwrap();
        pool.write_row(p, 3, &[1.0, 2.0], &[3.0, 4.0]);
        let at = (p as usize * 4 + 3) * 2;
        assert_eq!(&pool.k_store()[at..at + 2], &[1.0, 2.0]);
        assert_eq!(&pool.v_store()[at..at + 2], &[3.0, 4.0]);
    }

    #[test]
    fn block_table_grows_and_locates() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let mut pool = PagePool::new(2, sh.head_dim, 32);
        let mut t = BlockTable::new(sh, 2);
        assert_eq!(t.max_blocks(), 2);
        assert_eq!(t.capacity_tokens(), 0);
        t.ensure_capacity(1, &mut pool).unwrap();
        assert_eq!(t.blocks(), 1);
        assert_eq!(t.capacity_tokens(), 2);
        assert_eq!(t.pages_held(), 6); // layers * kv_heads
        assert_eq!(pool.used_pages(), 6);
        // growing within capacity is a no-op
        t.ensure_capacity(2, &mut pool).unwrap();
        assert_eq!(t.blocks(), 1);
        t.ensure_capacity(4, &mut pool).unwrap();
        assert_eq!(t.blocks(), 2);

        // every (layer, kv_head) plane has distinct pages; row 3 lives in
        // block 1 slot 1
        let (p0, s0) = t.locate(0, 0, 3);
        let (p1, s1) = t.locate(1, 2, 3);
        assert_eq!(s0, 1);
        assert_eq!(s1, 1);
        assert_ne!(p0, p1);
        let lp = t.layer_pages(1);
        assert_eq!(lp.len(), sh.kv_heads * t.max_blocks());
        assert_eq!(lp[2 * t.max_blocks() + 1], p1);

        t.ensure_capacity(5, &mut pool)
            .expect_err("beyond max_seq must fail");
        t.release_all(&mut pool);
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(t.blocks(), 0);
    }

    #[test]
    fn block_table_rolls_back_partial_groups() {
        let sh = shape(); // group = 6 pages per block
        let mut pool = PagePool::new(2, sh.head_dim, 4);
        let mut t = BlockTable::new(sh, 2);
        assert_eq!(
            t.ensure_capacity(1, &mut pool),
            Err(PageAllocError::OutOfPages)
        );
        // the partial group was rolled back — nothing leaked
        assert_eq!(pool.used_pages(), 0);
        assert_eq!(t.blocks(), 0);
    }

    // --- tiered paged KV ----------------------------------------------

    #[test]
    fn pcie_link_batched_moves_amortize_latency() {
        let link = PcieLink::new(10e9, 20e-6);
        let pb = 4096usize;
        let one = link.transfer_s(pb);
        assert!((one - (20e-6 + 4096.0 / 10e9)).abs() < 1e-12);
        // one batched 10-page move beats ten single-page moves
        assert!(link.transfer_s(10 * pb) < 10.0 * one);
    }

    #[test]
    fn migrate_block_preserves_rows_and_frees_device_pages() {
        let sh = shape(); // layers 2, kv_heads 3, max_seq 4, head_dim 2
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(4, pools.device_mut()).unwrap();
        assert_eq!(t.blocks(), 2);
        assert_eq!(t.device_blocks(), 2);
        // distinct rows everywhere
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    assert_eq!(tier, Tier::Device);
                    pools.write_row(tier, page, slot, &[base, base + 0.5], &[-base, -base - 0.5]);
                }
            }
        }
        assert_eq!(pools.device().used_pages(), 2 * group);

        let moved = t.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(moved, group);
        assert_eq!(t.block_tier(0), Tier::Host);
        assert_eq!(t.block_tier(1), Tier::Device);
        assert_eq!(t.device_blocks(), 1);
        assert_eq!(pools.device().used_pages(), group, "block 0 device pages freed");
        assert_eq!(pools.host().used_pages(), group);

        // every row reads back identically through its (possibly new) tier
        for l in 0..sh.layers {
            for g in 0..sh.kv_heads {
                for r in 0..4 {
                    let base = ((l * 10 + g) * 10 + r) as f32;
                    let (tier, page, slot) = t.locate_tiered(l, g, r);
                    assert_eq!(tier, if r < 2 { Tier::Host } else { Tier::Device });
                    let at = (page as usize * 2 + slot) * sh.head_dim;
                    assert_eq!(&pools.k_store(tier)[at..at + 2], &[base, base + 0.5]);
                    assert_eq!(&pools.v_store(tier)[at..at + 2], &[-base, -base - 0.5]);
                }
            }
        }

        // accounting: one batch of `group` pages at page_bytes each
        let st = pools.stats();
        assert_eq!(st.pages_moved, group as u64);
        assert_eq!(st.batches, 1);
        assert_eq!(st.bytes_moved, (group * pools.page_bytes()) as u64);
        assert!(st.modeled_s > 0.0);

        // release drains both tiers
        t.release_all_tiered(&mut pools);
        assert_eq!(pools.device().used_pages(), 0);
        assert_eq!(pools.host().used_pages(), 0);
        assert_eq!(t.blocks(), 0);
        assert_eq!(pools.free_pages_total(), pools.total_pages());
    }

    #[test]
    fn migrate_refuses_without_host_capacity() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        // host tier holds less than one block group
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, group - 1, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(2, pools.device_mut()).unwrap();
        assert_eq!(
            t.migrate_block_to_host(0, &mut pools),
            Err(PageAllocError::OutOfPages)
        );
        // nothing changed
        assert_eq!(t.block_tier(0), Tier::Device);
        assert_eq!(pools.host().used_pages(), 0);
        assert_eq!(pools.stats(), MigrationStats::default());
    }

    #[test]
    fn coldest_block_policy_spares_the_tail() {
        let sh = shape();
        let group = sh.layers * sh.kv_heads;
        let mut pools =
            TieredPagePool::new(2, sh.head_dim, 2 * group, 2 * group, PcieLink::default());
        let mut t = BlockTable::new(sh, 2);
        t.ensure_capacity(2, pools.device_mut()).unwrap(); // one block
        assert_eq!(t.coldest_device_block(false), None, "lone block is the hot tail");
        assert_eq!(t.coldest_device_block(true), Some(0));
        t.ensure_capacity(4, pools.device_mut()).unwrap(); // two blocks
        assert_eq!(t.coldest_device_block(false), Some(0));
        t.migrate_block_to_host(0, &mut pools).unwrap();
        assert_eq!(t.coldest_device_block(false), None, "only the tail is left on device");
        assert_eq!(t.coldest_device_block(true), Some(1));
        t.release_all_tiered(&mut pools);
    }

    #[test]
    fn tiered_for_budget_zero_host_disables_the_tier() {
        let sh = shape();
        let pools = TieredPagePool::for_budget(sh, 2, 64 * 1024, 0, PcieLink::default());
        assert_eq!(pools.host().num_pages(), 0);
        assert!(pools.device().num_pages() > 0);
        assert_eq!(pools.total_pages(), pools.device().num_pages());
        // page geometry identical across tiers
        assert_eq!(pools.page_size(), 2);
        assert_eq!(pools.head_dim(), sh.head_dim);
        assert_eq!(pools.page_bytes(), 2 * 4 * 2 * sh.head_dim);
    }

    #[test]
    fn pages_needed_math() {
        let sh = shape();
        assert_eq!(BlockTable::pages_needed(sh, 2, 0), 0);
        assert_eq!(BlockTable::pages_needed(sh, 2, 1), 6);
        assert_eq!(BlockTable::pages_needed(sh, 2, 2), 6);
        assert_eq!(BlockTable::pages_needed(sh, 2, 3), 12);
        let pool = PagePool::for_budget(sh, 2, 6 * 2 * 4 * 2 * sh.head_dim);
        assert_eq!(pool.num_pages(), 6);
        assert_eq!(pool.page_bytes(), 2 * 4 * 2 * sh.head_dim);
    }
}
