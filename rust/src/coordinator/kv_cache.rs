//! KV-cache manager: per-sequence caches, batch packing, and the
//! host/device tier accounting the CPU–GPU cooperative strategy uses.
//!
//! The AOT decode artifact consumes caches of shape
//! `[L, B, Nkv, max_seq, D]` for a fixed batch bucket `B`.  Sequences own
//! caches of shape `[L, 1, Nkv, max_seq, D]`; this module packs any
//! (≤ B)-subset of sequences into the batch tensor and scatters the
//! updated batch back — the memcpy boundary of continuous batching.

use anyhow::{bail, Result};

/// Cache geometry (from the artifact manifest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheShape {
    pub layers: usize,
    pub kv_heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
}

impl CacheShape {
    /// f32 elements of one sequence's K (or V) cache.
    pub fn seq_elems(&self) -> usize {
        self.layers * self.kv_heads * self.max_seq * self.head_dim
    }

    /// Elements of one layer-row within a single-sequence cache
    /// (`[Nkv, S, D]` — also the per-(layer, slot) plane of a batch
    /// tensor, which is exactly what batched decode attention consumes).
    pub fn layer_elems(&self) -> usize {
        self.kv_heads * self.max_seq * self.head_dim
    }

    /// Bytes of one sequence's full KV (K + V) cache.
    pub fn seq_bytes(&self) -> usize {
        2 * 4 * self.seq_elems()
    }

    /// Flat offset of `(layer, slot)` inside a `[L, B, Nkv, S, D]` batch
    /// plane — the start of that sequence's `[Nkv, S, D]` sub-plane.
    pub fn batch_slot_offset(&self, batch: usize, layer: usize, slot: usize) -> usize {
        debug_assert!(slot < batch);
        (layer * batch + slot) * self.layer_elems()
    }

    /// Flat offset of `(layer, slot, kv_head, row)` inside a batch plane
    /// — where a decode step writes the new token's K/V row.
    pub fn batch_row_offset(
        &self,
        batch: usize,
        layer: usize,
        slot: usize,
        kv_head: usize,
        row: usize,
    ) -> usize {
        debug_assert!(kv_head < self.kv_heads && row < self.max_seq);
        self.batch_slot_offset(batch, layer, slot)
            + (kv_head * self.max_seq + row) * self.head_dim
    }
}

/// One sequence's KV cache (K and V planes, flat f32, `[L,1,Nkv,S,D]`).
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub shape: CacheShape,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

impl SeqCache {
    /// Zero-initialized cache (a fresh slot).
    pub fn zeros(shape: CacheShape) -> Self {
        let n = shape.seq_elems();
        Self { shape, k: vec![0.0; n], v: vec![0.0; n] }
    }
}

/// Pack `seqs` (each `[L,1,Nkv,S,D]`) into a `[L,B,Nkv,S,D]` batch plane.
/// Unused slots stay zero.  Returns the flat batch tensor.
pub fn pack_batch(
    shape: CacheShape,
    batch: usize,
    seqs: &[(usize, &[f32])],
) -> Result<Vec<f32>> {
    let le = shape.layer_elems();
    let mut out = vec![0.0f32; shape.layers * batch * le];
    for &(slot, data) in seqs {
        if slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &data[layer * le..][..le];
            let dst = &mut out[(layer * batch + slot) * le..][..le];
            dst.copy_from_slice(src);
        }
    }
    Ok(out)
}

/// Scatter a `[L,B,Nkv,S,D]` batch plane back into per-sequence caches.
pub fn unpack_batch(
    shape: CacheShape,
    batch: usize,
    plane: &[f32],
    seqs: &mut [(usize, &mut [f32])],
) -> Result<()> {
    let le = shape.layer_elems();
    if plane.len() != shape.layers * batch * le {
        bail!("batch plane has {} elems, expected {}", plane.len(), shape.layers * batch * le);
    }
    for (slot, data) in seqs.iter_mut() {
        if *slot >= batch {
            bail!("slot {slot} out of batch {batch}");
        }
        if data.len() != shape.seq_elems() {
            bail!("sequence cache has {} elems, expected {}", data.len(), shape.seq_elems());
        }
        for layer in 0..shape.layers {
            let src = &plane[(layer * batch + *slot) * le..][..le];
            data[layer * le..][..le].copy_from_slice(src);
        }
    }
    Ok(())
}

/// Placement tier for a layer's KV cache (§4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Device (GPU/NPU) resident.
    Device,
    /// Host (CPU) resident — the cooperative strategy's pre-L_CPU layers.
    Host,
}

/// Capacity-tracking cache pool with per-tier accounting.
#[derive(Debug)]
pub struct CachePool {
    pub shape: CacheShape,
    device_budget_bytes: usize,
    device_used_bytes: usize,
    host_used_bytes: usize,
    active: usize,
}

impl CachePool {
    pub fn new(shape: CacheShape, device_budget_bytes: usize) -> Self {
        Self {
            shape,
            device_budget_bytes,
            device_used_bytes: 0,
            host_used_bytes: 0,
            active: 0,
        }
    }

    /// Can another sequence's cache be placed on-device?
    pub fn has_device_room(&self) -> bool {
        self.device_used_bytes + self.shape.seq_bytes() <= self.device_budget_bytes
    }

    /// Allocate a cache; spills to Host when the device is full (the
    /// engine treats Host-tier caches via the cooperative path).
    pub fn allocate(&mut self) -> (SeqCache, Tier) {
        let tier = if self.has_device_room() { Tier::Device } else { Tier::Host };
        match tier {
            Tier::Device => self.device_used_bytes += self.shape.seq_bytes(),
            Tier::Host => self.host_used_bytes += self.shape.seq_bytes(),
        }
        self.active += 1;
        (SeqCache::zeros(self.shape), tier)
    }

    /// Release a cache allocated at `tier`.
    pub fn release(&mut self, tier: Tier) {
        match tier {
            Tier::Device => {
                self.device_used_bytes =
                    self.device_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
            Tier::Host => {
                self.host_used_bytes =
                    self.host_used_bytes.saturating_sub(self.shape.seq_bytes());
            }
        }
        self.active = self.active.saturating_sub(1);
    }

    pub fn active(&self) -> usize {
        self.active
    }

    pub fn device_used_bytes(&self) -> usize {
        self.device_used_bytes
    }

    pub fn host_used_bytes(&self) -> usize {
        self.host_used_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> CacheShape {
        CacheShape { layers: 2, kv_heads: 3, max_seq: 4, head_dim: 2 }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let sh = shape();
        let n = sh.seq_elems();
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| -(i as f32)).collect();
        let plane = pack_batch(sh, 4, &[(0, &a), (2, &b)]).unwrap();
        assert_eq!(plane.len(), sh.layers * 4 * sh.seq_elems() / sh.layers);

        let mut a2 = vec![0.0; n];
        let mut b2 = vec![0.0; n];
        unpack_batch(sh, 4, &plane, &mut [(0, &mut a2), (2, &mut b2)]).unwrap();
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    #[test]
    fn unused_slots_zero() {
        let sh = shape();
        let n = sh.seq_elems();
        let a = vec![1.0f32; n];
        let plane = pack_batch(sh, 3, &[(1, &a)]).unwrap();
        // slot 0 of layer 0 must be all zeros
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        assert!(plane[..le].iter().all(|&x| x == 0.0));
        assert!(plane[le..2 * le].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_interleaving_correct() {
        // value at [layer, slot] must land at plane[(layer*B + slot)*le]
        let sh = shape();
        let n = sh.seq_elems();
        let le = sh.kv_heads * sh.max_seq * sh.head_dim;
        let mut a = vec![0.0f32; n];
        a[0] = 7.0; // layer 0 first elem
        a[le] = 9.0; // layer 1 first elem
        let plane = pack_batch(sh, 2, &[(1, &a)]).unwrap();
        assert_eq!(plane[(0 * 2 + 1) * le], 7.0);
        assert_eq!(plane[(1 * 2 + 1) * le], 9.0);
    }

    #[test]
    fn batch_offsets_match_pack_layout() {
        // a value written at (layer, slot, kv_head, row) in a sequence
        // cache must land at batch_row_offset after pack_batch.
        let sh = shape();
        let (layer, kv_head, row, t) = (1usize, 2usize, 3usize, 1usize);
        let mut a = vec![0.0f32; sh.seq_elems()];
        let seq_idx = layer * sh.layer_elems()
            + (kv_head * sh.max_seq + row) * sh.head_dim
            + t;
        a[seq_idx] = 5.5;
        let b = 3;
        let slot = 2;
        let plane = pack_batch(sh, b, &[(slot, &a)]).unwrap();
        assert_eq!(plane[sh.batch_row_offset(b, layer, slot, kv_head, row) + t], 5.5);
        assert_eq!(
            sh.batch_slot_offset(b, layer, slot),
            (layer * b + slot) * sh.layer_elems()
        );
    }

    #[test]
    fn bad_slot_rejected() {
        let sh = shape();
        let a = vec![0.0f32; sh.seq_elems()];
        assert!(pack_batch(sh, 2, &[(2, &a)]).is_err());
    }

    #[test]
    fn pool_spills_to_host() {
        let sh = shape();
        let mut pool = CachePool::new(sh, sh.seq_bytes() * 2);
        let (_, t1) = pool.allocate();
        let (_, t2) = pool.allocate();
        let (_, t3) = pool.allocate();
        assert_eq!(t1, Tier::Device);
        assert_eq!(t2, Tier::Device);
        assert_eq!(t3, Tier::Host);
        assert_eq!(pool.active(), 3);
        pool.release(t1);
        assert!(pool.has_device_room());
    }
}
