//! Execution backends for the serving engine.
//!
//! The engine owns scheduling, batching and KV-cache bookkeeping; a
//! [`Backend`] owns the actual model math of one prefill or decode step.
//! Two implementations:
//!
//! * [`ArtifactBackend`] — the AOT path: executes the lowered
//!   `prefill_b*_s*` / `decode_b*` artifacts on the PJRT runtime
//!   (attention happens inside the compiled HLO);
//! * [`HostModelBackend`] — a pure-rust tiny transformer whose decode
//!   attention runs through [`batch_decode_attention`]: all sequences ×
//!   all query heads of the step fused into one flat work queue on the
//!   engine's [`WorkPool`].  Weights are deterministic functions of a
//!   seed, so two backends with the same seed generate token-for-token
//!   identical outputs — which is what lets the integration tests assert
//!   sequential-vs-parallel parity without any artifact bundle.
//!
//! Both speak the engine's wire format: token/position vectors per batch
//! slot plus packed `[L, B, Nkv, S, D]` KV planes (see
//! [`kv_cache`](super::kv_cache)).  The host backend additionally
//! executes against the **paged** KV cache (`supports_paged`):
//! [`Backend::decode_paged`] and [`Backend::prefill_chunk`] read and
//! write rows in place through per-sequence block tables, which is what
//! lets the engine drop the pack/unpack memcpy and admit prompts longer
//! than any prefill bucket.  Plane and paged execution share
//! `forward_step`, so they are bit-identical.

use anyhow::{bail, Context, Result};

use crate::attention::batch::{
    batch_decode_attention, cascade_batch_decode_attention, BatchShape, CascadeGroup,
    CascadeStats, ParallelConfig, SeqAttn, SeqKv, WorkPool,
};
use crate::coordinator::kv_cache::{BlockTable, CacheShape, PageCodec, TieredPagePool};
use crate::models::ModelShape;
use crate::proptest::Rng;
use crate::runtime::{HostTensor, Manifest, Runtime};

/// Model geometry a backend serves (mirrors the artifact manifest's
/// `model` block; the host backend synthesizes one).
pub use crate::runtime::artifacts::ModelInfo;

/// The (batch, seq) bucket grid a backend was lowered for.
#[derive(Debug, Clone)]
pub struct BucketGrid {
    pub prefill_batches: Vec<usize>,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
}

/// Outputs of one prefill or decode step.
pub struct StepOut {
    /// `[B, vocab]` flat.
    pub logits: Vec<f32>,
    /// Updated K cache plane, `[L, B, Nkv, S, D]` flat.
    pub k_plane: Vec<f32>,
    /// Updated V cache plane, same shape.
    pub v_plane: Vec<f32>,
}

/// One model-execution backend.
pub trait Backend {
    /// Model geometry (cache shape, vocab, …).
    fn model(&self) -> &ModelInfo;

    /// The lowered bucket grid.
    fn buckets(&self) -> BucketGrid;

    /// Adopt the engine's parallelism config (backends that manage their
    /// own parallelism, like PJRT, may ignore it).
    fn set_parallel(&mut self, _cfg: ParallelConfig) {}

    /// Run a prefill over `tokens` `[B, S]` (right-padded) with per-row
    /// `lengths` `[B]`; returns last-token logits and fresh KV planes.
    fn prefill(
        &mut self,
        batch: usize,
        seq: usize,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<StepOut>;

    /// Run one decode step: per-slot `tokens` `[B]` at `pos` `[B]` over
    /// the packed KV planes; returns next-token logits and the planes
    /// with the new row written.
    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        k_plane: Vec<f32>,
        v_plane: Vec<f32>,
        pos: &[i32],
    ) -> Result<StepOut>;

    /// True when the backend can execute against a paged KV cache —
    /// the engine then serves through [`Backend::decode_paged`] /
    /// [`Backend::prefill_chunk`] instead of packing planes.
    fn supports_paged(&self) -> bool {
        false
    }

    /// One decode step over (tiered) paged KV: each row's K/V is read
    /// and the new token's row written *in place* through its block
    /// table (no pack/unpack memcpy); blocks migrated to the host tier
    /// are gathered from the host store, bit-identically.  Tables must
    /// already have capacity for row `pos`.  Returns `[rows, vocab]`
    /// logits.
    fn decode_paged(
        &mut self,
        _rows: &[PagedRow<'_>],
        _pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        bail!("backend does not support paged KV")
    }

    /// [`Backend::decode_paged`] with cascade hints: each
    /// [`CascadeGroup`] names rows that share a page-identical KV
    /// prefix, which the backend may gather once per batch instead of
    /// once per row (bit-identically — see
    /// [`cascade_batch_decode_attention`]).  The default ignores the
    /// hints and delegates, so non-cascade backends stay correct.
    fn decode_paged_cascade(
        &mut self,
        rows: &[PagedRow<'_>],
        _groups: &[CascadeGroup],
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        self.decode_paged(rows, pools)
    }

    /// Drain cascade accounting accumulated since the last call (pass
    /// and saved-row counts across layers); zeros for backends that
    /// never cascade.
    fn take_cascade_stats(&mut self) -> CascadeStats {
        CascadeStats::default()
    }

    /// One chunked-prefill step for a single sequence: run `tokens`
    /// (occupying absolute positions `start_pos ..`) through the model,
    /// writing KV through `table`; causal masking across the chunk
    /// boundary is exact because every token attends to all rows
    /// `<= its position`, including those written by earlier chunks.
    /// Returns the chunk's last-token `[vocab]` logits.
    fn prefill_chunk(
        &mut self,
        _tokens: &[i32],
        _start_pos: usize,
        _table: &BlockTable,
        _pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        bail!("backend does not support chunked prefill")
    }

    /// True when [`Backend::verify_step`] is implemented — the engine
    /// only takes the speculative decode path over such backends and
    /// falls back to vanilla decode otherwise (so `speculate > 0` can
    /// never change tokens, only step shape).
    fn supports_verify(&self) -> bool {
        false
    }

    /// Speculative **batched verify**: score `tokens` (the sequence's
    /// last accepted token followed by its draft tokens) at the
    /// consecutive cache positions `start_pos ..`, in ONE pass through
    /// the paged attention — the multi-position machinery of
    /// [`Backend::prefill_chunk`], with the same chunk-boundary causal
    /// mask (`attention::mask::chunk_row_visible`): row `t` attends
    /// exactly the KV rows `<= start_pos + t`.  KV for every position
    /// is written through `table` — *speculatively* for the draft
    /// positions; the engine rolls rejected rows back with
    /// [`BlockTable::truncate`].  Unlike `prefill_chunk`, logits come
    /// back for **every** position (`[tokens.len(), vocab]`, row `t` =
    /// the next-token distribution after consuming `tokens[t]`), which
    /// is what accept-longest-prefix needs.
    fn verify_step(
        &mut self,
        _tokens: &[i32],
        _start_pos: usize,
        _table: &BlockTable,
        _pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        bail!("backend does not support speculative verification")
    }

    /// Simulated devices the backend shards KV heads across.  `1` for
    /// single-device backends; the engine builds one page pool and one
    /// block table per shard and drives every paged step through the
    /// `*_sharded` entry points below.
    fn shard_count(&self) -> usize {
        1
    }

    /// Cumulative modeled tiling-AllReduce accounting for the sharded
    /// combine (see [`AllReduceStats`]); single-device backends report
    /// zeros.  The engine copies this into
    /// [`EngineMetrics`](crate::metrics::EngineMetrics) after each
    /// paged step, alongside `pcie_modeled_s`.
    fn comm_stats(&self) -> AllReduceStats {
        AllReduceStats::default()
    }

    /// One decode step over per-shard paged KV: `rows[i].tables[s]`
    /// pairs with `pools[s]`.  The default covers single-device
    /// backends by delegating to [`Backend::decode_paged`]; sharded
    /// backends override it to run per-shard attention and combine the
    /// head slices with the tiling-AllReduce schedule.
    fn decode_paged_sharded(
        &mut self,
        rows: &[ShardedRow<'_>],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<f32>> {
        if pools.len() != 1 {
            bail!("backend cannot execute across {} KV shards", pools.len());
        }
        let prows: Vec<PagedRow<'_>> = rows
            .iter()
            .map(|r| PagedRow { table: &r.tables[0], token: r.token, pos: r.pos })
            .collect();
        self.decode_paged(&prows, &mut pools[0])
    }

    /// Chunked prefill over per-shard paged KV (`tables[s]` pairs with
    /// `pools[s]`); default delegates to [`Backend::prefill_chunk`] for
    /// the single-shard case.
    fn prefill_chunk_sharded(
        &mut self,
        tokens: &[i32],
        start_pos: usize,
        tables: &[BlockTable],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<f32>> {
        if pools.len() != 1 || tables.len() != 1 {
            bail!("backend cannot execute across {} KV shards", pools.len());
        }
        self.prefill_chunk(tokens, start_pos, &tables[0], &mut pools[0])
    }

    /// **Batched** chunked prefill: one chunk from each of several
    /// sequences, executed together — position `t` of every chunk packs
    /// into one forward step, exactly like bucketed prefill rows (the
    /// engine packs admitting sequences under its prefill-token budget).
    /// Cross-sequence rows are independent, so each chunk's result is
    /// bit-identical to running [`Backend::prefill_chunk_sharded`]
    /// alone.  Returns each chunk's last-token `[vocab]` logits, aligned
    /// with `chunks`.  The default runs the chunks sequentially, which
    /// keeps non-batching backends (the artifact path) correct.
    fn prefill_chunks_sharded(
        &mut self,
        chunks: &[ChunkRun<'_>],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<Vec<f32>>> {
        chunks
            .iter()
            .map(|c| self.prefill_chunk_sharded(c.tokens, c.start_pos, c.tables, pools))
            .collect()
    }
}

/// Cumulative modeled timing/volume of the per-tile B-allreduce combine
/// a sharded backend performs (accounting only — the numerics go
/// through the real in-process ring; see `coordinator::sharded`).
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct AllReduceStats {
    /// B-allreduce operations issued (one per tile per layer step).
    pub tiles: u64,
    /// Activation bytes combined across shards.
    pub bytes: u64,
    /// Total modeled communication seconds (as if serialized).
    pub modeled_s: f64,
    /// Communication seconds hidden under the next tile's compute.
    pub hidden_s: f64,
    /// Modeled makespan of the executed (overlapped or serial) schedule.
    pub makespan_s: f64,
    /// Modeled makespan of the serial baseline over the same workload —
    /// `serial_makespan_s / makespan_s` is the tiling-AllReduce speedup.
    pub serial_makespan_s: f64,
}

/// One paged decode row: the sequence behind `table` feeds `token` at
/// cache position `pos`.
pub struct PagedRow<'a> {
    pub table: &'a BlockTable,
    pub token: i32,
    pub pos: usize,
}

/// One sharded paged decode row: `tables[s]` is the sequence's block
/// table on shard `s` and pairs with `pools[s]` of the sharded call.
pub struct ShardedRow<'a> {
    pub tables: &'a [BlockTable],
    pub token: i32,
    pub pos: usize,
}

/// One sequence's chunk inside a batched chunked-prefill step
/// ([`Backend::prefill_chunks_sharded`]).
pub struct ChunkRun<'a> {
    /// The chunk's tokens, occupying absolute positions `start_pos ..`.
    pub tokens: &'a [i32],
    /// Absolute cache position of `tokens[0]`.
    pub start_pos: usize,
    /// Per-shard block tables: `tables[s]` pairs with `pools[s]`.
    pub tables: &'a [BlockTable],
}

// ---------------------------------------------------------------------
// Artifact (PJRT) backend
// ---------------------------------------------------------------------

/// The AOT-artifact backend: thin adapter over [`Runtime`].
pub struct ArtifactBackend {
    rt: Runtime,
}

impl ArtifactBackend {
    pub fn new(rt: Runtime) -> Self {
        Self { rt }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    fn step_out(outs: Vec<HostTensor>, what: &str) -> Result<StepOut> {
        let mut it = outs.into_iter();
        let logits = it.next().with_context(|| format!("{what}: missing logits"))?;
        let k = it.next().with_context(|| format!("{what}: missing k cache"))?;
        let v = it.next().with_context(|| format!("{what}: missing v cache"))?;
        Ok(StepOut {
            logits: logits.into_f32()?,
            k_plane: k.into_f32()?,
            v_plane: v.into_f32()?,
        })
    }
}

impl Backend for ArtifactBackend {
    fn model(&self) -> &ModelInfo {
        &self.rt.manifest.model
    }

    fn buckets(&self) -> BucketGrid {
        BucketGrid {
            prefill_batches: self.rt.manifest.prefill_batches.clone(),
            prefill_seqs: self.rt.manifest.prefill_seqs.clone(),
            decode_batches: self.rt.manifest.decode_batches.clone(),
        }
    }

    fn prefill(
        &mut self,
        batch: usize,
        seq: usize,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<StepOut> {
        let name = format!("prefill_b{batch}_s{seq}");
        let outs = self
            .rt
            .run_host(
                &name,
                &[
                    HostTensor::i32(vec![batch, seq], tokens.to_vec()),
                    HostTensor::i32(vec![batch], lengths.to_vec()),
                ],
            )
            .with_context(|| format!("prefill artifact {name}"))?;
        Self::step_out(outs, &name)
    }

    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        k_plane: Vec<f32>,
        v_plane: Vec<f32>,
        pos: &[i32],
    ) -> Result<StepOut> {
        let m = &self.rt.manifest.model;
        let name = format!("decode_b{batch}");
        let cache_dims =
            vec![m.n_layers, batch, m.n_kv_heads, m.max_seq, m.head_dim];
        let outs = self
            .rt
            .run_host(
                &name,
                &[
                    HostTensor::i32(vec![batch, 1], tokens.to_vec()),
                    HostTensor::f32(cache_dims.clone(), k_plane),
                    HostTensor::f32(cache_dims, v_plane),
                    HostTensor::i32(vec![batch], pos.to_vec()),
                ],
            )
            .with_context(|| format!("decode artifact {name}"))?;
        Self::step_out(outs, &name)
    }
}

// ---------------------------------------------------------------------
// Host-model backend
// ---------------------------------------------------------------------

/// Configuration of the pure-rust host model.
#[derive(Debug, Clone)]
pub struct HostModelConfig {
    /// Transformer shape (GQA-aware: `kv_heads ≤ heads`).
    pub model: ModelShape,
    /// Cache capacity (tokens).
    pub max_seq: usize,
    /// Weight seed: equal seeds ⇒ bit-identical models.
    pub seed: u64,
    pub buckets: BucketGrid,
    /// KV tile rows of the decode-attention kernel (`BatchShape::
    /// block_kv`).  Cascade groups round their shared prefix down to
    /// this tile size, so tests with short prompts shrink it to the
    /// page size; the default matches `BatchShape::new`.
    pub block_kv: usize,
}

impl HostModelConfig {
    /// A small GQA config sized for tests and benches: 4 query heads
    /// over 2 KV heads.  Forward math is a few µs per token.
    pub fn tiny_gqa() -> Self {
        Self {
            model: ModelShape {
                name: "host-tiny-gqa",
                params: 0,
                layers: 2,
                heads: 4,
                kv_heads: 2,
                head_dim: 8,
                ffn: 64,
                vocab: 64,
            },
            max_seq: 96,
            seed: 0xFA57_A77E,
            buckets: BucketGrid {
                prefill_batches: vec![1, 4],
                prefill_seqs: vec![8, 16, 32],
                decode_batches: vec![1, 4, 8],
            },
            block_kv: 128,
        }
    }

    /// Override the decode-attention KV tile size (see `block_kv`).
    pub fn with_block_kv(mut self, block_kv: usize) -> Self {
        self.block_kv = block_kv.max(1);
        self
    }

    /// Wrap any zoo shape (e.g. [`crate::models::TINY_GQA`]): the
    /// prefill bucket grid is derived from `max_seq` (powers of two from
    /// 8 up to `max_seq`), so prompts are only limited by the cache.
    pub fn for_shape(model: ModelShape, max_seq: usize) -> Self {
        let mut prefill_seqs = Vec::new();
        let mut s = 8usize;
        while s < max_seq {
            prefill_seqs.push(s);
            s *= 2;
        }
        prefill_seqs.push(max_seq);
        Self {
            model,
            max_seq,
            buckets: BucketGrid {
                prefill_batches: vec![1, 4],
                prefill_seqs,
                decode_batches: vec![1, 4, 8],
            },
            ..Self::tiny_gqa()
        }
    }
}

/// Per-layer projection weights, row-major `[fan_in, fan_out]`.
/// Crate-visible so the sharded backend can run per-shard column slices
/// of the same projections (see `coordinator::sharded`).
pub(crate) struct LayerWeights {
    pub(crate) wq: Vec<f32>,
    pub(crate) wk: Vec<f32>,
    pub(crate) wv: Vec<f32>,
    pub(crate) wo: Vec<f32>,
    pub(crate) w1: Vec<f32>,
    pub(crate) w2: Vec<f32>,
}

/// A deterministic tiny transformer running decode attention through the
/// batched parallel path.
pub struct HostModelBackend {
    cfg: HostModelConfig,
    info: ModelInfo,
    cache: CacheShape,
    /// Token embedding `[vocab, d_model]`; also the (tied) unembedding.
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    pool: WorkPool,
    /// Cascade accounting since the last [`Backend::take_cascade_stats`].
    cascade_stats: CascadeStats,
}

/// `out[j] = Σ_i x[i] · w[i * cols + j]` (row-major mat-vec).
pub(crate) fn matvec(x: &[f32], w: &[f32], out: &mut [f32]) {
    let cols = out.len();
    debug_assert_eq!(w.len(), x.len() * cols);
    out.fill(0.0);
    for (i, &xi) in x.iter().enumerate() {
        let wrow = &w[i * cols..][..cols];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

/// RMS-normalize into a fresh vector (parameter-free).
pub(crate) fn rmsnorm(x: &[f32]) -> Vec<f32> {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len().max(1) as f32;
    let inv = 1.0 / (ms + 1e-6).sqrt();
    x.iter().map(|v| v * inv).collect()
}

impl HostModelBackend {
    pub fn new(cfg: HostModelConfig) -> Self {
        Self::with_parallel(cfg, ParallelConfig::default())
    }

    pub fn with_parallel(cfg: HostModelConfig, par: ParallelConfig) -> Self {
        let m = &cfg.model;
        let (d_model, heads, kvh, hd) = (
            m.hidden() as usize,
            m.heads as usize,
            m.kv_heads as usize,
            m.head_dim as usize,
        );
        assert!(kvh >= 1 && heads % kvh == 0, "kv_heads must divide heads");
        let (d_ff, vocab, layers) = (m.ffn as usize, m.vocab as usize, m.layers as usize);

        let mut rng = Rng::new(cfg.seed);
        let mut init = |fan_in: usize, fan_out: usize| -> Vec<f32> {
            let scale = (1.0 / fan_in.max(1) as f32).sqrt();
            (0..fan_in * fan_out).map(|_| rng.f32() * scale).collect()
        };
        let embed = init(d_model, vocab); // stored [vocab, d_model] via transpose-free indexing below
        let layer_weights: Vec<LayerWeights> = (0..layers)
            .map(|_| LayerWeights {
                wq: init(d_model, heads * hd),
                wk: init(d_model, kvh * hd),
                wv: init(d_model, kvh * hd),
                wo: init(heads * hd, d_model),
                w1: init(d_model, d_ff),
                w2: init(d_ff, d_model),
            })
            .collect();

        let n_params = embed.len()
            + layer_weights
                .iter()
                .map(|l| {
                    l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len() + l.w1.len() + l.w2.len()
                })
                .sum::<usize>();
        let info = ModelInfo {
            name: m.name.to_string(),
            vocab,
            n_layers: layers,
            d_model,
            n_heads: heads,
            n_kv_heads: kvh,
            head_dim: hd,
            d_ff,
            max_seq: cfg.max_seq,
            n_params,
        };
        let cache = CacheShape {
            layers,
            kv_heads: kvh,
            max_seq: cfg.max_seq,
            head_dim: hd,
        };
        Self {
            cfg,
            info,
            cache,
            embed,
            layers: layer_weights,
            pool: WorkPool::new(par),
            cascade_stats: CascadeStats::default(),
        }
    }

    pub(crate) fn d_model(&self) -> usize {
        self.info.d_model
    }

    /// The per-layer projection weights (for the sharded backend's
    /// column-sliced execution of the same model).
    pub(crate) fn layer_weights(&self) -> &[LayerWeights] {
        &self.layers
    }

    /// The backend's batched-attention work pool.
    pub(crate) fn work_pool(&self) -> &WorkPool {
        &self.pool
    }

    /// The full (unsharded) cache geometry this model was built for.
    pub(crate) fn cache_shape(&self) -> CacheShape {
        self.cache
    }

    /// Embedding row of a token (ids folded into the vocab — prompts are
    /// synthetic and may exceed it).
    pub(crate) fn embed_row(&self, token: i32) -> Vec<f32> {
        let v = self.info.vocab;
        let t = (token.rem_euclid(v as i32)) as usize;
        self.embed[t * self.d_model()..][..self.d_model()].to_vec()
    }

    /// Tied unembedding: `logits[v] = rmsnorm(x) · embed[v]`.
    pub(crate) fn logits_row(&self, x: &[f32], out: &mut [f32]) {
        let d = self.d_model();
        let h = rmsnorm(x);
        for (v, o) in out.iter_mut().enumerate() {
            let row = &self.embed[v * d..][..d];
            *o = h.iter().zip(row).map(|(a, b)| a * b).sum();
        }
    }

    /// One token step for `rows = [(slot, token, pos)]`: writes each
    /// row's new K/V into the backing (packed planes or the paged
    /// pool), runs **batched** decode attention across all rows × heads
    /// per layer, returns final hidden states aligned with `rows`.
    ///
    /// For [`StepKv::Plane`], `slot` indexes the batch plane; for
    /// [`StepKv::Paged`], `slot` indexes `tables`.  The per-row math is
    /// identical either way — the backings stream the same rows through
    /// `KvView` — so plane and paged execution are bit-identical.
    fn forward_step(&self, rows: &[(usize, i32, usize)], kv: &mut StepKv<'_>) -> Vec<Vec<f32>> {
        self.forward_step_cascade(rows, kv, &[]).0
    }

    /// [`Self::forward_step`] with cascade groups: when `groups` is
    /// non-empty the per-layer attention runs through
    /// [`cascade_batch_decode_attention`] (bit-identical, shared-prefix
    /// tiles gathered once per group), and the per-layer stats are
    /// summed into the returned [`CascadeStats`].
    fn forward_step_cascade(
        &self,
        rows: &[(usize, i32, usize)],
        kv: &mut StepKv<'_>,
        groups: &[CascadeGroup],
    ) -> (Vec<Vec<f32>>, CascadeStats) {
        let d = self.d_model();
        let (heads, kvh, hd) = (self.info.n_heads, self.info.n_kv_heads, self.info.head_dim);
        let (qdim, kvdim) = (heads * hd, kvh * hd);
        let le = self.cache.layer_elems();
        let mut bshape = BatchShape::new(heads, kvh, hd, self.cache.max_seq);
        bshape.block_kv = self.cfg.block_kv.max(1);
        let mut stats = CascadeStats::default();

        let mut xs: Vec<Vec<f32>> =
            rows.iter().map(|&(_, tok, _)| self.embed_row(tok)).collect();
        let mut qbuf = vec![0.0f32; rows.len() * qdim];
        let mut attn = vec![0.0f32; rows.len() * qdim];
        let mut krow = vec![0.0f32; kvdim];
        let mut vrow = vec![0.0f32; kvdim];
        let mut proj = vec![0.0f32; d.max(self.info.d_ff)];

        for (l, w) in self.layers.iter().enumerate() {
            // ---- projections + KV write (per row, sequential) --------
            for (ri, &(slot, _, pos)) in rows.iter().enumerate() {
                let h = rmsnorm(&xs[ri]);
                matvec(&h, &w.wq, &mut qbuf[ri * qdim..][..qdim]);
                matvec(&h, &w.wk, &mut krow);
                matvec(&h, &w.wv, &mut vrow);
                match kv {
                    StepKv::Plane { batch, k, v } => {
                        for g in 0..kvh {
                            let at = self.cache.batch_row_offset(*batch, l, slot, g, pos);
                            k[at..at + hd].copy_from_slice(&krow[g * hd..][..hd]);
                            v[at..at + hd].copy_from_slice(&vrow[g * hd..][..hd]);
                        }
                    }
                    StepKv::Paged { pools, tables } => {
                        for g in 0..kvh {
                            let (tier, page, in_page) = tables[ri].locate_tiered(l, g, pos);
                            pools.write_row(
                                tier,
                                page,
                                in_page,
                                &krow[g * hd..][..hd],
                                &vrow[g * hd..][..hd],
                            );
                        }
                    }
                }
            }

            // ---- fused batched attention over all rows × heads -------
            {
                let seqs: Vec<SeqAttn<'_>> = match &*kv {
                    StepKv::Plane { batch, k, v } => {
                        let kp: &[f32] = &**k;
                        let vp: &[f32] = &**v;
                        rows.iter()
                            .enumerate()
                            .map(|(ri, &(slot, _, pos))| SeqAttn {
                                q: &qbuf[ri * qdim..][..qdim],
                                kv: SeqKv::Contig {
                                    k: &kp[self.cache.batch_slot_offset(*batch, l, slot)..][..le],
                                    v: &vp[self.cache.batch_slot_offset(*batch, l, slot)..][..le],
                                },
                                kv_len: pos + 1,
                            })
                            .collect()
                    }
                    StepKv::Paged { pools, tables } => {
                        // with no host tier configured nothing can ever
                        // be host-resident — keep the single-store
                        // gather (no per-row tier dispatch) on that
                        // default path; both stream identical rows.
                        // The pool codec picks the f32 or fused-int8
                        // view — writes already encoded through it.
                        let host_empty = pools.host().num_pages() == 0;
                        let codec = pools.codec();
                        rows.iter()
                            .enumerate()
                            .map(|(ri, &(_, _, pos))| SeqAttn {
                                q: &qbuf[ri * qdim..][..qdim],
                                kv: match (codec, host_empty) {
                                    (PageCodec::F32, true) => SeqKv::Paged {
                                        k_store: pools.device().k_store(),
                                        v_store: pools.device().v_store(),
                                        pages: tables[ri].layer_pages(l),
                                        max_blocks: tables[ri].max_blocks(),
                                        page_size: tables[ri].page_size(),
                                    },
                                    (PageCodec::F32, false) => SeqKv::Tiered {
                                        k_device: pools.device().k_store(),
                                        v_device: pools.device().v_store(),
                                        k_host: pools.host().k_store(),
                                        v_host: pools.host().v_store(),
                                        pages: tables[ri].layer_pages(l),
                                        tiers: tables[ri].layer_tiers(l),
                                        max_blocks: tables[ri].max_blocks(),
                                        page_size: tables[ri].page_size(),
                                    },
                                    (PageCodec::Int8, true) => SeqKv::PagedI8 {
                                        k: pools.device().k_quant_store(),
                                        v: pools.device().v_quant_store(),
                                        pages: tables[ri].layer_pages(l),
                                        max_blocks: tables[ri].max_blocks(),
                                        page_size: tables[ri].page_size(),
                                    },
                                    (PageCodec::Int8, false) => SeqKv::TieredI8 {
                                        k_device: pools.device().k_quant_store(),
                                        v_device: pools.device().v_quant_store(),
                                        k_host: pools.host().k_quant_store(),
                                        v_host: pools.host().v_quant_store(),
                                        pages: tables[ri].layer_pages(l),
                                        tiers: tables[ri].layer_tiers(l),
                                        max_blocks: tables[ri].max_blocks(),
                                        page_size: tables[ri].page_size(),
                                    },
                                },
                                kv_len: pos + 1,
                            })
                            .collect()
                    }
                };
                if groups.is_empty() {
                    batch_decode_attention(&bshape, &seqs, &mut attn, &self.pool);
                } else {
                    let pool = &self.pool;
                    let s = cascade_batch_decode_attention(&bshape, &seqs, groups, &mut attn, pool);
                    stats.passes += s.passes;
                    stats.rows_saved += s.rows_saved;
                }
            }

            // ---- output proj + MLP (per row, sequential) -------------
            for (ri, x) in xs.iter_mut().enumerate() {
                matvec(&attn[ri * qdim..][..qdim], &w.wo, &mut proj[..d]);
                for (xi, &p) in x.iter_mut().zip(&proj[..d]) {
                    *xi += p;
                }
                let h = rmsnorm(x);
                matvec(&h, &w.w1, &mut proj[..self.info.d_ff]);
                for p in &mut proj[..self.info.d_ff] {
                    *p = p.max(0.0); // ReLU
                }
                let mlp = proj[..self.info.d_ff].to_vec();
                matvec(&mlp, &w.w2, &mut proj[..d]);
                for (xi, &p) in x.iter_mut().zip(&proj[..d]) {
                    *xi += p;
                }
            }
        }
        (xs, stats)
    }

    /// Shared body of [`Backend::decode_paged`] and
    /// [`Backend::decode_paged_cascade`]: validates rows, runs the
    /// forward step (with cascade hints when given) and folds the step's
    /// cascade accounting into `self.cascade_stats`.
    fn decode_paged_with_groups(
        &mut self,
        rows: &[PagedRow<'_>],
        groups: &[CascadeGroup],
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        for (i, r) in rows.iter().enumerate() {
            self.check_table(r.table, pools, "decode_paged")?;
            if r.pos >= self.cache.max_seq {
                bail!(
                    "decode_paged row {i}: pos {} out of cache range {}",
                    r.pos,
                    self.cache.max_seq
                );
            }
            if r.table.capacity_tokens() <= r.pos {
                bail!(
                    "decode_paged row {i}: table holds {} tokens, row {} needs capacity first",
                    r.table.capacity_tokens(),
                    r.pos
                );
            }
        }
        let tables: Vec<&BlockTable> = rows.iter().map(|r| r.table).collect();
        let frows: Vec<(usize, i32, usize)> = rows
            .iter()
            .enumerate()
            .map(|(i, r)| (i, r.token, r.pos))
            .collect();
        let (xs, stats) = self.forward_step_cascade(
            &frows,
            &mut StepKv::Paged { pools, tables: &tables },
            groups,
        );
        self.cascade_stats.passes += stats.passes;
        self.cascade_stats.rows_saved += stats.rows_saved;

        let vocab = self.info.vocab;
        let mut logits = vec![0.0f32; rows.len() * vocab];
        for (i, x) in xs.iter().enumerate() {
            self.logits_row(x, &mut logits[i * vocab..][..vocab]);
        }
        Ok(logits)
    }

    fn plane_elems(&self, batch: usize) -> usize {
        self.info.n_layers * batch * self.cache.layer_elems()
    }

    /// A table's geometry must match the model's cache shape and the
    /// pool's page layout — a mismatched pair would index the row store
    /// with the wrong stride and corrupt KV silently.
    fn check_table(&self, t: &BlockTable, pools: &TieredPagePool, what: &str) -> Result<()> {
        if t.layers() != self.cache.layers || t.kv_heads() != self.cache.kv_heads {
            bail!(
                "{what}: block table is [{} layers, {} kv_heads], model wants [{}, {}]",
                t.layers(),
                t.kv_heads(),
                self.cache.layers,
                self.cache.kv_heads
            );
        }
        if t.page_size() != pools.page_size() {
            bail!(
                "{what}: table page_size {} != pool page_size {}",
                t.page_size(),
                pools.page_size()
            );
        }
        if pools.head_dim() != self.cache.head_dim {
            bail!(
                "{what}: pool head_dim {} != model head_dim {}",
                pools.head_dim(),
                self.cache.head_dim
            );
        }
        Ok(())
    }
}

/// Where a host-model forward step reads/writes KV: the engine wire
/// format's packed `[L, B, Nkv, S, D]` planes, or the tiered paged pool
/// behind per-row block tables (rows gather across the device and host
/// stores; fresh rows land on whichever tier the table names).
enum StepKv<'a> {
    Plane { batch: usize, k: &'a mut [f32], v: &'a mut [f32] },
    Paged { pools: &'a mut TieredPagePool, tables: &'a [&'a BlockTable] },
}

impl Backend for HostModelBackend {
    fn model(&self) -> &ModelInfo {
        &self.info
    }

    fn buckets(&self) -> BucketGrid {
        self.cfg.buckets.clone()
    }

    fn set_parallel(&mut self, cfg: ParallelConfig) {
        self.pool = WorkPool::new(cfg);
    }

    fn prefill(
        &mut self,
        batch: usize,
        seq: usize,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<StepOut> {
        if tokens.len() != batch * seq || lengths.len() != batch {
            bail!(
                "prefill shape: {} tokens / {} lengths for b={batch} s={seq}",
                tokens.len(),
                lengths.len()
            );
        }
        let max_len = lengths.iter().copied().max().unwrap_or(0).max(0) as usize;
        if max_len > seq {
            bail!("prefill length {max_len} exceeds seq bucket {seq}");
        }
        if max_len > self.cache.max_seq {
            bail!("prefill length {max_len} exceeds max_seq {}", self.cache.max_seq);
        }
        let mut k_plane = vec![0.0f32; self.plane_elems(batch)];
        let mut v_plane = vec![0.0f32; self.plane_elems(batch)];
        let vocab = self.info.vocab;
        let mut finals: Vec<Vec<f32>> = vec![Vec::new(); batch];

        for t in 0..max_len {
            let rows: Vec<(usize, i32, usize)> = (0..batch)
                .filter(|&i| (t as i32) < lengths[i])
                .map(|i| (i, tokens[i * seq + t], t))
                .collect();
            let xs = self.forward_step(
                &rows,
                &mut StepKv::Plane { batch, k: &mut k_plane, v: &mut v_plane },
            );
            for (&(slot, _, _), x) in rows.iter().zip(xs) {
                if t as i32 == lengths[slot] - 1 {
                    finals[slot] = x;
                }
            }
        }

        let mut logits = vec![0.0f32; batch * vocab];
        for (slot, x) in finals.iter().enumerate() {
            if !x.is_empty() {
                self.logits_row(x, &mut logits[slot * vocab..][..vocab]);
            }
        }
        Ok(StepOut { logits, k_plane, v_plane })
    }

    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        mut k_plane: Vec<f32>,
        mut v_plane: Vec<f32>,
        pos: &[i32],
    ) -> Result<StepOut> {
        if tokens.len() != batch || pos.len() != batch {
            bail!("decode shape: {} tokens / {} pos for b={batch}", tokens.len(), pos.len());
        }
        if k_plane.len() != self.plane_elems(batch) || v_plane.len() != k_plane.len() {
            bail!(
                "decode planes: {} elems, want {}",
                k_plane.len(),
                self.plane_elems(batch)
            );
        }
        for (i, &p) in pos.iter().enumerate() {
            if p < 0 || p as usize >= self.cache.max_seq {
                bail!("decode pos[{i}] = {p} out of cache range {}", self.cache.max_seq);
            }
        }
        let rows: Vec<(usize, i32, usize)> =
            (0..batch).map(|i| (i, tokens[i], pos[i] as usize)).collect();
        let xs = self.forward_step(
            &rows,
            &mut StepKv::Plane { batch, k: &mut k_plane, v: &mut v_plane },
        );

        let vocab = self.info.vocab;
        let mut logits = vec![0.0f32; batch * vocab];
        for (slot, x) in xs.iter().enumerate() {
            self.logits_row(x, &mut logits[slot * vocab..][..vocab]);
        }
        Ok(StepOut { logits, k_plane, v_plane })
    }

    fn supports_paged(&self) -> bool {
        true
    }

    fn decode_paged(
        &mut self,
        rows: &[PagedRow<'_>],
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        self.decode_paged_with_groups(rows, &[], pools)
    }

    fn decode_paged_cascade(
        &mut self,
        rows: &[PagedRow<'_>],
        groups: &[CascadeGroup],
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        self.decode_paged_with_groups(rows, groups, pools)
    }

    fn take_cascade_stats(&mut self) -> CascadeStats {
        std::mem::take(&mut self.cascade_stats)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        start_pos: usize,
        table: &BlockTable,
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("prefill_chunk: empty chunk");
        }
        self.check_table(table, pools, "prefill_chunk")?;
        let end = start_pos + tokens.len();
        if end > self.cache.max_seq {
            bail!("prefill_chunk: positions ..{end} exceed max_seq {}", self.cache.max_seq);
        }
        if table.capacity_tokens() < end {
            bail!(
                "prefill_chunk: table holds {} tokens, chunk ends at {end}",
                table.capacity_tokens()
            );
        }
        let tables = [table];
        let mut last: Vec<f32> = Vec::new();
        for (t, &tok) in tokens.iter().enumerate() {
            // chunk-boundary causality contract: row `t` of this chunk
            // attends exactly the KV rows `attention::mask` says it may
            // (forward_step derives kv_len = pos + 1 from the same
            // absolute position).
            debug_assert_eq!(
                crate::attention::mask::chunk_row_visible(start_pos, t),
                start_pos + t + 1,
            );
            let xs = self.forward_step(
                &[(0, tok, start_pos + t)],
                &mut StepKv::Paged { pools: &mut *pools, tables: &tables },
            );
            last = xs.into_iter().next().expect("one row per step");
        }
        let mut logits = vec![0.0f32; self.info.vocab];
        self.logits_row(&last, &mut logits);
        Ok(logits)
    }

    fn supports_verify(&self) -> bool {
        true
    }

    fn verify_step(
        &mut self,
        tokens: &[i32],
        start_pos: usize,
        table: &BlockTable,
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        if tokens.is_empty() {
            bail!("verify_step: empty token run");
        }
        self.check_table(table, pools, "verify_step")?;
        let end = start_pos + tokens.len();
        if end > self.cache.max_seq {
            bail!("verify_step: positions ..{end} exceed max_seq {}", self.cache.max_seq);
        }
        if table.capacity_tokens() < end {
            bail!(
                "verify_step: table holds {} tokens, verify run ends at {end}",
                table.capacity_tokens()
            );
        }
        // All k+1 positions of one sequence as rows of ONE forward
        // step: each layer writes every row's K/V before its batched
        // attention runs, and the per-row `kv_len = pos + 1` caps row
        // `t`'s reads at exactly the chunk-boundary causal visibility —
        // later draft rows' freshly written KV stays invisible to
        // earlier rows, so each row scores bit-identically to a vanilla
        // decode step at its position.
        let tables: Vec<&BlockTable> = vec![table; tokens.len()];
        let rows: Vec<(usize, i32, usize)> = tokens
            .iter()
            .enumerate()
            .map(|(t, &tok)| {
                debug_assert_eq!(
                    crate::attention::mask::chunk_row_visible(start_pos, t),
                    start_pos + t + 1,
                );
                (t, tok, start_pos + t)
            })
            .collect();
        let xs =
            self.forward_step(&rows, &mut StepKv::Paged { pools: &mut *pools, tables: &tables });
        let vocab = self.info.vocab;
        let mut logits = vec![0.0f32; tokens.len() * vocab];
        for (i, x) in xs.iter().enumerate() {
            self.logits_row(x, &mut logits[i * vocab..][..vocab]);
        }
        Ok(logits)
    }

    fn prefill_chunks_sharded(
        &mut self,
        chunks: &[ChunkRun<'_>],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<Vec<f32>>> {
        if pools.len() != 1 {
            bail!("backend cannot execute across {} KV shards", pools.len());
        }
        if chunks.is_empty() {
            return Ok(Vec::new());
        }
        let pool = &mut pools[0];
        let mut max_len = 0usize;
        for (i, c) in chunks.iter().enumerate() {
            if c.tokens.is_empty() {
                bail!("prefill_chunks row {i}: empty chunk");
            }
            if c.tables.len() != 1 {
                bail!("prefill_chunks row {i}: {} tables for 1 shard", c.tables.len());
            }
            self.check_table(&c.tables[0], pool, "prefill_chunks")?;
            let end = c.start_pos + c.tokens.len();
            if end > self.cache.max_seq {
                bail!(
                    "prefill_chunks row {i}: positions ..{end} exceed max_seq {}",
                    self.cache.max_seq
                );
            }
            if c.tables[0].capacity_tokens() < end {
                bail!(
                    "prefill_chunks row {i}: table holds {} tokens, chunk ends at {end}",
                    c.tables[0].capacity_tokens()
                );
            }
            max_len = max_len.max(c.tokens.len());
        }
        // one forward step per chunk position, every still-unfinished
        // chunk contributing one row — the same ragged-batch shape as
        // bucketed prefill, so cross-sequence packing cannot change any
        // chunk's own rows (they are independent per row).
        let mut finals: Vec<Vec<f32>> = vec![Vec::new(); chunks.len()];
        for t in 0..max_len {
            let live: Vec<usize> =
                (0..chunks.len()).filter(|&ci| t < chunks[ci].tokens.len()).collect();
            let tables: Vec<&BlockTable> =
                live.iter().map(|&ci| &chunks[ci].tables[0]).collect();
            let rows: Vec<(usize, i32, usize)> = live
                .iter()
                .enumerate()
                .map(|(ri, &ci)| {
                    debug_assert_eq!(
                        crate::attention::mask::chunk_row_visible(chunks[ci].start_pos, t),
                        chunks[ci].start_pos + t + 1,
                    );
                    (ri, chunks[ci].tokens[t], chunks[ci].start_pos + t)
                })
                .collect();
            let xs = self.forward_step(
                &rows,
                &mut StepKv::Paged { pools: &mut *pool, tables: &tables },
            );
            for (&ci, x) in live.iter().zip(xs) {
                if t == chunks[ci].tokens.len() - 1 {
                    finals[ci] = x;
                }
            }
        }
        let vocab = self.info.vocab;
        let mut out = Vec::with_capacity(chunks.len());
        for x in &finals {
            let mut logits = vec![0.0f32; vocab];
            self.logits_row(x, &mut logits);
            out.push(logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::PcieLink;

    fn backend(par: ParallelConfig) -> HostModelBackend {
        HostModelBackend::with_parallel(HostModelConfig::tiny_gqa(), par)
    }

    #[test]
    fn same_seed_same_weights() {
        let a = backend(ParallelConfig::sequential());
        let b = backend(ParallelConfig::sequential());
        assert_eq!(a.embed, b.embed);
        assert_eq!(a.layers[0].wq, b.layers[0].wq);
        assert!(a.info.n_params > 0);
        assert_eq!(a.info.n_kv_heads, 2);
    }

    #[test]
    fn decode_continues_prefill() {
        // prefill [t0 t1 t2] then decode t3 must equal prefill [t0..t3]:
        // same cache contents and the same last-token logits.
        let mut be = backend(ParallelConfig::sequential());
        let toks = [3i32, 9, 17, 25];

        let full = be.prefill(1, 8, &pad(&toks, 8), &[4]).unwrap();
        let part = be.prefill(1, 8, &pad(&toks[..3], 8), &[3]).unwrap();
        let step = be
            .decode(1, &[toks[3]], part.k_plane, part.v_plane, &[3])
            .unwrap();
        assert_eq!(valid_prefix(&be, &full.k_plane, 4), valid_prefix(&be, &step.k_plane, 4));
        let la = &full.logits[..be.info.vocab];
        let lb = &step.logits[..be.info.vocab];
        let err = la
            .iter()
            .zip(lb)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(err < 1e-5, "prefill-vs-decode logits diverge: {err}");
    }

    #[test]
    fn parallel_backend_is_bit_identical() {
        let mut seq = backend(ParallelConfig::sequential());
        let mut par = backend(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        let toks: Vec<i32> = (0..24).map(|i| i * 7 + 1).collect();
        let a = seq.prefill(4, 8, &grid(&toks, 4, 8), &[8, 8, 8, 8]).unwrap();
        let b = par.prefill(4, 8, &grid(&toks, 4, 8), &[8, 8, 8, 8]).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.k_plane, b.k_plane);

        let da = seq.decode(4, &[1, 2, 3, 4], a.k_plane, a.v_plane, &[8, 8, 8, 8]).unwrap();
        let db = par.decode(4, &[1, 2, 3, 4], b.k_plane, b.v_plane, &[8, 8, 8, 8]).unwrap();
        assert_eq!(da.logits, db.logits);
        assert_eq!(da.k_plane, db.k_plane);
        assert_eq!(da.v_plane, db.v_plane);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut be = backend(ParallelConfig::sequential());
        assert!(be.prefill(2, 8, &[0; 8], &[1, 1]).is_err());
        assert!(be.decode(2, &[0, 0], vec![0.0; 8], vec![0.0; 8], &[0, 0]).is_err());
        let n = be.plane_elems(1);
        assert!(be
            .decode(1, &[0], vec![0.0; n], vec![0.0; n], &[be.cache.max_seq as i32])
            .is_err());
    }

    /// Chunked paged prefill must be bit-identical to the plane prefill
    /// of the same prompt, for any chunk partition — the chunk-boundary
    /// causal-masking property — even with cold blocks migrating to the
    /// host tier between chunks.
    #[test]
    fn chunked_paged_prefill_matches_plane() {
        let mut rng = Rng::new(99);
        for case in 0..12u64 {
            let mut be = backend(ParallelConfig::sequential());
            let len = rng.range(1, 33);
            let toks: Vec<i32> = (0..len).map(|_| rng.below(64) as i32).collect();

            // plane path: one bucketed prefill over the whole prompt
            let plane = be.prefill(1, len, &toks, &[len as i32]).unwrap();

            // paged path: random chunk partition over the tiered pool
            let page_size = rng.range(1, 7);
            let cap = BlockTable::pages_needed(be.cache, page_size, be.cache.max_seq);
            let mut pools = TieredPagePool::new(
                page_size,
                be.cache.head_dim,
                cap,
                cap,
                PcieLink::default(),
            );
            let mut table = BlockTable::new(be.cache, page_size);
            let mut start = 0;
            let mut logits = Vec::new();
            while start < len {
                let chunk = rng.range(1, len - start + 1);
                let end = start + chunk;
                table.ensure_capacity(end, pools.device_mut()).unwrap();
                logits = be
                    .prefill_chunk(&toks[start..end], start, &table, &mut pools)
                    .unwrap();
                start = end;
                // randomly offload the coldest block between chunks —
                // later chunks and decode must not care where KV lives
                if rng.bool() {
                    if let Some(b) = table.coldest_device_block(true) {
                        table.migrate_block_to_host(b, &mut pools).unwrap();
                    }
                }
            }
            assert_eq!(
                &plane.logits[..be.info.vocab],
                &logits[..],
                "case {case}: len={len} page_size={page_size}"
            );

            // the caches agree row for row, whichever tier holds them
            for l in 0..be.cache.layers {
                for g in 0..be.cache.kv_heads {
                    for r in 0..len {
                        let at = be.cache.batch_row_offset(1, l, 0, g, r);
                        let (tier, page, slot) = table.locate_tiered(l, g, r);
                        let pat = (page as usize * page_size + slot) * be.cache.head_dim;
                        assert_eq!(
                            &plane.k_plane[at..at + be.cache.head_dim],
                            &pools.k_store(tier)[pat..pat + be.cache.head_dim],
                            "case {case}: K row l={l} g={g} r={r} ({tier:?})"
                        );
                        assert_eq!(
                            &plane.v_plane[at..at + be.cache.head_dim],
                            &pools.v_store(tier)[pat..pat + be.cache.head_dim],
                            "case {case}: V row l={l} g={g} r={r} ({tier:?})"
                        );
                    }
                }
            }

            // decode continuation agrees bit for bit too
            let next = 7i32;
            let dp = be
                .decode(1, &[next], plane.k_plane, plane.v_plane, &[len as i32])
                .unwrap();
            table.ensure_capacity(len + 1, pools.device_mut()).unwrap();
            let rows = [PagedRow { table: &table, token: next, pos: len }];
            let dl = be.decode_paged(&rows, &mut pools).unwrap();
            assert_eq!(&dp.logits[..be.info.vocab], &dl[..], "case {case}: decode");
        }
    }

    /// Decode over a partially-offloaded sequence (some blocks migrated
    /// to the host tier) must be bit-identical to decode over the same
    /// sequence fully device-resident.
    #[test]
    fn decode_after_migration_bit_identical() {
        let mut be = backend(ParallelConfig::sequential());
        let page_size = 4usize;
        let cap = BlockTable::pages_needed(be.cache, page_size, be.cache.max_seq);
        let toks: Vec<i32> = (0..20).map(|i| (i * 5 + 3) % 64).collect();

        let run = |be: &mut HostModelBackend, migrate: &[usize]| -> Vec<f32> {
            let mut pools =
                TieredPagePool::new(page_size, be.cache.head_dim, cap, cap, PcieLink::default());
            let mut table = BlockTable::new(be.cache, page_size);
            table.ensure_capacity(toks.len(), pools.device_mut()).unwrap();
            be.prefill_chunk(&toks, 0, &table, &mut pools).unwrap();
            for &b in migrate {
                table.migrate_block_to_host(b, &mut pools).unwrap();
            }
            table.ensure_capacity(toks.len() + 1, pools.device_mut()).unwrap();
            let rows = [PagedRow { table: &table, token: 9, pos: toks.len() }];
            be.decode_paged(&rows, &mut pools).unwrap()
        };
        let device_only = run(&mut be, &[]);
        // 20 tokens at page_size 4 → 5 blocks; offload two cold ones
        let tiered = run(&mut be, &[0, 2]);
        assert_eq!(device_only, tiered, "migration must not change decode bits");
    }

    /// Decode across a swap-out/restore cycle must be bit-identical to
    /// never having suspended: writes into a restored (promoted) block
    /// land device-side, writes into a still-parked block land
    /// host-side, and the gather streams the same rows either way.
    #[test]
    fn decode_after_suspend_resume_bit_identical() {
        let mut be = backend(ParallelConfig::sequential());
        let page_size = 4usize;
        let cap = BlockTable::pages_needed(be.cache, page_size, be.cache.max_seq);
        let toks: Vec<i32> = (0..20).map(|i| (i * 11 + 2) % 64).collect();

        let run = |be: &mut HostModelBackend, cycle: u8| -> Vec<f32> {
            let mut pools =
                TieredPagePool::new(page_size, be.cache.head_dim, cap, cap, PcieLink::default());
            let mut table = BlockTable::new(be.cache, page_size);
            table.ensure_capacity(toks.len(), pools.device_mut()).unwrap();
            be.prefill_chunk(&toks, 0, &table, &mut pools).unwrap();
            match cycle {
                0 => {}
                1 => {
                    // park the whole table, decode against the host store
                    table.suspend_to_host(&mut pools).unwrap();
                }
                _ => {
                    // park and fully restore: back on device
                    table.suspend_to_host(&mut pools).unwrap();
                    table.resume_from_host(&mut pools).unwrap();
                    assert_eq!(table.host_blocks(), 0);
                }
            }
            table.ensure_capacity(toks.len() + 1, pools.device_mut()).unwrap();
            let rows = [PagedRow { table: &table, token: 9, pos: toks.len() }];
            be.decode_paged(&rows, &mut pools).unwrap()
        };
        let never = run(&mut be, 0);
        let parked = run(&mut be, 1);
        let restored = run(&mut be, 2);
        assert_eq!(never, parked, "decode from the host store must match device bits");
        assert_eq!(never, restored, "a swap round trip must be invisible to decode");
    }

    #[test]
    fn paged_rejects_bad_geometry() {
        let mut be = backend(ParallelConfig::sequential());
        let mut pool = TieredPagePool::new(4, be.cache.head_dim, 64, 0, PcieLink::default());
        let mut table = BlockTable::new(be.cache, 4);
        // no capacity yet → decode_paged refuses
        let rows = [PagedRow { table: &table, token: 1, pos: 0 }];
        assert!(be.decode_paged(&rows, &mut pool).is_err());
        // wrong-shape table refused
        let other = CacheShape { layers: 1, kv_heads: 1, max_seq: 8, head_dim: be.cache.head_dim };
        let bad = BlockTable::new(other, 4);
        let rows = [PagedRow { table: &bad, token: 1, pos: 0 }];
        assert!(be.decode_paged(&rows, &mut pool).is_err());
        // page_size mismatch between table and pool refused (would
        // otherwise index the row store with the wrong stride)
        let mut pool8 = TieredPagePool::new(8, be.cache.head_dim, 64, 0, PcieLink::default());
        let mut skewed = BlockTable::new(be.cache, 8);
        skewed.ensure_capacity(1, pool8.device_mut()).unwrap();
        let rows = [PagedRow { table: &skewed, token: 1, pos: 0 }];
        assert!(be.decode_paged(&rows, &mut pool).is_err());
        // chunk beyond capacity refused; empty chunk refused
        assert!(be.prefill_chunk(&[1, 2], 0, &table, &mut pool).is_err());
        table.ensure_capacity(2, pool.device_mut()).unwrap();
        assert!(be.prefill_chunk(&[], 0, &table, &mut pool).is_err());
        assert!(be.prefill_chunk(&[1, 2], 0, &table, &mut pool).is_ok());
    }

    fn pad(toks: &[i32], s: usize) -> Vec<i32> {
        let mut v = toks.to_vec();
        v.resize(s, 0);
        v
    }

    fn grid(toks: &[i32], b: usize, s: usize) -> Vec<i32> {
        let mut v = vec![0i32; b * s];
        for (i, chunk) in toks.chunks(s).take(b).enumerate() {
            v[i * s..][..chunk.len()].copy_from_slice(chunk);
        }
        v
    }

    /// The first `len` rows of every (layer, head) plane of slot 0.
    fn valid_prefix(be: &HostModelBackend, plane: &[f32], len: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for l in 0..be.info.n_layers {
            for g in 0..be.info.n_kv_heads {
                let at = be.cache.batch_row_offset(1, l, 0, g, 0);
                out.extend_from_slice(&plane[at..at + len * be.info.head_dim]);
            }
        }
        out
    }
}
