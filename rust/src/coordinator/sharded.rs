//! Tensor-parallel sharded serving backend (§4.2's multi-NPU path).
//!
//! [`ShardedBackend`] composes N per-device [`HostModelBackend`]s with
//! the KV heads sharded across simulated devices: shard `s` owns query
//! heads `[s·H/N, (s+1)·H/N)` and KV heads `[s·Nkv/N, (s+1)·Nkv/N)`
//! (FlashAttention-2-style head partitioning — GQA groups never split,
//! because `N | Nkv` is required).  Every shard executes decode/prefill
//! attention over its own head slice through the existing batched paged
//! path against its own [`TieredPagePool`], and the per-shard partial
//! attention outputs are combined with the paper's tiling-AllReduce
//! schedule:
//!
//! * **numerics** go through the real in-process ring
//!   ([`ring_all_reduce`]): each shard contributes a zero-padded
//!   full-width activation tile whose support is its own head slice, so
//!   the reduction is an exact concatenation — sharded decode is
//!   bit-identical to the single-device engine, token for token;
//! * **timing** is charged to the modeled ring ([`RingSpec`]): one
//!   B-allreduce per tile of `tile_rows` decode rows, overlapped with
//!   the next tile's compute via [`overlapped_schedule`] (or serialized
//!   when [`ShardedConfig::overlap`] is off), accumulated into
//!   [`AllReduceStats`] which the engine surfaces as
//!   `allreduce_modeled_s` / `allreduce_hidden_s` alongside
//!   `pcie_modeled_s`.
//!
//! Weights are fully replicated (each shard holds the same
//! deterministic model and *uses* only its head columns); the
//! projections before and after attention are computed once on the
//! primary shard, exactly as a single device would, which is what makes
//! the bit-identity property testable rather than approximate.

use anyhow::{bail, Result};

use crate::attention::batch::{
    batch_decode_attention, BatchShape, ParallelConfig, SeqAttn, SeqKv,
};
use crate::coordinator::allreduce::{ranks_bit_identical, ring_all_reduce};
use crate::coordinator::backend::{
    matvec, rmsnorm, AllReduceStats, Backend, BucketGrid, ChunkRun, HostModelBackend,
    HostModelConfig, ModelInfo, PagedRow, ShardedRow, StepOut,
};
use crate::coordinator::kv_cache::{BlockTable, PageCodec, TieredPagePool};
use crate::sim::collective::{
    overlapped_schedule, serial_schedule, AllReduceBlock, RingSpec,
};

/// How a [`ShardedBackend`] splits and combines work across shards.
#[derive(Debug, Clone, Copy)]
pub struct ShardedConfig {
    /// Simulated devices (tensor-parallel degree).  Must divide the
    /// model's KV head count.
    pub shards: usize,
    /// Modeled interconnect; `n` is overridden to `shards`.
    pub ring: RingSpec,
    /// Decode rows per B-allreduce tile (≥ 1): each tile's combine
    /// overlaps the next tile's attention compute.
    pub tile_rows: usize,
    /// Modeled per-row attention compute seconds feeding the overlap
    /// schedule (the in-process math is microseconds — the model is
    /// what carries device-scale timing).
    pub modeled_row_compute_s: f64,
    /// `true`: tiling-AllReduce (per-tile combine overlapped with the
    /// next tile, the real ring running on a spawned channel thread);
    /// `false`: serial baseline (all tiles computed, then one combine).
    pub overlap: bool,
}

impl ShardedConfig {
    /// Tiling-AllReduce defaults for `shards` devices.
    pub fn for_shards(shards: usize) -> Self {
        Self {
            shards: shards.max(1),
            ring: RingSpec::default(),
            tile_rows: 4,
            modeled_row_compute_s: 50e-6,
            overlap: true,
        }
    }

    /// The serial-combine ablation of the same geometry.
    pub fn serial(shards: usize) -> Self {
        Self { overlap: false, ..Self::for_shards(shards) }
    }
}

/// N per-device host models sharded by KV head, combined per tile with
/// the tiling-AllReduce schedule.  See the module docs.
pub struct ShardedBackend {
    shards: Vec<HostModelBackend>,
    scfg: ShardedConfig,
    comm: AllReduceStats,
}

impl ShardedBackend {
    /// Build `scfg.shards` replicas of the host model.  Fails when the
    /// shard count does not divide the model's KV heads (a GQA group
    /// must never straddle devices).
    pub fn new(cfg: HostModelConfig, scfg: ShardedConfig) -> Result<Self> {
        let n = scfg.shards.max(1);
        let kvh = cfg.model.kv_heads as usize;
        if kvh % n != 0 {
            bail!("{n} shards do not divide {kvh} kv heads");
        }
        let scfg = ShardedConfig {
            shards: n,
            ring: RingSpec { n: n as u64, ..scfg.ring },
            tile_rows: scfg.tile_rows.max(1),
            ..scfg
        };
        let shards: Vec<HostModelBackend> =
            (0..n).map(|_| HostModelBackend::new(cfg.clone())).collect();
        Ok(Self { shards, scfg, comm: AllReduceStats::default() })
    }

    /// The sharding/overlap configuration in effect.
    pub fn config(&self) -> &ShardedConfig {
        &self.scfg
    }

    /// Per-shard table/pool geometry must match the *shard* slice of
    /// the model (`kv_heads / shards` heads), or the row stores would
    /// be indexed with the wrong stride.
    fn check_shard_table(&self, t: &BlockTable, pools: &TieredPagePool, what: &str) -> Result<()> {
        let cache = self.shards[0].cache_shape();
        let kvh_l = cache.kv_heads / self.shards.len();
        if t.layers() != cache.layers || t.kv_heads() != kvh_l {
            bail!(
                "{what}: shard table is [{} layers, {} kv_heads], shard wants [{}, {kvh_l}]",
                t.layers(),
                t.kv_heads(),
                cache.layers
            );
        }
        if t.page_size() != pools.page_size() {
            bail!(
                "{what}: table page_size {} != pool page_size {}",
                t.page_size(),
                pools.page_size()
            );
        }
        if pools.head_dim() != cache.head_dim {
            bail!(
                "{what}: pool head_dim {} != model head_dim {}",
                pools.head_dim(),
                cache.head_dim
            );
        }
        Ok(())
    }
}

/// One token step for `rows = [(token, pos)]` across all shards:
/// projections once (replicated math, identical to a single device),
/// KV writes and attention per shard over its head slice, per-tile ring
/// combine, output projection + MLP once.  Returns final hidden states
/// aligned with `rows`.
///
/// `row_tables[ri][s]` is row `ri`'s block table on shard `s`, paired
/// with `pools[s]`.  `overlap` selects the combine schedule charged to
/// `comm` (prefill always charges serial — tokens are sequential, so
/// there is no next tile to hide communication under).
fn forward_sharded(
    shards: &[HostModelBackend],
    scfg: &ShardedConfig,
    comm: &mut AllReduceStats,
    rows: &[(i32, usize)],
    row_tables: &[&[BlockTable]],
    pools: &mut [TieredPagePool],
    overlap: bool,
) -> Vec<Vec<f32>> {
    let n = shards.len();
    let primary = &shards[0];
    let info = primary.model();
    let cache = primary.cache_shape();
    let d = primary.d_model();
    let (heads, kvh, hd) = (info.n_heads, info.n_kv_heads, info.head_dim);
    let (heads_l, kvh_l) = (heads / n, kvh / n);
    let (qdim, kvdim, hdim_l) = (heads * hd, kvh * hd, heads_l * hd);
    let bshape_l = BatchShape::new(heads_l, kvh_l, hd, cache.max_seq);
    let weights = primary.layer_weights();
    let ring = scfg.ring;
    let tile_rows = scfg.tile_rows.max(1);

    let mut xs: Vec<Vec<f32>> = rows.iter().map(|&(tok, _)| primary.embed_row(tok)).collect();
    let mut qbuf = vec![0.0f32; rows.len() * qdim];
    let mut attn = vec![0.0f32; rows.len() * qdim];
    let mut krow = vec![0.0f32; kvdim];
    let mut vrow = vec![0.0f32; kvdim];
    let mut proj = vec![0.0f32; d.max(info.d_ff)];

    for (l, w) in weights.iter().enumerate() {
        // ---- projections (once) + per-shard KV writes ----------------
        for (ri, &(_, pos)) in rows.iter().enumerate() {
            let h = rmsnorm(&xs[ri]);
            matvec(&h, &w.wq, &mut qbuf[ri * qdim..][..qdim]);
            matvec(&h, &w.wk, &mut krow);
            matvec(&h, &w.wv, &mut vrow);
            for (s, pool) in pools.iter_mut().enumerate() {
                for g_local in 0..kvh_l {
                    let g = s * kvh_l + g_local;
                    let (tier, page, in_page) =
                        row_tables[ri][s].locate_tiered(l, g_local, pos);
                    pool.write_row(
                        tier,
                        page,
                        in_page,
                        &krow[g * hd..][..hd],
                        &vrow[g * hd..][..hd],
                    );
                }
            }
        }

        // ---- per-shard attention, tiled, combined via the ring -------
        // At most one combine is in flight (the interconnect channel is
        // serial); its thread runs while the next tile's attention
        // computes — the overlap the timing model charges for.
        let mut pending: Option<(
            Vec<usize>,
            std::thread::JoinHandle<Vec<Vec<f32>>>,
        )> = None;
        let mut layer_blocks: Vec<AllReduceBlock> = Vec::new();
        let mut tile_start = 0usize;
        while tile_start < rows.len() {
            let tile_end = (tile_start + tile_rows).min(rows.len());
            let tile: Vec<usize> = (tile_start..tile_end).collect();
            let tile_len = tile.len();

            // each shard's partial outputs, zero-padded to full width
            // with support on its own head slice — the ring sum is an
            // exact concatenation (x + 0.0 is exact)
            let mut shard_vecs: Vec<Vec<f32>> = Vec::with_capacity(n);
            for s in 0..n {
                let pool = &pools[s];
                let host_empty = pool.host().num_pages() == 0;
                let codec = pool.codec();
                let seqs: Vec<SeqAttn<'_>> = tile
                    .iter()
                    .map(|&ri| {
                        let t = &row_tables[ri][s];
                        let pos = rows[ri].1;
                        SeqAttn {
                            q: &qbuf[ri * qdim + s * hdim_l..][..hdim_l],
                            kv: match (codec, host_empty) {
                                (PageCodec::F32, true) => SeqKv::Paged {
                                    k_store: pool.device().k_store(),
                                    v_store: pool.device().v_store(),
                                    pages: t.layer_pages(l),
                                    max_blocks: t.max_blocks(),
                                    page_size: t.page_size(),
                                },
                                (PageCodec::F32, false) => SeqKv::Tiered {
                                    k_device: pool.device().k_store(),
                                    v_device: pool.device().v_store(),
                                    k_host: pool.host().k_store(),
                                    v_host: pool.host().v_store(),
                                    pages: t.layer_pages(l),
                                    tiers: t.layer_tiers(l),
                                    max_blocks: t.max_blocks(),
                                    page_size: t.page_size(),
                                },
                                (PageCodec::Int8, true) => SeqKv::PagedI8 {
                                    k: pool.device().k_quant_store(),
                                    v: pool.device().v_quant_store(),
                                    pages: t.layer_pages(l),
                                    max_blocks: t.max_blocks(),
                                    page_size: t.page_size(),
                                },
                                (PageCodec::Int8, false) => SeqKv::TieredI8 {
                                    k_device: pool.device().k_quant_store(),
                                    v_device: pool.device().v_quant_store(),
                                    k_host: pool.host().k_quant_store(),
                                    v_host: pool.host().v_quant_store(),
                                    pages: t.layer_pages(l),
                                    tiers: t.layer_tiers(l),
                                    max_blocks: t.max_blocks(),
                                    page_size: t.page_size(),
                                },
                            },
                            kv_len: pos + 1,
                        }
                    })
                    .collect();
                let mut part = vec![0.0f32; tile_len * hdim_l];
                batch_decode_attention(&bshape_l, &seqs, &mut part, shards[s].work_pool());
                let mut padded = vec![0.0f32; tile_len * qdim];
                for k in 0..tile_len {
                    padded[k * qdim + s * hdim_l..][..hdim_l]
                        .copy_from_slice(&part[k * hdim_l..][..hdim_l]);
                }
                shard_vecs.push(padded);
            }

            if n == 1 {
                // single device: the "slice" is the whole row
                for (k, &ri) in tile.iter().enumerate() {
                    attn[ri * qdim..][..qdim]
                        .copy_from_slice(&shard_vecs[0][k * qdim..][..qdim]);
                }
            } else {
                layer_blocks.push(AllReduceBlock {
                    compute_s: tile_len as f64 * scfg.modeled_row_compute_s,
                    bytes: (tile_len * qdim * 4) as u64,
                });
                if overlap {
                    // stitch the previous tile's combine, then launch
                    // this tile's on the channel thread
                    if let Some((prows, handle)) = pending.take() {
                        stitch(&prows, handle, &mut attn, qdim);
                    }
                    pending =
                        Some((tile, std::thread::spawn(move || ring_all_reduce(shard_vecs))));
                } else {
                    let reduced = ring_all_reduce(shard_vecs);
                    assert!(
                        ranks_bit_identical(&reduced),
                        "allreduce ranks diverged (layer {l})"
                    );
                    for (k, &ri) in tile.iter().enumerate() {
                        attn[ri * qdim..][..qdim]
                            .copy_from_slice(&reduced[0][k * qdim..][..qdim]);
                    }
                }
            }
            tile_start = tile_end;
        }
        if let Some((prows, handle)) = pending.take() {
            stitch(&prows, handle, &mut attn, qdim);
        }

        // ---- modeled comm accounting for this layer ------------------
        if n > 1 && !layer_blocks.is_empty() {
            let total_bytes: u64 = layer_blocks.iter().map(|b| b.bytes).sum();
            let serial_t = serial_schedule(&ring, &layer_blocks);
            comm.tiles += layer_blocks.len() as u64;
            comm.bytes += total_bytes;
            comm.serial_makespan_s += serial_t;
            if overlap {
                let r = overlapped_schedule(&ring, &layer_blocks);
                comm.modeled_s += r.total_comm_s;
                comm.hidden_s += r.hidden_comm_s;
                comm.makespan_s += r.makespan_s;
            } else {
                comm.modeled_s += ring.allreduce(total_bytes);
                comm.makespan_s += serial_t;
            }
        }

        // ---- output projection + MLP (once, replicated) --------------
        for (ri, x) in xs.iter_mut().enumerate() {
            matvec(&attn[ri * qdim..][..qdim], &w.wo, &mut proj[..d]);
            for (xi, &p) in x.iter_mut().zip(&proj[..d]) {
                *xi += p;
            }
            let h = rmsnorm(x);
            matvec(&h, &w.w1, &mut proj[..info.d_ff]);
            for p in &mut proj[..info.d_ff] {
                *p = p.max(0.0); // ReLU
            }
            let mlp = proj[..info.d_ff].to_vec();
            matvec(&mlp, &w.w2, &mut proj[..d]);
            for (xi, &p) in x.iter_mut().zip(&proj[..d]) {
                *xi += p;
            }
        }
    }
    xs
}

/// Join a tile's in-flight combine and scatter rank 0's reduced rows
/// back into the full-width attention buffer, asserting every rank
/// agreed bit-for-bit first (the rank-agreement contract of the ring).
fn stitch(
    tile: &[usize],
    handle: std::thread::JoinHandle<Vec<Vec<f32>>>,
    attn: &mut [f32],
    qdim: usize,
) {
    let reduced = handle.join().expect("allreduce channel thread");
    assert!(ranks_bit_identical(&reduced), "allreduce ranks diverged");
    for (k, &ri) in tile.iter().enumerate() {
        attn[ri * qdim..][..qdim].copy_from_slice(&reduced[0][k * qdim..][..qdim]);
    }
}

impl Backend for ShardedBackend {
    fn model(&self) -> &ModelInfo {
        self.shards[0].model()
    }

    fn buckets(&self) -> BucketGrid {
        self.shards[0].buckets()
    }

    fn set_parallel(&mut self, cfg: ParallelConfig) {
        for s in &mut self.shards {
            s.set_parallel(cfg);
        }
    }

    fn prefill(
        &mut self,
        batch: usize,
        seq: usize,
        tokens: &[i32],
        lengths: &[i32],
    ) -> Result<StepOut> {
        // plane execution is inherently single-device; shard 0 holds
        // the full replicated model
        self.shards[0].prefill(batch, seq, tokens, lengths)
    }

    fn decode(
        &mut self,
        batch: usize,
        tokens: &[i32],
        k_plane: Vec<f32>,
        v_plane: Vec<f32>,
        pos: &[i32],
    ) -> Result<StepOut> {
        self.shards[0].decode(batch, tokens, k_plane, v_plane, pos)
    }

    fn supports_paged(&self) -> bool {
        true
    }

    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn comm_stats(&self) -> AllReduceStats {
        self.comm
    }

    fn decode_paged(
        &mut self,
        rows: &[PagedRow<'_>],
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        if self.shards.len() != 1 {
            bail!("sharded backend needs the per-shard paged entry points");
        }
        self.shards[0].decode_paged(rows, pools)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        start_pos: usize,
        table: &BlockTable,
        pools: &mut TieredPagePool,
    ) -> Result<Vec<f32>> {
        if self.shards.len() != 1 {
            bail!("sharded backend needs the per-shard paged entry points");
        }
        self.shards[0].prefill_chunk(tokens, start_pos, table, pools)
    }

    fn decode_paged_sharded(
        &mut self,
        rows: &[ShardedRow<'_>],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<f32>> {
        let n = self.shards.len();
        if pools.len() != n {
            bail!("decode_paged_sharded: {} pools for {n} shards", pools.len());
        }
        let cache = self.shards[0].cache_shape();
        for (i, r) in rows.iter().enumerate() {
            if r.tables.len() != n {
                bail!("decode_paged_sharded row {i}: {} tables for {n} shards", r.tables.len());
            }
            for (s, t) in r.tables.iter().enumerate() {
                self.check_shard_table(t, &pools[s], "decode_paged_sharded")?;
                if t.capacity_tokens() <= r.pos {
                    bail!(
                        "decode_paged_sharded row {i} shard {s}: table holds {} tokens, \
                         row {} needs capacity first",
                        t.capacity_tokens(),
                        r.pos
                    );
                }
            }
            if r.pos >= cache.max_seq {
                bail!(
                    "decode_paged_sharded row {i}: pos {} out of cache range {}",
                    r.pos,
                    cache.max_seq
                );
            }
        }
        let frows: Vec<(i32, usize)> = rows.iter().map(|r| (r.token, r.pos)).collect();
        let row_tables: Vec<&[BlockTable]> = rows.iter().map(|r| r.tables).collect();
        let overlap = self.scfg.overlap;
        let xs = forward_sharded(
            &self.shards,
            &self.scfg,
            &mut self.comm,
            &frows,
            &row_tables,
            pools,
            overlap,
        );

        let vocab = self.shards[0].model().vocab;
        let mut logits = vec![0.0f32; rows.len() * vocab];
        for (i, x) in xs.iter().enumerate() {
            self.shards[0].logits_row(x, &mut logits[i * vocab..][..vocab]);
        }
        Ok(logits)
    }

    fn prefill_chunk_sharded(
        &mut self,
        tokens: &[i32],
        start_pos: usize,
        tables: &[BlockTable],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<f32>> {
        let n = self.shards.len();
        if tokens.is_empty() {
            bail!("prefill_chunk_sharded: empty chunk");
        }
        if pools.len() != n || tables.len() != n {
            bail!(
                "prefill_chunk_sharded: {} tables / {} pools for {n} shards",
                tables.len(),
                pools.len()
            );
        }
        let cache = self.shards[0].cache_shape();
        let end = start_pos + tokens.len();
        if end > cache.max_seq {
            bail!("prefill_chunk_sharded: positions ..{end} exceed max_seq {}", cache.max_seq);
        }
        for (s, t) in tables.iter().enumerate() {
            self.check_shard_table(t, &pools[s], "prefill_chunk_sharded")?;
            if t.capacity_tokens() < end {
                bail!(
                    "prefill_chunk_sharded shard {s}: table holds {} tokens, chunk ends at {end}",
                    t.capacity_tokens()
                );
            }
        }
        let row_tables = [tables];
        let mut last: Vec<f32> = Vec::new();
        for (t, &tok) in tokens.iter().enumerate() {
            // tokens are strictly sequential — token t+1's attention
            // reads token t's KV at every layer — so each step is one
            // tile and its combine is charged serial (nothing to hide
            // it under)
            debug_assert_eq!(
                crate::attention::mask::chunk_row_visible(start_pos, t),
                start_pos + t + 1,
            );
            let xs = forward_sharded(
                &self.shards,
                &self.scfg,
                &mut self.comm,
                &[(tok, start_pos + t)],
                &row_tables,
                pools,
                false,
            );
            last = xs.into_iter().next().expect("one row per step");
        }
        let mut logits = vec![0.0f32; self.shards[0].model().vocab];
        self.shards[0].logits_row(&last, &mut logits);
        Ok(logits)
    }

    fn prefill_chunks_sharded(
        &mut self,
        chunks: &[ChunkRun<'_>],
        pools: &mut [TieredPagePool],
    ) -> Result<Vec<Vec<f32>>> {
        let n = self.shards.len();
        if chunks.is_empty() {
            return Ok(Vec::new());
        }
        let cache = self.shards[0].cache_shape();
        let mut max_len = 0usize;
        for (i, c) in chunks.iter().enumerate() {
            if c.tokens.is_empty() {
                bail!("prefill_chunks_sharded row {i}: empty chunk");
            }
            if c.tables.len() != n {
                bail!("prefill_chunks_sharded row {i}: {} tables for {n} shards", c.tables.len());
            }
            let end = c.start_pos + c.tokens.len();
            if end > cache.max_seq {
                bail!(
                    "prefill_chunks_sharded row {i}: positions ..{end} exceed max_seq {}",
                    cache.max_seq
                );
            }
            for (s, t) in c.tables.iter().enumerate() {
                self.check_shard_table(t, &pools[s], "prefill_chunks_sharded")?;
                if t.capacity_tokens() < end {
                    bail!(
                        "prefill_chunks_sharded row {i} shard {s}: table holds {} tokens, \
                         chunk ends at {end}",
                        t.capacity_tokens()
                    );
                }
            }
            max_len = max_len.max(c.tokens.len());
        }
        // Positions stay sequential within each chunk, so the combine
        // stays serial (overlap = false), but every still-unfinished
        // chunk contributes a row to the same step — one ring combine
        // amortised over the packed rows.
        let mut finals: Vec<Vec<f32>> = vec![Vec::new(); chunks.len()];
        for t in 0..max_len {
            let live: Vec<usize> =
                (0..chunks.len()).filter(|&ci| t < chunks[ci].tokens.len()).collect();
            let rows: Vec<(i32, usize)> = live
                .iter()
                .map(|&ci| {
                    debug_assert_eq!(
                        crate::attention::mask::chunk_row_visible(chunks[ci].start_pos, t),
                        chunks[ci].start_pos + t + 1,
                    );
                    (chunks[ci].tokens[t], chunks[ci].start_pos + t)
                })
                .collect();
            let row_tables: Vec<&[BlockTable]> =
                live.iter().map(|&ci| chunks[ci].tables).collect();
            let xs = forward_sharded(
                &self.shards,
                &self.scfg,
                &mut self.comm,
                &rows,
                &row_tables,
                pools,
                false,
            );
            for (&ci, x) in live.iter().zip(xs) {
                if t == chunks[ci].tokens.len() - 1 {
                    finals[ci] = x;
                }
            }
        }
        let vocab = self.shards[0].model().vocab;
        let mut out = Vec::with_capacity(chunks.len());
        for x in &finals {
            let mut logits = vec![0.0f32; vocab];
            self.shards[0].logits_row(x, &mut logits);
            out.push(logits);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::kv_cache::{CacheShape, PcieLink};
    use crate::models::ModelShape;

    /// A GQA shape whose 4 KV heads split across 1, 2 or 4 shards.
    fn shard_cfg() -> HostModelConfig {
        HostModelConfig {
            model: ModelShape {
                name: "host-shard-test",
                params: 0,
                layers: 2,
                heads: 8,
                kv_heads: 4,
                head_dim: 4,
                ffn: 32,
                vocab: 32,
            },
            max_seq: 64,
            ..HostModelConfig::tiny_gqa()
        }
    }

    /// Per-shard pools + tables sized for `seqs` sequences of up to
    /// `max_seq` tokens each.
    fn shard_kv(
        be: &ShardedBackend,
        seqs: usize,
    ) -> (Vec<TieredPagePool>, Vec<Vec<BlockTable>>) {
        let n = be.shard_count();
        let cache = be.shards[0].cache_shape();
        let shard_shape = CacheShape { kv_heads: cache.kv_heads / n, ..cache };
        let page_size = 4;
        let cap = seqs * BlockTable::pages_needed(shard_shape, page_size, cache.max_seq);
        let pools: Vec<TieredPagePool> = (0..n)
            .map(|_| TieredPagePool::new(page_size, cache.head_dim, cap, cap, PcieLink::default()))
            .collect();
        let tables: Vec<Vec<BlockTable>> = (0..seqs)
            .map(|_| (0..n).map(|_| BlockTable::new(shard_shape, page_size)).collect())
            .collect();
        (pools, tables)
    }

    /// Drive `steps` greedy decode steps for `prompts` through a
    /// sharded backend, returning every step's logits.
    fn run_sharded(cfg: &HostModelConfig, scfg: ShardedConfig, prompts: &[Vec<i32>], steps: usize) -> (Vec<Vec<f32>>, AllReduceStats) {
        let mut be = ShardedBackend::new(cfg.clone(), scfg).unwrap();
        let n = be.shard_count();
        let (mut pools, mut tables) = shard_kv(&be, prompts.len());
        let mut lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let mut next: Vec<i32> = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            for (t, p2) in tables[i].iter_mut().zip(pools.iter_mut()) {
                t.ensure_capacity(p.len(), p2.device_mut()).unwrap();
            }
            let logits = be
                .prefill_chunk_sharded(p, 0, &tables[i], &mut pools)
                .unwrap();
            next.push(argmax(&logits));
        }
        let mut out = Vec::new();
        for _ in 0..steps {
            for i in 0..prompts.len() {
                for (t, p2) in tables[i].iter_mut().zip(pools.iter_mut()) {
                    t.ensure_capacity(lens[i] + 1, p2.device_mut()).unwrap();
                }
            }
            let rows: Vec<ShardedRow<'_>> = (0..prompts.len())
                .map(|i| ShardedRow { tables: &tables[i], token: next[i], pos: lens[i] })
                .collect();
            let logits = be.decode_paged_sharded(&rows, &mut pools).unwrap();
            let vocab = be.model().vocab;
            for i in 0..prompts.len() {
                next[i] = argmax(&logits[i * vocab..][..vocab]);
                lens[i] += 1;
            }
            out.push(logits);
        }
        assert_eq!(n, be.shard_count());
        (out, be.comm_stats())
    }

    fn argmax(xs: &[f32]) -> i32 {
        let mut best = 0;
        for (i, &v) in xs.iter().enumerate() {
            if v > xs[best] {
                best = i;
            }
        }
        best as i32
    }

    #[test]
    fn rejects_bad_shard_geometry() {
        let cfg = shard_cfg(); // 4 kv heads
        assert!(ShardedBackend::new(cfg.clone(), ShardedConfig::for_shards(3)).is_err());
        assert!(ShardedBackend::new(cfg.clone(), ShardedConfig::for_shards(8)).is_err());
        assert!(ShardedBackend::new(cfg, ShardedConfig::for_shards(4)).is_ok());
    }

    #[test]
    fn sharded_decode_bit_identical_across_shard_counts() {
        let cfg = shard_cfg();
        let prompts: Vec<Vec<i32>> =
            (0..3).map(|i| (0..7 + i).map(|t| (t * 5 + i as i32 + 1) % 32).collect()).collect();
        let (base, stats1) = run_sharded(&cfg, ShardedConfig::for_shards(1), &prompts, 6);
        assert_eq!(stats1, AllReduceStats::default(), "single device models no allreduce");
        for n in [2usize, 4] {
            for overlap in [true, false] {
                let scfg = if overlap {
                    ShardedConfig::for_shards(n)
                } else {
                    ShardedConfig::serial(n)
                };
                let scfg = ShardedConfig { tile_rows: 2, ..scfg };
                let (got, stats) = run_sharded(&cfg, scfg, &prompts, 6);
                assert_eq!(base, got, "{n} shards (overlap={overlap}) diverged from 1 device");
                assert!(stats.modeled_s > 0.0, "{n} shards must charge comm time");
                assert!(stats.bytes > 0 && stats.tiles > 0);
                assert!(
                    stats.serial_makespan_s >= stats.makespan_s - 1e-12,
                    "overlap can only help: serial {} < makespan {}",
                    stats.serial_makespan_s,
                    stats.makespan_s
                );
                if overlap {
                    assert!(stats.hidden_s > 0.0, "multi-tile decode must hide some comm");
                } else {
                    assert_eq!(stats.hidden_s, 0.0, "serial combine hides nothing");
                }
            }
        }
    }

    #[test]
    fn overlap_beats_serial_on_batched_decode() {
        // 8 decode rows × tile_rows 2 → 4 tiles per layer: the tiled
        // schedule must strictly beat the serial one (deterministic
        // model arithmetic, not wall clock).
        let cfg = shard_cfg();
        let prompts: Vec<Vec<i32>> = (0..8).map(|i| vec![1 + i as i32, 2, 3, 4, 5]).collect();
        let scfg = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(2) };
        let (_, stats) = run_sharded(&cfg, scfg, &prompts, 4);
        assert!(
            stats.makespan_s < stats.serial_makespan_s,
            "tiled {} !< serial {}",
            stats.makespan_s,
            stats.serial_makespan_s
        );
        let speedup = stats.serial_makespan_s / stats.makespan_s;
        assert!(speedup > 1.0, "tiling-AllReduce speedup {speedup} must exceed 1.0");
    }

    #[test]
    fn sharded_single_shard_matches_host_backend() {
        // n = 1 through the sharded entry points is the host backend
        let cfg = shard_cfg();
        let mut host = HostModelBackend::new(cfg.clone());
        let mut be = ShardedBackend::new(cfg.clone(), ShardedConfig::for_shards(1)).unwrap();
        let cache = host.cache_shape();
        let page_size = 4;
        let cap = BlockTable::pages_needed(cache, page_size, cache.max_seq);
        let mut hpool =
            TieredPagePool::new(page_size, cache.head_dim, cap, cap, PcieLink::default());
        let mut htab = BlockTable::new(cache, page_size);
        let toks = [3i32, 9, 17, 25, 2];
        htab.ensure_capacity(toks.len() + 1, hpool.device_mut()).unwrap();
        let hl = host.prefill_chunk(&toks, 0, &htab, &mut hpool).unwrap();

        let (mut pools, mut tables) = shard_kv(&be, 1);
        tables[0][0].ensure_capacity(toks.len() + 1, pools[0].device_mut()).unwrap();
        let sl = be.prefill_chunk_sharded(&toks, 0, &tables[0], &mut pools).unwrap();
        assert_eq!(hl, sl);

        let hrow = [PagedRow { table: &htab, token: 7, pos: toks.len() }];
        let hd = host.decode_paged(&hrow, &mut hpool).unwrap();
        let srow = [ShardedRow { tables: &tables[0], token: 7, pos: toks.len() }];
        let sd = be.decode_paged_sharded(&srow, &mut pools).unwrap();
        assert_eq!(hd, sd);
        assert_eq!(be.comm_stats(), AllReduceStats::default());
    }
}
