//! Continuous batcher: groups waiting requests into prefill batches and
//! active sequences into decode batches, under the artifact bucket grid.
//!
//! vLLM-router-style policy, adapted to AOT bucketed shapes: prefill
//! batches group prompts that share the smallest covering (batch, seq)
//! bucket; decode batches take up to `max(decode_batches)` active
//! sequences regardless of their positions (per-row `pos`/`lengths` make
//! ragged batches exact — see `python/compile/model.py`).
//!
//! Admission is typed ([`AdmitError`]): only empty prompts and
//! KV-budget-impossible lengths are rejected outright.  With chunked
//! prefill enabled (`allow_chunked`, the paged engine path) prompts
//! longer than the largest prefill bucket are admissible — the engine
//! splits them into bucket-sized chunks; without it they fit no lowered
//! artifact and are refused with [`AdmitError::NoBucket`].

use std::collections::VecDeque;
use std::fmt;

use super::request::{Request, RequestId};

/// Why a request cannot be admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitError {
    /// Empty prompts carry no work.
    EmptyPrompt,
    /// `prompt + max_new_tokens` can never fit the per-sequence KV
    /// capacity, whatever the scheduler does.
    ImpossibleLength { need: usize, capacity: usize },
    /// The prompt fits no prefill bucket and chunked prefill is off
    /// (the contiguous / artifact path).
    NoBucket { len: usize, max_bucket: usize },
    /// The device page pool is smaller than one block group — nothing
    /// can ever be placed on this engine.
    PoolTooSmall { pages: usize, group: usize },
    /// Worst-case page demand (prompt + full generation budget) exceeds
    /// what both KV tiers together can ever hold.
    ExceedsKvPages { need: usize, usable: usize, tokens: usize },
}

impl fmt::Display for AdmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::EmptyPrompt => write!(f, "empty prompt"),
            Self::ImpossibleLength { need, capacity } => write!(
                f,
                "prompt + max_new_tokens = {need} tokens exceeds KV capacity {capacity}"
            ),
            Self::NoBucket { len, max_bucket } => write!(
                f,
                "prompt of {len} tokens exceeds the largest prefill bucket \
                 {max_bucket} and chunked prefill is unavailable"
            ),
            Self::PoolTooSmall { pages, group } => write!(
                f,
                "device page pool holds {pages} pages but one block group needs {group}"
            ),
            Self::ExceedsKvPages { need, usable, tokens } => write!(
                f,
                "request needs {need} KV pages ({tokens} tokens), tiers hold only \
                 {usable} usable"
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// A planned prefill execution.
#[derive(Debug, Clone)]
pub struct PrefillBatch {
    /// Bucketed batch size (artifact B).
    pub batch_bucket: usize,
    /// Bucketed sequence length (artifact S).
    pub seq_bucket: usize,
    /// The requests filling slots 0..n (n ≤ batch_bucket).
    pub requests: Vec<Request>,
}

/// A planned decode execution.
#[derive(Debug, Clone)]
pub struct DecodeBatch {
    /// Bucketed batch size (artifact B).
    pub batch_bucket: usize,
    /// Sequence ids in slots 0..n.
    pub seq_ids: Vec<RequestId>,
}

/// Batching policy configuration.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub prefill_batches: Vec<usize>,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
    /// Max sequences decoding concurrently (KV budget).
    pub max_active: usize,
    /// Per-sequence KV capacity in tokens (prompt + generated).
    pub max_seq_tokens: usize,
    /// Admit prompts longer than the largest prefill bucket (the engine
    /// runs them as chunked prefill over the paged cache).
    pub allow_chunked: bool,
    /// Token budget for one chunked-prefill step: chunk rows of several
    /// admitting sequences pack into one forward pass until their
    /// summed token count reaches this (the head sequence always gets
    /// its full chunk).  `0` resolves to one `max_chunk` worth — the
    /// compute of a single full chunk, spent on one long prompt or
    /// split across several short ones.
    pub max_batch_prefill_tokens: usize,
    /// Cap on committed tokens (prompt + full generation budget) summed
    /// across every live sequence; admission defers past it.  `0` is
    /// unbounded — the page-capacity gates then bound the batch.
    pub max_batch_total_tokens: usize,
    /// Anti-starvation ratio: once `waiting ≥ ratio × live`, the
    /// waiting queue is considered starved and SLO-protective prefill
    /// deferral is overridden.
    pub waiting_served_ratio: f64,
}

/// The waiting queue + batch formation logic.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    waiting: VecDeque<Request>,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self { cfg, waiting: VecDeque::new() }
    }

    /// Enqueue a request.  Rejects only empty prompts and KV-impossible
    /// lengths — and, when chunked prefill is unavailable, prompts that
    /// fit no prefill bucket.
    pub fn push(&mut self, req: Request) -> Result<(), AdmitError> {
        if req.prompt.is_empty() {
            return Err(AdmitError::EmptyPrompt);
        }
        let need = req.prompt.len() + req.params.max_new_tokens;
        if need > self.cfg.max_seq_tokens {
            return Err(AdmitError::ImpossibleLength {
                need,
                capacity: self.cfg.max_seq_tokens,
            });
        }
        let max_bucket = self.cfg.prefill_seqs.iter().copied().max().unwrap_or(0);
        if !self.cfg.allow_chunked && req.prompt.len() > max_bucket {
            return Err(AdmitError::NoBucket { len: req.prompt.len(), max_bucket });
        }
        self.waiting.push_back(req);
        Ok(())
    }

    /// Put a **recompute**-preempted request back at the head of the
    /// line — it was admitted before everything still waiting, so FCFS
    /// order is preserved and its prompt replays from scratch.
    ///
    /// **Swap**-preempted sequences never re-enter this queue: their
    /// KV is parked on the host tier and the engine resumes them
    /// directly (`Step::Resume`, which outranks new admissions), so
    /// the batcher only ever sees work that actually needs prefill.
    pub fn requeue_front(&mut self, req: Request) {
        self.waiting.push_front(req);
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Drop a waiting request by id (client-initiated cancel before
    /// admission).  True when it was queued and is now gone.
    pub fn remove(&mut self, id: RequestId) -> bool {
        let before = self.waiting.len();
        self.waiting.retain(|r| r.id != id);
        self.waiting.len() != before
    }

    /// The head-of-line request, if any.
    pub fn peek(&self) -> Option<&Request> {
        self.waiting.front()
    }

    /// Pop the head-of-line request for chunked (paged) admission — one
    /// sequence at a time; `None` when the active-capacity budget is
    /// full.  `active_now` counts every live sequence the engine
    /// tracks, including swap-out-suspended ones — suspended sequences
    /// still hold KV and will resume, so they keep their `max_active`
    /// slot.
    pub fn next_request(&mut self, active_now: usize) -> Option<Request> {
        if self.cfg.max_active.saturating_sub(active_now) == 0 {
            return None;
        }
        self.waiting.pop_front()
    }

    /// The per-step prefill-token budget, with `0` resolved to
    /// `max_chunk` (one full chunk of compute per step).
    pub fn prefill_token_budget(&self, max_chunk: usize) -> usize {
        if self.cfg.max_batch_prefill_tokens == 0 {
            max_chunk.max(1)
        } else {
            self.cfg.max_batch_prefill_tokens
        }
    }

    /// True when admitting `need` more committed tokens on top of
    /// `committed` stays inside `max_batch_total_tokens` (`0` =
    /// unbounded).
    pub fn fits_total_budget(&self, committed: usize, need: usize) -> bool {
        self.cfg.max_batch_total_tokens == 0
            || committed + need <= self.cfg.max_batch_total_tokens
    }

    /// True when the waiting queue has outgrown the served set by
    /// `waiting_served_ratio` — SLO-protective admission deferral must
    /// yield to the backlog.
    pub fn starved(&self, live: usize) -> bool {
        self.waiting.len() as f64 >= self.cfg.waiting_served_ratio * live.max(1) as f64
    }

    /// Smallest bucket ≥ want, if any.
    fn bucket(buckets: &[usize], want: usize) -> Option<usize> {
        buckets.iter().copied().filter(|&b| b >= want).min()
    }

    /// Form the next prefill batch: take the head-of-line request, find
    /// its seq bucket, then greedily add more waiting requests that fit
    /// the same bucket (FCFS within the bucket) up to the largest batch
    /// bucket and the active-capacity budget.
    pub fn next_prefill(&mut self, active_now: usize) -> Option<PrefillBatch> {
        let head = self.waiting.front()?;
        let room = self.cfg.max_active.saturating_sub(active_now);
        if room == 0 {
            return None;
        }
        let seq_bucket = Self::bucket(&self.cfg.prefill_seqs, head.prompt.len())?;
        let max_batch = self.cfg.prefill_batches.iter().copied().max()?;
        let take_max = room.min(max_batch);

        // Collect indices of queue entries that fit this seq bucket.
        let mut picked = Vec::new();
        for (i, r) in self.waiting.iter().enumerate() {
            if r.prompt.len() <= seq_bucket {
                picked.push(i);
                if picked.len() == take_max {
                    break;
                }
            }
        }
        let batch_bucket = Self::bucket(&self.cfg.prefill_batches, picked.len())?;

        // Drain picked (back to front to keep indices valid).
        let mut requests = Vec::with_capacity(picked.len());
        for &i in picked.iter().rev() {
            requests.push(self.waiting.remove(i).unwrap());
        }
        requests.reverse();
        Some(PrefillBatch { batch_bucket, seq_bucket, requests })
    }

    /// Form the next decode batch from `active` sequence ids (FCFS order):
    /// up to the largest decode bucket.
    pub fn next_decode(&self, active: &[RequestId]) -> Option<DecodeBatch> {
        if active.is_empty() {
            return None;
        }
        let max_batch = self.cfg.decode_batches.iter().copied().max()?;
        let take = active.len().min(max_batch);
        let batch_bucket = Self::bucket(&self.cfg.decode_batches, take)?;
        Some(DecodeBatch {
            batch_bucket,
            seq_ids: active[..take].to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::GenParams;

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            prefill_batches: vec![1, 4],
            prefill_seqs: vec![32, 64, 128],
            decode_batches: vec![1, 4],
            max_active: 8,
            max_seq_tokens: 256,
            allow_chunked: false,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
        }
    }

    fn req(id: u64, len: usize) -> Request {
        Request::new(id, vec![1; len], GenParams::default())
    }

    #[test]
    fn groups_same_bucket() {
        let mut b = Batcher::new(cfg());
        for (id, len) in [(1, 10), (2, 20), (3, 30), (4, 31)] {
            b.push(req(id, len)).unwrap();
        }
        let batch = b.next_prefill(0).unwrap();
        assert_eq!(batch.seq_bucket, 32);
        assert_eq!(batch.batch_bucket, 4);
        assert_eq!(batch.requests.len(), 4);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn mixed_buckets_split() {
        let mut b = Batcher::new(cfg());
        b.push(req(1, 10)).unwrap();
        b.push(req(2, 100)).unwrap(); // needs 128 bucket
        b.push(req(3, 12)).unwrap();
        let first = b.next_prefill(0).unwrap();
        // head req (len 10) → bucket 32; req 3 joins, req 2 does not.
        assert_eq!(first.seq_bucket, 32);
        assert_eq!(first.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3]);
        let second = b.next_prefill(0).unwrap();
        assert_eq!(second.seq_bucket, 128);
        assert_eq!(second.requests.len(), 1);
    }

    #[test]
    fn single_request_uses_small_batch_bucket() {
        let mut b = Batcher::new(cfg());
        b.push(req(1, 10)).unwrap();
        let batch = b.next_prefill(0).unwrap();
        assert_eq!(batch.batch_bucket, 1);
    }

    #[test]
    fn capacity_limits_prefill() {
        let mut b = Batcher::new(cfg());
        for id in 0..6 {
            b.push(req(id, 8)).unwrap();
        }
        // 7 active of max 8 → room for only 1
        let batch = b.next_prefill(7).unwrap();
        assert_eq!(batch.requests.len(), 1);
        // full → no prefill
        assert!(b.next_prefill(8).is_none());
    }

    #[test]
    fn rejects_oversized_and_empty() {
        let mut b = Batcher::new(cfg());
        assert_eq!(
            b.push(req(1, 500)),
            Err(AdmitError::ImpossibleLength { need: 516, capacity: 256 })
        );
        assert_eq!(b.push(req(2, 0)), Err(AdmitError::EmptyPrompt));
        // fits KV, exceeds every bucket, chunking off → NoBucket
        assert_eq!(
            b.push(req(3, 200)),
            Err(AdmitError::NoBucket { len: 200, max_bucket: 128 })
        );
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn chunked_admits_beyond_largest_bucket() {
        let mut b = Batcher::new(BatcherConfig { allow_chunked: true, ..cfg() });
        // longer than the 128 bucket but within KV capacity
        b.push(req(1, 200)).unwrap();
        assert_eq!(b.waiting(), 1);
        // KV-impossible still refused even with chunking
        assert_eq!(
            b.push(req(2, 250)),
            Err(AdmitError::ImpossibleLength { need: 266, capacity: 256 })
        );
        // long head-of-line prompt fits no bucket → no bucketed prefill
        assert!(b.next_prefill(0).is_none());
        // ...but pops through the chunked admission path
        let r = b.next_request(0).unwrap();
        assert_eq!(r.id, 1);
        assert!(b.next_request(0).is_none());
    }

    #[test]
    fn requeue_front_preserves_fcfs() {
        let mut b = Batcher::new(cfg());
        b.push(req(2, 8)).unwrap();
        b.push(req(3, 8)).unwrap();
        // a preempted earlier request goes back to the head
        b.requeue_front(req(1, 8));
        assert_eq!(b.peek().unwrap().id, 1);
        let batch = b.next_prefill(0).unwrap();
        assert_eq!(batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
    }

    #[test]
    fn requeue_front_restores_fcfs_after_cascaded_preemptions() {
        // the engine preempts youngest-first and requeues each victim at
        // the head: pushing 3 then 2 then 1 must leave 1, 2, 3 — i.e.
        // cascaded preemption reconstructs the original admission order.
        let mut b = Batcher::new(BatcherConfig { allow_chunked: true, ..cfg() });
        b.push(req(4, 8)).unwrap();
        b.requeue_front(req(3, 8));
        b.requeue_front(req(2, 8));
        b.requeue_front(req(1, 8));
        let order: Vec<u64> = std::iter::from_fn(|| b.next_request(0).map(|r| r.id)).collect();
        assert_eq!(order, vec![1, 2, 3, 4]);
    }

    #[test]
    fn requeued_request_survives_rejection_free() {
        // requeue_front bypasses admission (the request was already
        // admitted once) — even one that would now fail a push gate
        let mut b = Batcher::new(cfg());
        // longer than every prefill bucket: push would refuse it…
        assert!(matches!(b.push(req(7, 200)), Err(AdmitError::NoBucket { .. })));
        // …but a preempted one comes back and is visible at the head
        b.requeue_front(req(7, 200));
        assert_eq!(b.waiting(), 1);
        assert_eq!(b.peek().unwrap().id, 7);
    }

    #[test]
    fn admit_errors_display_capacity_details() {
        let mut b = Batcher::new(cfg());
        let e = b.push(req(1, 500)).unwrap_err();
        assert_eq!(e, AdmitError::ImpossibleLength { need: 516, capacity: 256 });
        let msg = e.to_string();
        assert!(msg.contains("516") && msg.contains("256"), "{msg}");

        let e = b.push(req(2, 0)).unwrap_err();
        assert_eq!(e.to_string(), "empty prompt");

        let e = b.push(req(3, 130)).unwrap_err();
        assert_eq!(e, AdmitError::NoBucket { len: 130, max_bucket: 128 });
        let msg = e.to_string();
        assert!(msg.contains("130") && msg.contains("128"), "{msg}");
        // all three rejections left the queue untouched
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn admit_boundaries_are_exact() {
        let mut b = Batcher::new(cfg());
        // exactly the largest bucket: admitted; one more token: NoBucket
        b.push(req(1, 128)).unwrap();
        assert!(matches!(b.push(req(2, 129)), Err(AdmitError::NoBucket { .. })));
        // prompt + max_new_tokens exactly at KV capacity: admitted
        let p156 = GenParams { max_new_tokens: 156, ..GenParams::default() };
        b.push(Request::new(3, vec![1; 100], p156))
            .unwrap();
        assert!(matches!(
            b.push(Request::new(
                4,
                vec![1; 100],
                GenParams { max_new_tokens: 157, eos_token: None, share_prefix: false }
            )),
            Err(AdmitError::ImpossibleLength { need: 257, capacity: 256 })
        ));
        // with chunking on, the bucket gate vanishes but KV gate stays
        let mut c = Batcher::new(BatcherConfig { allow_chunked: true, ..cfg() });
        c.push(req(5, 129)).unwrap();
        assert!(matches!(c.push(req(6, 500)), Err(AdmitError::ImpossibleLength { .. })));
    }

    #[test]
    fn next_request_respects_capacity() {
        let mut b = Batcher::new(BatcherConfig { allow_chunked: true, ..cfg() });
        b.push(req(1, 8)).unwrap();
        assert!(b.next_request(8).is_none(), "no room at max_active");
        assert_eq!(b.next_request(7).unwrap().id, 1);
    }

    #[test]
    fn decode_batches_cap_at_bucket() {
        let b = Batcher::new(cfg());
        let active: Vec<u64> = (0..6).collect();
        let d = b.next_decode(&active).unwrap();
        assert_eq!(d.batch_bucket, 4);
        assert_eq!(d.seq_ids, vec![0, 1, 2, 3]);
        assert!(b.next_decode(&[]).is_none());
    }

    #[test]
    fn decode_single_uses_b1() {
        let b = Batcher::new(cfg());
        let d = b.next_decode(&[42]).unwrap();
        assert_eq!(d.batch_bucket, 1);
    }

    #[test]
    fn prefill_budget_zero_resolves_to_one_chunk() {
        let b = Batcher::new(cfg());
        assert_eq!(b.prefill_token_budget(32), 32);
        let c = Batcher::new(BatcherConfig { max_batch_prefill_tokens: 96, ..cfg() });
        assert_eq!(c.prefill_token_budget(32), 96);
        // degenerate max_chunk still yields a positive budget
        assert_eq!(b.prefill_token_budget(0), 1);
    }

    #[test]
    fn total_budget_zero_is_unbounded() {
        let b = Batcher::new(cfg());
        assert!(b.fits_total_budget(usize::MAX - 1, 1));
        let c = Batcher::new(BatcherConfig { max_batch_total_tokens: 100, ..cfg() });
        assert!(c.fits_total_budget(60, 40));
        assert!(!c.fits_total_budget(60, 41));
    }

    #[test]
    fn starvation_ratio_compares_waiting_to_live() {
        let mut b = Batcher::new(BatcherConfig {
            allow_chunked: true,
            waiting_served_ratio: 1.5,
            ..cfg()
        });
        for id in 0..3 {
            b.push(req(id, 8)).unwrap();
        }
        // 3 waiting vs 2 live: 3 ≥ 1.5·2 → starved; vs 3 live: not
        assert!(b.starved(2));
        assert!(!b.starved(3));
        // live = 0 clamps to 1 so an empty engine with a backlog counts
        assert!(b.starved(0));
    }

    #[test]
    fn page_gate_errors_display_pool_details() {
        let e = AdmitError::PoolTooSmall { pages: 2, group: 4 };
        let msg = e.to_string();
        assert!(msg.contains('2') && msg.contains('4'), "{msg}");
        let e = AdmitError::ExceedsKvPages { need: 12, usable: 8, tokens: 48 };
        let msg = e.to_string();
        assert!(msg.contains("12") && msg.contains('8') && msg.contains("48"), "{msg}");
    }
}
