//! KV reclamation policy: victim selection and the recompute-vs-swap
//! cost model behind the engine's four-rung reclamation ladder.
//!
//! The engine owns the *mechanism* of reclaiming KV pages (evicting
//! idle prefix runs, migrating cold blocks, swapping a victim's table
//! to the host tier, recompute-preempting); this module owns the
//! *policy*: which live sequence pays when the device tier is
//! exhausted, and whether its pages are parked on the host tier
//! (save/restore over the modeled PCIe link) or dropped and recomputed
//! (prompt replay).  Keeping the policy pluggable behind
//! [`ReclaimPolicy`] is what lets `EngineConfig` trade FCFS purity
//! (evict-youngest) against pages lost or time-to-completion without
//! touching the engine's state machine.
//!
//! The ladder the engine executes, cheapest rung first:
//!
//! 1. **evict** an idle prefix-cache run — loses nothing computed;
//! 2. **migrate** cold blocks to the host tier — preserves computed KV
//!    on the slower store (batched across sequences to amortize the
//!    link setup latency);
//! 3. **swap out** the victim — its whole block table parks on the
//!    host tier and restores on resume, at 2× the PCIe cost of its
//!    device pages;
//! 4. **recompute** the victim — pages freed outright, its request
//!    replays from the head of the queue, at the prompt-replay cost
//!    modeled by [`crate::coordinator::offload::replay_cost_s`].
//!
//! Rungs 3 and 4 are the [`RecomputeVsSwap`] decision, taken per
//! victim: swap wins exactly when moving the victim's device pages
//! over the link (out and back) is cheaper than replaying its cached
//! tokens — vLLM's swap policy, FlashInfer's block-table save/restore.
//! Whichever wins, tokens are bit-identical: swap relocates rows, and
//! greedy replay regenerates them (pinned by the reclamation property
//! tests).

#![warn(missing_docs)]

use super::kv_cache::PcieLink;
use super::offload::replay_token_cost_s;
use super::request::RequestId;

/// What the engine knows about one preemption candidate when the
/// device tier is exhausted.  The engine never offers the oldest live
/// sequence (unless it is alone) — that exclusion, not the policy, is
/// what preserves the no-livelock admission induction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VictimCandidate {
    /// The candidate's request id (monotonic: larger = younger).
    pub id: RequestId,
    /// Pages the candidate holds across both tiers — what preempting
    /// it frees.
    pub pages_held: usize,
    /// Device-resident pages — what a swap-out must move.
    pub device_pages: usize,
    /// Tokens whose KV is cached (prefilled prompt + generated) — what
    /// a recompute must replay.
    pub tokens_cached: usize,
    /// Tokens still to produce (remaining prompt prefill + remaining
    /// generation budget) — distance from completion.
    pub tokens_remaining: usize,
    /// Whether every device page is solely owned (ref count 1): shared
    /// pages pin their holder to the device tier, so the candidate
    /// cannot be swapped, only recomputed.
    pub swappable: bool,
}

/// A pluggable victim-selection policy over preemption candidates.
pub trait ReclaimPolicy {
    /// The policy's display name (metrics / bench labels).
    fn name(&self) -> &'static str;

    /// Pick the victim.  `candidates` is never empty; the choice must
    /// be deterministic (ties broken on `id`).
    fn select<'a>(&self, candidates: &'a [VictimCandidate]) -> &'a VictimCandidate;
}

/// FCFS-compatible evict-youngest: the most recently admitted sequence
/// pays, so requeueing it at the head of the line reconstructs the
/// original admission order exactly.
pub struct YoungestVictim;

impl ReclaimPolicy for YoungestVictim {
    fn name(&self) -> &'static str {
        "youngest"
    }

    fn select<'a>(&self, candidates: &'a [VictimCandidate]) -> &'a VictimCandidate {
        candidates
            .iter()
            .max_by_key(|c| c.id)
            .expect("candidates never empty")
    }
}

/// Minimize work thrown away: the candidate holding the fewest pages
/// loses (ties: youngest).  Best when sequences differ wildly in
/// length — preempting a 2-block sequence costs far less than a
/// 20-block one, whichever was admitted first.
pub struct FewestPagesLost;

impl ReclaimPolicy for FewestPagesLost {
    fn name(&self) -> &'static str {
        "fewest-pages-lost"
    }

    fn select<'a>(&self, candidates: &'a [VictimCandidate]) -> &'a VictimCandidate {
        candidates
            .iter()
            .min_by_key(|c| (c.pages_held, std::cmp::Reverse(c.id)))
            .expect("candidates never empty")
    }
}

/// Minimize latency damage: the candidate closest to completion pays
/// (ties: youngest) — it will re-enter and finish soonest, so the tail
/// latency of the whole batch moves least.
pub struct ClosestToDone;

impl ReclaimPolicy for ClosestToDone {
    fn name(&self) -> &'static str {
        "closest-to-done"
    }

    fn select<'a>(&self, candidates: &'a [VictimCandidate]) -> &'a VictimCandidate {
        candidates
            .iter()
            .min_by_key(|c| (c.tokens_remaining, std::cmp::Reverse(c.id)))
            .expect("candidates never empty")
    }
}

/// Config-level victim-policy selector (`EngineConfig::victim_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VictimPolicy {
    /// Evict-youngest (FCFS-compatible; the default).
    Youngest,
    /// Fewest pages lost.
    FewestPagesLost,
    /// Closest to completion.
    ClosestToDone,
}

impl VictimPolicy {
    /// Instantiate the policy object.
    pub fn policy(self) -> Box<dyn ReclaimPolicy> {
        match self {
            Self::Youngest => Box::new(YoungestVictim),
            Self::FewestPagesLost => Box::new(FewestPagesLost),
            Self::ClosestToDone => Box::new(ClosestToDone),
        }
    }
}

/// How a chosen victim's pages are reclaimed
/// (`EngineConfig::preempt_mode`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptMode {
    /// Per-victim [`RecomputeVsSwap`] cost decision (the default).
    Auto,
    /// Always swap out when feasible (fall back to recompute when the
    /// victim is unswappable or the host tier cannot hold it).
    Swap,
    /// Always recompute (the pre-swap behavior; also what a
    /// `host_kv_budget: 0` engine degenerates to).
    Recompute,
}

/// The reclamation chosen for one victim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReclaimDecision {
    /// Park the victim's block table on the host tier; restore on
    /// resume.
    Swap,
    /// Free the victim's pages; replay its request from the queue head.
    Recompute,
}

/// The recompute-vs-swap cost model: modeled seconds to swap a
/// victim's device pages out and back over the PCIe link, against
/// modeled seconds to replay its cached tokens (the §4.4 cost bridge —
/// see [`crate::coordinator::offload::replay_cost_s`]).
///
/// On a tensor-parallel engine the accounting is **per primary
/// shard**: the engine hands this model per-shard `page_bytes` and
/// `heads / n_shards`, and feeds it per-shard candidate page counts.
/// Every shard swaps (or replays) in lockstep over its own link, so
/// both sides of the comparison scale by the shard count and the
/// decision is shard-invariant — one shard's ratio decides for all.
#[derive(Debug)]
pub struct RecomputeVsSwap {
    link: PcieLink,
    page_bytes: usize,
    /// Replay geometry: (layers, heads, head_dim, typical KV length).
    replay_geometry: (usize, usize, usize, usize),
    /// Lazily measured per-token replay cost — deferred so engines
    /// that never preempt never pay the measurement.
    replay_token_s: Option<f64>,
}

impl RecomputeVsSwap {
    /// A cost model over `link` for pages of `page_bytes`, replaying on
    /// a model of the given geometry.
    pub fn new(
        link: PcieLink,
        page_bytes: usize,
        layers: usize,
        heads: usize,
        head_dim: usize,
        typical_kv: usize,
    ) -> Self {
        Self {
            link,
            page_bytes,
            replay_geometry: (layers, heads, head_dim, typical_kv.max(1)),
            replay_token_s: None,
        }
    }

    /// A cost model with a fixed per-token replay cost (tests and
    /// simulations — no measurement).
    pub fn with_replay_token_s(link: PcieLink, page_bytes: usize, replay_token_s: f64) -> Self {
        Self {
            link,
            page_bytes,
            replay_geometry: (1, 1, 1, 1),
            replay_token_s: Some(replay_token_s),
        }
    }

    /// Modeled seconds to swap `device_pages` out now and back on
    /// resume (two batched transfers).
    pub fn swap_cost_s(&self, device_pages: usize) -> f64 {
        2.0 * self.link.transfer_s(device_pages * self.page_bytes)
    }

    /// Modeled seconds to replay `tokens` cached tokens.
    pub fn recompute_cost_s(&mut self, tokens: usize) -> f64 {
        tokens as f64 * self.replay_token_s()
    }

    fn replay_token_s(&mut self) -> f64 {
        *self.replay_token_s.get_or_insert_with(|| {
            let (layers, heads, head_dim, kv) = self.replay_geometry;
            replay_token_cost_s(layers, heads, head_dim, kv)
        })
    }
}

/// The engine's reclamation policy bundle: victim selection + the
/// per-victim recompute-vs-swap decision.
pub struct Reclaimer {
    policy: Box<dyn ReclaimPolicy>,
    mode: PreemptMode,
    cost: RecomputeVsSwap,
}

impl Reclaimer {
    /// Bundle a victim policy, a preemption mode and a cost model.
    pub fn new(policy: VictimPolicy, mode: PreemptMode, cost: RecomputeVsSwap) -> Self {
        Self { policy: policy.policy(), mode, cost }
    }

    /// The active victim policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Pick the victim among `candidates` (never empty).
    pub fn select<'a>(&self, candidates: &'a [VictimCandidate]) -> &'a VictimCandidate {
        self.policy.select(candidates)
    }

    /// Decide how the chosen victim's pages are reclaimed.  Swap is
    /// feasible only when the victim is swappable (no shared pages),
    /// actually holds device pages, and the host tier can take them —
    /// the same gating migrations obey, so swap reservations can never
    /// strand the ladder.
    pub fn decide(&mut self, victim: &VictimCandidate, host_free_pages: usize) -> ReclaimDecision {
        let feasible = victim.swappable
            && victim.device_pages > 0
            && host_free_pages >= victim.device_pages;
        match self.mode {
            PreemptMode::Recompute => ReclaimDecision::Recompute,
            PreemptMode::Swap if feasible => ReclaimDecision::Swap,
            PreemptMode::Swap => ReclaimDecision::Recompute,
            PreemptMode::Auto => {
                if feasible
                    && self.cost.swap_cost_s(victim.device_pages)
                        < self.cost.recompute_cost_s(victim.tokens_cached)
                {
                    ReclaimDecision::Swap
                } else {
                    ReclaimDecision::Recompute
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(
        id: RequestId,
        pages_held: usize,
        device_pages: usize,
        tokens_cached: usize,
        tokens_remaining: usize,
        swappable: bool,
    ) -> VictimCandidate {
        VictimCandidate { id, pages_held, device_pages, tokens_cached, tokens_remaining, swappable }
    }

    #[test]
    fn policies_rank_candidates_as_documented() {
        let cands = vec![
            cand(2, 12, 12, 40, 30, true), // oldest offered, biggest, far from done
            cand(3, 4, 4, 10, 2, true),    // smallest, nearly done
            cand(5, 8, 8, 20, 10, true),   // youngest
        ];
        assert_eq!(YoungestVictim.select(&cands).id, 5);
        assert_eq!(FewestPagesLost.select(&cands).id, 3);
        assert_eq!(ClosestToDone.select(&cands).id, 3);

        // ties break toward the youngest for the scored policies
        let tied = vec![cand(2, 4, 4, 10, 5, true), cand(7, 4, 4, 12, 5, true)];
        assert_eq!(FewestPagesLost.select(&tied).id, 7);
        assert_eq!(ClosestToDone.select(&tied).id, 7);

        // config enum wires the same objects
        assert_eq!(VictimPolicy::Youngest.policy().select(&cands).id, 5);
        assert_eq!(VictimPolicy::FewestPagesLost.policy().select(&cands).id, 3);
        assert_eq!(VictimPolicy::ClosestToDone.policy().select(&cands).id, 3);
    }

    #[test]
    fn auto_mode_swaps_exactly_when_link_beats_replay() {
        // 1 KiB pages over a 1 GB/s, 10 µs link; replay 1 ms per token:
        // swapping 4 pages costs 2·(10 µs + 4 KiB/1e9) ≈ 28 µs — far
        // cheaper than replaying 20 tokens (20 ms).
        let link = PcieLink::new(1e9, 10e-6);
        let mut r = Reclaimer::new(
            VictimPolicy::Youngest,
            PreemptMode::Auto,
            RecomputeVsSwap::with_replay_token_s(link, 1024, 1e-3),
        );
        let long = cand(4, 4, 4, 20, 10, true);
        assert_eq!(r.decide(&long, 100), ReclaimDecision::Swap);

        // a 1-token cache (1 ms replay) against a slow link where the
        // same 4 pages cost 2·(10 ms + …) > 20 ms: recompute wins
        let slow = PcieLink::new(1e3, 10e-3);
        let mut r = Reclaimer::new(
            VictimPolicy::Youngest,
            PreemptMode::Auto,
            RecomputeVsSwap::with_replay_token_s(slow, 1024, 1e-3),
        );
        let short = cand(4, 4, 4, 1, 10, true);
        assert_eq!(r.decide(&short, 100), ReclaimDecision::Recompute);
    }

    #[test]
    fn swap_gated_like_migrations() {
        let link = PcieLink::new(1e9, 10e-6);
        let mk = |mode| {
            Reclaimer::new(
                VictimPolicy::Youngest,
                mode,
                RecomputeVsSwap::with_replay_token_s(link, 1024, 1.0),
            )
        };
        // unswappable (shared pages) → recompute even in Swap mode
        let pinned = cand(4, 4, 4, 20, 10, false);
        assert_eq!(mk(PreemptMode::Swap).decide(&pinned, 100), ReclaimDecision::Recompute);
        // host tier too small for the victim's device pages → recompute
        let big = cand(4, 8, 8, 20, 10, true);
        assert_eq!(mk(PreemptMode::Swap).decide(&big, 7), ReclaimDecision::Recompute);
        assert_eq!(mk(PreemptMode::Swap).decide(&big, 8), ReclaimDecision::Swap);
        // nothing device-resident → swapping frees nothing → recompute
        let hostbound = cand(4, 8, 0, 20, 10, true);
        assert_eq!(mk(PreemptMode::Swap).decide(&hostbound, 100), ReclaimDecision::Recompute);
        // forced recompute ignores feasibility
        assert_eq!(mk(PreemptMode::Recompute).decide(&big, 100), ReclaimDecision::Recompute);
    }

    #[test]
    fn swap_cost_scales_with_pages_and_amortizes_latency() {
        let link = PcieLink::new(1e9, 10e-6);
        let c = RecomputeVsSwap::with_replay_token_s(link, 1024, 1e-3);
        let one = c.swap_cost_s(1);
        let eight = c.swap_cost_s(8);
        assert!(eight > one);
        // one batched 8-page round trip beats eight 1-page round trips
        assert!(eight < 8.0 * one);
        // out + back: exactly two transfers
        assert!((one - 2.0 * link.transfer_s(1024)).abs() < 1e-15);
    }

    #[test]
    fn measured_replay_cost_is_lazy_and_cached() {
        let link = PcieLink::default();
        let mut c = RecomputeVsSwap::new(link, 1024, 2, 4, 8, 32);
        let a = c.recompute_cost_s(10);
        let b = c.recompute_cost_s(10);
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), b.to_bits(), "per-token cost measured once");
        assert!(c.recompute_cost_s(20) > a);
    }
}
