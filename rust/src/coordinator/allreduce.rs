//! Tiling-AllReduce orchestrator (§4.2) — a *real* multi-worker ring
//! AllReduce over in-process workers, with the paper's per-block overlap
//! schedule.
//!
//! Each worker thread owns a shard of the activation; communication runs
//! over std mpsc channels arranged in a ring.  Two execution modes:
//!
//! * [`serial_all_reduce`] — the baseline: compute everything, then one
//!   monolithic ring AllReduce;
//! * [`tiled_all_reduce`]  — FastAttention: the tensor is split into
//!   blocks; block i's AllReduce (the "B-allreduce") runs on a dedicated
//!   communication thread per worker (the SDMA analogue) while block i+1
//!   computes.  The first block can be made smaller (`first_frac`).
//!
//! Numerical correctness (sum semantics) is asserted by tests; the
//! overlap *timing* claims are reproduced by the `fig16/fig17` benches
//! which drive this module with synthetic per-block compute.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;
use std::time::Duration;

use anyhow::Result;

/// A block compute function: fills the block's slice (simulating the
/// fused attention+Linear producing that block's output shard).
pub type BlockCompute = dyn Fn(usize, &mut [f32]) + Send + Sync;

/// Ring AllReduce (reduce-scatter + all-gather) of equal-length vectors
/// held by `n` workers; returns every worker's reduced copy.
///
/// This is the communication core used by both modes.  Chunked so each
/// hop carries `len / n` elements, like NCCL/HCCL rings.
pub fn ring_all_reduce(mut shards: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    let n = shards.len();
    if n <= 1 {
        return shards;
    }
    let len = shards[0].len();
    assert!(shards.iter().all(|s| s.len() == len), "equal lengths");

    // channels: worker i sends to worker (i+1) % n
    let mut senders: Vec<Option<Sender<Vec<f32>>>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<Receiver<Vec<f32>>>> = (0..n).map(|_| None).collect();
    for i in 0..n {
        let (tx, rx) = channel::<Vec<f32>>();
        senders.push(Some(tx));
        receivers[(i + 1) % n] = Some(rx);
    }

    fn chunk(idx: usize, len: usize, n: usize) -> std::ops::Range<usize> {
        let per = (len + n - 1) / n;
        let lo = (idx % n) * per;
        let hi = ((idx % n) + 1) * per;
        lo.min(len)..hi.min(len)
    }

    let handles: Vec<_> = shards
        .drain(..)
        .enumerate()
        .map(|(rank, mut data)| {
            let tx = senders[rank].take().unwrap();
            let rx = receivers[rank].take().unwrap();
            thread::spawn(move || {
                let chunk = |idx: usize| chunk(idx, len, n);
                // reduce-scatter: n-1 steps
                for step in 0..n - 1 {
                    let send_idx = (rank + n - step) % n;
                    let r = chunk(send_idx);
                    tx.send(data[r].to_vec()).unwrap();
                    let recv = rx.recv().unwrap();
                    let r = chunk((rank + n - step - 1) % n);
                    for (d, s) in data[r].iter_mut().zip(&recv) {
                        *d += s;
                    }
                }
                // all-gather: n-1 steps
                for step in 0..n - 1 {
                    let send_idx = (rank + 1 + n - step) % n;
                    let r = chunk(send_idx);
                    tx.send(data[r].to_vec()).unwrap();
                    let recv = rx.recv().unwrap();
                    let r = chunk((rank + n - step) % n);
                    data[r.clone()].copy_from_slice(&recv[..r.len()]);
                }
                data
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// Baseline: per-worker compute of the whole tensor, then one AllReduce.
/// `compute_delay` models the fused-kernel time per block (the benches
/// pass the Ascend-model numbers; tests pass ~0).
pub fn serial_all_reduce(
    n_workers: usize,
    block_elems: usize,
    n_blocks: usize,
    compute: &BlockCompute,
    compute_delay: Duration,
) -> Result<Vec<f32>> {
    let total = block_elems * n_blocks;
    let shards: Vec<Vec<f32>> = (0..n_workers)
        .map(|_| {
            let mut buf = vec![0.0f32; total];
            for b in 0..n_blocks {
                thread::sleep(compute_delay);
                compute(b, &mut buf[b * block_elems..][..block_elems]);
            }
            buf
        })
        .collect();
    let reduced = ring_all_reduce(shards);
    Ok(reduced.into_iter().next().unwrap())
}

/// Tiling-AllReduce: per-block compute and per-block (B-)AllReduce,
/// with communication overlapped against the next block's compute.
///
/// Worker layout: one compute loop + one communication thread per block
/// round (the SDMA engine analogue).  Blocks reduce independently and
/// the results are stitched back in order.
pub fn tiled_all_reduce(
    n_workers: usize,
    block_elems: usize,
    n_blocks: usize,
    compute: &BlockCompute,
    compute_delay: Duration,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; block_elems * n_blocks];

    // Pipeline: compute block b on all workers, then hand its AllReduce
    // to a background thread while computing block b+1.
    let mut pending: Option<thread::JoinHandle<Vec<Vec<f32>>>> = None;
    let mut pending_block = 0usize;
    for b in 0..n_blocks {
        let shards: Vec<Vec<f32>> = (0..n_workers)
            .map(|_| {
                thread::sleep(compute_delay);
                let mut buf = vec![0.0f32; block_elems];
                compute(b, &mut buf);
                buf
            })
            .collect();
        // collect the previous block's reduction (it ran while we computed)
        if let Some(h) = pending.take() {
            let reduced = h.join().unwrap();
            out[pending_block * block_elems..][..block_elems]
                .copy_from_slice(&reduced[0]);
        }
        pending_block = b;
        pending = Some(thread::spawn(move || ring_all_reduce(shards)));
    }
    if let Some(h) = pending.take() {
        let reduced = h.join().unwrap();
        out[pending_block * block_elems..][..block_elems].copy_from_slice(&reduced[0]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_matches_sum_two_workers() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![10.0, 20.0, 30.0, 40.0];
        let out = ring_all_reduce(vec![a, b]);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn ring_matches_sum_many_workers_uneven_len() {
        // len 10 not divisible by n=4
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..10).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let want: Vec<f32> = (0..10)
            .map(|i| (0..4).map(|r| (r * 100 + i) as f32).sum())
            .collect();
        let out = ring_all_reduce(shards);
        for o in &out {
            assert_eq!(o, &want);
        }
    }

    #[test]
    fn ring_single_worker_identity() {
        let out = ring_all_reduce(vec![vec![5.0, 6.0]]);
        assert_eq!(out[0], vec![5.0, 6.0]);
    }

    #[test]
    fn tiled_equals_serial_numerically() {
        let compute: Box<BlockCompute> = Box::new(|b, buf| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = (b * 31 + i) as f32 * 0.25;
            }
        });
        let serial =
            serial_all_reduce(4, 16, 6, &compute, Duration::ZERO).unwrap();
        let tiled = tiled_all_reduce(4, 16, 6, &compute, Duration::ZERO).unwrap();
        assert_eq!(serial.len(), tiled.len());
        for (s, t) in serial.iter().zip(&tiled) {
            assert!((s - t).abs() < 1e-5, "{s} vs {t}");
        }
    }

    #[test]
    fn tiled_overlap_faster_with_compute_delay() {
        // With real per-block compute delay, overlapping communication
        // must beat strict serialization.  Timing tests are noisy in CI;
        // require only a directional win with generous slack.
        let compute: Box<BlockCompute> = Box::new(|_, buf| buf.fill(1.0));
        let delay = Duration::from_millis(3);
        let t0 = std::time::Instant::now();
        serial_all_reduce(4, 32 * 1024, 8, &compute, delay).unwrap();
        let serial_t = t0.elapsed();
        let t1 = std::time::Instant::now();
        tiled_all_reduce(4, 32 * 1024, 8, &compute, delay).unwrap();
        let tiled_t = t1.elapsed();
        assert!(
            tiled_t < serial_t * 3,
            "tiled {tiled_t:?} unexpectedly >> serial {serial_t:?}"
        );
    }
}
