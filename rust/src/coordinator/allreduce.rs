//! Tiling-AllReduce orchestrator (§4.2) — a *real* multi-worker ring
//! AllReduce over in-process workers, with the paper's per-block overlap
//! schedule.
//!
//! Each worker thread owns a shard of the activation; communication runs
//! over std mpsc channels arranged in a ring.  Two execution modes:
//!
//! * [`serial_all_reduce`] — the baseline: compute everything, then one
//!   monolithic ring AllReduce;
//! * [`tiled_all_reduce`]  — FastAttention: the tensor is split into
//!   blocks; block i's AllReduce (the "B-allreduce") runs on a dedicated
//!   communication thread per worker (the SDMA analogue) while block i+1
//!   computes.  The first block can be made smaller (`first_frac`).
//!
//! Numerical correctness (sum semantics) is asserted by tests; the
//! overlap *timing* claims are reproduced by the `fig16/fig17` benches
//! which drive this module with synthetic per-block compute.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;
use std::time::Duration;

use anyhow::Result;

/// A block compute function: fills the block's slice (simulating the
/// fused attention+Linear producing that block's output shard).
pub type BlockCompute = dyn Fn(usize, &mut [f32]) + Send + Sync;

/// Ring AllReduce (reduce-scatter + all-gather) of equal-length vectors
/// held by `n` workers; returns every worker's reduced copy.
///
/// This is the communication core used by both modes.  Chunked so each
/// hop carries `len / n` elements, like NCCL/HCCL rings.
pub fn ring_all_reduce(mut shards: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
    let n = shards.len();
    if n <= 1 {
        return shards;
    }
    let len = shards[0].len();
    assert!(shards.iter().all(|s| s.len() == len), "equal lengths");

    // channels: worker i sends to worker (i+1) % n
    let mut senders: Vec<Option<Sender<Vec<f32>>>> = Vec::with_capacity(n);
    let mut receivers: Vec<Option<Receiver<Vec<f32>>>> = (0..n).map(|_| None).collect();
    for i in 0..n {
        let (tx, rx) = channel::<Vec<f32>>();
        senders.push(Some(tx));
        receivers[(i + 1) % n] = Some(rx);
    }

    fn chunk(idx: usize, len: usize, n: usize) -> std::ops::Range<usize> {
        let per = (len + n - 1) / n;
        let lo = (idx % n) * per;
        let hi = ((idx % n) + 1) * per;
        lo.min(len)..hi.min(len)
    }

    let handles: Vec<_> = shards
        .drain(..)
        .enumerate()
        .map(|(rank, mut data)| {
            let tx = senders[rank].take().unwrap();
            let rx = receivers[rank].take().unwrap();
            thread::spawn(move || {
                let chunk = |idx: usize| chunk(idx, len, n);
                // reduce-scatter: n-1 steps
                for step in 0..n - 1 {
                    let send_idx = (rank + n - step) % n;
                    let r = chunk(send_idx);
                    tx.send(data[r].to_vec()).unwrap();
                    let recv = rx.recv().unwrap();
                    let r = chunk((rank + n - step - 1) % n);
                    for (d, s) in data[r].iter_mut().zip(&recv) {
                        *d += s;
                    }
                }
                // all-gather: n-1 steps
                for step in 0..n - 1 {
                    let send_idx = (rank + 1 + n - step) % n;
                    let r = chunk(send_idx);
                    tx.send(data[r].to_vec()).unwrap();
                    let recv = rx.recv().unwrap();
                    let r = chunk((rank + n - step) % n);
                    data[r.clone()].copy_from_slice(&recv[..r.len()]);
                }
                data
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// True when every rank of a ring AllReduce holds a bit-identical copy
/// of the reduced vector.  The ring reduces each chunk in the same hop
/// order on every rank, so agreement is exact — not merely within an
/// epsilon — and both execution modes assert it before discarding all
/// ranks but rank 0.
pub fn ranks_bit_identical(ranks: &[Vec<f32>]) -> bool {
    ranks.windows(2).all(|w| {
        w[0].len() == w[1].len()
            && w[0].iter().zip(&w[1]).all(|(a, b)| a.to_bits() == b.to_bits())
    })
}

/// Run one block's compute on all `n_workers` concurrently — scoped
/// threads so the workers genuinely model N devices computing at the
/// same wall-clock time (a sequential loop would charge the caller
/// `n_workers ×` the per-device time).
fn compute_block_on_workers(
    n_workers: usize,
    block_elems: usize,
    b: usize,
    compute: &BlockCompute,
    compute_delay: Duration,
) -> Vec<Vec<f32>> {
    thread::scope(|s| {
        let handles: Vec<_> = (0..n_workers)
            .map(|_| {
                s.spawn(move || {
                    thread::sleep(compute_delay);
                    let mut buf = vec![0.0f32; block_elems];
                    compute(b, &mut buf);
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Baseline: per-worker compute of the whole tensor, then one AllReduce.
/// `compute_delay` models the fused-kernel time per block (the benches
/// pass the Ascend-model numbers; tests pass ~0).  Workers compute on
/// concurrent threads — N devices run at the same wall-clock time.
pub fn serial_all_reduce(
    n_workers: usize,
    block_elems: usize,
    n_blocks: usize,
    compute: &BlockCompute,
    compute_delay: Duration,
) -> Result<Vec<f32>> {
    let total = block_elems * n_blocks;
    let shards: Vec<Vec<f32>> = thread::scope(|s| {
        let handles: Vec<_> = (0..n_workers)
            .map(|_| {
                s.spawn(move || {
                    let mut buf = vec![0.0f32; total];
                    for b in 0..n_blocks {
                        thread::sleep(compute_delay);
                        compute(b, &mut buf[b * block_elems..][..block_elems]);
                    }
                    buf
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let reduced = ring_all_reduce(shards);
    assert!(ranks_bit_identical(&reduced), "AllReduce ranks disagree");
    Ok(reduced.into_iter().next().unwrap())
}

/// Tiling-AllReduce: per-block compute and per-block (B-)AllReduce,
/// with communication overlapped against the next block's compute.
///
/// Worker layout: per-block concurrent compute threads (one per
/// worker) + one communication thread per block round (the SDMA engine
/// analogue).  Blocks reduce independently and the results are
/// stitched back in order.
pub fn tiled_all_reduce(
    n_workers: usize,
    block_elems: usize,
    n_blocks: usize,
    compute: &BlockCompute,
    compute_delay: Duration,
) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; block_elems * n_blocks];

    // Pipeline: compute block b on all workers, then hand its AllReduce
    // to a background thread while computing block b+1.
    let mut pending: Option<thread::JoinHandle<Vec<Vec<f32>>>> = None;
    let mut pending_block = 0usize;
    let mut stitch = |h: thread::JoinHandle<Vec<Vec<f32>>>, block: usize, out: &mut [f32]| {
        let reduced = h.join().unwrap();
        assert!(ranks_bit_identical(&reduced), "B-allreduce ranks disagree");
        out[block * block_elems..][..block_elems].copy_from_slice(&reduced[0]);
    };
    for b in 0..n_blocks {
        let shards =
            compute_block_on_workers(n_workers, block_elems, b, compute, compute_delay);
        // collect the previous block's reduction (it ran while we computed)
        if let Some(h) = pending.take() {
            stitch(h, pending_block, &mut out);
        }
        pending_block = b;
        pending = Some(thread::spawn(move || ring_all_reduce(shards)));
    }
    if let Some(h) = pending.take() {
        stitch(h, pending_block, &mut out);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_matches_sum_two_workers() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![10.0, 20.0, 30.0, 40.0];
        let out = ring_all_reduce(vec![a, b]);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
        assert_eq!(out[0], out[1]);
    }

    #[test]
    fn ring_matches_sum_many_workers_uneven_len() {
        // len 10 not divisible by n=4
        let shards: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..10).map(|i| (r * 100 + i) as f32).collect())
            .collect();
        let want: Vec<f32> = (0..10)
            .map(|i| (0..4).map(|r| (r * 100 + i) as f32).sum())
            .collect();
        let out = ring_all_reduce(shards);
        for o in &out {
            assert_eq!(o, &want);
        }
    }

    #[test]
    fn ring_single_worker_identity() {
        let out = ring_all_reduce(vec![vec![5.0, 6.0]]);
        assert_eq!(out[0], vec![5.0, 6.0]);
    }

    #[test]
    fn tiled_equals_serial_numerically() {
        let compute: Box<BlockCompute> = Box::new(|b, buf| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = (b * 31 + i) as f32 * 0.25;
            }
        });
        let serial =
            serial_all_reduce(4, 16, 6, &compute, Duration::ZERO).unwrap();
        let tiled = tiled_all_reduce(4, 16, 6, &compute, Duration::ZERO).unwrap();
        assert_eq!(serial.len(), tiled.len());
        for (s, t) in serial.iter().zip(&tiled) {
            assert!((s - t).abs() < 1e-5, "{s} vs {t}");
        }
    }

    #[test]
    fn tiled_overlap_faster_with_compute_delay() {
        // With real per-block compute delay, overlapping communication
        // must beat strict serialization: both modes pay the same
        // compute wall (workers run concurrently), so the serial mode's
        // exposed monolithic AllReduce vs the tiled mode's single tail
        // B-allreduce is a directional win, not a noise band.  Retry a
        // few times before failing — CI schedulers can stall a thread.
        let compute: Box<BlockCompute> = Box::new(|_, buf| buf.fill(1.0));
        let delay = Duration::from_millis(5);
        let (block_elems, n_blocks) = (256 * 1024, 8);
        let mut last = (Duration::ZERO, Duration::ZERO);
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            serial_all_reduce(4, block_elems, n_blocks, &compute, delay).unwrap();
            let serial_t = t0.elapsed();
            let t1 = std::time::Instant::now();
            tiled_all_reduce(4, block_elems, n_blocks, &compute, delay).unwrap();
            let tiled_t = t1.elapsed();
            if tiled_t < serial_t {
                return;
            }
            last = (tiled_t, serial_t);
        }
        panic!(
            "tiled {:?} never beat serial {:?} — overlap is not hiding communication",
            last.0, last.1
        );
    }

    #[test]
    fn ranks_agree_bitwise_even_and_uneven() {
        // every rank's reduced copy must be bit-identical — including
        // when len % n != 0, where the trailing chunk is short and the
        // chunk map must not misalign across hops.
        for (n, len) in [(2usize, 8usize), (4, 10), (4, 21), (3, 7), (5, 5), (4, 3)] {
            let shards: Vec<Vec<f32>> = (0..n)
                .map(|r| (0..len).map(|i| ((r * 37 + i) as f32) * 0.125 + 0.01).collect())
                .collect();
            let out = ring_all_reduce(shards);
            assert_eq!(out.len(), n);
            assert!(
                ranks_bit_identical(&out),
                "ranks diverge for n={n} len={len}"
            );
            // and the agreed value is the elementwise sum
            let want: Vec<f32> = (0..len)
                .map(|i| (0..n).map(|r| ((r * 37 + i) as f32) * 0.125 + 0.01).sum())
                .collect();
            for (a, b) in out[0].iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "n={n} len={len}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn both_modes_assert_rank_agreement_with_uneven_chunks() {
        // block_elems * n_blocks = 21 elements over 4 workers: 21 % 4
        // != 0 exercises the short trailing chunk inside the modes'
        // internal rank-agreement assertion (they'd panic on disagreement).
        let compute: Box<BlockCompute> = Box::new(|b, buf| {
            for (i, x) in buf.iter_mut().enumerate() {
                *x = (b * 13 + i) as f32 * 0.5 + 1.0;
            }
        });
        let serial = serial_all_reduce(4, 7, 3, &compute, Duration::ZERO).unwrap();
        let tiled = tiled_all_reduce(4, 7, 3, &compute, Duration::ZERO).unwrap();
        assert_eq!(serial.len(), 21);
        for (s, t) in serial.iter().zip(&tiled) {
            assert!((s - t).abs() < 1e-5, "{s} vs {t}");
        }
    }

    #[test]
    fn ranks_bit_identical_detects_divergence() {
        let a = vec![vec![1.0f32, 2.0], vec![1.0, 2.0]];
        assert!(ranks_bit_identical(&a));
        let b = vec![vec![1.0f32, 2.0], vec![1.0, 2.0000002]];
        assert!(!ranks_bit_identical(&b));
        let c = vec![vec![0.0f32], vec![-0.0f32]]; // equal by ==, not by bits
        assert!(!ranks_bit_identical(&c));
    }
}
