//! Prefill/decode scheduling policy.
//!
//! The engine alternates step kinds; the policy decides which runs
//! next.  Default is decode-priority with an anti-starvation prefill
//! quantum (classic continuous-batching trade-off: prefill grows the
//! running batch — throughput; decode drains it — latency).  Sequences
//! mid chunked-prefill add a third kind: [`Step::Chunked`] continues
//! the oldest partially-prefilled sequence, and takes priority over
//! admitting new work (partial sequences hold KV pages — finishing them
//! frees capacity fastest).  Swap-out preemption adds a fourth:
//! [`Step::Resume`] brings a suspended sequence (KV parked on the host
//! tier) back **before any new admission** — a suspended sequence was
//! admitted earlier than everything still waiting, so resuming first
//! preserves FCFS age order and keeps the no-livelock induction intact.
//! Under `Fair`, chunks and resumes share the prefill quantum, so long
//! prompts interleave with decodes instead of monopolizing the engine.
//!
//! The policy is layout- and topology-agnostic: on a tensor-parallel
//! engine every shard mirrors page occupancy in lockstep, so the
//! pressure signal read off shard 0 speaks for the whole device group
//! and the schedule needs no per-shard awareness.

use super::batcher::Batcher;

/// What the engine should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Admit waiting request(s) (bucketed prefill, or the first chunk
    /// of a paged sequence).
    Prefill,
    /// Continue a partially-prefilled (chunked) sequence.
    Chunked,
    /// Resume a swap-out-suspended sequence (before new admissions).
    Resume,
    /// Advance running sequences by one token.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduling policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Always admit waiting work first (maximizes batch occupancy).
    PrefillFirst,
    /// Drain running sequences first; admit only when idle.
    DecodeFirst,
    /// DecodeFirst, but force a prefill every `quantum` decode steps so
    /// waiting requests cannot starve.
    Fair { quantum: u32 },
}

/// Stateful scheduler.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    decodes_since_prefill: u32,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Self { policy, decodes_since_prefill: 0 }
    }

    /// Pick the next step given queue state.  `chunking` counts
    /// sequences mid chunked-prefill (they are not in `active` yet).
    pub fn next_step(&mut self, batcher: &Batcher, active: usize, chunking: usize) -> Step {
        self.next_step_pressured(batcher, active, chunking, 0, false)
    }

    /// Like [`Self::next_step`], but aware of swap-out preemption and
    /// memory pressure.  `suspended` counts swap-out-suspended
    /// sequences: they take the admission slot (as [`Step::Resume`])
    /// before any *new* request is admitted.  `pressure` signals that
    /// the KV pool cannot place a new sequence's first block: admitting
    /// — or resuming, which is gated identically because a resumed
    /// sequence immediately competes for device pages — would only
    /// bounce off the allocator (or trigger a migration/preemption
    /// storm), so while anything is draining, decode work runs instead.
    /// Continuing a *partial* (chunked) sequence still wins — partial
    /// sequences hold pages, and finishing them frees capacity fastest.
    /// With nothing to drain, admission/resume proceeds regardless (the
    /// engine's migrate/swap/preempt machinery is then the right tool).
    pub fn next_step_pressured(
        &mut self,
        batcher: &Batcher,
        active: usize,
        chunking: usize,
        suspended: usize,
        pressure: bool,
    ) -> Step {
        self.next_step_serving(batcher, active, chunking, suspended, pressure, false).0
    }

    /// The serving request plane's entry point: like
    /// [`Self::next_step_pressured`], plus an SLO-protective admission
    /// deferral.  `slo_defer` signals that recent decode step time has
    /// degraded past the TPOT target (and the waiting queue is not yet
    /// starved): **new admissions** yield to decode while anything is
    /// active, but chunk continuation and resume still run — they hold
    /// pages and finishing them is what restores decode speed.  Returns
    /// the step and whether an admission was actually deferred by the
    /// SLO gate (for `EngineMetrics::slo_deferrals`).
    pub fn next_step_serving(
        &mut self,
        batcher: &Batcher,
        active: usize,
        chunking: usize,
        suspended: usize,
        pressure: bool,
        slo_defer: bool,
    ) -> (Step, bool) {
        let has_prefill_work = batcher.waiting() > 0 || chunking > 0 || suspended > 0;
        let has_active = active > 0;
        // continuing a partial sequence beats resuming a suspended one
        // beats admitting a new one
        let prefill_kind = if chunking > 0 {
            Step::Chunked
        } else if suspended > 0 {
            Step::Resume
        } else {
            Step::Prefill
        };
        let step = match (has_prefill_work, has_active, self.policy) {
            (false, false, _) => Step::Idle,
            (true, false, _) => prefill_kind,
            (false, true, _) => Step::Decode,
            (true, true, Policy::PrefillFirst) => prefill_kind,
            (true, true, Policy::DecodeFirst) => Step::Decode,
            (true, true, Policy::Fair { quantum }) => {
                if self.decodes_since_prefill >= quantum {
                    prefill_kind
                } else {
                    Step::Decode
                }
            }
        };
        let mut slo_deferred = false;
        let step = match step {
            Step::Prefill | Step::Resume if pressure && has_active => Step::Decode,
            Step::Prefill if slo_defer && has_active => {
                slo_deferred = true;
                Step::Decode
            }
            s => s,
        };
        match step {
            Step::Decode => self.decodes_since_prefill += 1,
            Step::Prefill | Step::Chunked | Step::Resume => self.decodes_since_prefill = 0,
            Step::Idle => {}
        }
        (step, slo_deferred)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::request::{GenParams, Request};

    fn batcher(waiting: usize) -> Batcher {
        let mut b = Batcher::new(BatcherConfig {
            prefill_batches: vec![1, 4],
            prefill_seqs: vec![32],
            decode_batches: vec![1, 4],
            max_active: 8,
            max_seq_tokens: 64,
            allow_chunked: false,
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
        });
        for id in 0..waiting as u64 {
            b.push(Request::new(id, vec![1; 4], GenParams::default())).unwrap();
        }
        b
    }

    #[test]
    fn idle_when_empty() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step(&batcher(0), 0, 0), Step::Idle);
    }

    #[test]
    fn prefill_when_only_waiting() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 0, 0), Step::Prefill);
    }

    #[test]
    fn decode_first_prefers_decode() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 0), Step::Decode);
    }

    #[test]
    fn prefill_first_prefers_prefill() {
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 0), Step::Prefill);
    }

    #[test]
    fn fair_quantum_prevents_starvation() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 3 });
        let b = batcher(1);
        // three decodes pass, the fourth call must be a prefill
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Prefill);
        // counter reset after the prefill
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
    }

    #[test]
    fn fair_quantum_holds_under_continuous_decode_pressure() {
        // with work always waiting and actives never draining, prefills
        // fire exactly every quantum+1 steps — no starvation, no drift.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(4);
        let steps: Vec<Step> = (0..9).map(|_| s.next_step(&b, 3, 0)).collect();
        assert_eq!(
            steps,
            vec![
                Step::Decode,
                Step::Decode,
                Step::Prefill,
                Step::Decode,
                Step::Decode,
                Step::Prefill,
                Step::Decode,
                Step::Decode,
                Step::Prefill,
            ]
        );
    }

    #[test]
    fn chunked_continues_before_admitting() {
        // a partially-prefilled sequence takes the prefill slot
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 1), Step::Chunked);
        // with no waiting work either, chunks still run
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step(&batcher(0), 0, 2), Step::Chunked);
    }

    #[test]
    fn fair_quantum_schedules_chunks() {
        // a chunked sequence interleaves with decodes under Fair, and
        // resets the quantum like a prefill does.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(0);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 1), Step::Chunked);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
    }

    #[test]
    fn pressure_defers_admission_while_draining() {
        // under pressure, admitting new work yields to decode — even
        // for PrefillFirst — as long as something is draining
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(2), 3, 0, 0, true), Step::Decode);
        // with nothing active, admission must proceed (or nothing ever runs)
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(2), 0, 0, 0, true), Step::Prefill);
        // chunked continuation is not admission: it still runs — the
        // partial sequence holds pages and finishing it frees them
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(0), 3, 1, 0, true), Step::Chunked);
        // once pressure lifts, the Fair quantum admits immediately
        let mut s = Scheduler::new(Policy::Fair { quantum: 1 });
        let b = batcher(1);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 0, true), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 0, true), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 0, false), Step::Prefill);
    }

    #[test]
    fn decode_first_drains_before_chunks() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(0), 1, 1), Step::Decode);
        assert_eq!(s.next_step(&batcher(0), 0, 1), Step::Chunked);
    }

    // --- swap-out suspension: Step::Resume ----------------------------

    #[test]
    fn resume_takes_the_admission_slot_before_new_requests() {
        // a suspended sequence was admitted before everything still
        // waiting — it must come back first
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(3), 1, 0, 2, false), Step::Resume);
        // …but a partial (chunked) sequence still beats it: it holds
        // pages and finishing it frees capacity fastest
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(3), 1, 1, 2, false), Step::Chunked);
        // with nothing else in the system, a lone suspended sequence
        // still resumes (never strands)
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step_pressured(&batcher(0), 0, 0, 1, false), Step::Resume);
    }

    #[test]
    fn resume_is_pressure_gated_like_admission() {
        // under pressure with active work draining, resume defers — a
        // resumed sequence immediately competes for device pages
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(0), 2, 0, 1, true), Step::Decode);
        // with nothing draining, resume proceeds regardless
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(0), 0, 0, 1, true), Step::Resume);
    }

    #[test]
    fn fair_quantum_schedules_resumes() {
        // a suspended sequence shares the prefill quantum and resets it
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(0);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 1, false), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 1, false), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 1, false), Step::Resume);
        assert_eq!(s.next_step_pressured(&b, 1, 0, 1, false), Step::Decode);
    }

    // --- next_step_pressured edge cases (previously only covered
    // indirectly through the engine integration tests) ----------------

    #[test]
    fn all_running_drain_under_pressure_never_idles() {
        // nothing waiting, nothing chunked, pressure on: the only legal
        // answer is Decode until the actives drain to zero…
        let mut s = Scheduler::new(Policy::Fair { quantum: 1 });
        let b = batcher(0);
        for active in (1..=4).rev() {
            assert_eq!(s.next_step_pressured(&b, active, 0, 0, true), Step::Decode);
        }
        // …and with everything drained the system goes idle, pressure
        // notwithstanding
        assert_eq!(s.next_step_pressured(&b, 0, 0, 0, true), Step::Idle);
    }

    #[test]
    fn chunked_only_queue_runs_chunks_under_any_policy_and_pressure() {
        // only partial sequences exist: every policy must continue them
        // (they are the only work), pressure on or off
        for policy in [Policy::PrefillFirst, Policy::DecodeFirst, Policy::Fair { quantum: 1 }] {
            for pressure in [false, true] {
                let mut s = Scheduler::new(policy);
                assert_eq!(
                    s.next_step_pressured(&batcher(0), 0, 3, 0, pressure),
                    Step::Chunked,
                    "{policy:?} pressure={pressure}"
                );
            }
        }
    }

    #[test]
    fn pressure_flapping_preserves_the_fair_quantum() {
        // pressure toggling on and off between calls must not corrupt
        // the anti-starvation counter: deferred prefills count as
        // decodes, and the first unpressured slot past the quantum
        // admits immediately.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(2);
        let pressure = [true, false, true, true, false, false, true, false];
        let mut admitted = 0;
        let mut since_admit = 0;
        for &p in &pressure {
            match s.next_step_pressured(&b, 2, 0, 0, p) {
                Step::Prefill => {
                    assert!(!p, "admission never fires under pressure with actives");
                    admitted += 1;
                    since_admit = 0;
                }
                Step::Decode => since_admit += 1,
                other => panic!("unexpected step {other:?}"),
            }
            assert!(since_admit <= 4, "pressure flapping must not starve admission");
        }
        assert!(admitted >= 2, "unpressured quantum slots must admit, got {admitted}");
    }

    // --- SLO-protective admission deferral ----------------------------

    #[test]
    fn slo_defer_demotes_only_new_admissions() {
        // with actives draining, a degraded TPOT defers Prefill…
        let mut s = Scheduler::new(Policy::PrefillFirst);
        let b = batcher(2);
        assert_eq!(s.next_step_serving(&b, 2, 0, 0, false, true), (Step::Decode, true));
        // …but chunk continuation and resume still run: they hold pages
        // and finishing them is what restores decode speed
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_serving(&b, 2, 3, 0, false, true), (Step::Chunked, false));
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_serving(&b, 2, 0, 1, false, true), (Step::Resume, false));
    }

    #[test]
    fn slo_defer_yields_when_nothing_is_active() {
        // no active work to protect: admission proceeds regardless
        let mut s = Scheduler::new(Policy::Fair { quantum: 1 });
        let b = batcher(1);
        assert_eq!(s.next_step_serving(&b, 0, 0, 0, false, true), (Step::Prefill, false));
    }

    #[test]
    fn slo_defer_counts_as_decode_for_the_fair_quantum() {
        // an SLO-deferred admission slot must advance the quantum
        // counter like the pressure path does, so the first slot after
        // the SLO clears admits immediately.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(2);
        assert_eq!(s.next_step_serving(&b, 2, 0, 0, false, false).0, Step::Decode);
        assert_eq!(s.next_step_serving(&b, 2, 0, 0, false, false).0, Step::Decode);
        // quantum expired, but SLO degraded → deferred
        assert_eq!(s.next_step_serving(&b, 2, 0, 0, false, true), (Step::Decode, true));
        // SLO recovered → the admission fires on the next slot
        assert_eq!(s.next_step_serving(&b, 2, 0, 0, false, false), (Step::Prefill, false));
    }
}
