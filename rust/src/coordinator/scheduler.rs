//! Prefill/decode scheduling policy.
//!
//! The engine alternates two step kinds; the policy decides which runs
//! next.  Default is decode-priority with an anti-starvation prefill
//! quantum (classic continuous-batching trade-off: prefill grows the
//! running batch — throughput; decode drains it — latency).

use super::batcher::Batcher;

/// What the engine should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    Prefill,
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduling policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Always admit waiting work first (maximizes batch occupancy).
    PrefillFirst,
    /// Drain running sequences first; admit only when idle.
    DecodeFirst,
    /// DecodeFirst, but force a prefill every `quantum` decode steps so
    /// waiting requests cannot starve.
    Fair { quantum: u32 },
}

/// Stateful scheduler.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    decodes_since_prefill: u32,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Self { policy, decodes_since_prefill: 0 }
    }

    /// Pick the next step given queue state.
    pub fn next_step(&mut self, batcher: &Batcher, active: usize) -> Step {
        let has_waiting = batcher.waiting() > 0;
        let has_active = active > 0;
        let step = match (has_waiting, has_active, self.policy) {
            (false, false, _) => Step::Idle,
            (true, false, _) => Step::Prefill,
            (false, true, _) => Step::Decode,
            (true, true, Policy::PrefillFirst) => Step::Prefill,
            (true, true, Policy::DecodeFirst) => Step::Decode,
            (true, true, Policy::Fair { quantum }) => {
                if self.decodes_since_prefill >= quantum {
                    Step::Prefill
                } else {
                    Step::Decode
                }
            }
        };
        match step {
            Step::Decode => self.decodes_since_prefill += 1,
            Step::Prefill => self.decodes_since_prefill = 0,
            Step::Idle => {}
        }
        step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::request::{GenParams, Request};

    fn batcher(waiting: usize) -> Batcher {
        let mut b = Batcher::new(BatcherConfig {
            prefill_batches: vec![1, 4],
            prefill_seqs: vec![32],
            decode_batches: vec![1, 4],
            max_active: 8,
        });
        for id in 0..waiting as u64 {
            b.push(Request::new(id, vec![1; 4], GenParams::default())).unwrap();
        }
        b
    }

    #[test]
    fn idle_when_empty() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step(&batcher(0), 0), Step::Idle);
    }

    #[test]
    fn prefill_when_only_waiting() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 0), Step::Prefill);
    }

    #[test]
    fn decode_first_prefers_decode() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 2), Step::Decode);
    }

    #[test]
    fn prefill_first_prefers_prefill() {
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step(&batcher(1), 2), Step::Prefill);
    }

    #[test]
    fn fair_quantum_prevents_starvation() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 3 });
        let b = batcher(1);
        // three decodes pass, the fourth call must be a prefill
        assert_eq!(s.next_step(&b, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1), Step::Prefill);
        // counter reset after the prefill
        assert_eq!(s.next_step(&b, 1), Step::Decode);
    }
}
