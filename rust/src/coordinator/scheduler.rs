//! Prefill/decode scheduling policy.
//!
//! The engine alternates step kinds; the policy decides which runs
//! next.  Default is decode-priority with an anti-starvation prefill
//! quantum (classic continuous-batching trade-off: prefill grows the
//! running batch — throughput; decode drains it — latency).  Sequences
//! mid chunked-prefill add a third kind: [`Step::Chunked`] continues
//! the oldest partially-prefilled sequence, and takes priority over
//! admitting new work (partial sequences hold KV pages — finishing them
//! frees capacity fastest).  Under `Fair`, chunks share the prefill
//! quantum, so long prompts interleave with decodes instead of
//! monopolizing the engine.

use super::batcher::Batcher;

/// What the engine should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// Admit waiting request(s) (bucketed prefill, or the first chunk
    /// of a paged sequence).
    Prefill,
    /// Continue a partially-prefilled (chunked) sequence.
    Chunked,
    Decode,
    /// Nothing to do.
    Idle,
}

/// Scheduling policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Always admit waiting work first (maximizes batch occupancy).
    PrefillFirst,
    /// Drain running sequences first; admit only when idle.
    DecodeFirst,
    /// DecodeFirst, but force a prefill every `quantum` decode steps so
    /// waiting requests cannot starve.
    Fair { quantum: u32 },
}

/// Stateful scheduler.
#[derive(Debug)]
pub struct Scheduler {
    policy: Policy,
    decodes_since_prefill: u32,
}

impl Scheduler {
    pub fn new(policy: Policy) -> Self {
        Self { policy, decodes_since_prefill: 0 }
    }

    /// Pick the next step given queue state.  `chunking` counts
    /// sequences mid chunked-prefill (they are not in `active` yet).
    pub fn next_step(&mut self, batcher: &Batcher, active: usize, chunking: usize) -> Step {
        self.next_step_pressured(batcher, active, chunking, false)
    }

    /// Like [`Self::next_step`], but `pressure` signals that the KV
    /// pool cannot place a new sequence's first block: admitting would
    /// only bounce off the allocator (or trigger a migration/preemption
    /// storm), so while anything is draining, decode work runs instead.
    /// Continuing a *partial* (chunked) sequence still wins — partial
    /// sequences hold pages, and finishing them frees capacity fastest.
    /// With nothing to drain, admission proceeds regardless (the
    /// engine's migrate/preempt machinery is then the right tool).
    pub fn next_step_pressured(
        &mut self,
        batcher: &Batcher,
        active: usize,
        chunking: usize,
        pressure: bool,
    ) -> Step {
        let has_prefill_work = batcher.waiting() > 0 || chunking > 0;
        let has_active = active > 0;
        // continuing a partial sequence beats admitting a new one
        let prefill_kind = if chunking > 0 { Step::Chunked } else { Step::Prefill };
        let step = match (has_prefill_work, has_active, self.policy) {
            (false, false, _) => Step::Idle,
            (true, false, _) => prefill_kind,
            (false, true, _) => Step::Decode,
            (true, true, Policy::PrefillFirst) => prefill_kind,
            (true, true, Policy::DecodeFirst) => Step::Decode,
            (true, true, Policy::Fair { quantum }) => {
                if self.decodes_since_prefill >= quantum {
                    prefill_kind
                } else {
                    Step::Decode
                }
            }
        };
        let step = match step {
            Step::Prefill if pressure && has_active => Step::Decode,
            s => s,
        };
        match step {
            Step::Decode => self.decodes_since_prefill += 1,
            Step::Prefill | Step::Chunked => self.decodes_since_prefill = 0,
            Step::Idle => {}
        }
        step
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::request::{GenParams, Request};

    fn batcher(waiting: usize) -> Batcher {
        let mut b = Batcher::new(BatcherConfig {
            prefill_batches: vec![1, 4],
            prefill_seqs: vec![32],
            decode_batches: vec![1, 4],
            max_active: 8,
            max_seq_tokens: 64,
            allow_chunked: false,
        });
        for id in 0..waiting as u64 {
            b.push(Request::new(id, vec![1; 4], GenParams::default())).unwrap();
        }
        b
    }

    #[test]
    fn idle_when_empty() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step(&batcher(0), 0, 0), Step::Idle);
    }

    #[test]
    fn prefill_when_only_waiting() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 0, 0), Step::Prefill);
    }

    #[test]
    fn decode_first_prefers_decode() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 0), Step::Decode);
    }

    #[test]
    fn prefill_first_prefers_prefill() {
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 0), Step::Prefill);
    }

    #[test]
    fn fair_quantum_prevents_starvation() {
        let mut s = Scheduler::new(Policy::Fair { quantum: 3 });
        let b = batcher(1);
        // three decodes pass, the fourth call must be a prefill
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 0), Step::Prefill);
        // counter reset after the prefill
        assert_eq!(s.next_step(&b, 1, 0), Step::Decode);
    }

    #[test]
    fn fair_quantum_holds_under_continuous_decode_pressure() {
        // with work always waiting and actives never draining, prefills
        // fire exactly every quantum+1 steps — no starvation, no drift.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(4);
        let steps: Vec<Step> = (0..9).map(|_| s.next_step(&b, 3, 0)).collect();
        assert_eq!(
            steps,
            vec![
                Step::Decode,
                Step::Decode,
                Step::Prefill,
                Step::Decode,
                Step::Decode,
                Step::Prefill,
                Step::Decode,
                Step::Decode,
                Step::Prefill,
            ]
        );
    }

    #[test]
    fn chunked_continues_before_admitting() {
        // a partially-prefilled sequence takes the prefill slot
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step(&batcher(1), 2, 1), Step::Chunked);
        // with no waiting work either, chunks still run
        let mut s = Scheduler::new(Policy::Fair { quantum: 4 });
        assert_eq!(s.next_step(&batcher(0), 0, 2), Step::Chunked);
    }

    #[test]
    fn fair_quantum_schedules_chunks() {
        // a chunked sequence interleaves with decodes under Fair, and
        // resets the quantum like a prefill does.
        let mut s = Scheduler::new(Policy::Fair { quantum: 2 });
        let b = batcher(0);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
        assert_eq!(s.next_step(&b, 1, 1), Step::Chunked);
        assert_eq!(s.next_step(&b, 1, 1), Step::Decode);
    }

    #[test]
    fn pressure_defers_admission_while_draining() {
        // under pressure, admitting new work yields to decode — even
        // for PrefillFirst — as long as something is draining
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(2), 3, 0, true), Step::Decode);
        // with nothing active, admission must proceed (or nothing ever runs)
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(2), 0, 0, true), Step::Prefill);
        // chunked continuation is not admission: it still runs — the
        // partial sequence holds pages and finishing it frees them
        let mut s = Scheduler::new(Policy::PrefillFirst);
        assert_eq!(s.next_step_pressured(&batcher(0), 3, 1, true), Step::Chunked);
        // once pressure lifts, the Fair quantum admits immediately
        let mut s = Scheduler::new(Policy::Fair { quantum: 1 });
        let b = batcher(1);
        assert_eq!(s.next_step_pressured(&b, 1, 0, true), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, true), Step::Decode);
        assert_eq!(s.next_step_pressured(&b, 1, 0, false), Step::Prefill);
    }

    #[test]
    fn decode_first_drains_before_chunks() {
        let mut s = Scheduler::new(Policy::DecodeFirst);
        assert_eq!(s.next_step(&batcher(0), 1, 1), Step::Decode);
        assert_eq!(s.next_step(&batcher(0), 0, 1), Step::Chunked);
    }
}
