//! FlashAttention2 on the host CPU — real, not simulated.
//!
//! Online-softmax tiled attention with the FlashAttention2 loop order
//! (outer over Q blocks, inner over KV blocks, per-row running max/sum,
//! single rescale per block).  This kernel executes the cooperative
//! strategy's host-side decode attention (§4.4): when a layer's KV cache
//! is CPU-resident, the coordinator ships the one-token Q down here
//! instead of uploading tens of MB of KV over PCIe.
//!
//! Layout matches [`standard`](super::standard): flat
//! `[heads][seq][head_dim]` row-major f32 for Q and the output.  K/V are
//! `[kv_heads][seq][head_dim]` — grouped-query attention (GQA) shares one
//! KV head across `heads / kv_heads` query heads; `kv_heads == heads`
//! recovers classic multi-head attention.

/// Tiling + shape parameters.
#[derive(Debug, Clone, Copy)]
pub struct FlashParams {
    pub heads: usize,
    /// KV heads (GQA): must divide `heads`; `== heads` is plain MHA.
    pub kv_heads: usize,
    pub seq_q: usize,
    pub seq_kv: usize,
    pub head_dim: usize,
    pub causal: bool,
    /// Q rows per block.
    pub block_q: usize,
    /// KV rows per block.
    pub block_kv: usize,
    pub scale: f32,
}

impl FlashParams {
    /// Decode-step shape: one query row over `kv` cached tokens (MHA).
    pub fn decode(heads: usize, kv: usize, head_dim: usize) -> Self {
        Self::decode_gqa(heads, heads, kv, head_dim)
    }

    /// Decode-step shape with grouped-query attention: `kv_heads` KV
    /// heads shared across `heads` query heads.
    pub fn decode_gqa(heads: usize, kv_heads: usize, kv: usize, head_dim: usize) -> Self {
        Self {
            heads,
            kv_heads,
            seq_q: 1,
            seq_kv: kv,
            head_dim,
            causal: false,
            block_q: 1,
            block_kv: 128,
            scale: 1.0 / (head_dim as f32).sqrt(),
        }
    }

    /// Query heads sharing each KV head.
    pub fn group_size(&self) -> usize {
        self.heads / self.kv_heads
    }
}

/// Eight-accumulator dot product: breaks the serial FP dependency chain
/// so the compiler can vectorize the body into full 256-bit FMA lanes
/// (one 8-wide f32 fused multiply-add per iteration) instead of four
/// scalar pipes — the SIMD-friendly shape LLVM auto-vectorizes without
/// intrinsics.  Bounds checks are hoisted by the up-front slice
/// reborrow, so the hot loop is branch-free.  Every attention path —
/// blocked tiles ([`fill_score_tile`]) and the rowwise baseline alike —
/// funnels through here, which is what keeps
/// `prop_blocked_equals_rowwise` bit-exact across the unroll.
#[inline]
fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let (a8, b8) = (&a[..chunks * 8], &b[..chunks * 8]);
    let mut s = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            s[lane] += a8[i + lane] * b8[i + lane];
        }
    }
    let mut rest = 0.0f32;
    for i in chunks * 8..n {
        rest += a[i] * b[i];
    }
    (((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))) + rest
}

/// [`dot4`] against an int8 row: `Σ a[t] · b[t] as f32`, with the same
/// 8-wide accumulator shape so the i8→f32 widening vectorizes
/// (`vpmovsxbd` + `vcvtdq2ps` feeding the FMA lanes).  The caller folds
/// the row scale into the product afterwards, so dequantization costs
/// one multiply per row instead of one per element.
#[inline]
fn dot4_i8(a: &[f32], b: &[i8]) -> f32 {
    let n = a.len().min(b.len());
    let chunks = n / 8;
    let (a8, b8) = (&a[..chunks * 8], &b[..chunks * 8]);
    let mut s = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for lane in 0..8 {
            s[lane] += a8[i + lane] * b8[i + lane] as f32;
        }
    }
    let mut rest = 0.0f32;
    for i in chunks * 8..n {
        rest += a[i] * b[i] as f32;
    }
    (((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))) + rest
}

use crate::coordinator::kv_cache::{QuantStore, Tier};

/// A row source for K or V: a contiguous `[rows, head_dim]` slice, rows
/// gathered through a page table (the paged KV cache's block-table
/// layout — see `coordinator::kv_cache`), or rows gathered across the
/// *two* stores of the tiered cache (device + host), with a per-block
/// tier tag selecting the store.  The `*I8` variants are the same two
/// paged layouts over int8 stores with per-row scale side-channels
/// ([`QuantStore`]) — dequantization is fused into the kernel loops.
///
/// The kernel walks page-contiguous runs through [`KvView::run_at`],
/// streaming the exact same values in the exact same order for every
/// f32 layout — paged and tiered attention are **bit-identical** to
/// contiguous attention by construction (pinned by
/// `prop_blocked_equals_rowwise`).
#[derive(Debug, Clone, Copy)]
pub enum KvView<'a> {
    /// Contiguous `[rows, head_dim]` row-major.
    Contig(&'a [f32]),
    /// `pages[r / page_size]` names the page holding row `r` at in-page
    /// slot `r % page_size`; `store` is `[num_pages, page_size,
    /// head_dim]` flat.
    Paged {
        store: &'a [f32],
        pages: &'a [u32],
        page_size: usize,
    },
    /// Like `Paged`, but block `r / page_size` lives in whichever store
    /// `tiers[r / page_size]` names — the partially-offloaded sequence
    /// of the §4.4 cold-page strategy.  Page ids are per-store.
    Tiered {
        device_store: &'a [f32],
        host_store: &'a [f32],
        pages: &'a [u32],
        tiers: &'a [Tier],
        page_size: usize,
    },
    /// `Paged` over an int8 store: rows dequantize in the kernel as
    /// `q[t] as f32 * scales[row]`.
    PagedI8 {
        store: QuantStore<'a>,
        pages: &'a [u32],
        page_size: usize,
    },
    /// `Tiered` over int8 stores, one [`QuantStore`] per tier.
    TieredI8 {
        device_store: QuantStore<'a>,
        host_store: QuantStore<'a>,
        pages: &'a [u32],
        tiers: &'a [Tier],
        page_size: usize,
    },
}

/// One page-contiguous run of rows handed out by [`KvView::run_at`]:
/// raw f32 rows, or int8 rows with their per-row scales (dequantized
/// in-loop by the kernel, never materialized).
#[derive(Debug, Clone, Copy)]
pub enum KvRun<'a> {
    /// `len × head_dim` contiguous f32 elements.
    F32(&'a [f32]),
    /// `len × head_dim` contiguous i8 elements + `len` per-row scales.
    I8 { q: &'a [i8], scales: &'a [f32] },
}

impl<'a> KvView<'a> {
    /// Row `r` as a `head_dim`-length f32 slice — the scalar gather the
    /// pre-blocked kernel used ([`flash_attention_view_rowwise`] keeps
    /// it alive as the bench baseline).  Panics for the int8 variants,
    /// whose rows only exist fused inside the kernel.
    #[inline(always)]
    pub fn row(&self, r: usize, d: usize) -> &'a [f32] {
        match *self {
            KvView::Contig(s) => &s[r * d..][..d],
            KvView::Paged { store, pages, page_size } => {
                let page = pages[r / page_size] as usize;
                &store[(page * page_size + r % page_size) * d..][..d]
            }
            KvView::Tiered { device_store, host_store, pages, tiers, page_size } => {
                let b = r / page_size;
                let store = match tiers[b] {
                    Tier::Device => device_store,
                    Tier::Host => host_store,
                };
                &store[(pages[b] as usize * page_size + r % page_size) * d..][..d]
            }
            KvView::PagedI8 { .. } | KvView::TieredI8 { .. } => {
                panic!("int8 views have no f32 rows — walk them with run_at")
            }
        }
    }

    /// The longest page-contiguous run starting at row `r`, capped at
    /// `max_rows` rows.  Returns the run and its row count (≥ 1): the
    /// per-row page-index division, tier dispatch and bounds checks are
    /// paid once per run instead of once per row, and the kernel loops
    /// stream the returned slice directly.
    #[inline(always)]
    pub fn run_at(&self, r: usize, max_rows: usize, d: usize) -> (KvRun<'a>, usize) {
        debug_assert!(max_rows >= 1, "empty run request");
        match *self {
            KvView::Contig(s) => {
                let n = max_rows.min(s.len() / d.max(1) - r);
                (KvRun::F32(&s[r * d..][..n * d]), n)
            }
            KvView::Paged { store, pages, page_size } => {
                let (b, slot) = (r / page_size, r % page_size);
                let n = max_rows.min(page_size - slot);
                let at = (pages[b] as usize * page_size + slot) * d;
                (KvRun::F32(&store[at..][..n * d]), n)
            }
            KvView::Tiered { device_store, host_store, pages, tiers, page_size } => {
                debug_assert_eq!(pages.len(), tiers.len(), "tiered pages/tiers skew");
                let (b, slot) = (r / page_size, r % page_size);
                let n = max_rows.min(page_size - slot);
                let store = match tiers[b] {
                    Tier::Device => device_store,
                    Tier::Host => host_store,
                };
                let at = (pages[b] as usize * page_size + slot) * d;
                (KvRun::F32(&store[at..][..n * d]), n)
            }
            KvView::PagedI8 { store, pages, page_size } => {
                let (b, slot) = (r / page_size, r % page_size);
                let n = max_rows.min(page_size - slot);
                let row = pages[b] as usize * page_size + slot;
                (
                    KvRun::I8 {
                        q: &store.q[row * d..][..n * d],
                        scales: &store.scales[row..][..n],
                    },
                    n,
                )
            }
            KvView::TieredI8 { device_store, host_store, pages, tiers, page_size } => {
                debug_assert_eq!(pages.len(), tiers.len(), "tiered pages/tiers skew");
                let (b, slot) = (r / page_size, r % page_size);
                let n = max_rows.min(page_size - slot);
                let store = match tiers[b] {
                    Tier::Device => device_store,
                    Tier::Host => host_store,
                };
                let row = pages[b] as usize * page_size + slot;
                (
                    KvRun::I8 {
                        q: &store.q[row * d..][..n * d],
                        scales: &store.scales[row..][..n],
                    },
                    n,
                )
            }
        }
    }

    /// Rows this view can address (an upper bound for the paged
    /// layouts, whose tail pages may be unallocated sentinels — callers
    /// bound reads by their own `kv_len`).
    pub fn addressable_rows(&self, d: usize) -> usize {
        match *self {
            KvView::Contig(s) => s.len() / d.max(1),
            KvView::Paged { pages, page_size, .. }
            | KvView::PagedI8 { pages, page_size, .. } => pages.len() * page_size,
            KvView::Tiered { pages, tiers, page_size, .. }
            | KvView::TieredI8 { pages, tiers, page_size, .. } => {
                debug_assert_eq!(
                    pages.len(),
                    tiers.len(),
                    "tiered view pages/tiers lengths must agree"
                );
                pages.len().min(tiers.len()) * page_size
            }
        }
    }
}

/// Per-call scratch of the single-head kernel (one (bq × bkv) score
/// tile + running online-softmax stats + one tile-local accumulator).
struct FlashScratch {
    scores: Vec<f32>,
    m: Vec<f32>,
    l: Vec<f32>,
    acc: Vec<f32>,
    tacc: Vec<f32>,
}

impl FlashScratch {
    fn new(bq: usize, bkv: usize, d: usize) -> Self {
        Self {
            scores: vec![0.0; bq * bkv],
            m: vec![0.0; bq],
            l: vec![0.0; bq],
            acc: vec![0.0; bq * d],
            tacc: vec![0.0; d],
        }
    }
}

/// Effective tile sizes and geometry of one head's kernel run.
#[derive(Debug, Clone, Copy)]
struct HeadGeom {
    sq: usize,
    skv: usize,
    d: usize,
    causal: bool,
    bq: usize,
    bkv: usize,
    scale: f32,
}

impl HeadGeom {
    fn of(p: &FlashParams) -> Self {
        Self {
            sq: p.seq_q,
            skv: p.seq_kv,
            d: p.head_dim,
            causal: p.causal,
            bq: p.block_q.max(1).min(p.seq_q.max(1)),
            bkv: p.block_kv.max(1).min(p.seq_kv.max(1)),
            scale: p.scale,
        }
    }
}

/// Merge one partial online-softmax state into another.
///
/// `(m, l, acc)` is the running state — `m` the max score seen, `l` the
/// sum of `exp(s − m)`, `acc` the un-normalized `Σ exp(s − m)·v` —
/// and `(mb, lb, accb)` is a second partial state over a disjoint set
/// of KV columns.  After the call, `(m, l, acc)` covers the union.
/// `m == −∞` encodes the empty state (zero columns) on either side.
///
/// This is the LSE-merge at the heart of cascade attention: the shared
/// prefix's state (computed once per batch) merges with each request's
/// suffix state.  `flash_head` folds every KV tile through this exact
/// function, so a cascade split at any tile boundary is **bit-identical**
/// to the single pass — `merge(state_a, tile_b) == pass(a ∥ b)` exactly
/// in f32, not merely within tolerance (pinned by
/// `prop_merge_equals_single_pass`).  Note the merge is *not*
/// associative in f32 across several tiles, which is why cascade phase 2
/// continues from the phase-1 state rather than merging two
/// independently-built multi-tile states.
pub fn merge_softmax_states(
    m: &mut f32,
    l: &mut f32,
    acc: &mut [f32],
    mb: f32,
    lb: f32,
    accb: &[f32],
) {
    assert_eq!(acc.len(), accb.len(), "merge_softmax_states dim mismatch");
    if mb == f32::NEG_INFINITY {
        return; // b is the empty state
    }
    if *m == f32::NEG_INFINITY {
        *m = mb;
        *l = lb;
        acc.copy_from_slice(accb);
        return;
    }
    let m_new = m.max(mb);
    let alpha = (*m - m_new).exp();
    let beta = (mb - m_new).exp();
    for (a, &b) in acc.iter_mut().zip(accb) {
        *a = *a * alpha + b * beta;
    }
    *l = *l * alpha + lb * beta;
    *m = m_new;
}

/// Fill `srow[..nk]` with scaled `q·k` scores for KV columns
/// `[k0, k0 + nk)`, walking page-contiguous runs of `k`.  Shared by
/// [`flash_head`] and the cascade kernel so their score arithmetic
/// cannot drift.
#[inline]
pub(crate) fn fill_score_tile(
    qi: &[f32],
    k: &KvView<'_>,
    k0: usize,
    nk: usize,
    d: usize,
    scale: f32,
    srow: &mut [f32],
) {
    let mut j = 0;
    while j < nk {
        let (run, n) = k.run_at(k0 + j, nk - j, d);
        match run {
            KvRun::F32(rows) => {
                for (jj, sc) in srow[j..j + n].iter_mut().enumerate() {
                    *sc = dot4(qi, &rows[jj * d..][..d]) * scale;
                }
            }
            KvRun::I8 { q, scales } => {
                for (jj, sc) in srow[j..j + n].iter_mut().enumerate() {
                    *sc = dot4_i8(qi, &q[jj * d..][..d]) * (scales[jj] * scale);
                }
            }
        }
        j += n;
    }
}

/// Local softmax state of one score tile: returns `(mt, lt)` with `mt`
/// the tile max, `lt = Σ exp(s − mt)` and `tacc = Σ exp(s − mt)·v`
/// over columns `[k0, k0 + vis)` of `v`.  The caller folds the result
/// into its running state via [`merge_softmax_states`].  Shared by
/// [`flash_head`] and the cascade kernel.
#[inline]
pub(crate) fn row_tile_state(
    srow: &[f32],
    v: &KvView<'_>,
    k0: usize,
    vis: usize,
    d: usize,
    tacc: &mut [f32],
) -> (f32, f32) {
    let mut mt = f32::NEG_INFINITY;
    for &sc in &srow[..vis] {
        if sc > mt {
            mt = sc;
        }
    }
    tacc[..d].fill(0.0);
    let mut lt = 0.0f32;
    let mut j = 0;
    while j < vis {
        let (run, n) = v.run_at(k0 + j, vis - j, d);
        match run {
            KvRun::F32(rows) => {
                for jj in 0..n {
                    let pij = (srow[j + jj] - mt).exp();
                    lt += pij;
                    let vj = &rows[jj * d..][..d];
                    for t in 0..d {
                        tacc[t] += pij * vj[t];
                    }
                }
            }
            KvRun::I8 { q, scales } => {
                for jj in 0..n {
                    let pij = (srow[j + jj] - mt).exp();
                    lt += pij;
                    let w = pij * scales[jj];
                    let vj = &q[jj * d..][..d];
                    for t in 0..d {
                        tacc[t] += w * vj[t] as f32;
                    }
                }
            }
        }
        j += n;
    }
    (mt, lt)
}

/// The single-head FlashAttention2 loop over one pair of K/V views.
///
/// The inner loops walk page-contiguous runs ([`KvView::run_at`]):
/// page-index division, tier dispatch and bounds checks are hoisted
/// out of the per-row loop, and each run streams straight through the
/// online-softmax accumulator.  Each KV tile builds a *local*
/// `(mt, lt, tacc)` state ([`row_tile_state`]) folded into the running
/// `(m, l, acc)` through [`merge_softmax_states`] — so a cascade split
/// at any tile boundary reproduces this kernel bit-for-bit.  The
/// per-row arithmetic matches [`flash_head_rowwise`] exactly, so every
/// f32 layout stays bit-identical to the rowwise baseline; int8 runs
/// dequantize in-loop with one fused scale multiply per row.
fn flash_head(
    qh: &[f32],
    k: &KvView<'_>,
    v: &KvView<'_>,
    oh: &mut [f32],
    g: HeadGeom,
    s: &mut FlashScratch,
) {
    let HeadGeom { sq, skv, d, causal, bq, bkv, scale } = g;
    let (scores, m, l, acc, tacc) =
        (&mut s.scores, &mut s.m, &mut s.l, &mut s.acc, &mut s.tacc);

    let mut q0 = 0;
    while q0 < sq {
        let nq = bq.min(sq - q0);
        m[..nq].fill(f32::NEG_INFINITY);
        l[..nq].fill(0.0);
        acc[..nq * d].fill(0.0);

        // causal suffix alignment: row i sees cols <= i + (skv - sq)
        let row_limit = |i: usize| -> usize {
            if causal { q0 + i + 1 + skv - sq } else { skv }
        };
        let block_cols = if causal { row_limit(nq - 1).min(skv) } else { skv };

        let mut k0 = 0;
        while k0 < block_cols {
            let nk = bkv.min(block_cols - k0);

            // --- scores tile: q_blk @ k_blkᵀ -----------------------
            for i in 0..nq {
                let qi = &qh[(q0 + i) * d..][..d];
                fill_score_tile(qi, k, k0, nk, d, scale, &mut scores[i * bkv..][..nk]);
            }

            // --- online softmax: tile-local state, LSE-merged ------
            for i in 0..nq {
                let limit = row_limit(i);
                // columns of this tile visible to row i
                let vis = limit.saturating_sub(k0).min(nk);
                if vis == 0 {
                    continue;
                }
                let srow = &scores[i * bkv..][..nk];
                let (mt, lt) = row_tile_state(srow, v, k0, vis, d, tacc);
                merge_softmax_states(
                    &mut m[i],
                    &mut l[i],
                    &mut acc[i * d..][..d],
                    mt,
                    lt,
                    &tacc[..d],
                );
            }
            k0 += nk;
        }

        // --- final normalize ---------------------------------------
        for i in 0..nq {
            let inv = if l[i] > 0.0 { 1.0 / l[i] } else { 0.0 };
            let orow = &mut oh[(q0 + i) * d..][..d];
            let arow = &acc[i * d..][..d];
            for t in 0..d {
                orow[t] = arow[t] * inv;
            }
        }
        q0 += nq;
    }
}

/// The pre-blocked single-head loop: one [`KvView::row`] call (page
/// division + bounds check) per row.  Kept as the scalar-gather
/// baseline that `benches/hotpath.rs` and the bit-identity property
/// measure the blocked kernel against.  F32 layouts only.
fn flash_head_rowwise(
    qh: &[f32],
    k: &KvView<'_>,
    v: &KvView<'_>,
    oh: &mut [f32],
    g: HeadGeom,
    s: &mut FlashScratch,
) {
    let HeadGeom { sq, skv, d, causal, bq, bkv, scale } = g;
    let (scores, m, l, acc, tacc) =
        (&mut s.scores, &mut s.m, &mut s.l, &mut s.acc, &mut s.tacc);

    let mut q0 = 0;
    while q0 < sq {
        let nq = bq.min(sq - q0);
        m[..nq].fill(f32::NEG_INFINITY);
        l[..nq].fill(0.0);
        acc[..nq * d].fill(0.0);

        let row_limit = |i: usize| -> usize {
            if causal { q0 + i + 1 + skv - sq } else { skv }
        };
        let block_cols = if causal { row_limit(nq - 1).min(skv) } else { skv };

        let mut k0 = 0;
        while k0 < block_cols {
            let nk = bkv.min(block_cols - k0);
            for i in 0..nq {
                let qi = &qh[(q0 + i) * d..][..d];
                let srow = &mut scores[i * bkv..][..nk];
                for (j, sc) in srow.iter_mut().enumerate() {
                    *sc = dot4(qi, k.row(k0 + j, d)) * scale;
                }
            }
            for i in 0..nq {
                let limit = row_limit(i);
                let vis = limit.saturating_sub(k0).min(nk);
                if vis == 0 {
                    continue;
                }
                let srow = &scores[i * bkv..][..nk];
                let mut mt = f32::NEG_INFINITY;
                for &sc in &srow[..vis] {
                    if sc > mt {
                        mt = sc;
                    }
                }
                tacc[..d].fill(0.0);
                let mut lt = 0.0f32;
                for j in 0..vis {
                    let pij = (srow[j] - mt).exp();
                    lt += pij;
                    let vj = v.row(k0 + j, d);
                    for t in 0..d {
                        tacc[t] += pij * vj[t];
                    }
                }
                merge_softmax_states(
                    &mut m[i],
                    &mut l[i],
                    &mut acc[i * d..][..d],
                    mt,
                    lt,
                    &tacc[..d],
                );
            }
            k0 += nk;
        }

        for i in 0..nq {
            let inv = if l[i] > 0.0 { 1.0 / l[i] } else { 0.0 };
            let orow = &mut oh[(q0 + i) * d..][..d];
            let arow = &acc[i * d..][..d];
            for t in 0..d {
                orow[t] = arow[t] * inv;
            }
        }
        q0 += nq;
    }
}

/// FlashAttention2 forward: `out = softmax(q kᵀ·scale [+causal]) v`.
///
/// With `kv_heads < heads` (GQA), query head `h` reads KV head
/// `h / (heads / kv_heads)`.
pub fn flash_attention(q: &[f32], k: &[f32], v: &[f32], out: &mut [f32], p: &FlashParams) {
    let (h, sq, skv, d) = (p.heads, p.seq_q, p.seq_kv, p.head_dim);
    let kvh = p.kv_heads;
    assert!(kvh >= 1 && h % kvh == 0, "kv_heads {kvh} must divide heads {h}");
    assert_eq!(q.len(), h * sq * d, "q shape");
    assert_eq!(k.len(), kvh * skv * d, "k shape");
    assert_eq!(v.len(), kvh * skv * d, "v shape");
    assert_eq!(out.len(), h * sq * d, "out shape");
    let group = p.group_size();
    let geom = HeadGeom::of(p);
    let mut scratch = FlashScratch::new(geom.bq, geom.bkv, d);

    for head in 0..h {
        let kv_head = head / group;
        let qh = &q[head * sq * d..][..sq * d];
        let kview = KvView::Contig(&k[kv_head * skv * d..][..skv * d]);
        let vview = KvView::Contig(&v[kv_head * skv * d..][..skv * d]);
        let oh = &mut out[head * sq * d..][..sq * d];
        flash_head(qh, &kview, &vview, oh, geom, &mut scratch);
    }
}

/// FlashAttention2 forward over [`KvView`] row sources — the paged-KV
/// entry point.  All `p.heads` query heads read the *same* pair of
/// views, so `p.kv_heads` must be 1 (callers with several KV heads run
/// one call per head-group, as `attention::batch` does).
pub fn flash_attention_view(
    q: &[f32],
    k: &KvView<'_>,
    v: &KvView<'_>,
    out: &mut [f32],
    p: &FlashParams,
) {
    let (h, sq, skv, d) = (p.heads, p.seq_q, p.seq_kv, p.head_dim);
    assert_eq!(p.kv_heads, 1, "flash_attention_view is single-KV-head");
    assert_eq!(q.len(), h * sq * d, "q shape");
    assert_eq!(out.len(), h * sq * d, "out shape");
    assert!(k.addressable_rows(d) >= skv, "k view shorter than seq_kv");
    assert!(v.addressable_rows(d) >= skv, "v view shorter than seq_kv");
    let geom = HeadGeom::of(p);
    let mut scratch = FlashScratch::new(geom.bq, geom.bkv, d);

    for head in 0..h {
        let qh = &q[head * sq * d..][..sq * d];
        let oh = &mut out[head * sq * d..][..sq * d];
        flash_head(qh, k, v, oh, geom, &mut scratch);
    }
}

/// [`flash_attention_view`] through the pre-blocked per-row gather
/// ([`KvView::row`] once per KV row) — the scalar baseline the blocked
/// kernel is benched and bit-compared against.  F32 views only (int8
/// views panic: they have no materialized f32 rows).
pub fn flash_attention_view_rowwise(
    q: &[f32],
    k: &KvView<'_>,
    v: &KvView<'_>,
    out: &mut [f32],
    p: &FlashParams,
) {
    let (h, sq, skv, d) = (p.heads, p.seq_q, p.seq_kv, p.head_dim);
    assert_eq!(p.kv_heads, 1, "flash_attention_view_rowwise is single-KV-head");
    assert_eq!(q.len(), h * sq * d, "q shape");
    assert_eq!(out.len(), h * sq * d, "out shape");
    assert!(k.addressable_rows(d) >= skv, "k view shorter than seq_kv");
    assert!(v.addressable_rows(d) >= skv, "v view shorter than seq_kv");
    let geom = HeadGeom::of(p);
    let mut scratch = FlashScratch::new(geom.bq, geom.bkv, d);

    for head in 0..h {
        let qh = &q[head * sq * d..][..sq * d];
        let oh = &mut out[head * sq * d..][..sq * d];
        flash_head_rowwise(qh, k, v, oh, geom, &mut scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::super::standard::{standard_attention, StdParams};
    use super::*;
    use crate::prop_ensure;
    use crate::proptest::check;

    fn run_both(
        h: usize,
        sq: usize,
        skv: usize,
        d: usize,
        causal: bool,
        bq: usize,
        bkv: usize,
        seed: u64,
    ) -> (Vec<f32>, Vec<f32>) {
        // simple deterministic pseudo-random fill
        let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            ((state as f64 / u64::MAX as f64) as f32 - 0.5) * 2.0
        };
        let q: Vec<f32> = (0..h * sq * d).map(|_| next()).collect();
        let k: Vec<f32> = (0..h * skv * d).map(|_| next()).collect();
        let v: Vec<f32> = (0..h * skv * d).map(|_| next()).collect();
        let scale = 1.0 / (d as f32).sqrt();

        let mut flash = vec![0.0; h * sq * d];
        flash_attention(
            &q,
            &k,
            &v,
            &mut flash,
            &FlashParams {
                heads: h,
                kv_heads: h,
                seq_q: sq,
                seq_kv: skv,
                head_dim: d,
                causal,
                block_q: bq,
                block_kv: bkv,
                scale,
            },
        );
        let mut std = vec![0.0; h * sq * d];
        standard_attention(
            &q,
            &k,
            &v,
            &mut std,
            &StdParams { heads: h, seq_q: sq, seq_kv: skv, head_dim: d, causal, scale },
        );
        (flash, std)
    }

    fn max_err(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn matches_standard_noncausal() {
        let (f, s) = run_both(2, 16, 24, 8, false, 4, 8, 1);
        assert!(max_err(&f, &s) < 1e-5);
    }

    #[test]
    fn matches_standard_causal() {
        let (f, s) = run_both(2, 16, 16, 8, true, 4, 8, 2);
        assert!(max_err(&f, &s) < 1e-5);
    }

    #[test]
    fn matches_standard_causal_rect() {
        // decode chunk: 4 new rows over 20 cached
        let (f, s) = run_both(1, 4, 20, 8, true, 2, 8, 3);
        assert!(max_err(&f, &s) < 1e-5);
    }

    #[test]
    fn decode_shape() {
        let (f, s) = run_both(4, 1, 77, 16, false, 1, 16, 4);
        assert!(max_err(&f, &s) < 1e-5);
    }

    #[test]
    fn block_sizes_irrelevant() {
        let (a, _) = run_both(1, 13, 29, 4, false, 3, 5, 9);
        let (b, _) = run_both(1, 13, 29, 4, false, 13, 29, 9);
        assert!(max_err(&a, &b) < 1e-5);
    }

    /// Property: flash == standard for arbitrary shapes/tilings.
    #[test]
    fn prop_flash_equals_standard() {
        check(48, |rng| {
            let h = rng.range(1, 3);
            let sq = rng.range(1, 24);
            let skv = sq + rng.range(0, 24);
            let d = *rng.pick(&[1usize, 4, 8, 16]);
            let causal = rng.bool();
            let bq = rng.range(1, 12);
            let bkv = rng.range(1, 16);
            let seed = rng.next_u64();
            let (f, s) = run_both(h, sq, skv, d, causal, bq, bkv, seed);
            let err = max_err(&f, &s);
            prop_ensure!(
                err < 2e-5,
                "h={h} sq={sq} skv={skv} d={d} causal={causal} \
                 bq={bq} bkv={bkv}: err {err}"
            );
            Ok(())
        });
    }

    /// A paged view over scattered pages must be bit-identical to the
    /// contiguous kernel on the same rows.
    #[test]
    fn view_paged_equals_contig() {
        let (h, skv, d, page_size) = (3usize, 29usize, 8usize, 4usize);
        let mut rng = crate::proptest::Rng::new(5);
        let q = rng.f32_vec(h * d);
        let k = rng.f32_vec(skv * d);
        let v = rng.f32_vec(skv * d);

        // scatter rows into an oversized store through a permuted map
        let nblocks = skv.div_ceil(page_size);
        let npages = nblocks + 2;
        let pages: Vec<u32> = (0..nblocks).map(|b| (npages - 1 - b) as u32).collect();
        let mut kstore = vec![0.0f32; npages * page_size * d];
        let mut vstore = vec![0.0f32; npages * page_size * d];
        for r in 0..skv {
            let p = pages[r / page_size] as usize;
            let at = (p * page_size + r % page_size) * d;
            kstore[at..at + d].copy_from_slice(&k[r * d..][..d]);
            vstore[at..at + d].copy_from_slice(&v[r * d..][..d]);
        }

        let p = FlashParams {
            heads: h,
            kv_heads: 1,
            seq_q: 1,
            seq_kv: skv,
            head_dim: d,
            causal: false,
            block_q: 1,
            block_kv: 7,
            scale: 1.0 / (d as f32).sqrt(),
        };
        let mut contig = vec![0.0; h * d];
        flash_attention(&q, &k, &v, &mut contig, &p);

        let kview = KvView::Paged { store: &kstore, pages: &pages, page_size };
        let vview = KvView::Paged { store: &vstore, pages: &pages, page_size };
        assert_eq!(kview.addressable_rows(d), nblocks * page_size);
        let mut paged = vec![0.0; h * d];
        flash_attention_view(&q, &kview, &vview, &mut paged, &p);
        assert_eq!(contig, paged, "paged gather must not change bits");
    }

    /// A tiered view with blocks split across two stores must be
    /// bit-identical to the contiguous kernel on the same rows.
    #[test]
    fn view_tiered_equals_contig() {
        use crate::coordinator::kv_cache::Tier;
        let (h, skv, d, page_size) = (2usize, 23usize, 8usize, 4usize);
        let mut rng = crate::proptest::Rng::new(6);
        let q = rng.f32_vec(h * d);
        let k = rng.f32_vec(skv * d);
        let v = rng.f32_vec(skv * d);

        // even blocks stay "device", odd blocks go "host"; page ids are
        // per-store and deliberately non-identity
        let nblocks = skv.div_ceil(page_size);
        let tiers: Vec<Tier> = (0..nblocks)
            .map(|b| if b % 2 == 0 { Tier::Device } else { Tier::Host })
            .collect();
        let per_store = nblocks.div_ceil(2) + 1;
        let mut pages = vec![0u32; nblocks];
        let (mut next_dev, mut next_host) = (per_store as u32 - 1, 0u32);
        for b in 0..nblocks {
            match tiers[b] {
                Tier::Device => {
                    pages[b] = next_dev;
                    next_dev -= 1;
                }
                Tier::Host => {
                    pages[b] = next_host;
                    next_host += 1;
                }
            }
        }
        let mut kdev = vec![0.0f32; per_store * page_size * d];
        let mut vdev = kdev.clone();
        let mut khost = kdev.clone();
        let mut vhost = kdev.clone();
        for r in 0..skv {
            let b = r / page_size;
            let at = (pages[b] as usize * page_size + r % page_size) * d;
            let (ks, vs) = match tiers[b] {
                Tier::Device => (&mut kdev, &mut vdev),
                Tier::Host => (&mut khost, &mut vhost),
            };
            ks[at..at + d].copy_from_slice(&k[r * d..][..d]);
            vs[at..at + d].copy_from_slice(&v[r * d..][..d]);
        }

        let p = FlashParams {
            heads: h,
            kv_heads: 1,
            seq_q: 1,
            seq_kv: skv,
            head_dim: d,
            causal: false,
            block_q: 1,
            block_kv: 5,
            scale: 1.0 / (d as f32).sqrt(),
        };
        let mut contig = vec![0.0; h * d];
        flash_attention(&q, &k, &v, &mut contig, &p);

        let kview = KvView::Tiered {
            device_store: &kdev,
            host_store: &khost,
            pages: &pages,
            tiers: &tiers,
            page_size,
        };
        let vview = KvView::Tiered {
            device_store: &vdev,
            host_store: &vhost,
            pages: &pages,
            tiers: &tiers,
            page_size,
        };
        assert_eq!(kview.addressable_rows(d), nblocks * page_size);
        let mut tiered = vec![0.0; h * d];
        flash_attention_view(&q, &kview, &vview, &mut tiered, &p);
        assert_eq!(contig, tiered, "tiered gather must not change bits");
    }

    /// GQA must equal MHA with each KV head repeated `group` times.
    #[test]
    fn gqa_equals_expanded_mha() {
        let (h, kvh, sq, skv, d) = (6usize, 2usize, 5usize, 19usize, 8usize);
        let mut rng = crate::proptest::Rng::new(77);
        let q = rng.f32_vec(h * sq * d);
        let k = rng.f32_vec(kvh * skv * d);
        let v = rng.f32_vec(kvh * skv * d);
        let scale = 1.0 / (d as f32).sqrt();

        let mut gqa = vec![0.0; h * sq * d];
        flash_attention(
            &q,
            &k,
            &v,
            &mut gqa,
            &FlashParams {
                heads: h,
                kv_heads: kvh,
                seq_q: sq,
                seq_kv: skv,
                head_dim: d,
                causal: true,
                block_q: 2,
                block_kv: 7,
                scale,
            },
        );

        // expand KV per query head, run as MHA
        let ke = crate::proptest::expand_kv(&k, h, kvh, skv, d);
        let ve = crate::proptest::expand_kv(&v, h, kvh, skv, d);
        let mut mha = vec![0.0; h * sq * d];
        flash_attention(
            &q,
            &ke,
            &ve,
            &mut mha,
            &FlashParams {
                heads: h,
                kv_heads: h,
                seq_q: sq,
                seq_kv: skv,
                head_dim: d,
                causal: true,
                block_q: 2,
                block_kv: 7,
                scale,
            },
        );
        assert_eq!(gqa, mha, "GQA must be bit-identical to expanded MHA");
    }

    /// Property: the blocked run-walking kernel is bit-identical to the
    /// pre-blocked per-row gather on paged f32 views — the f32-codec
    /// "nothing changed" pin for this PR's inner-loop rewrite.
    #[test]
    fn prop_blocked_equals_rowwise() {
        check(32, |rng| {
            let h = rng.range(1, 3);
            let skv = rng.range(1, 40);
            let d = *rng.pick(&[4usize, 8, 16]);
            let page_size = *rng.pick(&[1usize, 3, 4, 7]);
            let bkv = rng.range(1, 17);
            let mut r = crate::proptest::Rng::new(rng.next_u64());
            let q = r.f32_vec(h * d);
            let k = r.f32_vec(skv * d);
            let v = r.f32_vec(skv * d);
            // scatter into a reverse-permuted paged store
            let nblocks = skv.div_ceil(page_size);
            let npages = nblocks + 1;
            let pages: Vec<u32> = (0..nblocks).map(|b| (npages - 1 - b) as u32).collect();
            let mut kstore = vec![0.0f32; npages * page_size * d];
            let mut vstore = vec![0.0f32; npages * page_size * d];
            for rr in 0..skv {
                let p = pages[rr / page_size] as usize;
                let at = (p * page_size + rr % page_size) * d;
                kstore[at..at + d].copy_from_slice(&k[rr * d..][..d]);
                vstore[at..at + d].copy_from_slice(&v[rr * d..][..d]);
            }
            let p = FlashParams {
                heads: h,
                kv_heads: 1,
                seq_q: 1,
                seq_kv: skv,
                head_dim: d,
                causal: false,
                block_q: 1,
                block_kv: bkv,
                scale: 1.0 / (d as f32).sqrt(),
            };
            let kview = KvView::Paged { store: &kstore, pages: &pages, page_size };
            let vview = KvView::Paged { store: &vstore, pages: &pages, page_size };
            let mut blocked = vec![0.0; h * d];
            flash_attention_view(&q, &kview, &vview, &mut blocked, &p);
            let mut rowwise = vec![0.0; h * d];
            flash_attention_view_rowwise(&q, &kview, &vview, &mut rowwise, &p);
            prop_ensure!(
                blocked == rowwise,
                "blocked gather changed bits: skv={skv} ps={page_size} bkv={bkv} d={d}"
            );
            Ok(())
        });
    }

    /// Int8 pages gathered through the fused-dequant kernel stay within
    /// quantization tolerance of the f32 kernel on the same rows.
    #[test]
    fn int8_view_within_tolerance() {
        use crate::coordinator::kv_cache::{PageCodec, PagePool};
        let (h, skv, d, page_size) = (3usize, 37usize, 16usize, 4usize);
        let mut rng = crate::proptest::Rng::new(9);
        let q = rng.f32_vec(h * d);
        let k = rng.f32_vec(skv * d);
        let v = rng.f32_vec(skv * d);
        let nblocks = skv.div_ceil(page_size);
        let mut pool = PagePool::with_codec(page_size, d, nblocks, PageCodec::Int8);
        let pages: Vec<u32> = (0..nblocks).map(|_| pool.alloc().unwrap()).collect();
        for r in 0..skv {
            pool.write_row(pages[r / page_size], r % page_size, &k[r * d..][..d], &v[r * d..][..d]);
        }
        let p = FlashParams {
            heads: h,
            kv_heads: 1,
            seq_q: 1,
            seq_kv: skv,
            head_dim: d,
            causal: false,
            block_q: 1,
            block_kv: 7,
            scale: 1.0 / (d as f32).sqrt(),
        };
        let mut exact = vec![0.0; h * d];
        flash_attention(&q, &k, &v, &mut exact, &p);
        let kview = KvView::PagedI8 { store: pool.k_quant_store(), pages: &pages, page_size };
        let vview = KvView::PagedI8 { store: pool.v_quant_store(), pages: &pages, page_size };
        assert_eq!(kview.addressable_rows(d), nblocks * page_size);
        let mut quant = vec![0.0; h * d];
        flash_attention_view(&q, &kview, &vview, &mut quant, &p);
        let err = max_err(&quant, &exact);
        assert!(err < 0.05, "int8 fused gather err {err} out of tolerance");
        assert!(err > 0.0, "int8 output suspiciously exact — dequant path not exercised?");
    }

    /// A tiered view whose `pages`/`tiers` lengths disagree must be
    /// caught by the debug assertion (codec-typed views can't silently
    /// skew the addressable range).
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "pages/tiers lengths must agree")]
    fn tiered_pages_tiers_skew_is_caught() {
        let store = [0.0f32; 16];
        let pages = [0u32, 1];
        let tiers = [Tier::Device]; // one entry short
        let view = KvView::Tiered {
            device_store: &store,
            host_store: &store,
            pages: &pages,
            tiers: &tiers,
            page_size: 2,
        };
        let _ = view.addressable_rows(2);
    }

    /// Property: output rows are convex combinations of V rows — within
    /// [min, max] of the visible V per dimension.
    #[test]
    fn prop_output_in_v_hull() {
        check(64, |rng| {
            let skv = rng.range(1, 32);
            let d = *rng.pick(&[2usize, 4, 8]);
            let seed = rng.next_u64();
            let (f, _) = run_both(1, 1, skv, d, false, 1, 8, seed);
            // regenerate v with the same seed stream to find bounds
            let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1);
            let mut next = move || {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                ((state as f64 / u64::MAX as f64) as f32 - 0.5) * 2.0
            };
            let _q: Vec<f32> = (0..d).map(|_| next()).collect();
            let _k: Vec<f32> = (0..skv * d).map(|_| next()).collect();
            let v: Vec<f32> = (0..skv * d).map(|_| next()).collect();
            for t in 0..d {
                let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                for j in 0..skv {
                    lo = lo.min(v[j * d + t]);
                    hi = hi.max(v[j * d + t]);
                }
                prop_ensure!(
                    f[t] >= lo - 1e-4 && f[t] <= hi + 1e-4,
                    "dim {t}: {} not in [{lo}, {hi}]",
                    f[t]
                );
            }
            Ok(())
        });
    }

    /// `merge_softmax_states(state_a, state_b)` must equal the single
    /// flash pass over the concatenated columns **f32 bit-exact** when
    /// each segment is one KV tile — the invariant that lets cascade
    /// decode split at a tile boundary without changing any bit.
    #[test]
    fn prop_merge_equals_single_pass() {
        check(64, |rng| {
            let d = *rng.pick(&[2usize, 4, 8, 16]);
            let len_a = rng.range(1, 24);
            // |b| ≤ |a| so the concat pass tiles exactly as [a | b]
            let len_b = rng.range(1, len_a + 1);
            let scale = 1.0 / (d as f32).sqrt();
            let q = rng.f32_vec(d);
            let ka = rng.f32_vec(len_a * d);
            let va = rng.f32_vec(len_a * d);
            let kb = rng.f32_vec(len_b * d);
            let vb = rng.f32_vec(len_b * d);

            // single pass over [a | b] with block_kv = |a|
            let kcat: Vec<f32> = ka.iter().chain(&kb).copied().collect();
            let vcat: Vec<f32> = va.iter().chain(&vb).copied().collect();
            let mut single = vec![0.0; d];
            flash_attention_view(
                &q,
                &KvView::Contig(&kcat),
                &KvView::Contig(&vcat),
                &mut single,
                &FlashParams {
                    heads: 1,
                    kv_heads: 1,
                    seq_q: 1,
                    seq_kv: len_a + len_b,
                    head_dim: d,
                    causal: false,
                    block_q: 1,
                    block_kv: len_a,
                    scale,
                },
            );

            // tile-local state of each segment, merged by hand
            let mut scores = vec![0.0f32; len_a];
            let mut tacc = vec![0.0f32; d];
            let (mut m, mut l) = (f32::NEG_INFINITY, 0.0f32);
            let mut acc = vec![0.0f32; d];
            for (kseg, vseg, n) in [(&ka, &va, len_a), (&kb, &vb, len_b)] {
                let kv = KvView::Contig(kseg);
                let vv = KvView::Contig(vseg);
                fill_score_tile(&q, &kv, 0, n, d, scale, &mut scores[..n]);
                let (mt, lt) = row_tile_state(&scores[..n], &vv, 0, n, d, &mut tacc);
                merge_softmax_states(&mut m, &mut l, &mut acc, mt, lt, &tacc[..d]);
            }
            let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
            let merged: Vec<f32> = acc.iter().map(|a| a * inv).collect();
            prop_ensure!(
                merged == single,
                "d={d} |a|={len_a} |b|={len_b}: merged state differs from single pass"
            );
            Ok(())
        });
    }

    /// `m == −∞` encodes the empty state: merging it from either side
    /// leaves the other state bit-untouched.
    #[test]
    fn merge_empty_state_is_identity() {
        let (m0, l0, acc0) = (0.75f32, 2.5f32, [0.5f32, -1.25, 3.0]);

        // empty ∪ b == b
        let (mut m, mut l) = (f32::NEG_INFINITY, 0.0f32);
        let mut acc = [0.0f32; 3];
        merge_softmax_states(&mut m, &mut l, &mut acc, m0, l0, &acc0);
        assert_eq!((m, l, acc), (m0, l0, acc0));

        // a ∪ empty == a
        merge_softmax_states(&mut m, &mut l, &mut acc, f32::NEG_INFINITY, 0.0, &[0.0; 3]);
        assert_eq!((m, l, acc), (m0, l0, acc0));
    }

    /// One `run_at` walk: starting at row 0, request runs under the
    /// given per-step caps and check every logical row appears exactly
    /// once, in order, with its expected content.  Rows are
    /// content-addressed (`f32` element = `row * d + t`; `i8` element =
    /// `qval(row, t)`, scale = `row + 0.25`), so a skipped, duplicated
    /// or reordered row cannot go unnoticed.
    fn walk_runs(view: &KvView<'_>, rows: usize, d: usize, caps: &[usize]) -> Result<(), String> {
        let qval = |r: usize, t: usize| (((r * d + t) % 250) as i32 - 125) as i8;
        let mut r = 0usize;
        let mut step = 0usize;
        while r < rows {
            let max_rows = caps[step % caps.len()].min(rows - r);
            step += 1;
            let (run, n) = view.run_at(r, max_rows, d);
            prop_ensure!(n >= 1 && n <= max_rows, "run at {r}: {n} rows for cap {max_rows}");
            match run {
                KvRun::F32(s) => {
                    prop_ensure!(s.len() == n * d, "run at {r}: {} elems for {n} rows", s.len());
                    for jj in 0..n {
                        for t in 0..d {
                            prop_ensure!(
                                s[jj * d + t] == ((r + jj) * d + t) as f32,
                                "row {} content mismatch at dim {t}",
                                r + jj
                            );
                        }
                    }
                }
                KvRun::I8 { q, scales } => {
                    prop_ensure!(q.len() == n * d, "run at {r}: {} elems for {n} rows", q.len());
                    prop_ensure!(scales.len() == n, "run at {r}: {} scales", scales.len());
                    for jj in 0..n {
                        prop_ensure!(
                            scales[jj] == (r + jj) as f32 + 0.25,
                            "row {} scale mismatch",
                            r + jj
                        );
                        for t in 0..d {
                            prop_ensure!(
                                q[jj * d + t] == qval(r + jj, t),
                                "row {} quant content mismatch at dim {t}",
                                r + jj
                            );
                        }
                    }
                }
            }
            r += n;
        }
        prop_ensure!(r == rows, "walk covered {r} of {rows} rows");
        Ok(())
    }

    /// Property: `KvView::run_at` enumerates every logical row exactly
    /// once, in order, under arbitrary run caps, for random block
    /// tables across all view variants (Contig + Paged/Tiered ×
    /// F32/Int8) — the enumeration contract the blocked gather and the
    /// cascade shared/unique split both stand on.
    #[test]
    fn prop_run_at_enumerates_rows_in_order() {
        check(48, |rng| {
            let d = *rng.pick(&[1usize, 2, 4, 8]);
            let page_size = rng.range(1, 8);
            let rows = rng.range(1, 48);
            let nblocks = rows.div_ceil(page_size);
            let npages = nblocks + rng.range(0, 3);
            // random page permutation + random tier per block
            let mut ids: Vec<u32> = (0..npages as u32).collect();
            for i in (1..ids.len()).rev() {
                let j = rng.below(i + 1);
                ids.swap(i, j);
            }
            let pages = &ids[..nblocks];
            let tiers: Vec<Tier> = (0..nblocks)
                .map(|_| if rng.bool() { Tier::Device } else { Tier::Host })
                .collect();
            let caps: Vec<usize> = (0..rows).map(|_| rng.range(1, rows + 1)).collect();
            let qval = |r: usize, t: usize| (((r * d + t) % 250) as i32 - 125) as i8;

            // content-addressed stores: full (single-store variants) and
            // tier-split (tiered variants, same per-store page ids)
            let elems = npages * page_size * d;
            let contig: Vec<f32> = (0..rows * d).map(|e| e as f32).collect();
            let mut full = vec![0.0f32; elems];
            let mut dev = vec![0.0f32; elems];
            let mut host = vec![0.0f32; elems];
            let mut qfull = vec![0i8; elems];
            let mut qdev = vec![0i8; elems];
            let mut qhost = vec![0i8; elems];
            let mut sfull = vec![0.0f32; npages * page_size];
            let mut sdev = vec![0.0f32; npages * page_size];
            let mut shost = vec![0.0f32; npages * page_size];
            for r in 0..rows {
                let b = r / page_size;
                let slot = pages[b] as usize * page_size + r % page_size;
                let (tf, tq, ts) = match tiers[b] {
                    Tier::Device => (&mut dev, &mut qdev, &mut sdev),
                    Tier::Host => (&mut host, &mut qhost, &mut shost),
                };
                for t in 0..d {
                    full[slot * d + t] = (r * d + t) as f32;
                    tf[slot * d + t] = (r * d + t) as f32;
                    qfull[slot * d + t] = qval(r, t);
                    tq[slot * d + t] = qval(r, t);
                }
                sfull[slot] = r as f32 + 0.25;
                ts[slot] = r as f32 + 0.25;
            }

            let views = [
                KvView::Contig(&contig),
                KvView::Paged { store: &full, pages, page_size },
                KvView::Tiered {
                    device_store: &dev,
                    host_store: &host,
                    pages,
                    tiers: &tiers,
                    page_size,
                },
                KvView::PagedI8 {
                    store: QuantStore { q: &qfull, scales: &sfull },
                    pages,
                    page_size,
                },
                KvView::TieredI8 {
                    device_store: QuantStore { q: &qdev, scales: &sdev },
                    host_store: QuantStore { q: &qhost, scales: &shost },
                    pages,
                    tiers: &tiers,
                    page_size,
                },
            ];
            for view in &views {
                walk_runs(view, rows, d, &caps)?;
            }
            Ok(())
        });
    }
}
