//! The tiling-mask generator (§4.1, Figure 3).
//!
//! Replaces the S×S causal `attention_mask` (8 GB at S=64K fp16) with one
//! (2M)×(2M) *M-mask* (M = maximal block size; 512 → 256 KB): every b×b
//! *B-mask* any attention_score block needs, b ≤ M, is a shifted
//! contiguous view of the M-mask.  Mirrors
//! `python/compile/kernels/maskgen.py`; the equivalence with direct
//! computation is property-tested on both sides.
//!
//! Convention: `1` = visible, `0` = masked; causal entry (i, j) visible
//! iff `j <= i`.

/// The (2M)×(2M) master mask.
#[derive(Debug, Clone)]
pub struct MMask {
    m: usize,
    /// Row-major (2M)×(2M), values 0/1.
    data: Vec<u8>,
}

/// Classification of an attention_score block under the causal mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockKind {
    /// All-masked — skip the block entirely (the ~50% Cube saving).
    Zero,
    /// All-visible — skip the `QKᵀ + mask` add (Vector saving).
    Full,
    /// Mixed — apply the B-mask.
    Partial,
}

impl MMask {
    /// Build the M-mask for maximal block size `m` (lower-triangular
    /// ones over (2M)×(2M)).
    pub fn new(m: usize) -> Self {
        assert!(m >= 1, "M must be >= 1");
        let n = 2 * m;
        let mut data = vec![0u8; n * n];
        for i in 0..n {
            for j in 0..=i {
                data[i * n + j] = 1;
            }
        }
        Self { m, data }
    }

    /// Maximal block size M.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Memory held by the generator, bytes.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// The `(row, col)` entry of the master mask.
    fn at(&self, row: usize, col: usize) -> u8 {
        self.data[row * 2 * self.m + col]
    }

    /// The shift at which the b×b view for a block at global offset
    /// (row0, col0) starts: the view's diagonal offset `r - c` must equal
    /// (or causally dominate) `row0 - col0`.
    fn shift(&self, row0: u64, col0: u64, b: usize) -> (usize, usize) {
        let n = 2 * self.m;
        let max0 = n - b; // largest in-bounds start index
        if row0 >= col0 {
            let diag = (row0 - col0) as usize;
            // diag > max0 means fully visible; the clamped view at
            // (max0, 0) is all-ones because max0 >= M >= b.
            (diag.min(max0), 0)
        } else {
            let diag = (col0 - row0) as usize;
            (0, diag.min(max0))
        }
    }

    /// Extract the b×b B-mask for the block at (row0, col0) into `out`
    /// (row-major, length b·b).  Requires `b <= M`.
    pub fn b_mask_into(&self, row0: u64, col0: u64, b: usize, out: &mut [u8]) {
        assert!(b <= self.m, "B-mask size {b} exceeds M={}", self.m);
        assert_eq!(out.len(), b * b, "out buffer");
        let (r, c) = self.shift(row0, col0, b);
        for i in 0..b {
            for j in 0..b {
                out[i * b + j] = self.at(r + i, c + j);
            }
        }
    }

    /// Allocating variant of [`b_mask_into`](Self::b_mask_into).
    pub fn b_mask(&self, row0: u64, col0: u64, b: usize) -> Vec<u8> {
        let mut out = vec![0u8; b * b];
        self.b_mask_into(row0, col0, b, &mut out);
        out
    }
}

/// Direct (non-generator) B-mask computation — the oracle.
pub fn b_mask_direct(row0: u64, col0: u64, b: usize) -> Vec<u8> {
    let mut out = vec![0u8; b * b];
    for i in 0..b {
        for j in 0..b {
            out[i * b + j] = u8::from(col0 + j as u64 <= row0 + i as u64);
        }
    }
    out
}

/// Classify the block at (row0, col0) of size b (§4.1's two special
/// scenarios plus the general one).
pub fn classify_block(row0: u64, col0: u64, b: usize) -> BlockKind {
    let b = b as u64;
    if col0 > row0 + b - 1 {
        BlockKind::Zero
    } else if col0 + b - 1 <= row0 {
        BlockKind::Full
    } else {
        BlockKind::Partial
    }
}

// ---------------------------------------------------------------------
// Chunked-prefill masking
// ---------------------------------------------------------------------
//
// Chunked prefill runs a prompt in slices of rows: chunk rows are
// *relative*, but causal visibility is over *absolute* positions, so a
// chunk starting at absolute position `chunk_start` attends both to all
// KV written by earlier chunks and, triangularly, to its own rows.  The
// helpers below express that shift; composing per-chunk masks over any
// partition reproduces the full causal mask exactly (property-tested),
// which is the correctness contract of `Backend::prefill_chunk`.

/// Visible KV columns of row `r` (chunk-relative) of a prefill chunk
/// whose first row sits at absolute position `chunk_start`: columns
/// `0 ..= chunk_start + r`, i.e. `chunk_start + r + 1` of them.
pub fn chunk_row_visible(chunk_start: usize, r: usize) -> usize {
    chunk_start + r + 1
}

/// Visible KV columns of verify row `t` of a speculative draft–verify
/// pass starting at absolute position `start_pos` — row `t` scores
/// draft token `t` written at position `start_pos + t`, and must see
/// exactly the committed prefix plus the drafts *before* it, never a
/// later draft (a later draft is downstream of this row's own output
/// and would be circular).  That requirement is precisely the
/// chunk-boundary causal mask with `chunk_start = start_pos`:
/// speculative verification is a chunked prefill of not-yet-committed
/// tokens, which is why `Backend::verify_step` reuses the
/// `prefill_chunk` path (and this helper is [`chunk_row_visible`] by
/// another name — the identity is pinned by
/// `prop_verify_mask_is_chunk_mask`).
pub fn verify_row_visible(start_pos: usize, t: usize) -> usize {
    chunk_row_visible(start_pos, t)
}

/// Classify a b×b attention_score block of a chunked-prefill step:
/// block rows start at chunk-relative `row0` in the chunk at
/// `chunk_start`; columns are absolute KV positions from `col0`.
pub fn classify_chunk_block(chunk_start: u64, row0: u64, col0: u64, b: usize) -> BlockKind {
    classify_block(chunk_start + row0, col0, b)
}

/// Extract the B-mask of a chunked-prefill block from the M-mask
/// generator — the shifted-view trick works unchanged because only the
/// *absolute* row offset enters the shift.
pub fn chunk_b_mask(mm: &MMask, chunk_start: u64, row0: u64, col0: u64, b: usize) -> Vec<u8> {
    mm.b_mask(chunk_start + row0, col0, b)
}

/// Count block kinds over the full (S/b)² causal grid — drives the Cube /
/// Vector savings accounting in the Ascend model and Table 2.
pub fn census(seq: u64, b: usize) -> (u64, u64, u64) {
    let nb = (seq + b as u64 - 1) / b as u64;
    let (mut zero, mut full, mut partial) = (0, 0, 0);
    for i in 0..nb {
        for j in 0..nb {
            match classify_block(i * b as u64, j * b as u64, b) {
                BlockKind::Zero => zero += 1,
                BlockKind::Full => full += 1,
                BlockKind::Partial => partial += 1,
            }
        }
    }
    (zero, full, partial)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_ensure;
    use crate::proptest::check;

    #[test]
    fn m_mask_is_lower_triangular() {
        let mm = MMask::new(3);
        assert_eq!(mm.bytes(), 36);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(mm.at(i, j), u8::from(j <= i));
            }
        }
    }

    #[test]
    fn paper_memory_claim() {
        // M=512 → (2·512)² = 1M entries ≈ 256 KB at 2 bits.. the paper
        // quotes 256KB; at 1 byte/entry it's 1 MB — still 4 orders below
        // the 8 GB S=64K mask.
        let mm = MMask::new(512);
        assert_eq!(mm.bytes(), 1024 * 1024);
        let full_mask_bytes: u64 = 64 * 1024 * 64 * 1024 * 2;
        assert_eq!(full_mask_bytes, 8 * 1024 * 1024 * 1024);
        assert!(mm.bytes() as u64 * 8000 < full_mask_bytes);
    }

    #[test]
    fn figure3_exhaustive() {
        // M=3, b=3 as in Figure 3: every block offset reproduces direct.
        let mm = MMask::new(3);
        for row0 in 0..20u64 {
            for col0 in 0..20u64 {
                assert_eq!(
                    mm.b_mask(row0, col0, 3),
                    b_mask_direct(row0, col0, 3),
                    "({row0},{col0})"
                );
            }
        }
    }

    #[test]
    fn classify_special_cases() {
        assert_eq!(classify_block(0, 64, 16), BlockKind::Zero);
        assert_eq!(classify_block(64, 0, 16), BlockKind::Full);
        assert_eq!(classify_block(16, 16, 16), BlockKind::Partial);
        // diagonal-adjacent corner cases: (row0=31, col0=16, b=16) has its
        // last column (31) <= first row (31) → Full exactly at the edge.
        assert_eq!(classify_block(15, 16, 16), BlockKind::Partial);
        assert_eq!(classify_block(30, 16, 16), BlockKind::Partial);
        assert_eq!(classify_block(31, 16, 16), BlockKind::Full);
        assert_eq!(classify_block(32, 16, 16), BlockKind::Full);
    }

    #[test]
    fn census_counts_sum() {
        let (z, f, p) = census(1024, 64);
        let nb = 1024 / 64;
        assert_eq!(z + f + p, nb * nb);
        assert_eq!(p, nb); // diagonal blocks
        assert_eq!(z, nb * (nb - 1) / 2);
        assert_eq!(f, nb * (nb - 1) / 2);
    }

    #[test]
    fn census_zero_fraction_approaches_half() {
        let (z, _, _) = census(16384, 128);
        let nb = 16384 / 128;
        let frac = z as f64 / (nb * nb) as f64;
        assert!(frac > 0.45 && frac < 0.5, "{frac}");
    }

    #[test]
    #[should_panic(expected = "exceeds M")]
    fn b_larger_than_m_panics() {
        MMask::new(4).b_mask(0, 0, 5);
    }

    /// The generator's shifted view equals direct computation for all
    /// offsets/sizes — Figure 3's claim.
    #[test]
    fn prop_shift_equals_direct() {
        check(256, |rng| {
            let row0 = rng.below(4096);
            let col0 = rng.below(4096);
            let b = rng.range(1, 16);
            let m = b + rng.range(0, 16);
            let mm = MMask::new(m);
            prop_ensure!(
                mm.b_mask(row0, col0, b) == b_mask_direct(row0, col0, b),
                "({row0},{col0}) b={b} m={m}"
            );
            Ok(())
        });
    }

    /// Stacking per-chunk visibilities over any random partition of S
    /// rows reproduces the full causal mask — chunk boundaries change
    /// nothing (the `prefill_chunk` correctness contract).
    #[test]
    fn prop_chunked_masks_tile_causal() {
        check(128, |rng| {
            let s = rng.range(1, 48);
            // random partition of [0, s)
            let mut starts = vec![0usize];
            while *starts.last().unwrap() < s {
                let last = *starts.last().unwrap();
                starts.push(last + rng.range(1, s - last + 1));
            }
            for w in starts.windows(2) {
                let (chunk_start, chunk_end) = (w[0], w[1]);
                for r in 0..chunk_end - chunk_start {
                    let vis = chunk_row_visible(chunk_start, r);
                    let abs_row = chunk_start + r;
                    prop_ensure!(
                        vis == abs_row + 1,
                        "s={s} chunk_start={chunk_start} r={r}: vis {vis}"
                    );
                    for c in 0..s {
                        let visible = c < vis;
                        prop_ensure!(
                            visible == (c <= abs_row),
                            "s={s} row {abs_row} col {c}: chunked {visible}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    /// Chunk-block classification and B-mask extraction agree with the
    /// absolute-offset oracle for random chunk offsets.
    #[test]
    fn prop_chunk_blocks_match_absolute() {
        check(128, |rng| {
            let chunk_start = rng.below(1024);
            let row0 = rng.below(64);
            let col0 = rng.below(1024);
            let b = rng.range(1, 12);
            let m = b + rng.range(0, 8);
            let mm = MMask::new(m);
            prop_ensure!(
                classify_chunk_block(chunk_start, row0, col0, b)
                    == classify_block(chunk_start + row0, col0, b),
                "classify ({chunk_start},{row0},{col0}) b={b}"
            );
            prop_ensure!(
                chunk_b_mask(&mm, chunk_start, row0, col0, b)
                    == b_mask_direct(chunk_start + row0, col0, b),
                "b_mask ({chunk_start},{row0},{col0}) b={b} m={m}"
            );
            Ok(())
        });
    }

    /// The draft–verify visibility rule IS the chunk causal mask: row
    /// `t` of a verify pass at `start_pos` sees the committed prefix
    /// plus earlier drafts only — the same columns a chunked-prefill
    /// row at the same absolute position sees — and stepping one
    /// position grows visibility by exactly one column (each verify
    /// row is bit-identical to the vanilla decode step at its
    /// position).
    #[test]
    fn prop_verify_mask_is_chunk_mask() {
        check(128, |rng| {
            let start_pos = rng.below(1024) as usize;
            let k = rng.range(0, 9);
            for t in 0..=k {
                let vis = verify_row_visible(start_pos, t);
                prop_ensure!(
                    vis == chunk_row_visible(start_pos, t),
                    "start={start_pos} t={t}: verify {vis}"
                );
                // the row sees its own position but nothing after it
                prop_ensure!(
                    vis == start_pos + t + 1,
                    "start={start_pos} t={t}: vis {vis}"
                );
                if t > 0 {
                    prop_ensure!(
                        vis == verify_row_visible(start_pos, t - 1) + 1,
                        "start={start_pos} t={t}: rows must grow by one column"
                    );
                }
            }
            Ok(())
        });
    }

    /// Classification agrees with mask content.
    #[test]
    fn prop_classify_matches_content() {
        check(256, |rng| {
            let row0 = rng.below(2048);
            let col0 = rng.below(2048);
            let b = rng.range(1, 24);
            let mask = b_mask_direct(row0, col0, b);
            let ones: usize = mask.iter().map(|&x| x as usize).sum();
            let ok = match classify_block(row0, col0, b) {
                BlockKind::Zero => ones == 0,
                BlockKind::Full => ones == b * b,
                BlockKind::Partial => ones > 0 && ones < b * b,
            };
            prop_ensure!(ok, "({row0},{col0}) b={b}: ones={ones}");
            Ok(())
        });
    }
}
