//! Two-level tile-size planner (§4.1).
//!
//! Chooses the first-level (L1-buffer) and second-level (L0-buffer) block
//! sizes under the Ascend capacity constraints, then scores candidate
//! plans with the pipeline model to pick the latency-optimal one — the
//! planner behind Figure 9's block-size sweep.

use crate::sim::ascend::{AscendSpec, FastAttnOptions, Tiling};
use crate::sim::AttnWorkload;

/// A concrete two-level plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TilePlan {
    /// First-level KV block rows (L1-resident slab).
    pub block1: u64,
    /// Second-level KV block rows (L0-resident sub-tile).
    pub block2: u64,
    /// Q rows per block.
    pub block_q: u64,
    /// Predicted kernel latency under the Ascend model, seconds.
    pub predicted_s: f64,
    /// Bytes of L1 occupied by one slab (K+V).
    pub l1_bytes: u64,
    /// Bytes of L0 occupied by one sub-tile operand pair.
    pub l0_bytes: u64,
}

/// Does a (block1 × head_dim) K slab + V slab (double-buffered) fit L1?
pub fn fits_l1(spec: &AscendSpec, block1: u64, head_dim: u64, elem: u64) -> bool {
    // 2 slabs (K, V) × 2 buffers (double buffering).
    4 * block1 * head_dim * elem <= spec.l1_bytes
}

/// Does a (block_q × block2) sub-tile's operand pair fit L0?
pub fn fits_l0(spec: &AscendSpec, block_q: u64, block2: u64, head_dim: u64, elem: u64) -> bool {
    // A tile (block_q × D) + B tile (block2 × D) in L0A/L0B.
    (block_q + block2) * head_dim * elem <= spec.l0_bytes
}

/// Enumerate feasible plans and return the predicted-latency-optimal one.
pub fn plan(spec: &AscendSpec, w: &AttnWorkload, elem: u64) -> TilePlan {
    let candidates_b1 = [128u64, 256, 512, 1024, 2048];
    let candidates_b2 = [64u64, 128, 256];
    let block_q = 128u64.min(w.seq_q.max(1));

    let mut best: Option<TilePlan> = None;
    for &b1 in &candidates_b1 {
        if !fits_l1(spec, b1, w.head_dim, elem) {
            continue;
        }
        for &b2 in &candidates_b2 {
            if b2 > b1 || b1 % b2 != 0 {
                continue;
            }
            if !fits_l0(spec, block_q, b2, w.head_dim, elem) {
                continue;
            }
            let opts = FastAttnOptions {
                tiling: Tiling::TwoLevel { block1: b1, block2: b2 },
                tiling_mask: true,
                elem_bytes: elem,
            };
            let predicted = spec.fastattn_latency(w, &opts).latency_s;
            let plan = TilePlan {
                block1: b1,
                block2: b2,
                block_q,
                predicted_s: predicted,
                l1_bytes: 4 * b1 * w.head_dim * elem,
                l0_bytes: (block_q + b2) * w.head_dim * elem,
            };
            if best.map_or(true, |b| predicted < b.predicted_s) {
                best = Some(plan);
            }
        }
    }
    best.expect("no feasible tile plan — L0/L1 too small for head_dim")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(s: u64) -> AttnWorkload {
        AttnWorkload::prefill(1, 5, s, 128, true)
    }

    #[test]
    fn plan_is_feasible() {
        let spec = AscendSpec::default();
        let p = plan(&spec, &w(8192), 2);
        assert!(fits_l1(&spec, p.block1, 128, 2));
        assert!(fits_l0(&spec, p.block_q, p.block2, 128, 2));
        assert_eq!(p.block1 % p.block2, 0);
    }

    #[test]
    fn long_seq_prefers_large_first_level() {
        // Fig 9: at S >= 4K, larger first-level blocks win.
        let spec = AscendSpec::default();
        let p = plan(&spec, &w(16384), 2);
        assert!(p.block1 >= 512, "block1 = {}", p.block1);
        assert!(p.block2 < p.block1);
    }

    #[test]
    fn plan_beats_bs128_baseline() {
        // The planner should beat the BS=128 unified-ish baseline.
        let spec = AscendSpec::default();
        let workload = w(8192);
        let p = plan(&spec, &workload, 2);
        let baseline = spec
            .fastattn_latency(
                &workload,
                &FastAttnOptions {
                    tiling: Tiling::TwoLevel { block1: 128, block2: 128 },
                    tiling_mask: true,
                    elem_bytes: 2,
                },
            )
            .latency_s;
        assert!(p.predicted_s <= baseline);
    }

    #[test]
    fn l1_capacity_respected() {
        let spec = AscendSpec::default();
        // 1 MiB L1, D=128, fp16: 4·b1·128·2 <= 1 MiB → b1 <= 1024.
        assert!(fits_l1(&spec, 1024, 128, 2));
        assert!(!fits_l1(&spec, 2048, 128, 2));
    }

    #[test]
    #[should_panic(expected = "no feasible tile plan")]
    fn impossible_head_dim_panics() {
        let spec = AscendSpec { l0_bytes: 64, l1_bytes: 128, ..Default::default() };
        plan(&spec, &w(1024), 2);
    }
}
