//! Batched parallel decode attention — the serving hot path fused across
//! an entire decode batch.
//!
//! The seed kernel ([`flash`](super::flash)) runs one sequence, one head
//! at a time on a single thread, and the engine used to call it
//! per-sequence in a loop.  FlashAttention-2 gets its wins from better
//! work partitioning across heads and sequences (Dao, 2023); serving
//! engines like FlashInfer extend that to whole batches with
//! head-group-aware scheduling.  This module does the same for the host
//! decode path:
//!
//! * every `(sequence, query-head)` pair of a decode batch becomes one
//!   item of a flat work queue;
//! * a [`WorkPool`] splits the queue into per-worker ranges, weighted by
//!   each item's KV length, and runs them on scoped threads
//!   (`std::thread::scope` — workers borrow the batch in place, no
//!   copies, and are joined before the call returns, so the engine API
//!   stays synchronous and deterministic);
//! * grouped-query attention (GQA) is native: `kv_heads ≤ heads`, query
//!   head `h` reads KV head `h / (heads / kv_heads)` directly from the
//!   cache layout — KV is never materialized per query head.
//!
//! Every item is computed by the same single-head FlashAttention2 kernel
//! regardless of the thread count, so results are **bit-identical**
//! between `threads = 1` (the sequential fallback, equivalent to the
//! seed's per-sequence loop) and any `threads = N`.

use crate::coordinator::kv_cache::{QuantStore, Tier};

use super::flash::{flash_attention_view, FlashParams, KvView};

/// Parallelism knobs for the batched attention path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads; `1` selects the sequential in-place path (no
    /// spawns), which is bit-identical to the parallel one.
    pub threads: usize,
    /// Minimum work (KV rows) per worker: batches with less total work
    /// than `threads * min_work_per_thread` use fewer workers, so tiny
    /// batches never pay spawn overhead.  `0` disables the floor.
    pub min_work_per_thread: usize,
}

impl ParallelConfig {
    /// The sequential fallback (`threads = 1`).
    pub fn sequential() -> Self {
        Self { threads: 1, min_work_per_thread: 0 }
    }
}

impl Default for ParallelConfig {
    fn default() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        // ~4K KV rows ≈ a few hundred µs of streaming per worker — well
        // above scoped-spawn cost (~tens of µs).
        Self { threads, min_work_per_thread: 4096 }
    }
}

/// A reusable pool policy executing cost-weighted item ranges on scoped
/// threads.  The pool object carries the sizing policy across calls;
/// workers are scoped to each dispatch so they can borrow the batch
/// in place and the caller never observes a thread.
#[derive(Debug, Clone, Copy)]
pub struct WorkPool {
    cfg: ParallelConfig,
}

impl WorkPool {
    pub fn new(cfg: ParallelConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> ParallelConfig {
        self.cfg
    }

    /// Workers to use for `items` items totalling `total_cost` work.
    fn effective_workers(&self, total_cost: usize, items: usize) -> usize {
        let t = self.cfg.threads.max(1);
        if t == 1 || items <= 1 {
            return 1;
        }
        let by_work = if self.cfg.min_work_per_thread == 0 {
            t
        } else {
            (total_cost / self.cfg.min_work_per_thread).max(1)
        };
        t.min(by_work).min(items)
    }

    /// Run `f(item_index, item_output)` for every item, in parallel over
    /// cost-balanced contiguous ranges.  `out` is `items × item_elems`
    /// flat; each item owns its disjoint `item_elems` output chunk.
    /// Results are identical for any worker count (items are
    /// independent), and `threads = 1` runs inline with zero spawns.
    pub fn run_items<F>(&self, costs: &[usize], out: &mut [f32], item_elems: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let n = costs.len();
        assert!(item_elems > 0, "item_elems must be positive");
        assert_eq!(out.len(), n * item_elems, "out shape");
        if n == 0 {
            return;
        }
        let total: usize = costs.iter().sum();
        let workers = self.effective_workers(total, n);
        if workers <= 1 {
            for (i, chunk) in out.chunks_mut(item_elems).enumerate() {
                f(i, chunk);
            }
            return;
        }

        let ranges = partition_by_cost(costs, workers);
        let fref = &f;
        std::thread::scope(|scope| {
            let mut rest = out;
            for &(lo, hi) in &ranges {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * item_elems);
                rest = tail;
                scope.spawn(move || {
                    for (j, item_out) in chunk.chunks_mut(item_elems).enumerate() {
                        fref(lo + j, item_out);
                    }
                });
            }
        });
    }
}

/// Split items into ≤ `parts` contiguous ranges of near-equal total cost
/// (each range non-empty; assumes every cost ≥ 1).
///
/// A boundary closes *before* the item whose inclusion would overshoot
/// the proportional target by more than stopping short undershoots it —
/// so a dominant-cost item at the tail ends up alone in its range
/// instead of swallowing every cheaper item queued ahead of it.
fn partition_by_cost(costs: &[usize], parts: usize) -> Vec<(usize, usize)> {
    let n = costs.len();
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let total: usize = costs.iter().sum();
    if parts == 1 || total == 0 {
        return vec![(0, n)];
    }
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize; // cost of the open range
    let mut done = 0usize; // cost of the closed ranges
    for (i, &c) in costs.iter().enumerate() {
        let k = ranges.len() + 1; // index of the boundary being sought
        if k < parts && i > start {
            // ideal cumulative cost after k ranges, rounded
            let target = (total * k + parts / 2) / parts;
            let without = done + acc;
            let with = without + c;
            if with > target && with - target >= target.saturating_sub(without) {
                ranges.push((start, i));
                done += acc;
                acc = 0;
                start = i;
            }
        }
        acc += c;
    }
    ranges.push((start, n));
    ranges
}

/// Shape of one batched decode-attention call (shared by all sequences).
#[derive(Debug, Clone, Copy)]
pub struct BatchShape {
    pub heads: usize,
    /// KV heads (GQA): must divide `heads`.
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Allocated KV rows per head in the cache layout (`max_seq`); each
    /// sequence's valid prefix is its own `kv_len`.
    pub kv_stride: usize,
    /// KV rows per tile of the inner flash kernel.
    pub block_kv: usize,
    pub scale: f32,
}

impl BatchShape {
    pub fn new(heads: usize, kv_heads: usize, head_dim: usize, kv_stride: usize) -> Self {
        Self {
            heads,
            kv_heads,
            head_dim,
            kv_stride,
            block_kv: 128,
            scale: 1.0 / (head_dim as f32).sqrt(),
        }
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.heads / self.kv_heads
    }
}

/// Where one sequence's K/V rows live: contiguous cache planes, the
/// paged pool behind a block table, or the *tiered* paged pool whose
/// blocks are split between a device store and a host store (cold-page
/// offload).  All layouts stream identical rows through [`KvView`], so
/// they are bit-identical.
#[derive(Debug, Clone, Copy)]
pub enum SeqKv<'a> {
    /// `[kv_heads, kv_stride, head_dim]` planes (the packed engine wire
    /// format).
    Contig { k: &'a [f32], v: &'a [f32] },
    /// Rows gathered through a page table: `pages` is `[kv_heads,
    /// max_blocks]` page ids into `[num_pages, page_size, head_dim]`
    /// stores (see `coordinator::kv_cache::{PagePool, BlockTable}`).
    Paged {
        k_store: &'a [f32],
        v_store: &'a [f32],
        pages: &'a [u32],
        max_blocks: usize,
        page_size: usize,
    },
    /// Rows gathered across both tiers of the tiered paged cache:
    /// `tiers` (parallel to `pages`, `[kv_heads, max_blocks]`) says
    /// which store each block's page id indexes (see
    /// `coordinator::kv_cache::TieredPagePool`).
    Tiered {
        k_device: &'a [f32],
        v_device: &'a [f32],
        k_host: &'a [f32],
        v_host: &'a [f32],
        pages: &'a [u32],
        tiers: &'a [Tier],
        max_blocks: usize,
        page_size: usize,
    },
    /// `Paged` over int8 stores with per-row scale side-channels (the
    /// [`PageCodec::Int8`](crate::coordinator::kv_cache::PageCodec)
    /// pool layout) — rows dequantize fused inside the kernel.
    PagedI8 {
        k: QuantStore<'a>,
        v: QuantStore<'a>,
        pages: &'a [u32],
        max_blocks: usize,
        page_size: usize,
    },
    /// `Tiered` over int8 stores, one [`QuantStore`] per tier and side.
    TieredI8 {
        k_device: QuantStore<'a>,
        v_device: QuantStore<'a>,
        k_host: QuantStore<'a>,
        v_host: QuantStore<'a>,
        pages: &'a [u32],
        tiers: &'a [Tier],
        max_blocks: usize,
        page_size: usize,
    },
}

impl<'a> SeqKv<'a> {
    /// (K, V) row views of KV head `g`.  `kv_stride` is the contiguous
    /// row stride (ignored by the paged layouts).
    pub fn head(&self, g: usize, d: usize, kv_stride: usize) -> (KvView<'a>, KvView<'a>) {
        match *self {
            SeqKv::Contig { k, v } => {
                let plane = kv_stride * d;
                (
                    KvView::Contig(&k[g * plane..][..plane]),
                    KvView::Contig(&v[g * plane..][..plane]),
                )
            }
            SeqKv::Paged { k_store, v_store, pages, max_blocks, page_size } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                (
                    KvView::Paged { store: k_store, pages: p, page_size },
                    KvView::Paged { store: v_store, pages: p, page_size },
                )
            }
            SeqKv::Tiered {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                let t = &tiers[g * max_blocks..][..max_blocks];
                (
                    KvView::Tiered {
                        device_store: k_device,
                        host_store: k_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                    KvView::Tiered {
                        device_store: v_device,
                        host_store: v_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                )
            }
            SeqKv::PagedI8 { k, v, pages, max_blocks, page_size } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                (
                    KvView::PagedI8 { store: k, pages: p, page_size },
                    KvView::PagedI8 { store: v, pages: p, page_size },
                )
            }
            SeqKv::TieredI8 {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                let t = &tiers[g * max_blocks..][..max_blocks];
                (
                    KvView::TieredI8 {
                        device_store: k_device,
                        host_store: k_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                    KvView::TieredI8 {
                        device_store: v_device,
                        host_store: v_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                )
            }
        }
    }
}

/// One sequence's slice of a decode batch.
///
/// `q` is `[heads, head_dim]` (the one new token's query rows); `kv`
/// names the sequence's K/V rows of which the first `kv_len` per KV
/// head are valid.
#[derive(Debug, Clone, Copy)]
pub struct SeqAttn<'a> {
    pub q: &'a [f32],
    pub kv: SeqKv<'a>,
    pub kv_len: usize,
}

impl<'a> SeqAttn<'a> {
    /// A sequence over contiguous `[kv_heads, kv_stride, head_dim]`
    /// cache planes (the pre-paging layout).
    pub fn contig(q: &'a [f32], k: &'a [f32], v: &'a [f32], kv_len: usize) -> Self {
        Self { q, kv: SeqKv::Contig { k, v }, kv_len }
    }
}

/// Fused decode attention over a whole batch: all sequences × all query
/// heads as one flat work queue, executed on `pool`.
///
/// `out` is `[seqs, heads, head_dim]` flat.  Bit-identical for any
/// `ParallelConfig` and for contiguous-vs-paged KV (see module docs).
pub fn batch_decode_attention(
    shape: &BatchShape,
    seqs: &[SeqAttn<'_>],
    out: &mut [f32],
    pool: &WorkPool,
) {
    let (h, kvh, d) = (shape.heads, shape.kv_heads, shape.head_dim);
    assert!(kvh >= 1 && h % kvh == 0, "kv_heads {kvh} must divide heads {h}");
    assert_eq!(out.len(), seqs.len() * h * d, "out shape");
    let group = shape.group_size();
    let plane = shape.kv_stride * d;
    for (i, s) in seqs.iter().enumerate() {
        assert_eq!(s.q.len(), h * d, "seq {i} q shape");
        assert!(s.kv_len <= shape.kv_stride, "seq {i} kv_len > kv_stride");
        match s.kv {
            SeqKv::Contig { k, v } => {
                assert_eq!(k.len(), kvh * plane, "seq {i} k shape");
                assert_eq!(v.len(), kvh * plane, "seq {i} v shape");
            }
            SeqKv::Paged { k_store, v_store, pages, max_blocks, page_size } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(k_store.len(), v_store.len(), "seq {i} store shapes");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    for &p in &pages[g * max_blocks..][..used] {
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= k_store.len(), "seq {i} page {p} out of store");
                    }
                }
            }
            SeqKv::Tiered {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(tiers.len(), pages.len(), "seq {i} tier tags shape");
                assert_eq!(k_device.len(), v_device.len(), "seq {i} device store shapes");
                assert_eq!(k_host.len(), v_host.len(), "seq {i} host store shapes");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    let ps = &pages[g * max_blocks..][..used];
                    let ts = &tiers[g * max_blocks..][..used];
                    for (&p, &t) in ps.iter().zip(ts) {
                        let store_len = match t {
                            Tier::Device => k_device.len(),
                            Tier::Host => k_host.len(),
                        };
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= store_len, "seq {i} page {p} out of {t:?} store");
                    }
                }
            }
            SeqKv::PagedI8 { k, v, pages, max_blocks, page_size } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(k.q.len(), v.q.len(), "seq {i} store shapes");
                assert_eq!(k.q.len(), k.scales.len() * d, "seq {i} k scale side-channel");
                assert_eq!(v.q.len(), v.scales.len() * d, "seq {i} v scale side-channel");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    for &p in &pages[g * max_blocks..][..used] {
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= k.q.len(), "seq {i} page {p} out of store");
                    }
                }
            }
            SeqKv::TieredI8 {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(tiers.len(), pages.len(), "seq {i} tier tags shape");
                assert_eq!(k_device.q.len(), v_device.q.len(), "seq {i} device store shapes");
                assert_eq!(k_host.q.len(), v_host.q.len(), "seq {i} host store shapes");
                assert_eq!(
                    k_device.q.len(),
                    k_device.scales.len() * d,
                    "seq {i} device scale side-channel"
                );
                assert_eq!(
                    k_host.q.len(),
                    k_host.scales.len() * d,
                    "seq {i} host scale side-channel"
                );
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    let ps = &pages[g * max_blocks..][..used];
                    let ts = &tiers[g * max_blocks..][..used];
                    for (&p, &t) in ps.iter().zip(ts) {
                        let store_len = match t {
                            Tier::Device => k_device.q.len(),
                            Tier::Host => k_host.q.len(),
                        };
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= store_len, "seq {i} page {p} out of {t:?} store");
                    }
                }
            }
        }
    }

    // cost model: one item streams kv_len KV rows (+1 keeps zero-length
    // sequences schedulable).
    let costs: Vec<usize> = seqs
        .iter()
        .flat_map(|s| std::iter::repeat(s.kv_len + 1).take(h))
        .collect();

    pool.run_items(&costs, out, d, |item, item_out| {
        let (si, head) = (item / h, item % h);
        let s = &seqs[si];
        let g = head / group;
        let kv = s.kv_len;
        let p = FlashParams {
            heads: 1,
            kv_heads: 1,
            seq_q: 1,
            seq_kv: kv,
            head_dim: d,
            causal: false,
            block_q: 1,
            block_kv: shape.block_kv,
            scale: shape.scale,
        };
        let qh = &s.q[head * d..][..d];
        let (kview, vview) = s.kv.head(g, d, shape.kv_stride);
        flash_attention_view(qh, &kview, &vview, item_out, &p);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::flash::flash_attention;
    use crate::proptest::Rng;

    /// Reference: per-sequence GQA flash over the valid prefix.
    fn reference(shape: &BatchShape, seqs: &[SeqAttn<'_>]) -> Vec<f32> {
        let (h, kvh, d) = (shape.heads, shape.kv_heads, shape.head_dim);
        let mut out = vec![0.0f32; seqs.len() * h * d];
        for (i, s) in seqs.iter().enumerate() {
            let SeqKv::Contig { k: sk, v: sv } = s.kv else {
                panic!("reference expects contiguous KV");
            };
            // compact the valid prefix of each KV head into [kvh, kv, d]
            let kv = s.kv_len;
            let mut k = Vec::with_capacity(kvh * kv * d);
            let mut v = Vec::with_capacity(kvh * kv * d);
            for g in 0..kvh {
                k.extend_from_slice(&sk[g * shape.kv_stride * d..][..kv * d]);
                v.extend_from_slice(&sv[g * shape.kv_stride * d..][..kv * d]);
            }
            let p = FlashParams {
                heads: h,
                kv_heads: kvh,
                seq_q: 1,
                seq_kv: kv,
                head_dim: d,
                causal: false,
                block_q: 1,
                block_kv: shape.block_kv,
                scale: shape.scale,
            };
            flash_attention(s.q, &k, &v, &mut out[i * h * d..][..h * d], &p);
        }
        out
    }

    struct Batch {
        shape: BatchShape,
        q: Vec<Vec<f32>>,
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
        lens: Vec<usize>,
    }

    impl Batch {
        fn random(rng: &mut Rng, nseq: usize, h: usize, kvh: usize, d: usize, stride: usize) -> Self {
            let shape = BatchShape::new(h, kvh, d, stride);
            let mut q = Vec::new();
            let mut k = Vec::new();
            let mut v = Vec::new();
            let mut lens = Vec::new();
            for _ in 0..nseq {
                q.push(rng.f32_vec(h * d));
                k.push(rng.f32_vec(kvh * stride * d));
                v.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
            }
            Self { shape, q, k, v, lens }
        }

        fn seqs(&self) -> Vec<SeqAttn<'_>> {
            (0..self.q.len())
                .map(|i| SeqAttn::contig(&self.q[i], &self.k[i], &self.v[i], self.lens[i]))
                .collect()
        }

        /// The same batch with every sequence's rows scattered into a
        /// shared paged store (per-seq tables, shuffled page order).
        fn paged(&self) -> PagedBatch {
            let (kvh, d, stride) = (self.shape.kv_heads, self.shape.head_dim, self.shape.kv_stride);
            let page_size = 3;
            let max_blocks = stride.div_ceil(page_size);
            let pages_per_seq = kvh * max_blocks;
            let npages = pages_per_seq * self.q.len();
            let mut k_store = vec![0.0f32; npages * page_size * d];
            let mut v_store = vec![0.0f32; npages * page_size * d];
            let mut tables = Vec::new();
            for i in 0..self.q.len() {
                // reversed page order scatters blocks away from identity
                let base = i * pages_per_seq;
                let pages: Vec<u32> = (0..pages_per_seq)
                    .map(|j| (base + pages_per_seq - 1 - j) as u32)
                    .collect();
                for g in 0..kvh {
                    for r in 0..stride {
                        let p = pages[g * max_blocks + r / page_size] as usize;
                        let at = (p * page_size + r % page_size) * d;
                        let src = g * stride * d + r * d;
                        k_store[at..at + d].copy_from_slice(&self.k[i][src..src + d]);
                        v_store[at..at + d].copy_from_slice(&self.v[i][src..src + d]);
                    }
                }
                tables.push(pages);
            }
            PagedBatch { k_store, v_store, tables, max_blocks, page_size }
        }
    }

    struct PagedBatch {
        k_store: Vec<f32>,
        v_store: Vec<f32>,
        tables: Vec<Vec<u32>>,
        max_blocks: usize,
        page_size: usize,
    }

    impl PagedBatch {
        fn seqs<'a>(&'a self, b: &'a Batch) -> Vec<SeqAttn<'a>> {
            (0..b.q.len())
                .map(|i| SeqAttn {
                    q: &b.q[i],
                    kv: SeqKv::Paged {
                        k_store: &self.k_store,
                        v_store: &self.v_store,
                        pages: &self.tables[i],
                        max_blocks: self.max_blocks,
                        page_size: self.page_size,
                    },
                    kv_len: b.lens[i],
                })
                .collect()
        }
    }

    #[test]
    fn matches_per_sequence_flash_mha() {
        let mut rng = Rng::new(11);
        let b = Batch::random(&mut rng, 5, 4, 4, 8, 24);
        let seqs = b.seqs();
        let mut out = vec![0.0; seqs.len() * 4 * 8];
        let pool = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn matches_per_sequence_flash_gqa() {
        let mut rng = Rng::new(12);
        let b = Batch::random(&mut rng, 6, 8, 2, 16, 33);
        let seqs = b.seqs();
        let mut out = vec![0.0; seqs.len() * 8 * 16];
        let pool = WorkPool::new(ParallelConfig { threads: 3, min_work_per_thread: 0 });
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn threads_do_not_change_bits() {
        let mut rng = Rng::new(13);
        let b = Batch::random(&mut rng, 9, 6, 3, 8, 40);
        let seqs = b.seqs();
        let n = seqs.len() * 6 * 8;
        let mut seq_out = vec![0.0; n];
        batch_decode_attention(
            &b.shape,
            &seqs,
            &mut seq_out,
            &WorkPool::new(ParallelConfig::sequential()),
        );
        for threads in [2, 4, 7] {
            let mut par_out = vec![0.0; n];
            let pool =
                WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            batch_decode_attention(&b.shape, &seqs, &mut par_out, &pool);
            assert_eq!(seq_out, par_out, "threads={threads}");
        }
    }

    #[test]
    fn empty_batch_and_empty_kv_are_safe() {
        let shape = BatchShape::new(2, 2, 4, 8);
        let pool = WorkPool::new(ParallelConfig::default());
        let mut out: Vec<f32> = Vec::new();
        batch_decode_attention(&shape, &[], &mut out, &pool);

        // kv_len = 0 → zero output rows
        let q = vec![1.0f32; 2 * 4];
        let k = vec![1.0f32; 2 * 8 * 4];
        let v = vec![1.0f32; 2 * 8 * 4];
        let seqs = [SeqAttn::contig(&q, &k, &v, 0)];
        let mut out = vec![9.0f32; 2 * 4];
        batch_decode_attention(&shape, &seqs, &mut out, &pool);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_gather_is_bit_identical_to_contig() {
        let mut rng = Rng::new(21);
        for threads in [1usize, 4] {
            let b = Batch::random(&mut rng, 7, 6, 3, 8, 26);
            let contig = b.seqs();
            let pb = b.paged();
            let paged = pb.seqs(&b);
            let n = contig.len() * 6 * 8;
            let pool = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&b.shape, &contig, &mut out_c, &pool);
            let mut out_p = vec![0.0; n];
            batch_decode_attention(&b.shape, &paged, &mut out_p, &pool);
            assert_eq!(out_c, out_p, "threads={threads}");
        }
    }

    #[test]
    fn tiered_gather_is_bit_identical_to_contig() {
        use crate::coordinator::kv_cache::{BlockTable, CacheShape, PcieLink, TieredPagePool};
        let mut rng = Rng::new(22);
        for threads in [1usize, 4] {
            let b = Batch::random(&mut rng, 5, 6, 3, 8, 26);
            let (kvh, d, stride) = (3usize, 8usize, 26usize);
            let page_size = 4;
            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let mut pools = TieredPagePool::new(
                page_size,
                d,
                5 * kvh * max_blocks,
                5 * kvh * max_blocks,
                PcieLink::default(),
            );
            // fill per-seq tables on device, then migrate every other
            // block to the host tier
            let mut tables = Vec::new();
            for i in 0..5 {
                let mut t = BlockTable::new(cache, page_size);
                t.ensure_capacity(b.lens[i], pools.device_mut()).unwrap();
                for g in 0..kvh {
                    for r in 0..b.lens[i] {
                        let (tier, page, slot) = t.locate_tiered(0, g, r);
                        let src = g * stride * d + r * d;
                        pools.write_row(
                            tier,
                            page,
                            slot,
                            &b.k[i][src..src + d],
                            &b.v[i][src..src + d],
                        );
                    }
                }
                for blk in (0..t.blocks()).step_by(2) {
                    t.migrate_block_to_host(blk, &mut pools).unwrap();
                }
                tables.push(t);
            }
            let tiered: Vec<SeqAttn<'_>> = (0..5)
                .map(|i| SeqAttn {
                    q: &b.q[i],
                    kv: SeqKv::Tiered {
                        k_device: pools.device().k_store(),
                        v_device: pools.device().v_store(),
                        k_host: pools.host().k_store(),
                        v_host: pools.host().v_store(),
                        pages: tables[i].layer_pages(0),
                        tiers: tables[i].layer_tiers(0),
                        max_blocks: tables[i].max_blocks(),
                        page_size,
                    },
                    kv_len: b.lens[i],
                })
                .collect();
            let contig = b.seqs();
            let n = 5 * 6 * 8;
            let pool = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&b.shape, &contig, &mut out_c, &pool);
            let mut out_t = vec![0.0; n];
            batch_decode_attention(&b.shape, &tiered, &mut out_t, &pool);
            assert_eq!(out_c, out_t, "threads={threads}");
        }
    }

    /// The same rows quantized once and gathered through the two int8
    /// layouts must agree bit-for-bit (single-store vs tiered with
    /// migrated blocks), and stay within quantization tolerance of the
    /// exact f32 batch decode.
    #[test]
    fn int8_tiered_gather_matches_int8_paged_and_f32_within_tol() {
        use crate::coordinator::kv_cache::{
            BlockTable, CacheShape, PageCodec, PagePool, PcieLink, TieredPagePool,
        };
        let mut rng = Rng::new(23);
        let b = Batch::random(&mut rng, 4, 6, 3, 8, 26);
        let (kvh, d, stride) = (3usize, 8usize, 26usize);
        let page_size = 4;
        let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
        let max_blocks = stride.div_ceil(page_size);

        // (a) single-store int8 pool
        let mut pool =
            PagePool::with_codec(page_size, d, 4 * kvh * max_blocks, PageCodec::Int8);
        let mut ptables = Vec::new();
        for i in 0..4 {
            let mut t = BlockTable::new(cache, page_size);
            t.ensure_capacity(b.lens[i], &mut pool).unwrap();
            for g in 0..kvh {
                for r in 0..b.lens[i] {
                    let (page, slot) = t.locate(0, g, r);
                    let src = g * stride * d + r * d;
                    pool.write_row(page, slot, &b.k[i][src..src + d], &b.v[i][src..src + d]);
                }
            }
            ptables.push(t);
        }
        let paged: Vec<SeqAttn<'_>> = (0..4)
            .map(|i| SeqAttn {
                q: &b.q[i],
                kv: SeqKv::PagedI8 {
                    k: pool.k_quant_store(),
                    v: pool.v_quant_store(),
                    pages: ptables[i].layer_pages(0),
                    max_blocks: ptables[i].max_blocks(),
                    page_size,
                },
                kv_len: b.lens[i],
            })
            .collect();

        // (b) tiered int8 pools, alternate blocks migrated to host
        let mut pools = TieredPagePool::new_with_codec(
            page_size,
            d,
            4 * kvh * max_blocks,
            4 * kvh * max_blocks,
            PcieLink::default(),
            PageCodec::Int8,
        );
        let mut tables = Vec::new();
        for i in 0..4 {
            let mut t = BlockTable::new(cache, page_size);
            t.ensure_capacity(b.lens[i], pools.device_mut()).unwrap();
            for g in 0..kvh {
                for r in 0..b.lens[i] {
                    let (tier, page, slot) = t.locate_tiered(0, g, r);
                    let src = g * stride * d + r * d;
                    pools.write_row(tier, page, slot, &b.k[i][src..src + d], &b.v[i][src..src + d]);
                }
            }
            for blk in (0..t.blocks()).step_by(2) {
                t.migrate_block_to_host(blk, &mut pools).unwrap();
            }
            tables.push(t);
        }
        let tiered: Vec<SeqAttn<'_>> = (0..4)
            .map(|i| SeqAttn {
                q: &b.q[i],
                kv: SeqKv::TieredI8 {
                    k_device: pools.device().k_quant_store(),
                    v_device: pools.device().v_quant_store(),
                    k_host: pools.host().k_quant_store(),
                    v_host: pools.host().v_quant_store(),
                    pages: tables[i].layer_pages(0),
                    tiers: tables[i].layer_tiers(0),
                    max_blocks: tables[i].max_blocks(),
                    page_size,
                },
                kv_len: b.lens[i],
            })
            .collect();

        let n = 4 * 6 * 8;
        let wp = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        let mut out_p = vec![0.0; n];
        batch_decode_attention(&b.shape, &paged, &mut out_p, &wp);
        let mut out_t = vec![0.0; n];
        batch_decode_attention(&b.shape, &tiered, &mut out_t, &wp);
        assert_eq!(out_p, out_t, "tiered int8 must be bit-identical to paged int8");

        let contig = b.seqs();
        let mut out_c = vec![0.0; n];
        batch_decode_attention(&b.shape, &contig, &mut out_c, &wp);
        let err =
            out_c.iter().zip(&out_p).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 0.05, "int8 batch decode err {err} out of tolerance");
    }

    #[test]
    fn min_work_floor_collapses_to_sequential() {
        // total work far below the floor → one worker regardless of
        // `threads`; output must still be complete.
        let mut rng = Rng::new(14);
        let b = Batch::random(&mut rng, 2, 2, 1, 4, 6);
        let seqs = b.seqs();
        let pool =
            WorkPool::new(ParallelConfig { threads: 8, min_work_per_thread: 1 << 20 });
        assert_eq!(pool.effective_workers(10, 4), 1);
        let mut out = vec![0.0; seqs.len() * 2 * 4];
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn partition_covers_all_items_in_order() {
        for (costs, parts) in [
            (vec![1usize; 10], 3usize),
            (vec![100, 1, 1, 1], 4),
            (vec![1, 1, 1, 100], 4),
            (vec![5], 4),
            (vec![3, 3, 3, 3, 3, 3, 3, 3], 8),
        ] {
            let ranges = partition_by_cost(&costs, parts);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts.min(costs.len()));
            let mut next = 0;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, next, "gap before {lo}");
                assert!(hi > lo, "empty range at {lo}");
                next = hi;
            }
            assert_eq!(next, costs.len(), "items uncovered");
        }
        assert!(partition_by_cost(&[], 4).is_empty());
    }

    #[test]
    fn run_items_visits_every_item_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        let costs = vec![1usize; 37];
        let mut out = vec![0.0f32; 37 * 2];
        let calls = AtomicUsize::new(0);
        pool.run_items(&costs, &mut out, 2, |i, chunk| {
            calls.fetch_add(1, Ordering::Relaxed);
            chunk[0] = i as f32;
            chunk[1] = 2.0 * i as f32;
        });
        assert_eq!(calls.load(Ordering::Relaxed), 37);
        for i in 0..37 {
            assert_eq!(out[i * 2], i as f32);
            assert_eq!(out[i * 2 + 1], 2.0 * i as f32);
        }
    }
}
