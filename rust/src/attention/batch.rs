//! Batched parallel decode attention — the serving hot path fused across
//! an entire decode batch.
//!
//! The seed kernel ([`flash`](super::flash)) runs one sequence, one head
//! at a time on a single thread, and the engine used to call it
//! per-sequence in a loop.  FlashAttention-2 gets its wins from better
//! work partitioning across heads and sequences (Dao, 2023); serving
//! engines like FlashInfer extend that to whole batches with
//! head-group-aware scheduling.  This module does the same for the host
//! decode path:
//!
//! * every `(sequence, query-head)` pair of a decode batch becomes one
//!   item of a flat work queue;
//! * a [`WorkPool`] splits the queue into per-worker ranges, weighted by
//!   each item's KV length, and runs them on scoped threads
//!   (`std::thread::scope` — workers borrow the batch in place, no
//!   copies, and are joined before the call returns, so the engine API
//!   stays synchronous and deterministic);
//! * grouped-query attention (GQA) is native: `kv_heads ≤ heads`, query
//!   head `h` reads KV head `h / (heads / kv_heads)` directly from the
//!   cache layout — KV is never materialized per query head.
//!
//! Every item is computed by the same single-head FlashAttention2 kernel
//! regardless of the thread count, so results are **bit-identical**
//! between `threads = 1` (the sequential fallback, equivalent to the
//! seed's per-sequence loop) and any `threads = N`.
//!
//! Nothing here requires the batch's rows to come from *different*
//! sequences: a row is just `(q, kv view, kv_len)`.  Chunked prefill
//! and speculative verification (`Backend::verify_step`) exploit this
//! by packing k+1 consecutive positions of ONE sequence as k+1 rows of
//! a single batched pass — row `t` carries `kv_len = pos + t + 1`
//! (`mask::verify_row_visible`), so after all rows' K/V are appended,
//! each row attends exactly its causal prefix and is bit-identical to
//! the vanilla decode step at its position.

use crate::coordinator::kv_cache::{QuantStore, Tier};

use super::flash::{
    fill_score_tile, flash_attention_view, merge_softmax_states, row_tile_state, FlashParams,
    KvView,
};

/// Parallelism knobs for the batched attention path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelConfig {
    /// Worker threads; `1` selects the sequential in-place path (no
    /// spawns), which is bit-identical to the parallel one.
    pub threads: usize,
    /// Minimum work (KV rows) per worker: batches with less total work
    /// than `threads * min_work_per_thread` use fewer workers, so tiny
    /// batches never pay spawn overhead.  `0` disables the floor.
    pub min_work_per_thread: usize,
}

impl ParallelConfig {
    /// The sequential fallback (`threads = 1`).
    pub fn sequential() -> Self {
        Self { threads: 1, min_work_per_thread: 0 }
    }
}

impl Default for ParallelConfig {
    fn default() -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        // ~4K KV rows ≈ a few hundred µs of streaming per worker — well
        // above scoped-spawn cost (~tens of µs).
        Self { threads, min_work_per_thread: 4096 }
    }
}

/// A reusable pool policy executing cost-weighted item ranges on scoped
/// threads.  The pool object carries the sizing policy across calls;
/// workers are scoped to each dispatch so they can borrow the batch
/// in place and the caller never observes a thread.
#[derive(Debug, Clone, Copy)]
pub struct WorkPool {
    cfg: ParallelConfig,
}

impl WorkPool {
    pub fn new(cfg: ParallelConfig) -> Self {
        Self { cfg }
    }

    pub fn config(&self) -> ParallelConfig {
        self.cfg
    }

    /// Workers to use for `items` items totalling `total_cost` work.
    fn effective_workers(&self, total_cost: usize, items: usize) -> usize {
        let t = self.cfg.threads.max(1);
        if t == 1 || items <= 1 {
            return 1;
        }
        let by_work = if self.cfg.min_work_per_thread == 0 {
            t
        } else {
            (total_cost / self.cfg.min_work_per_thread).max(1)
        };
        t.min(by_work).min(items)
    }

    /// Run `f(item_index, item_output)` for every item, in parallel over
    /// cost-balanced contiguous ranges.  `out` is `items × item_elems`
    /// flat; each item owns its disjoint `item_elems` output chunk.
    /// Results are identical for any worker count (items are
    /// independent), and `threads = 1` runs inline with zero spawns.
    pub fn run_items<F>(&self, costs: &[usize], out: &mut [f32], item_elems: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let n = costs.len();
        assert!(item_elems > 0, "item_elems must be positive");
        assert_eq!(out.len(), n * item_elems, "out shape");
        if n == 0 {
            return;
        }
        let total: usize = costs.iter().sum();
        let workers = self.effective_workers(total, n);
        if workers <= 1 {
            for (i, chunk) in out.chunks_mut(item_elems).enumerate() {
                f(i, chunk);
            }
            return;
        }

        let ranges = partition_by_cost(costs, workers);
        let fref = &f;
        std::thread::scope(|scope| {
            let mut rest = out;
            for &(lo, hi) in &ranges {
                let (chunk, tail) =
                    std::mem::take(&mut rest).split_at_mut((hi - lo) * item_elems);
                rest = tail;
                scope.spawn(move || {
                    for (j, item_out) in chunk.chunks_mut(item_elems).enumerate() {
                        fref(lo + j, item_out);
                    }
                });
            }
        });
    }
}

/// Split items into ≤ `parts` contiguous ranges of near-equal total cost
/// (each range non-empty; assumes every cost ≥ 1).
///
/// A boundary closes *before* the item whose inclusion would overshoot
/// the proportional target by more than stopping short undershoots it —
/// so a dominant-cost item at the tail ends up alone in its range
/// instead of swallowing every cheaper item queued ahead of it.
fn partition_by_cost(costs: &[usize], parts: usize) -> Vec<(usize, usize)> {
    let n = costs.len();
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let total: usize = costs.iter().sum();
    if parts == 1 || total == 0 {
        return vec![(0, n)];
    }
    let mut ranges = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0usize; // cost of the open range
    let mut done = 0usize; // cost of the closed ranges
    for (i, &c) in costs.iter().enumerate() {
        let k = ranges.len() + 1; // index of the boundary being sought
        if k < parts && i > start {
            // ideal cumulative cost after k ranges, rounded
            let target = (total * k + parts / 2) / parts;
            let without = done + acc;
            let with = without + c;
            if with > target && with - target >= target.saturating_sub(without) {
                ranges.push((start, i));
                done += acc;
                acc = 0;
                start = i;
            }
        }
        acc += c;
    }
    ranges.push((start, n));
    ranges
}

/// Shape of one batched decode-attention call (shared by all sequences).
#[derive(Debug, Clone, Copy)]
pub struct BatchShape {
    pub heads: usize,
    /// KV heads (GQA): must divide `heads`.
    pub kv_heads: usize,
    pub head_dim: usize,
    /// Allocated KV rows per head in the cache layout (`max_seq`); each
    /// sequence's valid prefix is its own `kv_len`.
    pub kv_stride: usize,
    /// KV rows per tile of the inner flash kernel.
    pub block_kv: usize,
    pub scale: f32,
}

impl BatchShape {
    pub fn new(heads: usize, kv_heads: usize, head_dim: usize, kv_stride: usize) -> Self {
        Self {
            heads,
            kv_heads,
            head_dim,
            kv_stride,
            block_kv: 128,
            scale: 1.0 / (head_dim as f32).sqrt(),
        }
    }

    /// Query heads per KV head.
    pub fn group_size(&self) -> usize {
        self.heads / self.kv_heads
    }
}

/// Where one sequence's K/V rows live: contiguous cache planes, the
/// paged pool behind a block table, or the *tiered* paged pool whose
/// blocks are split between a device store and a host store (cold-page
/// offload).  All layouts stream identical rows through [`KvView`], so
/// they are bit-identical.
#[derive(Debug, Clone, Copy)]
pub enum SeqKv<'a> {
    /// `[kv_heads, kv_stride, head_dim]` planes (the packed engine wire
    /// format).
    Contig { k: &'a [f32], v: &'a [f32] },
    /// Rows gathered through a page table: `pages` is `[kv_heads,
    /// max_blocks]` page ids into `[num_pages, page_size, head_dim]`
    /// stores (see `coordinator::kv_cache::{PagePool, BlockTable}`).
    Paged {
        k_store: &'a [f32],
        v_store: &'a [f32],
        pages: &'a [u32],
        max_blocks: usize,
        page_size: usize,
    },
    /// Rows gathered across both tiers of the tiered paged cache:
    /// `tiers` (parallel to `pages`, `[kv_heads, max_blocks]`) says
    /// which store each block's page id indexes (see
    /// `coordinator::kv_cache::TieredPagePool`).
    Tiered {
        k_device: &'a [f32],
        v_device: &'a [f32],
        k_host: &'a [f32],
        v_host: &'a [f32],
        pages: &'a [u32],
        tiers: &'a [Tier],
        max_blocks: usize,
        page_size: usize,
    },
    /// `Paged` over int8 stores with per-row scale side-channels (the
    /// [`PageCodec::Int8`](crate::coordinator::kv_cache::PageCodec)
    /// pool layout) — rows dequantize fused inside the kernel.
    PagedI8 {
        k: QuantStore<'a>,
        v: QuantStore<'a>,
        pages: &'a [u32],
        max_blocks: usize,
        page_size: usize,
    },
    /// `Tiered` over int8 stores, one [`QuantStore`] per tier and side.
    TieredI8 {
        k_device: QuantStore<'a>,
        v_device: QuantStore<'a>,
        k_host: QuantStore<'a>,
        v_host: QuantStore<'a>,
        pages: &'a [u32],
        tiers: &'a [Tier],
        max_blocks: usize,
        page_size: usize,
    },
}

impl<'a> SeqKv<'a> {
    /// (K, V) row views of KV head `g`.  `kv_stride` is the contiguous
    /// row stride (ignored by the paged layouts).
    pub fn head(&self, g: usize, d: usize, kv_stride: usize) -> (KvView<'a>, KvView<'a>) {
        match *self {
            SeqKv::Contig { k, v } => {
                let plane = kv_stride * d;
                (
                    KvView::Contig(&k[g * plane..][..plane]),
                    KvView::Contig(&v[g * plane..][..plane]),
                )
            }
            SeqKv::Paged { k_store, v_store, pages, max_blocks, page_size } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                (
                    KvView::Paged { store: k_store, pages: p, page_size },
                    KvView::Paged { store: v_store, pages: p, page_size },
                )
            }
            SeqKv::Tiered {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                let t = &tiers[g * max_blocks..][..max_blocks];
                (
                    KvView::Tiered {
                        device_store: k_device,
                        host_store: k_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                    KvView::Tiered {
                        device_store: v_device,
                        host_store: v_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                )
            }
            SeqKv::PagedI8 { k, v, pages, max_blocks, page_size } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                (
                    KvView::PagedI8 { store: k, pages: p, page_size },
                    KvView::PagedI8 { store: v, pages: p, page_size },
                )
            }
            SeqKv::TieredI8 {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                let p = &pages[g * max_blocks..][..max_blocks];
                let t = &tiers[g * max_blocks..][..max_blocks];
                (
                    KvView::TieredI8 {
                        device_store: k_device,
                        host_store: k_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                    KvView::TieredI8 {
                        device_store: v_device,
                        host_store: v_host,
                        pages: p,
                        tiers: t,
                        page_size,
                    },
                )
            }
        }
    }
}

/// One sequence's slice of a decode batch.
///
/// `q` is `[heads, head_dim]` (the one new token's query rows); `kv`
/// names the sequence's K/V rows of which the first `kv_len` per KV
/// head are valid.
#[derive(Debug, Clone, Copy)]
pub struct SeqAttn<'a> {
    pub q: &'a [f32],
    pub kv: SeqKv<'a>,
    pub kv_len: usize,
}

impl<'a> SeqAttn<'a> {
    /// A sequence over contiguous `[kv_heads, kv_stride, head_dim]`
    /// cache planes (the pre-paging layout).
    pub fn contig(q: &'a [f32], k: &'a [f32], v: &'a [f32], kv_len: usize) -> Self {
        Self { q, kv: SeqKv::Contig { k, v }, kv_len }
    }
}

/// Fused decode attention over a whole batch: all sequences × all query
/// heads as one flat work queue, executed on `pool`.
///
/// `out` is `[seqs, heads, head_dim]` flat.  Bit-identical for any
/// `ParallelConfig` and for contiguous-vs-paged KV (see module docs).
pub fn batch_decode_attention(
    shape: &BatchShape,
    seqs: &[SeqAttn<'_>],
    out: &mut [f32],
    pool: &WorkPool,
) {
    let (h, d) = (shape.heads, shape.head_dim);
    assert_eq!(out.len(), seqs.len() * h * d, "out shape");
    validate_decode_batch(shape, seqs);
    let group = shape.group_size();

    // cost model: one item streams kv_len KV rows (+1 keeps zero-length
    // sequences schedulable).
    let costs: Vec<usize> = seqs
        .iter()
        .flat_map(|s| std::iter::repeat(s.kv_len + 1).take(h))
        .collect();

    pool.run_items(&costs, out, d, |item, item_out| {
        let (si, head) = (item / h, item % h);
        let s = &seqs[si];
        let g = head / group;
        let kv = s.kv_len;
        let p = FlashParams {
            heads: 1,
            kv_heads: 1,
            seq_q: 1,
            seq_kv: kv,
            head_dim: d,
            causal: false,
            block_q: 1,
            block_kv: shape.block_kv,
            scale: shape.scale,
        };
        let qh = &s.q[head * d..][..d];
        let (kview, vview) = s.kv.head(g, d, shape.kv_stride);
        flash_attention_view(qh, &kview, &vview, item_out, &p);
    });
}

/// Shape/bounds validation shared by [`batch_decode_attention`] and
/// [`cascade_batch_decode_attention`]: every page a sequence's valid
/// prefix can touch must land inside its store.
fn validate_decode_batch(shape: &BatchShape, seqs: &[SeqAttn<'_>]) {
    let (h, kvh, d) = (shape.heads, shape.kv_heads, shape.head_dim);
    assert!(kvh >= 1 && h % kvh == 0, "kv_heads {kvh} must divide heads {h}");
    let plane = shape.kv_stride * d;
    for (i, s) in seqs.iter().enumerate() {
        assert_eq!(s.q.len(), h * d, "seq {i} q shape");
        assert!(s.kv_len <= shape.kv_stride, "seq {i} kv_len > kv_stride");
        match s.kv {
            SeqKv::Contig { k, v } => {
                assert_eq!(k.len(), kvh * plane, "seq {i} k shape");
                assert_eq!(v.len(), kvh * plane, "seq {i} v shape");
            }
            SeqKv::Paged { k_store, v_store, pages, max_blocks, page_size } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(k_store.len(), v_store.len(), "seq {i} store shapes");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    for &p in &pages[g * max_blocks..][..used] {
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= k_store.len(), "seq {i} page {p} out of store");
                    }
                }
            }
            SeqKv::Tiered {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(tiers.len(), pages.len(), "seq {i} tier tags shape");
                assert_eq!(k_device.len(), v_device.len(), "seq {i} device store shapes");
                assert_eq!(k_host.len(), v_host.len(), "seq {i} host store shapes");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    let ps = &pages[g * max_blocks..][..used];
                    let ts = &tiers[g * max_blocks..][..used];
                    for (&p, &t) in ps.iter().zip(ts) {
                        let store_len = match t {
                            Tier::Device => k_device.len(),
                            Tier::Host => k_host.len(),
                        };
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= store_len, "seq {i} page {p} out of {t:?} store");
                    }
                }
            }
            SeqKv::PagedI8 { k, v, pages, max_blocks, page_size } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(k.q.len(), v.q.len(), "seq {i} store shapes");
                assert_eq!(k.q.len(), k.scales.len() * d, "seq {i} k scale side-channel");
                assert_eq!(v.q.len(), v.scales.len() * d, "seq {i} v scale side-channel");
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    for &p in &pages[g * max_blocks..][..used] {
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= k.q.len(), "seq {i} page {p} out of store");
                    }
                }
            }
            SeqKv::TieredI8 {
                k_device,
                v_device,
                k_host,
                v_host,
                pages,
                tiers,
                max_blocks,
                page_size,
            } => {
                assert!(page_size >= 1, "seq {i} page_size");
                assert_eq!(pages.len(), kvh * max_blocks, "seq {i} page table shape");
                assert_eq!(tiers.len(), pages.len(), "seq {i} tier tags shape");
                assert_eq!(k_device.q.len(), v_device.q.len(), "seq {i} device store shapes");
                assert_eq!(k_host.q.len(), v_host.q.len(), "seq {i} host store shapes");
                assert_eq!(
                    k_device.q.len(),
                    k_device.scales.len() * d,
                    "seq {i} device scale side-channel"
                );
                assert_eq!(
                    k_host.q.len(),
                    k_host.scales.len() * d,
                    "seq {i} host scale side-channel"
                );
                let used = s.kv_len.div_ceil(page_size);
                assert!(used <= max_blocks, "seq {i} kv_len beyond page table");
                for g in 0..kvh {
                    let ps = &pages[g * max_blocks..][..used];
                    let ts = &tiers[g * max_blocks..][..used];
                    for (&p, &t) in ps.iter().zip(ts) {
                        let store_len = match t {
                            Tier::Device => k_device.q.len(),
                            Tier::Host => k_host.q.len(),
                        };
                        let end = (p as usize + 1) * page_size * d;
                        assert!(end <= store_len, "seq {i} page {p} out of {t:?} store");
                    }
                }
            }
        }
    }
}

/// One shared-prefix adopter group of a cascade decode call: `members`
/// index into the `seqs` slice, and the first `shared_rows` KV rows of
/// every member are physically the same pages (the COW prefix blocks
/// `BlockTable::block_shared` tracks).  Groups are disjoint; sequences
/// in no group run the plain per-item kernel.
#[derive(Debug, Clone)]
pub struct CascadeGroup {
    pub members: Vec<usize>,
    pub shared_rows: usize,
}

/// What one [`cascade_batch_decode_attention`] call actually shared.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct CascadeStats {
    /// Batched shared-prefix passes executed (one per group whose
    /// prefix covered ≥ 1 KV tile with ≥ 2 physically-matching
    /// members).
    pub passes: u64,
    /// K+V row reads avoided versus the per-sequence gather: tile-
    /// aligned shared rows × KV heads × 2 (K and V), for every group
    /// member beyond the first.
    pub rows_saved: u64,
}

/// Physical identity of the first `rows` KV rows of each KV head:
/// layout tag + page size + the (page id, tier) of every covering
/// block.  Two sequences with equal signatures over one store gather
/// identical bytes for those rows.  `None` for contiguous layouts,
/// which have no page table to compare — they never cascade.
fn shared_run_sig(kv: &SeqKv<'_>, kvh: usize, rows: usize) -> Option<(u8, usize, Vec<(u32, u8)>)> {
    let (kind, pages, tiers, max_blocks, page_size): (u8, &[u32], Option<&[Tier]>, usize, usize) =
        match *kv {
            SeqKv::Contig { .. } => return None,
            SeqKv::Paged { pages, max_blocks, page_size, .. } => {
                (1, pages, None, max_blocks, page_size)
            }
            SeqKv::Tiered { pages, tiers, max_blocks, page_size, .. } => {
                (2, pages, Some(tiers), max_blocks, page_size)
            }
            SeqKv::PagedI8 { pages, max_blocks, page_size, .. } => {
                (3, pages, None, max_blocks, page_size)
            }
            SeqKv::TieredI8 { pages, tiers, max_blocks, page_size, .. } => {
                (4, pages, Some(tiers), max_blocks, page_size)
            }
        };
    let nb = rows.div_ceil(page_size);
    if nb > max_blocks {
        return None;
    }
    let mut ids = Vec::with_capacity(kvh * nb);
    for g in 0..kvh {
        for b in 0..nb {
            let at = g * max_blocks + b;
            let t = tiers.map_or(0u8, |ts| match ts[at] {
                Tier::Device => 0,
                Tier::Host => 1,
            });
            ids.push((pages[at], t));
        }
    }
    Some((kind, page_size, ids))
}

/// Cascade decode attention: [`batch_decode_attention`] with shared
/// prefixes read **once per batch** instead of once per sequence.
///
/// Phase 1 walks the KV tiles that lie entirely inside each group's
/// shared prefix (`shared_rows / block_kv` tiles) one tile at a time
/// for *all* member heads before moving on — the shared K/V rows
/// stream from the page store once per (group, KV head) and stay hot
/// across the member loop — accumulating a per-(member, head) partial
/// softmax state.  Phase 2 resumes each item's tile walk at the split
/// point over its own views and normalizes.  Because
/// [`flash_attention_view`] folds every tile through the same
/// [`merge_softmax_states`] / [`row_tile_state`] pair, the result is
/// **bit-identical** to `batch_decode_attention` for every layout,
/// codec and thread count — and like it, invariant to `ParallelConfig`.
///
/// Group members whose page-table prefix does not physically match the
/// group's first member (or whose layout is contiguous) fall back to
/// the plain per-item kernel; a group needs ≥ 2 matching members and a
/// prefix covering ≥ 1 tile to run phase 1 at all.  All members must
/// gather from the same store — the caller's contract (the engine
/// builds groups from one pool's block tables).
///
/// Panics if a member index is out of range, a sequence appears in two
/// groups, or `shared_rows` exceeds a member's `kv_len`.
pub fn cascade_batch_decode_attention(
    shape: &BatchShape,
    seqs: &[SeqAttn<'_>],
    groups: &[CascadeGroup],
    out: &mut [f32],
    pool: &WorkPool,
) -> CascadeStats {
    let (h, kvh, d) = (shape.heads, shape.kv_heads, shape.head_dim);
    assert_eq!(out.len(), seqs.len() * h * d, "out shape");
    validate_decode_batch(shape, seqs);
    let group_sz = shape.group_size();
    let bkv = shape.block_kv.max(1);

    // --- plan: which members share which tile-aligned prefix --------
    struct Plan {
        members: Vec<usize>,
        tiles: usize,
        slot0: usize,
    }
    let mut stats = CascadeStats::default();
    let mut in_group = vec![false; seqs.len()];
    let mut plans: Vec<Plan> = Vec::new();
    let mut nslots = 0usize;
    for g in groups {
        for &mi in &g.members {
            assert!(mi < seqs.len(), "cascade member {mi} out of range");
            assert!(!in_group[mi], "sequence {mi} appears in two cascade groups");
            in_group[mi] = true;
            assert!(
                g.shared_rows <= seqs[mi].kv_len,
                "group shared_rows {} exceeds member {mi} kv_len {}",
                g.shared_rows,
                seqs[mi].kv_len
            );
        }
        // only tiles fully inside the shared prefix run batched; the
        // ragged tail (< one tile) stays in each member's own pass
        let tiles = g.shared_rows / bkv;
        if tiles == 0 || g.members.len() < 2 {
            continue;
        }
        let Some(sig0) = shared_run_sig(&seqs[g.members[0]].kv, kvh, tiles * bkv) else {
            continue;
        };
        let members: Vec<usize> = g
            .members
            .iter()
            .copied()
            .filter(|&mi| shared_run_sig(&seqs[mi].kv, kvh, tiles * bkv).as_ref() == Some(&sig0))
            .collect();
        if members.len() < 2 {
            continue;
        }
        stats.passes += 1;
        stats.rows_saved += (tiles * bkv * kvh * 2 * (members.len() - 1)) as u64;
        let slot0 = nslots;
        nslots += members.len() * h;
        plans.push(Plan { members, tiles, slot0 });
    }

    // --- per-(member, head) partial states + phase-2 resume points --
    // slot chunk layout: [m, l, acc[0..d]]; slots of one (plan, kv
    // head) unit are contiguous so phase 1 can split the buffer.
    let mut slot_of = vec![usize::MAX; seqs.len() * h];
    let mut resume_row = vec![0usize; seqs.len() * h];
    for p in &plans {
        for (mj, &mi) in p.members.iter().enumerate() {
            for head in 0..h {
                let kh = head / group_sz;
                let hg = head % group_sz;
                let slot = p.slot0 + (kh * p.members.len() + mj) * group_sz + hg;
                slot_of[mi * h + head] = slot;
                resume_row[mi * h + head] = p.tiles * bkv;
            }
        }
    }
    let mut state = vec![0.0f32; nslots * (d + 2)];
    for chunk in state.chunks_mut(d + 2) {
        chunk[0] = f32::NEG_INFINITY; // m = −∞ encodes the empty state
    }

    // --- phase 1: batched pass over each group's shared tiles -------
    struct Unit {
        plan: usize,
        kh: usize,
    }
    let units: Vec<Unit> = plans
        .iter()
        .enumerate()
        .flat_map(|(pi, _)| (0..kvh).map(move |kh| Unit { plan: pi, kh }))
        .collect();
    let run_unit = |u: &Unit, chunk: &mut [f32]| {
        let p = &plans[u.plan];
        debug_assert_eq!(chunk.len(), p.members.len() * group_sz * (d + 2));
        // every member's shared run is page-identical (checked above),
        // so member 0's views stand in for the whole group
        let (kview, vview) = seqs[p.members[0]].kv.head(u.kh, d, shape.kv_stride);
        let mut scores = vec![0.0f32; bkv];
        let mut tacc = vec![0.0f32; d];
        for t in 0..p.tiles {
            let k0 = t * bkv;
            for (mj, &mi) in p.members.iter().enumerate() {
                for hg in 0..group_sz {
                    let head = u.kh * group_sz + hg;
                    let qi = &seqs[mi].q[head * d..][..d];
                    fill_score_tile(qi, &kview, k0, bkv, d, shape.scale, &mut scores[..bkv]);
                    let (mt, lt) = row_tile_state(&scores[..bkv], &vview, k0, bkv, d, &mut tacc);
                    let st = &mut chunk[(mj * group_sz + hg) * (d + 2)..][..d + 2];
                    let (m, rest) = st.split_first_mut().unwrap();
                    let (l, acc) = rest.split_first_mut().unwrap();
                    merge_softmax_states(m, l, acc, mt, lt, &tacc[..d]);
                }
            }
        }
    };
    if !units.is_empty() {
        let unit_costs: Vec<usize> = units
            .iter()
            .map(|u| {
                let p = &plans[u.plan];
                p.tiles * bkv * p.members.len() * group_sz + 1
            })
            .collect();
        let unit_elems: Vec<usize> = units
            .iter()
            .map(|u| plans[u.plan].members.len() * group_sz * (d + 2))
            .collect();
        let workers = pool.effective_workers(unit_costs.iter().sum(), units.len());
        if workers <= 1 {
            let mut off = 0usize;
            for (ui, u) in units.iter().enumerate() {
                run_unit(u, &mut state[off..off + unit_elems[ui]]);
                off += unit_elems[ui];
            }
        } else {
            let ranges = partition_by_cost(&unit_costs, workers);
            let run_ref = &run_unit;
            std::thread::scope(|scope| {
                let mut rest = &mut state[..];
                for &(lo, hi) in &ranges {
                    let elems: usize = unit_elems[lo..hi].iter().sum();
                    let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(elems);
                    rest = tail;
                    let (units, unit_elems) = (&units, &unit_elems);
                    scope.spawn(move || {
                        let mut off = 0usize;
                        for ui in lo..hi {
                            run_ref(&units[ui], &mut chunk[off..off + unit_elems[ui]]);
                            off += unit_elems[ui];
                        }
                    });
                }
            });
        }
    }

    // --- phase 2: per-item continuation / plain pass ----------------
    let costs: Vec<usize> = (0..seqs.len() * h)
        .map(|item| seqs[item / h].kv_len - resume_row[item] + 1)
        .collect();
    let (state, slot_of, resume_row) = (&state, &slot_of, &resume_row);
    pool.run_items(&costs, out, d, |item, item_out| {
        let (si, head) = (item / h, item % h);
        let s = &seqs[si];
        let g = head / group_sz;
        let qh = &s.q[head * d..][..d];
        let (kview, vview) = s.kv.head(g, d, shape.kv_stride);
        let slot = slot_of[item];
        if slot == usize::MAX {
            // ungrouped: exactly batch_decode_attention's per-item call
            let p = FlashParams {
                heads: 1,
                kv_heads: 1,
                seq_q: 1,
                seq_kv: s.kv_len,
                head_dim: d,
                causal: false,
                block_q: 1,
                block_kv: shape.block_kv,
                scale: shape.scale,
            };
            flash_attention_view(qh, &kview, &vview, item_out, &p);
            return;
        }
        // grouped: resume the tile walk at the split point.  kv_len ≥
        // shared_rows ≥ block_kv here, so the plain kernel's effective
        // tile size equals ours and the walk is the same one it takes.
        let st = &state[slot * (d + 2)..][..d + 2];
        let (mut m, mut l) = (st[0], st[1]);
        let mut acc = st[2..].to_vec();
        let mut scores = vec![0.0f32; bkv];
        let mut tacc = vec![0.0f32; d];
        let mut k0 = resume_row[item];
        while k0 < s.kv_len {
            let nk = bkv.min(s.kv_len - k0);
            fill_score_tile(qh, &kview, k0, nk, d, shape.scale, &mut scores[..nk]);
            let (mt, lt) = row_tile_state(&scores[..nk], &vview, k0, nk, d, &mut tacc);
            merge_softmax_states(&mut m, &mut l, &mut acc, mt, lt, &tacc[..d]);
            k0 += nk;
        }
        let inv = if l > 0.0 { 1.0 / l } else { 0.0 };
        for (o, &a) in item_out.iter_mut().zip(&acc) {
            *o = a * inv;
        }
    });
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::flash::flash_attention;
    use crate::prop_ensure;
    use crate::proptest::{check, Rng};

    /// Reference: per-sequence GQA flash over the valid prefix.
    fn reference(shape: &BatchShape, seqs: &[SeqAttn<'_>]) -> Vec<f32> {
        let (h, kvh, d) = (shape.heads, shape.kv_heads, shape.head_dim);
        let mut out = vec![0.0f32; seqs.len() * h * d];
        for (i, s) in seqs.iter().enumerate() {
            let SeqKv::Contig { k: sk, v: sv } = s.kv else {
                panic!("reference expects contiguous KV");
            };
            // compact the valid prefix of each KV head into [kvh, kv, d]
            let kv = s.kv_len;
            let mut k = Vec::with_capacity(kvh * kv * d);
            let mut v = Vec::with_capacity(kvh * kv * d);
            for g in 0..kvh {
                k.extend_from_slice(&sk[g * shape.kv_stride * d..][..kv * d]);
                v.extend_from_slice(&sv[g * shape.kv_stride * d..][..kv * d]);
            }
            let p = FlashParams {
                heads: h,
                kv_heads: kvh,
                seq_q: 1,
                seq_kv: kv,
                head_dim: d,
                causal: false,
                block_q: 1,
                block_kv: shape.block_kv,
                scale: shape.scale,
            };
            flash_attention(s.q, &k, &v, &mut out[i * h * d..][..h * d], &p);
        }
        out
    }

    struct Batch {
        shape: BatchShape,
        q: Vec<Vec<f32>>,
        k: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
        lens: Vec<usize>,
    }

    impl Batch {
        fn random(rng: &mut Rng, nseq: usize, h: usize, kvh: usize, d: usize, stride: usize) -> Self {
            let shape = BatchShape::new(h, kvh, d, stride);
            let mut q = Vec::new();
            let mut k = Vec::new();
            let mut v = Vec::new();
            let mut lens = Vec::new();
            for _ in 0..nseq {
                q.push(rng.f32_vec(h * d));
                k.push(rng.f32_vec(kvh * stride * d));
                v.push(rng.f32_vec(kvh * stride * d));
                lens.push(rng.range(0, stride + 1));
            }
            Self { shape, q, k, v, lens }
        }

        fn seqs(&self) -> Vec<SeqAttn<'_>> {
            (0..self.q.len())
                .map(|i| SeqAttn::contig(&self.q[i], &self.k[i], &self.v[i], self.lens[i]))
                .collect()
        }

        /// The same batch with every sequence's rows scattered into a
        /// shared paged store (per-seq tables, shuffled page order).
        fn paged(&self) -> PagedBatch {
            let (kvh, d, stride) = (self.shape.kv_heads, self.shape.head_dim, self.shape.kv_stride);
            let page_size = 3;
            let max_blocks = stride.div_ceil(page_size);
            let pages_per_seq = kvh * max_blocks;
            let npages = pages_per_seq * self.q.len();
            let mut k_store = vec![0.0f32; npages * page_size * d];
            let mut v_store = vec![0.0f32; npages * page_size * d];
            let mut tables = Vec::new();
            for i in 0..self.q.len() {
                // reversed page order scatters blocks away from identity
                let base = i * pages_per_seq;
                let pages: Vec<u32> = (0..pages_per_seq)
                    .map(|j| (base + pages_per_seq - 1 - j) as u32)
                    .collect();
                for g in 0..kvh {
                    for r in 0..stride {
                        let p = pages[g * max_blocks + r / page_size] as usize;
                        let at = (p * page_size + r % page_size) * d;
                        let src = g * stride * d + r * d;
                        k_store[at..at + d].copy_from_slice(&self.k[i][src..src + d]);
                        v_store[at..at + d].copy_from_slice(&self.v[i][src..src + d]);
                    }
                }
                tables.push(pages);
            }
            PagedBatch { k_store, v_store, tables, max_blocks, page_size }
        }
    }

    struct PagedBatch {
        k_store: Vec<f32>,
        v_store: Vec<f32>,
        tables: Vec<Vec<u32>>,
        max_blocks: usize,
        page_size: usize,
    }

    impl PagedBatch {
        fn seqs<'a>(&'a self, b: &'a Batch) -> Vec<SeqAttn<'a>> {
            (0..b.q.len())
                .map(|i| SeqAttn {
                    q: &b.q[i],
                    kv: SeqKv::Paged {
                        k_store: &self.k_store,
                        v_store: &self.v_store,
                        pages: &self.tables[i],
                        max_blocks: self.max_blocks,
                        page_size: self.page_size,
                    },
                    kv_len: b.lens[i],
                })
                .collect()
        }
    }

    #[test]
    fn matches_per_sequence_flash_mha() {
        let mut rng = Rng::new(11);
        let b = Batch::random(&mut rng, 5, 4, 4, 8, 24);
        let seqs = b.seqs();
        let mut out = vec![0.0; seqs.len() * 4 * 8];
        let pool = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn matches_per_sequence_flash_gqa() {
        let mut rng = Rng::new(12);
        let b = Batch::random(&mut rng, 6, 8, 2, 16, 33);
        let seqs = b.seqs();
        let mut out = vec![0.0; seqs.len() * 8 * 16];
        let pool = WorkPool::new(ParallelConfig { threads: 3, min_work_per_thread: 0 });
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn threads_do_not_change_bits() {
        let mut rng = Rng::new(13);
        let b = Batch::random(&mut rng, 9, 6, 3, 8, 40);
        let seqs = b.seqs();
        let n = seqs.len() * 6 * 8;
        let mut seq_out = vec![0.0; n];
        batch_decode_attention(
            &b.shape,
            &seqs,
            &mut seq_out,
            &WorkPool::new(ParallelConfig::sequential()),
        );
        for threads in [2, 4, 7] {
            let mut par_out = vec![0.0; n];
            let pool =
                WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            batch_decode_attention(&b.shape, &seqs, &mut par_out, &pool);
            assert_eq!(seq_out, par_out, "threads={threads}");
        }
    }

    #[test]
    fn empty_batch_and_empty_kv_are_safe() {
        let shape = BatchShape::new(2, 2, 4, 8);
        let pool = WorkPool::new(ParallelConfig::default());
        let mut out: Vec<f32> = Vec::new();
        batch_decode_attention(&shape, &[], &mut out, &pool);

        // kv_len = 0 → zero output rows
        let q = vec![1.0f32; 2 * 4];
        let k = vec![1.0f32; 2 * 8 * 4];
        let v = vec![1.0f32; 2 * 8 * 4];
        let seqs = [SeqAttn::contig(&q, &k, &v, 0)];
        let mut out = vec![9.0f32; 2 * 4];
        batch_decode_attention(&shape, &seqs, &mut out, &pool);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn paged_gather_is_bit_identical_to_contig() {
        let mut rng = Rng::new(21);
        for threads in [1usize, 4] {
            let b = Batch::random(&mut rng, 7, 6, 3, 8, 26);
            let contig = b.seqs();
            let pb = b.paged();
            let paged = pb.seqs(&b);
            let n = contig.len() * 6 * 8;
            let pool = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&b.shape, &contig, &mut out_c, &pool);
            let mut out_p = vec![0.0; n];
            batch_decode_attention(&b.shape, &paged, &mut out_p, &pool);
            assert_eq!(out_c, out_p, "threads={threads}");
        }
    }

    #[test]
    fn tiered_gather_is_bit_identical_to_contig() {
        use crate::coordinator::kv_cache::{BlockTable, CacheShape, PcieLink, TieredPagePool};
        let mut rng = Rng::new(22);
        for threads in [1usize, 4] {
            let b = Batch::random(&mut rng, 5, 6, 3, 8, 26);
            let (kvh, d, stride) = (3usize, 8usize, 26usize);
            let page_size = 4;
            let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
            let max_blocks = stride.div_ceil(page_size);
            let mut pools = TieredPagePool::new(
                page_size,
                d,
                5 * kvh * max_blocks,
                5 * kvh * max_blocks,
                PcieLink::default(),
            );
            // fill per-seq tables on device, then migrate every other
            // block to the host tier
            let mut tables = Vec::new();
            for i in 0..5 {
                let mut t = BlockTable::new(cache, page_size);
                t.ensure_capacity(b.lens[i], pools.device_mut()).unwrap();
                for g in 0..kvh {
                    for r in 0..b.lens[i] {
                        let (tier, page, slot) = t.locate_tiered(0, g, r);
                        let src = g * stride * d + r * d;
                        pools.write_row(
                            tier,
                            page,
                            slot,
                            &b.k[i][src..src + d],
                            &b.v[i][src..src + d],
                        );
                    }
                }
                for blk in (0..t.blocks()).step_by(2) {
                    t.migrate_block_to_host(blk, &mut pools).unwrap();
                }
                tables.push(t);
            }
            let tiered: Vec<SeqAttn<'_>> = (0..5)
                .map(|i| SeqAttn {
                    q: &b.q[i],
                    kv: SeqKv::Tiered {
                        k_device: pools.device().k_store(),
                        v_device: pools.device().v_store(),
                        k_host: pools.host().k_store(),
                        v_host: pools.host().v_store(),
                        pages: tables[i].layer_pages(0),
                        tiers: tables[i].layer_tiers(0),
                        max_blocks: tables[i].max_blocks(),
                        page_size,
                    },
                    kv_len: b.lens[i],
                })
                .collect();
            let contig = b.seqs();
            let n = 5 * 6 * 8;
            let pool = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
            let mut out_c = vec![0.0; n];
            batch_decode_attention(&b.shape, &contig, &mut out_c, &pool);
            let mut out_t = vec![0.0; n];
            batch_decode_attention(&b.shape, &tiered, &mut out_t, &pool);
            assert_eq!(out_c, out_t, "threads={threads}");
        }
    }

    /// The same rows quantized once and gathered through the two int8
    /// layouts must agree bit-for-bit (single-store vs tiered with
    /// migrated blocks), and stay within quantization tolerance of the
    /// exact f32 batch decode.
    #[test]
    fn int8_tiered_gather_matches_int8_paged_and_f32_within_tol() {
        use crate::coordinator::kv_cache::{
            BlockTable, CacheShape, PageCodec, PagePool, PcieLink, TieredPagePool,
        };
        let mut rng = Rng::new(23);
        let b = Batch::random(&mut rng, 4, 6, 3, 8, 26);
        let (kvh, d, stride) = (3usize, 8usize, 26usize);
        let page_size = 4;
        let cache = CacheShape { layers: 1, kv_heads: kvh, max_seq: stride, head_dim: d };
        let max_blocks = stride.div_ceil(page_size);

        // (a) single-store int8 pool
        let mut pool =
            PagePool::with_codec(page_size, d, 4 * kvh * max_blocks, PageCodec::Int8);
        let mut ptables = Vec::new();
        for i in 0..4 {
            let mut t = BlockTable::new(cache, page_size);
            t.ensure_capacity(b.lens[i], &mut pool).unwrap();
            for g in 0..kvh {
                for r in 0..b.lens[i] {
                    let (page, slot) = t.locate(0, g, r);
                    let src = g * stride * d + r * d;
                    pool.write_row(page, slot, &b.k[i][src..src + d], &b.v[i][src..src + d]);
                }
            }
            ptables.push(t);
        }
        let paged: Vec<SeqAttn<'_>> = (0..4)
            .map(|i| SeqAttn {
                q: &b.q[i],
                kv: SeqKv::PagedI8 {
                    k: pool.k_quant_store(),
                    v: pool.v_quant_store(),
                    pages: ptables[i].layer_pages(0),
                    max_blocks: ptables[i].max_blocks(),
                    page_size,
                },
                kv_len: b.lens[i],
            })
            .collect();

        // (b) tiered int8 pools, alternate blocks migrated to host
        let mut pools = TieredPagePool::new_with_codec(
            page_size,
            d,
            4 * kvh * max_blocks,
            4 * kvh * max_blocks,
            PcieLink::default(),
            PageCodec::Int8,
        );
        let mut tables = Vec::new();
        for i in 0..4 {
            let mut t = BlockTable::new(cache, page_size);
            t.ensure_capacity(b.lens[i], pools.device_mut()).unwrap();
            for g in 0..kvh {
                for r in 0..b.lens[i] {
                    let (tier, page, slot) = t.locate_tiered(0, g, r);
                    let src = g * stride * d + r * d;
                    pools.write_row(tier, page, slot, &b.k[i][src..src + d], &b.v[i][src..src + d]);
                }
            }
            for blk in (0..t.blocks()).step_by(2) {
                t.migrate_block_to_host(blk, &mut pools).unwrap();
            }
            tables.push(t);
        }
        let tiered: Vec<SeqAttn<'_>> = (0..4)
            .map(|i| SeqAttn {
                q: &b.q[i],
                kv: SeqKv::TieredI8 {
                    k_device: pools.device().k_quant_store(),
                    v_device: pools.device().v_quant_store(),
                    k_host: pools.host().k_quant_store(),
                    v_host: pools.host().v_quant_store(),
                    pages: tables[i].layer_pages(0),
                    tiers: tables[i].layer_tiers(0),
                    max_blocks: tables[i].max_blocks(),
                    page_size,
                },
                kv_len: b.lens[i],
            })
            .collect();

        let n = 4 * 6 * 8;
        let wp = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        let mut out_p = vec![0.0; n];
        batch_decode_attention(&b.shape, &paged, &mut out_p, &wp);
        let mut out_t = vec![0.0; n];
        batch_decode_attention(&b.shape, &tiered, &mut out_t, &wp);
        assert_eq!(out_p, out_t, "tiered int8 must be bit-identical to paged int8");

        let contig = b.seqs();
        let mut out_c = vec![0.0; n];
        batch_decode_attention(&b.shape, &contig, &mut out_c, &wp);
        let err =
            out_c.iter().zip(&out_p).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 0.05, "int8 batch decode err {err} out of tolerance");
    }

    #[test]
    fn min_work_floor_collapses_to_sequential() {
        // total work far below the floor → one worker regardless of
        // `threads`; output must still be complete.
        let mut rng = Rng::new(14);
        let b = Batch::random(&mut rng, 2, 2, 1, 4, 6);
        let seqs = b.seqs();
        let pool =
            WorkPool::new(ParallelConfig { threads: 8, min_work_per_thread: 1 << 20 });
        assert_eq!(pool.effective_workers(10, 4), 1);
        let mut out = vec![0.0; seqs.len() * 2 * 4];
        batch_decode_attention(&b.shape, &seqs, &mut out, &pool);
        assert_eq!(out, reference(&b.shape, &seqs));
    }

    #[test]
    fn partition_covers_all_items_in_order() {
        for (costs, parts) in [
            (vec![1usize; 10], 3usize),
            (vec![100, 1, 1, 1], 4),
            (vec![1, 1, 1, 100], 4),
            (vec![5], 4),
            (vec![3, 3, 3, 3, 3, 3, 3, 3], 8),
        ] {
            let ranges = partition_by_cost(&costs, parts);
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts.min(costs.len()));
            let mut next = 0;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, next, "gap before {lo}");
                assert!(hi > lo, "empty range at {lo}");
                next = hi;
            }
            assert_eq!(next, costs.len(), "items uncovered");
        }
        assert!(partition_by_cost(&[], 4).is_empty());
    }

    #[test]
    fn run_items_visits_every_item_once() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = WorkPool::new(ParallelConfig { threads: 4, min_work_per_thread: 0 });
        let costs = vec![1usize; 37];
        let mut out = vec![0.0f32; 37 * 2];
        let calls = AtomicUsize::new(0);
        pool.run_items(&costs, &mut out, 2, |i, chunk| {
            calls.fetch_add(1, Ordering::Relaxed);
            chunk[0] = i as f32;
            chunk[1] = 2.0 * i as f32;
        });
        assert_eq!(calls.load(Ordering::Relaxed), 37);
        for i in 0..37 {
            assert_eq!(out[i * 2], i as f32);
            assert_eq!(out[i * 2 + 1], 2.0 * i as f32);
        }
    }

    /// `nseq` sequences scattered into one paged pool where the first
    /// `shared_blocks` blocks of every KV head are the SAME pages for
    /// every sequence — the engine's COW shared-prefix shape.
    struct SharedPagedBatch {
        pool: crate::coordinator::kv_cache::PagePool,
        tables: Vec<Vec<u32>>,
        lens: Vec<usize>,
        q: Vec<Vec<f32>>,
        max_blocks: usize,
        page_size: usize,
        shared_rows: usize,
    }

    impl SharedPagedBatch {
        #[allow(clippy::too_many_arguments)]
        fn random(
            rng: &mut Rng,
            codec: crate::coordinator::kv_cache::PageCodec,
            nseq: usize,
            h: usize,
            kvh: usize,
            d: usize,
            page_size: usize,
            shared_blocks: usize,
            extra_max: usize,
        ) -> Self {
            use crate::coordinator::kv_cache::PagePool;
            let shared_rows = shared_blocks * page_size;
            let max_blocks = (shared_rows + extra_max).div_ceil(page_size);
            let npages = kvh * (shared_blocks + nseq * max_blocks);
            let mut pool = PagePool::with_codec(page_size, d, npages, codec);
            // prefix pages, allocated and written exactly once
            let shared_pages: Vec<u32> =
                (0..kvh * shared_blocks).map(|_| pool.alloc().unwrap()).collect();
            for g in 0..kvh {
                for r in 0..shared_rows {
                    let page = shared_pages[g * shared_blocks + r / page_size];
                    pool.write_row(page, r % page_size, &rng.f32_vec(d), &rng.f32_vec(d));
                }
            }
            let (mut tables, mut lens, mut q) = (Vec::new(), Vec::new(), Vec::new());
            for _ in 0..nseq {
                let len = shared_rows + rng.range(0, extra_max + 1);
                let mut pages = vec![0u32; kvh * max_blocks];
                for g in 0..kvh {
                    for b in 0..shared_blocks {
                        pages[g * max_blocks + b] = shared_pages[g * shared_blocks + b];
                    }
                    for b in shared_blocks..max_blocks {
                        pages[g * max_blocks + b] = pool.alloc().unwrap();
                    }
                    for r in shared_rows..len {
                        let page = pages[g * max_blocks + r / page_size];
                        pool.write_row(page, r % page_size, &rng.f32_vec(d), &rng.f32_vec(d));
                    }
                }
                tables.push(pages);
                lens.push(len);
                q.push(rng.f32_vec(h * d));
            }
            Self { pool, tables, lens, q, max_blocks, page_size, shared_rows }
        }

        fn seqs(&self) -> Vec<SeqAttn<'_>> {
            self.seqs_with_tables(&self.tables)
        }

        fn seqs_with_tables<'a>(&'a self, tables: &'a [Vec<u32>]) -> Vec<SeqAttn<'a>> {
            use crate::coordinator::kv_cache::PageCodec;
            let int8 = self.pool.codec() == PageCodec::Int8;
            (0..self.q.len())
                .map(|i| SeqAttn {
                    q: &self.q[i],
                    kv: if int8 {
                        SeqKv::PagedI8 {
                            k: self.pool.k_quant_store(),
                            v: self.pool.v_quant_store(),
                            pages: &tables[i],
                            max_blocks: self.max_blocks,
                            page_size: self.page_size,
                        }
                    } else {
                        SeqKv::Paged {
                            k_store: self.pool.k_store(),
                            v_store: self.pool.v_store(),
                            pages: &tables[i],
                            max_blocks: self.max_blocks,
                            page_size: self.page_size,
                        }
                    },
                    kv_len: self.lens[i],
                })
                .collect()
        }
    }

    /// The headline cascade invariant at kernel level: cascade decode
    /// is bit-identical to the per-sequence gather for random shapes,
    /// codecs, prefix claims, tile sizes and thread counts, and the
    /// stats count exactly the tile-aligned shared rows it skipped.
    #[test]
    fn prop_cascade_equals_per_sequence_gather() {
        use crate::coordinator::kv_cache::PageCodec;
        check(24, |rng| {
            let (h, kvh) = *rng.pick(&[(1usize, 1usize), (2, 1), (4, 2), (6, 3)]);
            let d = *rng.pick(&[4usize, 8]);
            let page_size = rng.range(2, 6);
            let shared_blocks = rng.range(1, 4);
            let extra_max = rng.range(0, 10);
            let nseq = rng.range(2, 7);
            let codec = if rng.bool() { PageCodec::Int8 } else { PageCodec::F32 };
            let b = SharedPagedBatch::random(
                rng,
                codec,
                nseq,
                h,
                kvh,
                d,
                page_size,
                shared_blocks,
                extra_max,
            );
            let mut shape = BatchShape::new(h, kvh, d, b.shared_rows + extra_max);
            shape.block_kv = rng.range(1, 10);
            let seqs = b.seqs();
            // any claim within the physically-shared extent is valid
            let shared_rows = rng.range(0, b.shared_rows + 1);
            let groups = [CascadeGroup { members: (0..nseq).collect(), shared_rows }];

            let mut base = vec![0.0f32; nseq * h * d];
            batch_decode_attention(
                &shape,
                &seqs,
                &mut base,
                &WorkPool::new(ParallelConfig::sequential()),
            );
            let tiles = shared_rows / shape.block_kv;
            for threads in [1usize, 4] {
                let pool = WorkPool::new(ParallelConfig { threads, min_work_per_thread: 0 });
                let mut out = vec![0.0f32; nseq * h * d];
                let stats = cascade_batch_decode_attention(&shape, &seqs, &groups, &mut out, &pool);
                prop_ensure!(
                    out == base,
                    "threads={threads} codec={codec:?} bkv={} shared={shared_rows}: \
                     cascade differs from per-sequence gather",
                    shape.block_kv
                );
                prop_ensure!(
                    (stats.passes > 0) == (tiles >= 1),
                    "passes {} with {tiles} shared tiles",
                    stats.passes
                );
                if tiles >= 1 {
                    let want = (tiles * shape.block_kv * kvh * 2 * (nseq - 1)) as u64;
                    prop_ensure!(
                        stats.rows_saved == want,
                        "rows_saved {} want {want}",
                        stats.rows_saved
                    );
                }
            }
            Ok(())
        });
    }

    /// A member whose page-table prefix diverges from the group's is
    /// filtered out of phase 1 (it runs the plain per-item kernel) —
    /// and the output is still bit-identical to the full gather.
    #[test]
    fn cascade_mismatched_member_runs_ungrouped() {
        use crate::coordinator::kv_cache::PageCodec;
        let mut rng = Rng::new(31);
        let (h, kvh, d, page_size, shared_blocks) = (2usize, 1usize, 4usize, 4usize, 2usize);
        let b = SharedPagedBatch::random(
            &mut rng,
            PageCodec::F32,
            3,
            h,
            kvh,
            d,
            page_size,
            shared_blocks,
            5,
        );
        // divert member 2's first "shared" block to one of its own
        // pages: its prefix is no longer page-identical
        let mut tables = b.tables.clone();
        tables[2][0] = tables[2][b.max_blocks - 1];
        let seqs = b.seqs_with_tables(&tables);
        let mut shape = BatchShape::new(h, kvh, d, b.shared_rows + 5);
        shape.block_kv = page_size;
        let groups =
            [CascadeGroup { members: vec![0, 1, 2], shared_rows: b.shared_rows }];

        let wp = WorkPool::new(ParallelConfig { threads: 2, min_work_per_thread: 0 });
        let mut base = vec![0.0f32; 3 * h * d];
        batch_decode_attention(&shape, &seqs, &mut base, &wp);
        let mut out = vec![0.0f32; 3 * h * d];
        let stats = cascade_batch_decode_attention(&shape, &seqs, &groups, &mut out, &wp);
        assert_eq!(out, base, "fallback member changed bits");
        assert_eq!(stats.passes, 1);
        // only members 0 and 1 cascade → one non-first member saves rows
        let tiles = b.shared_rows / shape.block_kv;
        assert_eq!(stats.rows_saved, (tiles * shape.block_kv * kvh * 2) as u64);
    }

    /// Contiguous layouts have no page identity to verify, so a contig
    /// group must fall back wholesale (zero stats, identical bits).
    #[test]
    fn cascade_contig_group_falls_back() {
        let mut rng = Rng::new(32);
        let b = Batch::random(&mut rng, 4, 4, 2, 8, 20);
        let seqs = b.seqs();
        let shared = *b.lens.iter().min().unwrap();
        let groups = [CascadeGroup { members: vec![0, 1, 2, 3], shared_rows: shared }];
        let wp = WorkPool::new(ParallelConfig { threads: 2, min_work_per_thread: 0 });
        let n = 4 * 4 * 8;
        let mut base = vec![0.0f32; n];
        batch_decode_attention(&b.shape, &seqs, &mut base, &wp);
        let mut out = vec![0.0f32; n];
        let stats = cascade_batch_decode_attention(&b.shape, &seqs, &groups, &mut out, &wp);
        assert_eq!(out, base);
        assert_eq!(stats, CascadeStats::default());
    }

    #[test]
    #[should_panic(expected = "exceeds member")]
    fn cascade_shared_rows_beyond_kv_len_panics() {
        let mut rng = Rng::new(33);
        let b = Batch::random(&mut rng, 2, 2, 1, 4, 8);
        let seqs = b.seqs();
        let bad = b.lens.iter().max().unwrap() + 1;
        let groups = [CascadeGroup { members: vec![0, 1], shared_rows: bad }];
        let mut out = vec![0.0f32; 2 * 2 * 4];
        cascade_batch_decode_attention(
            &b.shape,
            &seqs,
            &groups,
            &mut out,
            &WorkPool::new(ParallelConfig::sequential()),
        );
    }

    #[test]
    #[should_panic(expected = "two cascade groups")]
    fn cascade_duplicate_member_panics() {
        let mut rng = Rng::new(34);
        let b = Batch::random(&mut rng, 2, 2, 1, 4, 8);
        let seqs = b.seqs();
        let groups = [
            CascadeGroup { members: vec![0, 1], shared_rows: 0 },
            CascadeGroup { members: vec![1], shared_rows: 0 },
        ];
        let mut out = vec![0.0f32; 2 * 2 * 4];
        cascade_batch_decode_attention(
            &b.shape,
            &seqs,
            &groups,
            &mut out,
            &WorkPool::new(ParallelConfig::sequential()),
        );
    }
}
