//! Executable model of Appendix B: Volta m8n8k4 thread-data layouts and
//! the back-to-back-GEMM exchange argument.
//!
//! Volta's MMA executes per *quadpair* (QP, 8 threads): one `m8n8k4`
//! multiplies A(8×4)·B(4×8) += C(8×8).  Attention chains two GEMMs
//! (S = QKᵀ, O = P·V) and the layout of GEMM1's accumulator C decides
//! whether its elements already sit in the registers of the thread that
//! needs them as GEMM2's A operand:
//!
//! * **FP32 accumulators** (Fig 14): each thread's 8 C elements interleave
//!   across *two* row pairs — half of them belong to other threads' A rows
//!   for the next multiply, so the threads must exchange registers (shared
//!   memory round trip + syncwarp) between the GEMMs;
//! * **FP16 accumulators** (Fig 15): each thread's C elements lie on a
//!   single row — exactly the row it owns as the next A operand, so GEMM1
//!   feeds GEMM2 with **zero** exchange.  This is FastAttention's choice,
//!   and the TPU/Pallas analogue is keeping `p` VMEM-resident between the
//!   two dots (see `python/compile/kernels/fast_attention.py`).
//!
//! The maps below follow the paper's figures structurally (8 QP threads
//! indexed 0..8; exact PTX lane ids differ but the ownership *pattern*,
//! and therefore the exchange count, is what matters).  Tests verify the
//! partition properties and the paper's claim computationally.

/// Accumulator precision of the first GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Accumulator {
    F32,
    F16,
}

/// Tile constants for one quadpair MMA.
pub const M: usize = 8;
pub const N: usize = 8;
pub const K: usize = 4;
/// Threads per quadpair.
pub const QP_THREADS: usize = 8;

/// Which QP thread owns A(row, k) for the next `m8n8k4`?
/// A is 8×4 fp16: one row per thread, 4 consecutive elements.
pub fn a_owner(row: usize, _k: usize) -> usize {
    assert!(row < M);
    row
}

/// Which QP thread owns C(row, col) after an m8n8k4 with the given
/// accumulator precision?
///
/// * F16: row-major per thread — thread t owns the whole row t
///   (8 half-precision values, Fig 15);
/// * F32: each thread owns a 2×4 footprint that spans two rows —
///   thread t owns rows {2·(t%4), 2·(t%4)+1} restricted to the column
///   half selected by t/4 (Fig 14's spread pattern).
pub fn c_owner(acc: Accumulator, row: usize, col: usize) -> usize {
    assert!(row < M && col < N);
    match acc {
        Accumulator::F16 => row,
        Accumulator::F32 => (row / 2) + 4 * (col / 4),
    }
}

/// Count of C elements per thread (both layouts hold 8).
pub fn elements_per_thread(acc: Accumulator) -> usize {
    let mut counts = [0usize; QP_THREADS];
    for r in 0..M {
        for c in 0..N {
            counts[c_owner(acc, r, c)] += 1;
        }
    }
    assert!(counts.iter().all(|&x| x == counts[0]));
    counts[0]
}

/// Fraction of GEMM1's C elements that must move to a *different* thread
/// before they can serve as GEMM2's A operand (the exchange the paper
/// eliminates).  GEMM2 consumes C(8×8) as two A tiles of 8×4.
pub fn exchange_fraction(acc: Accumulator) -> f64 {
    let mut moved = 0usize;
    let mut total = 0usize;
    for r in 0..M {
        for c in 0..N {
            let have = c_owner(acc, r, c);
            let need = a_owner(r, c % K);
            total += 1;
            if have != need {
                moved += 1;
            }
        }
    }
    moved as f64 / total as f64
}

/// Estimated inter-GEMM cost in "register-move equivalents" per tile —
/// the quantity the Volta model's kernel-efficiency gap (Fig 8) stands
/// on: FP32 forces a shared-memory exchange + syncwarp, FP16 none.
pub fn inter_gemm_moves(acc: Accumulator) -> usize {
    ((exchange_fraction(acc) * (M * N) as f64).round()) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_layouts_partition_c_evenly() {
        assert_eq!(elements_per_thread(Accumulator::F16), 8);
        assert_eq!(elements_per_thread(Accumulator::F32), 8);
    }

    #[test]
    fn every_element_has_exactly_one_owner() {
        for acc in [Accumulator::F16, Accumulator::F32] {
            let mut seen = [[false; N]; M];
            for r in 0..M {
                for c in 0..N {
                    let t = c_owner(acc, r, c);
                    assert!(t < QP_THREADS);
                    assert!(!seen[r][c]);
                    seen[r][c] = true;
                }
            }
        }
    }

    #[test]
    fn fp16_needs_no_exchange() {
        // The paper's Fig 15 claim: C of GEMM1 divides into two A tiles
        // of GEMM2 "without the need for the exchange between threads".
        assert_eq!(exchange_fraction(Accumulator::F16), 0.0);
        assert_eq!(inter_gemm_moves(Accumulator::F16), 0);
    }

    #[test]
    fn fp32_requires_exchange() {
        // Fig 14: "half of the elements ... are not the needed elements".
        let f = exchange_fraction(Accumulator::F32);
        assert!(f >= 0.5, "exchange fraction {f}");
        assert!(inter_gemm_moves(Accumulator::F32) >= 32);
    }

    #[test]
    fn fp16_c_rows_match_a_rows() {
        for r in 0..M {
            for c in 0..N {
                assert_eq!(
                    c_owner(Accumulator::F16, r, c),
                    a_owner(r, c % K),
                    "({r},{c})"
                );
            }
        }
    }

    #[test]
    fn fp32_threads_span_two_rows() {
        // the structural reason the exchange exists
        for t in 0..QP_THREADS {
            let mut rows = std::collections::BTreeSet::new();
            for r in 0..M {
                for c in 0..N {
                    if c_owner(Accumulator::F32, r, c) == t {
                        rows.insert(r);
                    }
                }
            }
            assert_eq!(rows.len(), 2, "thread {t} rows {rows:?}");
        }
    }
}
