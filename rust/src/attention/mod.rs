//! Real attention implementations + the paper's planning components.
//!
//! * [`standard`] — naive `softmax(QKᵀ/√d)V`, the numeric oracle for
//!   property tests and the paper's baseline definition (§5.1);
//! * [`flash`]    — a real FlashAttention2 (online-softmax, tiled) CPU
//!   kernel in rust with native grouped-query attention
//!   (`kv_heads ≤ heads`); it executes the cooperative strategy's
//!   host-side decode attention (§4.4) and is what `sim::cpu` measures;
//! * [`batch`]    — the serving hot path: decode attention fused across a
//!   whole batch (all sequences × all query heads as one flat,
//!   cost-weighted work queue) on a scoped thread pool.  `threads = 1` is
//!   bit-identical to the per-sequence loop; K/V rows come from
//!   contiguous planes or from the paged KV cache through a block table
//!   (`SeqKv`), bit-identically; the engine selects parallelism via
//!   `ParallelConfig` on its config (see `DESIGN.md`); cascade decode
//!   (`cascade_batch_decode_attention`) additionally reads each
//!   shared-prefix page run once per batch and folds per-request
//!   suffixes through the kernel's LSE merge, still bit-identically;
//! * [`tiling`]   — the two-level tile-size planner under L0/L1 capacity
//!   constraints (§4.1);
//! * [`mask`]     — the tiling-mask generator: M-mask, B-mask extraction
//!   by shifting, block classification (§4.1, Figure 3);
//! * [`volta_layout`] — the Appendix B m8n8k4 thread-layout model: why
//!   FP16 accumulators feed back-to-back GEMMs without a register
//!   exchange while FP32 cannot.
//!
//! Numeric contract: `standard` is the oracle; `flash` matches it within
//! FP tolerance for every shape/tiling; `batch` matches `flash` exactly
//! (same inner kernel) and is invariant to thread count.

pub mod batch;
pub mod flash;
pub mod mask;
pub mod standard;
pub mod tiling;
pub mod volta_layout;

pub use batch::{
    batch_decode_attention, cascade_batch_decode_attention, BatchShape, CascadeGroup,
    CascadeStats, ParallelConfig, SeqAttn, SeqKv, WorkPool,
};
pub use flash::{merge_softmax_states, KvView};
