//! Real attention implementations + the paper's planning components.
//!
//! * [`standard`] — naive `softmax(QKᵀ/√d)V`, the numeric oracle for
//!   property tests and the paper's baseline definition (§5.1);
//! * [`flash`]    — a real FlashAttention2 (online-softmax, tiled) CPU
//!   kernel in rust; it executes the cooperative strategy's host-side
//!   decode attention (§4.4) and is what `sim::cpu` measures;
//! * [`tiling`]   — the two-level tile-size planner under L0/L1 capacity
//!   constraints (§4.1);
//! * [`mask`]     — the tiling-mask generator: M-mask, B-mask extraction
//!   by shifting, block classification (§4.1, Figure 3);
//! * [`volta_layout`] — the Appendix B m8n8k4 thread-layout model: why
//!   FP16 accumulators feed back-to-back GEMMs without a register
//!   exchange while FP32 cannot.

pub mod flash;
pub mod mask;
pub mod standard;
pub mod tiling;
pub mod volta_layout;
