//! Naive attention — the paper's "standard attention" baseline (§5.1)
//! and the numeric oracle for the rust-side property tests.
//!
//! Layout: row-major `[heads][seq][head_dim]` flat slices, batch handled
//! by the caller (the serving path operates per-sequence).

/// Shape/config for one standard-attention invocation.
#[derive(Debug, Clone, Copy)]
pub struct StdParams {
    pub heads: usize,
    pub seq_q: usize,
    pub seq_kv: usize,
    pub head_dim: usize,
    pub causal: bool,
    /// Softmax scale; use `1/sqrt(head_dim)` for the paper's formula.
    pub scale: f32,
}

/// Compute `out = softmax(q kᵀ · scale + mask) v`, materializing the full
/// score matrix per head (exactly what FastAttention avoids).
///
/// `q`: `[heads, seq_q, head_dim]`, `k`/`v`: `[heads, seq_kv, head_dim]`,
/// `out`: `[heads, seq_q, head_dim]`.
pub fn standard_attention(q: &[f32], k: &[f32], v: &[f32], out: &mut [f32], p: &StdParams) {
    let (h, sq, skv, d) = (p.heads, p.seq_q, p.seq_kv, p.head_dim);
    assert_eq!(q.len(), h * sq * d, "q shape");
    assert_eq!(k.len(), h * skv * d, "k shape");
    assert_eq!(v.len(), h * skv * d, "v shape");
    assert_eq!(out.len(), h * sq * d, "out shape");

    let mut scores = vec![0.0f32; skv];
    for head in 0..h {
        let qh = &q[head * sq * d..][..sq * d];
        let kh = &k[head * skv * d..][..skv * d];
        let vh = &v[head * skv * d..][..skv * d];
        let oh = &mut out[head * sq * d..][..sq * d];
        for i in 0..sq {
            let qi = &qh[i * d..][..d];
            // causal with suffix alignment: row i sees j <= i + (skv - sq)
            let limit = if p.causal { i + 1 + skv - sq } else { skv };
            let mut max = f32::NEG_INFINITY;
            for j in 0..limit {
                let kj = &kh[j * d..][..d];
                let s: f32 = qi.iter().zip(kj).map(|(a, b)| a * b).sum();
                let s = s * p.scale;
                scores[j] = s;
                if s > max {
                    max = s;
                }
            }
            let mut sum = 0.0f32;
            for j in 0..limit {
                scores[j] = (scores[j] - max).exp();
                sum += scores[j];
            }
            let inv = if sum > 0.0 { 1.0 / sum } else { 0.0 };
            let oi = &mut oh[i * d..][..d];
            oi.fill(0.0);
            for j in 0..limit {
                let w = scores[j] * inv;
                let vj = &vh[j * d..][..d];
                for (o, x) in oi.iter_mut().zip(vj) {
                    *o += w * x;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(h: usize, sq: usize, skv: usize, d: usize, causal: bool) -> StdParams {
        StdParams {
            heads: h,
            seq_q: sq,
            seq_kv: skv,
            head_dim: d,
            causal,
            scale: 1.0 / (d as f32).sqrt(),
        }
    }

    #[test]
    fn uniform_scores_average_v() {
        // q = 0 → uniform weights → out = mean(v).
        let p = params(1, 1, 4, 2, false);
        let q = vec![0.0; 2];
        let k = vec![1.0; 8];
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut out = vec![0.0; 2];
        standard_attention(&q, &k, &v, &mut out, &p);
        assert!((out[0] - 4.0).abs() < 1e-6);
        assert!((out[1] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn causal_first_row_copies_v0() {
        let p = params(1, 3, 3, 2, true);
        let q: Vec<f32> = (0..6).map(|x| x as f32 * 0.1).collect();
        let k: Vec<f32> = (0..6).map(|x| (x as f32) * 0.2 - 0.5).collect();
        let v: Vec<f32> = vec![9.0, -3.0, 1.0, 1.0, 2.0, 2.0];
        let mut out = vec![0.0; 6];
        standard_attention(&q, &k, &v, &mut out, &p);
        assert!((out[0] - 9.0).abs() < 1e-6);
        assert!((out[1] + 3.0).abs() < 1e-6);
    }

    #[test]
    fn one_hot_scores_select_row() {
        // strongly peaked q·k picks one v row
        let p = StdParams { scale: 100.0, ..params(1, 1, 3, 2, false) };
        let q = vec![1.0, 0.0];
        let k = vec![0.0, 1.0, 1.0, 0.0, 0.0, -1.0]; // row 1 aligned with q
        let v = vec![1.0, 1.0, 7.0, 8.0, 2.0, 2.0];
        let mut out = vec![0.0; 2];
        standard_attention(&q, &k, &v, &mut out, &p);
        assert!((out[0] - 7.0).abs() < 1e-3);
        assert!((out[1] - 8.0).abs() < 1e-3);
    }

    #[test]
    #[should_panic(expected = "q shape")]
    fn bad_shape_panics() {
        let p = params(1, 2, 2, 2, false);
        let mut out = vec![0.0; 4];
        standard_attention(&[0.0; 3], &[0.0; 4], &[0.0; 4], &mut out, &p);
    }
}
