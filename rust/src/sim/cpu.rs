//! Host-CPU attention substrate.
//!
//! Table 3's CPU_Calc column is the cooperative strategy's host-side
//! decode attention.  Here we model it *and* measure it: the analytical
//! rate lives in `VoltaSpec::decode_attention_cpu`; this module measures
//! the real rust FlashAttention2 kernel (`attention::flash`) on this
//! machine so the model can be cross-checked (EXPERIMENTS.md records the
//! measured stream rate next to the calibrated one).

use std::time::Instant;

use crate::attention::flash::{flash_attention, FlashParams};

/// A measured decode-attention sample.
#[derive(Debug, Clone, Copy)]
pub struct CpuSample {
    /// KV length.
    pub kv: usize,
    /// Heads × head_dim used.
    pub heads: usize,
    pub head_dim: usize,
    /// Wall-clock seconds per decode step (batch 1).
    pub seconds: f64,
    /// Effective KV streaming rate, bytes/s (fp32 here; fp16 on the paper
    /// host — rates are comparable since both are memory-bound).
    pub stream_bw: f64,
}

/// Measure real decode attention (seq_q = 1) over a KV cache of length
/// `kv` with `heads`×`head_dim`, repeated `reps` times; returns the best
/// sample (standard micro-bench practice: min filters scheduler noise).
pub fn measure_decode(kv: usize, heads: usize, head_dim: usize, reps: usize) -> CpuSample {
    let q = vec![0.01f32; heads * head_dim];
    let k = vec![0.02f32; heads * kv * head_dim];
    let v = vec![0.03f32; heads * kv * head_dim];
    let mut out = vec![0.0f32; heads * head_dim];

    let params = FlashParams {
        heads,
        kv_heads: heads,
        seq_q: 1,
        seq_kv: kv,
        head_dim,
        causal: false,
        block_q: 1,
        block_kv: 64,
        scale: 1.0 / (head_dim as f32).sqrt(),
    };

    let mut best = f64::INFINITY;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        flash_attention(&q, &k, &v, &mut out, &params);
        let dt = t0.elapsed().as_secs_f64();
        if dt < best {
            best = dt;
        }
    }
    let kv_bytes = (2 * heads * kv * head_dim * 4) as f64;
    CpuSample {
        kv,
        heads,
        head_dim,
        seconds: best,
        stream_bw: kv_bytes / best,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measurement_is_positive_and_scales() {
        let small = measure_decode(512, 4, 64, 3);
        let large = measure_decode(4096, 4, 64, 3);
        assert!(small.seconds > 0.0);
        assert!(large.seconds > small.seconds);
        // Roughly linear in KV (memory-bound): 8× KV within 3×..20× time.
        let ratio = large.seconds / small.seconds;
        assert!(ratio > 3.0 && ratio < 24.0, "ratio {ratio:.1}");
    }

    #[test]
    fn stream_bw_plausible() {
        let s = measure_decode(8192, 8, 64, 3);
        // Any real machine streams KV between 0.05 (debug build) and
        // 400 GB/s (the release-build number is what EXPERIMENTS.md cites).
        assert!(
            s.stream_bw > 0.05e9 && s.stream_bw < 400e9,
            "bw {:.2} GB/s",
            s.stream_bw / 1e9
        );
    }
}
