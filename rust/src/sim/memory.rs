//! Appendix C memory formulas (eq. 15–20) and the CPU–GPU split planner.
//!
//! All quantities are bytes, fp16 storage (2 B/element) unless noted:
//!
//!   M_w    = L (8 H1² + 4 H1 H2)                (eq. 17)
//!   M_kv   = 4 B H1 (S + O) / n                 (eq. 18, per layer/GPU)
//!   M_mid  = 6 B S H1 / n                       (eq. 19)
//!   M_vocab= 2 V H1
//!   L_GPU  = (M_GPU - M_w/n - M_mid - M_vocab) / M_kv   (eq. 15/20)
//!   L_CPU  = L - L_GPU                          (eq. 16)
//!
//! Note: eq. 17 applied to Table 1's PanGu-38B config yields ~25 GB — far
//! below the 76 GB a true 38 B-parameter fp16 model occupies (the paper's
//! table appears to list a per-branch or reduced config).  The planner
//! therefore uses the *parameter count* for the weight term
//! (`M_w = 2·params`) and eq. 17 remains available as
//! [`Deployment::m_w_eq17`].  The baseline (FasterTransformer-without-
//! FastAttention) additionally holds a per-token runtime workspace during
//! its monolithic prefill (activation/logits buffers); the calibrated
//! default reproduces Fig 11's ~16K ceiling on 8×V100-16GB.  FastAttention
//! avoids that term by streaming prefill KV to the host asynchronously
//! (§4.4 step 3).

use crate::models::ModelShape;

/// Default V100 memory (the paper's 8×V100 node, 16 GB SXM2 variant).
pub const V100_16GB: u64 = 16 * (1 << 30);
/// Calibrated FT baseline workspace per token of context (activations,
/// logits, fp32 scratch during monolithic prefill).
pub const BASELINE_WORKSPACE_PER_TOKEN: u64 = 224 << 10;

/// Inference-deployment description for the memory planner.
#[derive(Debug, Clone, Copy)]
pub struct Deployment {
    pub model: ModelShape,
    /// Number of GPUs, `n`.
    pub n_gpus: u32,
    /// Single-GPU memory, bytes.
    pub gpu_mem_bytes: u64,
    /// Batch size `B`.
    pub batch: u64,
    /// Input length `S`.
    pub seq: u64,
    /// Output length `O`.
    pub out: u64,
    /// Baseline per-token prefill workspace (see module docs).
    pub workspace_per_token: u64,
}

impl Deployment {
    /// Standard 8×V100-16GB deployment for `model`.
    pub fn v100_node(model: ModelShape, seq: u64, out: u64) -> Self {
        Self {
            model,
            n_gpus: 8,
            gpu_mem_bytes: V100_16GB,
            batch: 1,
            seq,
            out,
            workspace_per_token: BASELINE_WORKSPACE_PER_TOKEN,
        }
    }
}

/// The planner's memory breakdown (bytes).
#[derive(Debug, Clone, Copy)]
pub struct MemoryBreakdown {
    pub weights_total: u64,
    pub weights_per_gpu: u64,
    pub kv_per_layer_per_gpu: u64,
    pub mid_per_gpu: u64,
    pub vocab: u64,
    /// Layers whose KV cache fits on the GPU (eq. 15), clamped to [0, L].
    pub l_gpu: u32,
    /// Layers whose KV cache lives on the host (eq. 16).
    pub l_cpu: u32,
    /// Whether decode-state KV fits entirely on-device.
    pub fits_without_offload: bool,
}

impl Deployment {
    /// Weight bytes: `2 · params` (true fp16 footprint).
    pub fn m_w(&self) -> u64 {
        2 * self.model.params
    }

    /// eq. 17 as literally written (transformer-block GEMM weights only).
    pub fn m_w_eq17(&self) -> u64 {
        self.model.weight_bytes_fp16()
    }

    /// eq. 18: one layer's KV cache per GPU, fp16.
    pub fn m_kv(&self) -> u64 {
        self.model
            .kv_bytes_per_layer_fp16(self.batch, self.seq + self.out, self.n_gpus)
    }

    /// eq. 19: intermediate activations per GPU, fp16.
    pub fn m_mid(&self) -> u64 {
        6 * self.batch * self.model.hidden() * self.seq / self.n_gpus as u64
    }

    /// Vocabulary matrix, fp16 (replicated in FT).
    pub fn m_vocab(&self) -> u64 {
        2 * self.model.vocab as u64 * self.model.hidden()
    }

    /// eq. 15/16/20: the full breakdown + layer split (decode state — the
    /// quantity the cooperative strategy plans against).
    pub fn plan(&self) -> MemoryBreakdown {
        let m_w = self.m_w();
        let m_kv = self.m_kv();
        let m_mid = self.m_mid();
        let m_vocab = self.m_vocab();
        let per_gpu_w = m_w / self.n_gpus as u64;

        let free = self.gpu_mem_bytes as i128
            - per_gpu_w as i128
            - m_mid as i128
            - m_vocab as i128;
        let l = self.model.layers;
        let l_gpu = if free <= 0 || m_kv == 0 {
            0
        } else {
            ((free as u128 / m_kv as u128) as u64).min(l as u64) as u32
        };
        MemoryBreakdown {
            weights_total: m_w,
            weights_per_gpu: per_gpu_w,
            kv_per_layer_per_gpu: m_kv,
            mid_per_gpu: m_mid,
            vocab: m_vocab,
            l_gpu,
            l_cpu: l - l_gpu,
            fits_without_offload: l_gpu >= l,
        }
    }

    /// Per-GPU bytes the *baseline* needs at context length `s`:
    /// weights + vocab + full KV residency + monolithic-prefill workspace.
    fn baseline_bytes_at(&self, s: u64) -> u128 {
        let d = Deployment { seq: s, ..*self };
        let plan = d.plan();
        plan.weights_per_gpu as u128
            + plan.vocab as u128
            + plan.mid_per_gpu as u128
            + plan.kv_per_layer_per_gpu as u128 * self.model.layers as u128
            + (self.workspace_per_token * s * self.batch) as u128
    }

    /// Largest input length `S` the baseline supports (full KV on-device,
    /// monolithic prefill) — Fig 11: FT-without-FastAttention ≈ 16K.
    pub fn max_seq_without_offload(&self) -> u64 {
        let mut lo = 0u64;
        let mut hi = 1u64 << 24;
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.baseline_bytes_at(mid) <= self.gpu_mem_bytes as u128 {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }

    /// Largest input length with the CPU–GPU cooperative strategy: the
    /// host absorbs pre-L_CPU layers' KV; the device keeps weights, vocab,
    /// the L_GPU layers' KV, and only block-streamed prefill buffers
    /// (§4.4 step 3 eliminates the monolithic workspace).
    pub fn max_seq_with_offload(&self, host_mem_bytes: u64) -> u64 {
        let mut lo = 0u64;
        let mut hi = 1u64 << 24;
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            let d = Deployment { seq: mid, ..*self };
            let plan = d.plan();
            let host_kv = plan.kv_per_layer_per_gpu as u128
                * plan.l_cpu as u128
                * self.n_gpus as u128;
            let dev = plan.weights_per_gpu as u128
                + plan.vocab as u128
                + plan.mid_per_gpu as u128
                + plan.kv_per_layer_per_gpu as u128 * plan.l_gpu as u128;
            let ok =
                host_kv <= host_mem_bytes as u128 && dev <= self.gpu_mem_bytes as u128;
            if ok {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::PANGU_38B;

    const GB: u64 = 1 << 30;

    fn pangu_deploy(seq: u64) -> Deployment {
        Deployment::v100_node(PANGU_38B, seq, 50)
    }

    #[test]
    fn short_seq_fits_without_offload() {
        // Table 3: rows 1K–8K show '-' (no offload needed).
        for s in [1024, 2048, 4096, 8192] {
            assert!(
                pangu_deploy(s).plan().fits_without_offload,
                "S={s} should fit"
            );
        }
    }

    #[test]
    fn long_seq_requires_offload() {
        // Table 3: from 64K the KV split engages; at 16K the KV itself
        // still fits but the baseline workspace doesn't (Fig 11 ceiling).
        for s in [64 * 1024, 128 * 1024, 256 * 1024] {
            let plan = pangu_deploy(s).plan();
            assert!(!plan.fits_without_offload, "S={s} should need offload");
            assert!(plan.l_cpu > 0);
            assert_eq!(plan.l_cpu + plan.l_gpu, PANGU_38B.layers);
        }
    }

    #[test]
    fn baseline_max_seq_near_16k() {
        // Fig 11: FT without FastAttention supports up to ~16K.
        let max = pangu_deploy(0).max_seq_without_offload();
        assert!(
            (10 * 1024..32 * 1024).contains(&max),
            "baseline max_seq = {max}"
        );
    }

    #[test]
    fn offload_reaches_256k() {
        // Fig 11 / Table 3: 256K with the cooperative strategy
        // (host-memory bound; a DGX-class host has ~512 GB+).
        let max = pangu_deploy(0).max_seq_with_offload(768 * GB);
        assert!(max >= 256 * 1024, "offload max_seq = {max}");
    }

    #[test]
    fn l_gpu_decreases_with_seq() {
        let a = pangu_deploy(32 * 1024).plan().l_gpu;
        let b = pangu_deploy(96 * 1024).plan().l_gpu;
        let c = pangu_deploy(256 * 1024).plan().l_gpu;
        assert!(a > b && b > c, "{a} {b} {c}");
    }

    #[test]
    fn kv_matches_eq18() {
        let d = pangu_deploy(16 * 1024);
        assert_eq!(d.m_kv(), 4 * 5120 * (16 * 1024 + 50) / 8);
    }

    #[test]
    fn mid_matches_eq19() {
        let d = pangu_deploy(4096);
        assert_eq!(d.m_mid(), 6 * 4096 * 5120 / 8);
    }

    #[test]
    fn eq17_lower_bound_documented() {
        // eq. 17 on Table 1's config understates the fp16 footprint; the
        // planner uses 2·params.  Keep both observable.
        let d = pangu_deploy(1024);
        assert!(d.m_w_eq17() < d.m_w());
        assert_eq!(d.m_w(), 76 * 1_000_000_000 / 1); // 2 × 38e9
    }
}
