//! Hardware substrates, rebuilt as calibrated simulators.
//!
//! The paper's evaluation ran on Ascend 910B NPUs and Tesla V100 GPUs —
//! neither exists in this environment (repro band 0), so per the
//! substitution rule (DESIGN.md §3) every device is modeled:
//!
//! * [`ascend`]  — 910B analytical model (Cube/Vector units, L0/L1/L2/GM
//!   hierarchy, sync overhead, SDMA) for the standard / unified-tiling /
//!   two-level-tiling attention variants;
//! * [`pipeline`] — discrete-event two-stage (Cube→Vector) pipeline
//!   simulator that produces the overlap behaviour of Figure 2;
//! * [`volta`]   — V100 model (tensor-core roofline, SRAM-limited tiles,
//!   PCIe) for Fig 8 / Table 3 / Fig 11;
//! * [`cpu`]     — host CPU attention rate model (Table 3 CPU_Calc),
//!   cross-checked against the *real* rust FlashAttention2 kernel in
//!   `attention::flash`;
//! * [`collective`] — ring-AllReduce model + the tiling-AllReduce overlap
//!   schedule (Fig 4, Table 2, Figs 16/17);
//! * [`memory`]  — the paper's Appendix C memory formulas (eq. 15–20).
//!
//! Calibration targets are the paper's *baseline absolutes* (e.g. Table 3
//! GPU_Calc = 0.058 ms at S=1K); the claims under test are the ratios and
//! crossovers.  See EXPERIMENTS.md for paper-vs-model tables.

pub mod ascend;
pub mod collective;
pub mod cpu;
pub mod memory;
pub mod pipeline;
pub mod volta;

/// An attention workload: the shape tuple every model consumes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttnWorkload {
    /// Batch size `B`.
    pub batch: u64,
    /// Heads resident on this device, `N`.
    pub heads: u64,
    /// Query sequence length (`S` for prefill, 1 for decode).
    pub seq_q: u64,
    /// Key/value sequence length.
    pub seq_kv: u64,
    /// Head dimension `D`.
    pub head_dim: u64,
    /// Causal masking.
    pub causal: bool,
}

impl AttnWorkload {
    /// Prefill workload (`seq_q == seq_kv == s`).
    pub fn prefill(batch: u64, heads: u64, s: u64, head_dim: u64, causal: bool) -> Self {
        Self { batch, heads, seq_q: s, seq_kv: s, head_dim, causal }
    }

    /// Decode-step workload (`seq_q = 1` over `kv` cached tokens).
    pub fn decode(batch: u64, heads: u64, kv: u64, head_dim: u64) -> Self {
        Self { batch, heads, seq_q: 1, seq_kv: kv, head_dim, causal: false }
    }

    /// Total attention FLOPs (2 GEMMs, 2 FLOPs/MAC), before causal skip.
    pub fn flops(&self) -> f64 {
        4.0 * self.batch as f64
            * self.heads as f64
            * self.seq_q as f64
            * self.seq_kv as f64
            * self.head_dim as f64
    }

    /// Fraction of score blocks that survive causal skipping:
    /// ~(S+b)/2S for block size b; 1.0 when non-causal.
    pub fn causal_keep_fraction(&self, block: u64) -> f64 {
        if !self.causal || self.seq_q != self.seq_kv {
            return 1.0;
        }
        let nb = (self.seq_kv + block - 1) / block;
        if nb == 0 {
            return 1.0;
        }
        // kept blocks per q-block row i: i+1 of nb
        let kept: u64 = (1..=nb).sum();
        kept as f64 / (nb * nb) as f64
    }

    /// Bytes of Q + K + V + O at `elem` bytes per element.
    pub fn io_bytes(&self, elem: u64) -> u64 {
        let q = self.batch * self.heads * self.seq_q * self.head_dim;
        let kv = 2 * self.batch * self.heads * self.seq_kv * self.head_dim;
        (2 * q + kv) * elem
    }

    /// Bytes of the full S×S score matrix (what standard attention
    /// round-trips through GM and what the tiling-mask avoids).
    pub fn score_bytes(&self, elem: u64) -> u64 {
        self.batch * self.heads * self.seq_q * self.seq_kv * elem
    }
}

/// Seconds → milliseconds, for display.
pub fn ms(s: f64) -> f64 {
    s * 1e3
}

/// Seconds → microseconds, for display.
pub fn us(s: f64) -> f64 {
    s * 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_matches_paper_formula() {
        // paper §5.2.3: 4 · seqlen² · head_dim · heads (B=1)
        let w = AttnWorkload::prefill(1, 64, 4096, 32, false);
        assert_eq!(w.flops(), 4.0 * 4096.0 * 4096.0 * 32.0 * 64.0);
    }

    #[test]
    fn causal_keep_fraction_halves_large_seq() {
        let w = AttnWorkload::prefill(1, 1, 16384, 128, true);
        let f = w.causal_keep_fraction(128);
        assert!(f > 0.5 && f < 0.51, "got {f}");
    }

    #[test]
    fn causal_keep_fraction_one_when_noncausal() {
        let w = AttnWorkload::prefill(1, 1, 4096, 128, false);
        assert_eq!(w.causal_keep_fraction(128), 1.0);
    }

    #[test]
    fn decode_workload_single_row() {
        let w = AttnWorkload::decode(4, 8, 1024, 64);
        assert_eq!(w.seq_q, 1);
        assert_eq!(w.flops(), 4.0 * 4.0 * 8.0 * 1024.0 * 64.0);
    }
}
