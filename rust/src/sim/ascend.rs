//! Ascend 910B cost model.
//!
//! Models the decoupled Cube/Vector AI-core architecture of §3 and the
//! three attention implementations of §4.1–4.2:
//!
//! * **standard attention** — unfused `softmax(QKᵀ/√d)V`: every
//!   intermediate S×S tensor (scores, masked scores, probabilities) and
//!   the S×S `attention_mask` round-trips global memory, plus one kernel
//!   launch per op;
//! * **unified tiling** — the direct FlashAttention2 port: small blocks,
//!   Cube→Vector handoff (and synchronization) per block, no GM
//!   double-buffering;
//! * **two-level tiling** — FastAttention: large first-level (L1-sized)
//!   blocks amortize synchronizations and make GM loads contiguous;
//!   second-level (L0-sized) sub-blocks keep the Cube fed; double
//!   buffering overlaps loads with compute.
//!
//! The **tiling-mask** option removes the S×S mask traffic, skips
//! fully-masked blocks (≈50% of Cube work for causal) and the mask-add on
//! fully-visible blocks.
//!
//! Constants are public-spec values calibrated so that standard-attention
//! absolutes land near the paper's baselines; the reproduced claims are
//! the ratios (Figs 7, 9; Tables 2, 4, 6, 8, 9).

use super::pipeline::{self, BlockTask, PipelineConfig, PipelineResult};
use super::AttnWorkload;

/// Ascend 910B hardware parameters.
#[derive(Debug, Clone, Copy)]
pub struct AscendSpec {
    /// Aggregate Cube (matrix) throughput, FP16 FLOP/s.
    pub cube_flops_fp16: f64,
    /// Aggregate Cube throughput, INT8 OP/s.
    pub cube_ops_int8: f64,
    /// Aggregate Vector (element-wise) throughput, FLOP/s.
    pub vector_flops: f64,
    /// Number of AI cores (Cube+Vector pairs).
    pub num_cores: u64,
    /// Global-memory (HBM) bandwidth, B/s.
    pub gm_bw: f64,
    /// L2 buffer bandwidth, B/s — K/V slabs re-read by subsequent q-block
    /// rows on the same core hit L2, not GM.
    pub l2_bw: f64,
    /// Effective per-transaction GM latency (drives the bandwidth
    /// efficiency of small, strided loads), seconds.
    pub gm_latency_s: f64,
    /// L1 buffer per AI core, bytes (Cube-side input buffer).
    pub l1_bytes: u64,
    /// L0A/L0B buffer per Cube unit, bytes.
    pub l0_bytes: u64,
    /// Cube↔Vector synchronization cost (decoupled units exchange through
    /// L2/GM), seconds.
    pub sync_s: f64,
    /// Host-side kernel launch overhead per op, seconds.
    pub op_launch_s: f64,
    /// PyTorch-eager per-op dispatch overhead (Table 6's unfused
    /// "standard attention" system), seconds.
    pub framework_op_overhead_s: f64,
    /// Ops per decoder layer in the eager unfused decode path.
    pub framework_ops_per_layer: f64,
    /// Ops per decoder layer when attention+linear are fused (the
    /// surrounding model still dispatches eagerly).
    pub framework_ops_fused: f64,
    /// Achievable fraction of Cube peak for well-shaped fp16 GEMM tiles.
    pub cube_eff: f64,
}

impl Default for AscendSpec {
    fn default() -> Self {
        Self {
            cube_flops_fp16: 376e12,
            cube_ops_int8: 752e12,
            vector_flops: 12e12,
            num_cores: 24,
            gm_bw: 1.6e12,
            l2_bw: 4.0e12,
            gm_latency_s: 1.2e-6,
            l1_bytes: 1 << 20,  // 1 MiB
            l0_bytes: 64 << 10, // 64 KiB
            sync_s: 2.0e-6,
            op_launch_s: 20.0e-6,
            framework_op_overhead_s: 70.0e-6,
            framework_ops_per_layer: 33.0,
            framework_ops_fused: 5.0,
            cube_eff: 0.70,
        }
    }
}

/// Which attention implementation to model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tiling {
    /// FlashAttention2 port with a single block level of size `block`.
    Unified { block: u64 },
    /// FastAttention two-level tiling: first level `block1` (L1-sized),
    /// second level `block2` (L0-sized), `block2 | block1`.
    TwoLevel { block1: u64, block2: u64 },
}

/// Options for the fused FastAttention kernel model.
#[derive(Debug, Clone, Copy)]
pub struct FastAttnOptions {
    pub tiling: Tiling,
    /// Apply the tiling-mask strategy: generate B-masks in-kernel from
    /// the M-mask instead of streaming the S×S mask from GM, and skip the
    /// mask-add on fully-visible blocks.  (Fully-*masked* block skipping
    /// is part of the tiling itself, as in FlashAttention2, and happens
    /// with or without this option — the paper's Table 2 ablation lists
    /// tiling-mask as memory-saving, speedup 1×.)
    pub tiling_mask: bool,
    /// Element size (2 = fp16, 1 = int8).
    pub elem_bytes: u64,
}

impl Default for FastAttnOptions {
    fn default() -> Self {
        Self {
            tiling: Tiling::TwoLevel { block1: 512, block2: 128 },
            tiling_mask: true,
            elem_bytes: 2,
        }
    }
}

/// Latency report for one attention invocation.
#[derive(Debug, Clone, Copy)]
pub struct AttnReport {
    /// End-to-end operator latency, seconds.
    pub latency_s: f64,
    /// Per-core pipeline detail.
    pub pipeline: PipelineResult,
    /// Effective Cube FLOP/s achieved.
    pub achieved_flops: f64,
    /// Cube-peak fraction achieved (the paper-style efficiency ratio).
    pub efficiency: f64,
}

/// Vector-unit element-wise op count per score element in the fused
/// kernel (max, sub, exp, running-sum, two rescales, final div ≈ 7).
const VECTOR_OPS_PER_SCORE: f64 = 7.0;
/// Extra Vector ops per score element for an explicit mask add.
const MASK_ADD_OPS: f64 = 1.0;
/// Vector passes per score element in the *unfused* standard softmax
/// (scale, mask add, max, sub+exp, sum, div — each a separate GM pass).
const STD_VECTOR_OPS: f64 = 6.0;

impl AscendSpec {
    fn bw_eff(&self, contiguous_bytes: f64) -> f64 {
        // Per-transaction latency model: efficiency rises with transfer
        // size; the two-level strategy's "larger continuous blocks for the
        // utilization of memory bandwidth".
        let per_core_bw = self.gm_bw / self.num_cores as f64;
        contiguous_bytes / (contiguous_bytes + self.gm_latency_s * per_core_bw)
    }

    fn cube_tile_eff(&self, m: u64, k: u64) -> f64 {
        // MXU/Cube pipelines drain on small tiles; 16×16 granularity.
        let e_m = m as f64 / (m as f64 + 16.0);
        let e_k = k as f64 / (k as f64 + 16.0);
        self.cube_eff * e_m.min(1.0) * e_k.min(1.0) / (128.0f64 / (128.0 + 16.0)).powi(2)
    }

    /// Latency of the unfused standard attention (the paper's baseline).
    pub fn standard_attention_latency(&self, w: &AttnWorkload) -> f64 {
        let flops = w.flops();
        let cube_t = flops / (self.cube_flops_fp16 * self.cube_eff);

        // GM traffic: QKᵀ writes S², mask-add reads S² + mask S² + writes
        // S², softmax reads+writes S² (two passes), PV reads S²; plus the
        // QKV/O tensors themselves.
        let score = w.score_bytes(2) as f64;
        let mask = if w.causal { score } else { 0.0 };
        let traffic = 7.0 * score + mask + w.io_bytes(2) as f64;
        let io_t = traffic / self.gm_bw;

        let vector_t =
            STD_VECTOR_OPS * w.score_bytes(1) as f64 / self.vector_flops;

        // Unfused: ~6 kernel launches (QKᵀ, scale, mask, softmax ×2, PV).
        let n_ops = if w.causal { 6.0 } else { 5.0 };
        cube_t + io_t + vector_t + n_ops * self.op_launch_s
    }

    /// Latency of the fused FastAttention kernel under `opts`.
    pub fn fastattn_latency(&self, w: &AttnWorkload, opts: &FastAttnOptions) -> AttnReport {
        let (block1, block2, depth, overlap, sync_per_l1) = match opts.tiling {
            Tiling::Unified { block } => (block, block, 2usize, false, false),
            Tiling::TwoLevel { block1, block2 } => (block1, block2, 2usize, true, true),
        };
        let block1 = block1.min(w.seq_kv.max(1));
        let block2 = block2.min(block1);

        let block_q = 128.min(w.seq_q.max(1));
        let d = w.head_dim;
        let eb = opts.elem_bytes as f64;

        // Work decomposition: (B·N·q-blocks) rows over the AI cores.
        let q_blocks = (w.seq_q + block_q - 1) / block_q;
        let rows = w.batch * w.heads * q_blocks;
        let rows_per_core = (rows + self.num_cores - 1) / self.num_cores;

        let kv_blocks_l1 = (w.seq_kv + block1 - 1) / block1;
        // causal skip: fully-masked blocks never execute (FA2-style, part
        // of the tiling regardless of the tiling-mask option)
        let keep = w.causal_keep_fraction(block1);
        let l1_per_row = ((kv_blocks_l1 as f64 * keep).ceil() as u64).max(1);

        // Per-core peaks.
        let cube_core = self.cube_flops_fp16 / self.num_cores as f64;
        let vec_core = self.vector_flops / self.num_cores as f64;

        let n_inner = (block1 + block2 - 1) / block2;
        let tile_eff = self.cube_tile_eff(block_q.min(128), block2.min(128));

        // --- per-L1-block stage times --------------------------------
        // Cube: QKᵀ + PV over the whole slab, sub-block by sub-block.
        let blk_flops = 4.0 * (block_q * block1 * d) as f64;
        let int8_scale = if opts.elem_bytes == 1 {
            self.cube_ops_int8 / self.cube_flops_fp16
        } else {
            1.0
        };
        let cube_s = blk_flops / (cube_core * tile_eff * int8_scale);

        // Vector: online-softmax update; mask-add extra when the mask is
        // explicit (no tiling-mask: every processed block adds the mask)
        // or the block is partial (≈ the diagonal fringe ≈ 1/l1_per_row
        // of processed blocks under tiling-mask).
        let scores = (block_q * block1) as f64;
        let mask_frac = if !opts.tiling_mask && w.causal {
            1.0
        } else if w.causal {
            1.0 / l1_per_row as f64
        } else {
            0.0
        };
        let vector_s =
            scores * (VECTOR_OPS_PER_SCORE + MASK_ADD_OPS * mask_frac) / vec_core;

        // Loads: K+V slab (+ the S×S mask slab when not tiling-masked).
        let kv_bytes = 2.0 * (block1 * d) as f64 * eb;
        let mask_bytes = if !opts.tiling_mask && w.causal {
            scores * eb
        } else {
            0.0
        };
        let contiguous = if sync_per_l1 { kv_bytes } else { kv_bytes / n_inner as f64 };
        // First q-block row on a core streams the slab from GM; the other
        // rows_per_core - 1 rows re-read it through L2.
        let gm_rate = self.gm_bw / self.num_cores as f64 * self.bw_eff(contiguous);
        let l2_rate = self.l2_bw / self.num_cores as f64;
        let rpc = rows_per_core as f64;
        let load_rate = rpc / (1.0 / gm_rate + (rpc - 1.0) / l2_rate);
        let load_s = (kv_bytes + mask_bytes) / load_rate;

        // --- build one core's task stream ----------------------------
        let tasks_per_l1: u64 = if sync_per_l1 { 1 } else { n_inner };
        let n_tasks = (rows_per_core * l1_per_row * tasks_per_l1) as usize;
        let scale = 1.0 / tasks_per_l1 as f64;
        let task = BlockTask {
            cube_s: cube_s * scale,
            vector_s: vector_s * scale,
            load_s: load_s * scale,
        };
        let tasks = vec![task; n_tasks.max(1)];
        let result = pipeline::simulate(
            &tasks,
            &PipelineConfig { sync_s: self.sync_s, depth, overlap_loads: overlap },
        );

        let latency = result.makespan_s + self.op_launch_s;
        let useful_flops = w.flops() * w.causal_keep_fraction(block1);
        AttnReport {
            latency_s: latency,
            pipeline: result,
            achieved_flops: useful_flops / latency,
            efficiency: useful_flops / latency / self.cube_flops_fp16,
        }
    }

    /// Prefill latency of one full transformer layer (attention via
    /// `opts`, projections/MLP at Cube GEMM rate, weight+activation GM
    /// traffic).  Used by the end-to-end compositions (Tables 4, 6, 7, 8).
    pub fn layer_prefill_latency(
        &self,
        w: &AttnWorkload,
        h1: u64,
        h2: u64,
        opts: &FastAttnOptions,
        fused: bool,
    ) -> f64 {
        let attn = self.fastattn_latency(w, opts).latency_s;
        attn + self.linear_latency(w.batch * w.seq_q, h1, h2, 1, opts.elem_bytes, fused)
    }

    /// Standard-attention layer prefill (baseline composition).
    pub fn layer_prefill_latency_std(&self, w: &AttnWorkload, h1: u64, h2: u64) -> f64 {
        self.standard_attention_latency(w) + self.linear_latency(w.batch * w.seq_q, h1, h2, 1, 2, false)
    }

    /// Projection + MLP GEMMs for `tokens` rows: 4 H1×H1 + 2 H1×H2,
    /// tensor-parallel sharded `shard` ways (weights and FLOPs divide).
    pub fn linear_latency(
        &self,
        tokens: u64,
        h1: u64,
        h2: u64,
        shard: u64,
        elem_bytes: u64,
        fused: bool,
    ) -> f64 {
        let shard = shard.max(1) as f64;
        let flops =
            2.0 * tokens as f64 * (4.0 * (h1 * h1) as f64 + 2.0 * (h1 * h2) as f64) / shard;
        let int8_scale = if elem_bytes == 1 { 2.0 } else { 1.0 };
        let compute = flops / (self.cube_flops_fp16 * self.cube_eff * int8_scale);
        let weight_bytes = ((4 * h1 * h1 + 2 * h1 * h2) * elem_bytes) as f64 / shard;
        let act_bytes = (tokens * h1 * elem_bytes) as f64 * 6.0 / shard;
        let io = (weight_bytes + act_bytes) / self.gm_bw;
        let launches = if fused { 2.0 } else { 6.0 };
        compute.max(io) + launches * self.op_launch_s
    }

    /// Decode-step latency for one layer at KV length `kv` (weight-bound
    /// GEMV + decode attention).
    pub fn layer_decode_latency(
        &self,
        batch: u64,
        heads: u64,
        kv: u64,
        head_dim: u64,
        h1: u64,
        h2: u64,
        shard: u64,
        elem_bytes: u64,
        fused: bool,
        eager: bool,
    ) -> f64 {
        let w = AttnWorkload::decode(batch, heads, kv, head_dim);
        let opts = FastAttnOptions { elem_bytes, ..Default::default() };
        let attn = if fused {
            self.fastattn_latency(&w, &opts).latency_s
        } else {
            self.standard_attention_latency(&w)
        };
        // Under an eager framework (Table 6's PyTorch systems) every op
        // pays dispatch overhead — the dominant cost at small batch.
        // Compiled/graph runtimes (Table 4's serving stack) do not.
        let framework = match (eager, fused) {
            (false, _) => 0.0,
            (true, true) => self.framework_ops_fused * self.framework_op_overhead_s,
            (true, false) => self.framework_ops_per_layer * self.framework_op_overhead_s,
        };
        attn + framework + self.linear_latency(batch, h1, h2, shard, elem_bytes, fused)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pangu38_w(s: u64) -> AttnWorkload {
        // §5.2.1: B=1, N=5 (per-NPU), D=128.
        AttnWorkload::prefill(1, 5, s, 128, true)
    }

    #[test]
    fn standard_attention_scales_quadratically() {
        let spec = AscendSpec::default();
        let a = spec.standard_attention_latency(&pangu38_w(2048));
        let b = spec.standard_attention_latency(&pangu38_w(8192));
        assert!(b / a > 10.0 && b / a < 20.0, "ratio {}", b / a);
    }

    #[test]
    fn fastattn_beats_standard_in_paper_range() {
        // Fig 7: 4.85–10.7× across S = 1K..16K for PanGu-38B shapes.
        let spec = AscendSpec::default();
        let opts = FastAttnOptions::default();
        for (s, lo, hi) in [
            (1024u64, 3.0, 8.0),
            (4096, 4.0, 10.0),
            (16384, 6.0, 13.0),
        ] {
            let w = pangu38_w(s);
            let std = spec.standard_attention_latency(&w);
            let fast = spec.fastattn_latency(&w, &opts).latency_s;
            let speedup = std / fast;
            assert!(
                speedup > lo && speedup < hi,
                "S={s}: speedup {speedup:.2} outside [{lo}, {hi}]"
            );
        }
    }

    #[test]
    fn two_level_beats_unified() {
        // Table 2: two-level (3.65–10.7×) > unified (2.55–7×).
        let spec = AscendSpec::default();
        for s in [1024u64, 4096, 16384] {
            let w = pangu38_w(s);
            let uni = spec
                .fastattn_latency(
                    &w,
                    &FastAttnOptions {
                        tiling: Tiling::Unified { block: 128 },
                        ..Default::default()
                    },
                )
                .latency_s;
            let two = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
            assert!(two < uni, "S={s}: two-level {two} !< unified {uni}");
        }
    }

    #[test]
    fn larger_first_level_block_reduces_latency_at_long_seq() {
        // Fig 9: BS 128 → 512 cuts latency 26–45% at S >= 4K.
        let spec = AscendSpec::default();
        let w = pangu38_w(8192);
        let small = spec
            .fastattn_latency(
                &w,
                &FastAttnOptions {
                    tiling: Tiling::TwoLevel { block1: 128, block2: 128 },
                    ..Default::default()
                },
            )
            .latency_s;
        let large = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
        let reduction = 1.0 - large / small;
        assert!(
            reduction > 0.15 && reduction < 0.55,
            "reduction {reduction:.2}"
        );
    }

    #[test]
    fn tiling_mask_removes_mask_overhead() {
        // Fully-masked-block skipping belongs to the tiling (both configs
        // get it); tiling-mask removes the SxS mask GM traffic and the
        // mask-add on fully visible blocks - a modest but real win
        // (its headline benefit is the 8 GB -> sub-MB memory saving).
        let spec = AscendSpec::default();
        let w = pangu38_w(8192);
        let with = spec.fastattn_latency(&w, &FastAttnOptions::default());
        let without = spec.fastattn_latency(
            &w,
            &FastAttnOptions { tiling_mask: false, ..Default::default() },
        );
        let ratio = without.latency_s / with.latency_s;
        assert!(ratio > 1.02 && ratio < 1.8, "ratio {ratio:.2}");
    }

    #[test]
    fn int8_faster_than_fp16_decode() {
        // Table 9: ~1.2× for decode shapes.
        let spec = AscendSpec::default();
        let fp16 = spec.layer_decode_latency(1, 4, 2048, 128, 4096, 16384, 8, 2, true, false);
        let int8 = spec.layer_decode_latency(1, 4, 2048, 128, 4096, 16384, 8, 1, true, false);
        let s = fp16 / int8;
        assert!(s > 1.05 && s < 2.2, "speedup {s:.2}");
    }

    #[test]
    fn efficiency_is_a_fraction() {
        let spec = AscendSpec::default();
        let r = spec.fastattn_latency(&pangu38_w(16384), &FastAttnOptions::default());
        assert!(r.efficiency > 0.05 && r.efficiency <= 1.0, "{}", r.efficiency);
    }
}
