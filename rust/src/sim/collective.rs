//! AllReduce cost model + the tiling-AllReduce overlap schedule (§4.2).
//!
//! In multi-NPU tensor-parallel inference each layer ends with an
//! AllReduce of the (B·S, H1) activation.  The baseline serializes
//! `attention → Linear → AllReduce`.  FastAttention fuses attention+Linear
//! and splits the AllReduce into per-block *B-allreduce* operations that
//! SDMA executes concurrently with the next block's compute — only the
//! first block's communication is exposed, so the paper "assigns smaller
//! computation tasks to the first block".

/// Interconnect parameters for the n-device ring.
#[derive(Debug, Clone, Copy)]
pub struct RingSpec {
    /// Per-link bandwidth, B/s (Ascend HCCS / NVLink class).
    pub link_bw: f64,
    /// Per-hop latency, seconds.
    pub hop_latency_s: f64,
    /// Number of devices in the ring.
    pub n: u64,
    /// Minimum message size at which the link reaches full bandwidth
    /// (small B-allreduce chunks are latency-bound; the paper enlarges
    /// blocks "to achieve better bandwidth utilization").
    pub saturation_bytes: f64,
}

impl Default for RingSpec {
    fn default() -> Self {
        Self {
            link_bw: 40e9, // effective HCCL ring bus bandwidth per 910B
            hop_latency_s: 6e-6,
            n: 8,
            saturation_bytes: 512.0 * 1024.0, // 512 KiB half-saturation
        }
    }
}

impl RingSpec {
    /// Effective bandwidth for one `bytes`-sized AllReduce message.
    pub fn eff_bw(&self, bytes: f64) -> f64 {
        self.link_bw * bytes / (bytes + self.saturation_bytes)
    }

    /// Ring AllReduce latency for `bytes` (reduce-scatter + all-gather).
    pub fn allreduce(&self, bytes: u64) -> f64 {
        if self.n <= 1 || bytes == 0 {
            // `eff_bw(0)` is 0 and would make the traffic term 0/0 = NaN;
            // an empty message costs nothing (no hops are taken for it).
            return 0.0;
        }
        let steps = 2 * (self.n - 1);
        let chunk_traffic = 2.0 * (self.n - 1) as f64 / self.n as f64 * bytes as f64;
        chunk_traffic / self.eff_bw(bytes as f64 / self.n as f64)
            + steps as f64 * self.hop_latency_s
    }
}

/// One block of the tiling-AllReduce pipeline.
#[derive(Debug, Clone, Copy)]
pub struct AllReduceBlock {
    /// Fused attention+Linear compute time for this block, seconds.
    pub compute_s: f64,
    /// Bytes this block contributes to the AllReduce.
    pub bytes: u64,
}

/// Result of scheduling the tiling-AllReduce pipeline.
#[derive(Debug, Clone, Copy)]
pub struct OverlapResult {
    /// Total makespan, seconds.
    pub makespan_s: f64,
    /// Seconds of communication hidden under compute.
    pub hidden_comm_s: f64,
    /// Total communication seconds (as if serialized).
    pub total_comm_s: f64,
}

/// Baseline: all compute, then one monolithic AllReduce.
pub fn serial_schedule(ring: &RingSpec, blocks: &[AllReduceBlock]) -> f64 {
    let compute: f64 = blocks.iter().map(|b| b.compute_s).sum();
    let bytes: u64 = blocks.iter().map(|b| b.bytes).sum();
    compute + ring.allreduce(bytes)
}

/// Tiling-AllReduce: per-block B-allreduce overlapped with subsequent
/// blocks' compute via SDMA.  Compute is serial on the device; the
/// communication channel is serial on the interconnect; comm for block i
/// starts once block i's compute is done and the channel is free.
pub fn overlapped_schedule(ring: &RingSpec, blocks: &[AllReduceBlock]) -> OverlapResult {
    let mut compute_done = 0.0f64;
    let mut comm_free = 0.0f64;
    let mut total_comm = 0.0f64;
    for b in blocks {
        compute_done += b.compute_s;
        let t = ring.allreduce(b.bytes);
        total_comm += t;
        comm_free = comm_free.max(compute_done) + t;
    }
    let makespan = comm_free.max(compute_done);
    OverlapResult {
        makespan_s: makespan,
        hidden_comm_s: (compute_done + total_comm - makespan).max(0.0),
        total_comm_s: total_comm,
    }
}

/// Split a layer's output of `total_bytes` with compute time `compute_s`
/// into `n_blocks` tiling-AllReduce blocks.  Per the paper, the first
/// block gets a smaller share (`first_frac`) so its exposed communication
/// starts early.
pub fn make_blocks(
    total_bytes: u64,
    compute_s: f64,
    n_blocks: usize,
    first_frac: f64,
) -> Vec<AllReduceBlock> {
    assert!(n_blocks >= 1);
    if n_blocks == 1 {
        return vec![AllReduceBlock { compute_s, bytes: total_bytes }];
    }
    let rest = (1.0 - first_frac) / (n_blocks - 1) as f64;
    let mut assigned = 0u64;
    (0..n_blocks)
        .map(|i| {
            let frac = if i == 0 { first_frac } else { rest };
            // Truncating every block would lose up to `n_blocks - 1`
            // bytes, silently undercounting tiled communication vs the
            // serial baseline — the last block takes the remainder so
            // the split always conserves `total_bytes`.
            let bytes = if i == n_blocks - 1 {
                total_bytes - assigned
            } else {
                ((total_bytes as f64 * frac) as u64).min(total_bytes - assigned)
            };
            assigned += bytes;
            AllReduceBlock { compute_s: compute_s * frac, bytes }
        })
        .collect()
}

/// Pick the block count that minimizes the overlapped makespan for a
/// layer (`total_bytes`, `compute_s`) — the paper's "enlarge the block
/// size to achieve better bandwidth utilization" trade-off.
pub fn best_block_count(ring: &RingSpec, total_bytes: u64, compute_s: f64) -> (usize, f64) {
    let mut best = (1usize, serial_schedule(ring, &make_blocks(total_bytes, compute_s, 1, 1.0)));
    for n in [2usize, 4, 6, 8, 12, 16, 24, 32] {
        let blocks = make_blocks(total_bytes, compute_s, n, 0.5 / n as f64);
        let r = overlapped_schedule(ring, &blocks);
        if r.makespan_s < best.1 {
            best = (n, r.makespan_s);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring() -> RingSpec {
        RingSpec::default()
    }

    #[test]
    fn allreduce_zero_on_single_device() {
        let r = RingSpec { n: 1, ..ring() };
        assert_eq!(r.allreduce(1 << 30), 0.0);
    }

    #[test]
    fn allreduce_monotone_in_bytes() {
        let r = ring();
        assert!(r.allreduce(1 << 20) < r.allreduce(1 << 24));
        assert!(r.allreduce(1 << 24) < r.allreduce(1 << 28));
    }

    #[test]
    fn small_messages_latency_bound() {
        let r = ring();
        let per_byte_small = r.allreduce(1 << 12) / (1 << 12) as f64;
        let per_byte_big = r.allreduce(1 << 28) / (1 << 28) as f64;
        assert!(per_byte_small > 10.0 * per_byte_big);
    }

    #[test]
    fn overlap_beats_serial() {
        // Fig 17 / Table 2: tiling-AllReduce 1.2–1.5× over serial.
        let r = ring();
        let total_bytes = 2u64 * 4096 * 5120; // B·S×H1 fp16, S=4K PanGu-38B
        let compute = 1.0e-3;
        let serial = serial_schedule(&r, &make_blocks(total_bytes, compute, 1, 1.0));
        let (nb, best) = best_block_count(&r, total_bytes, compute);
        let speedup = serial / best;
        assert!(nb > 1);
        assert!(speedup > 1.1 && speedup < 1.6, "speedup {speedup:.2} nb={nb}");
    }

    #[test]
    fn first_block_smaller_helps() {
        let r = ring();
        let total_bytes = 2u64 * 8192 * 5120;
        let compute = 2.0e-3;
        let even = overlapped_schedule(&r, &make_blocks(total_bytes, compute, 8, 1.0 / 8.0));
        let skewed = overlapped_schedule(&r, &make_blocks(total_bytes, compute, 8, 0.04));
        // The small first block starts communication earlier; the larger
        // tail blocks' messages cost slightly more, so allow a 5% band.
        assert!(skewed.makespan_s <= even.makespan_s * 1.05);
        // And the exposed head (before any overlap can begin) is smaller.
        assert!(0.04 * compute < compute / 8.0);
    }

    #[test]
    fn too_many_blocks_hurts() {
        // Latency-bound tiny chunks: 256 blocks must not beat the best.
        let r = ring();
        let total_bytes = 2u64 * 2048 * 5120;
        let compute = 0.5e-3;
        let (_, best) = best_block_count(&r, total_bytes, compute);
        let many = overlapped_schedule(&r, &make_blocks(total_bytes, compute, 256, 1.0 / 256.0));
        assert!(many.makespan_s > best * 0.999);
    }

    #[test]
    fn allreduce_zero_bytes_is_zero_not_nan() {
        // eff_bw(0) == 0: the traffic term used to be 0/0 = NaN, and a
        // small first_frac plus rounding can produce a 0-byte first
        // block, poisoning every best_block_count comparison (NaN
        // never orders below the incumbent).
        let r = ring();
        let t = r.allreduce(0);
        assert_eq!(t, 0.0, "zero-byte allreduce must cost nothing, got {t}");
        // a schedule containing a zero-byte block stays finite
        let blocks = [
            AllReduceBlock { compute_s: 1e-4, bytes: 0 },
            AllReduceBlock { compute_s: 1e-4, bytes: 1 << 20 },
        ];
        let res = overlapped_schedule(&r, &blocks);
        assert!(res.makespan_s.is_finite());
        assert!(res.total_comm_s.is_finite());
        // and best_block_count still returns a finite optimum even when
        // first_frac rounding yields an empty first block
        let (_, best) = best_block_count(&r, 7, 1e-3);
        assert!(best.is_finite());
    }

    #[test]
    fn make_blocks_conserves_bytes() {
        // sum(blocks.bytes) == total_bytes over random splits — the
        // per-block truncation used to lose up to n_blocks-1 bytes.
        let mut rng = crate::proptest::Rng::new(0xB10C_B10C);
        for _ in 0..200 {
            let total = rng.below(1 << 24) + 1;
            let n_blocks = rng.range(1, 33);
            let first_frac = if n_blocks == 1 {
                1.0
            } else {
                // include the pathological tiny-first-block corner
                0.5 / n_blocks as f64 * (rng.below(4) + 1) as f64 / 2.0
            };
            let blocks = make_blocks(total, 1e-3, n_blocks, first_frac);
            assert_eq!(blocks.len(), n_blocks);
            let sum: u64 = blocks.iter().map(|b| b.bytes).sum();
            assert_eq!(
                sum, total,
                "split of {total} into {n_blocks} blocks (first_frac {first_frac}) lost bytes"
            );
            let comp: f64 = blocks.iter().map(|b| b.compute_s).sum();
            assert!((comp - 1e-3).abs() < 1e-9, "compute shares must sum to the layer time");
        }
    }

    #[test]
    fn hidden_comm_accounting() {
        let r = ring();
        let blocks = make_blocks(1 << 26, 5e-3, 8, 0.05);
        let res = overlapped_schedule(&r, &blocks);
        assert!(res.hidden_comm_s >= 0.0);
        assert!(res.hidden_comm_s <= res.total_comm_s + 1e-12);
        assert!(res.makespan_s >= 5e-3);
    }
}
