//! Discrete-event simulator of the Cube→Vector two-stage pipeline.
//!
//! Models Figure 2's execution: a stream of block tasks, each needing the
//! Cube unit (matrix work) and then the Vector unit (element-wise work),
//! with a synchronization cost on every Cube→Vector handoff (data exchange
//! through the L2 buffer / GM in the decoupled Ascend architecture) and a
//! bounded number of in-flight blocks (the double-buffering depth).
//!
//! This is the mechanism behind the paper's two claims:
//!  * the *unified* tiling's small blocks → many handoffs → sync overhead
//!    dominates;
//!  * the *two-level* tiling's large first-level blocks → few handoffs +
//!    deeper buffering → Cube and Vector run overlapped (block4 does QKᵀ on
//!    Cube while block3 does Exp on Vector).

/// One block's worth of work for the two pipeline stages.
#[derive(Debug, Clone, Copy)]
pub struct BlockTask {
    /// Seconds of Cube (matrix) work.
    pub cube_s: f64,
    /// Seconds of Vector (element-wise) work.
    pub vector_s: f64,
    /// Seconds of GM→L1 load for this block (overlappable when
    /// double-buffered).
    pub load_s: f64,
}

/// Pipeline configuration.
#[derive(Debug, Clone, Copy)]
pub struct PipelineConfig {
    /// Cube→Vector synchronization cost per handoff (decoupled units
    /// exchange via L2/GM).
    pub sync_s: f64,
    /// In-flight block budget: 1 = strictly serial handoff, 2 = classic
    /// double buffering, etc.
    pub depth: usize,
    /// Whether GM loads overlap compute (double-buffering on GM,
    /// paper §4.1); if false, loads serialize ahead of Cube work.
    pub overlap_loads: bool,
}

/// Simulation output.
#[derive(Debug, Clone, Copy)]
pub struct PipelineResult {
    /// End-to-end makespan in seconds.
    pub makespan_s: f64,
    /// Busy seconds per stage.
    pub cube_busy_s: f64,
    pub vector_busy_s: f64,
    /// Utilizations (busy / makespan).
    pub cube_util: f64,
    pub vector_util: f64,
    /// Number of synchronizations charged.
    pub syncs: u64,
}

/// Run the two-stage pipeline over `tasks` in order.
pub fn simulate(tasks: &[BlockTask], cfg: &PipelineConfig) -> PipelineResult {
    assert!(cfg.depth >= 1, "pipeline depth must be >= 1");
    let n = tasks.len();
    if n == 0 {
        return PipelineResult {
            makespan_s: 0.0,
            cube_busy_s: 0.0,
            vector_busy_s: 0.0,
            cube_util: 0.0,
            vector_util: 0.0,
            syncs: 0,
        };
    }

    let mut cube_free = 0.0f64;
    let mut vector_free = 0.0f64;
    let mut load_free = 0.0f64;
    // vector finish times, for depth backpressure
    let mut vec_finish = vec![0.0f64; n];
    let mut cube_busy = 0.0;
    let mut vector_busy = 0.0;
    let mut syncs = 0u64;

    for (i, t) in tasks.iter().enumerate() {
        // Backpressure: block i's buffers can only be claimed once block
        // i - depth has fully drained through the Vector stage.
        let gate = if i >= cfg.depth { vec_finish[i - cfg.depth] } else { 0.0 };

        // GM load: its own DMA engine when overlapped, else serial on Cube.
        let (load_done, cube_extra) = if cfg.overlap_loads {
            let start = load_free.max(gate);
            load_free = start + t.load_s;
            (load_free, 0.0)
        } else {
            (gate, t.load_s)
        };

        let cube_start = cube_free.max(load_done);
        let cube_finish = cube_start + cube_extra + t.cube_s;
        cube_free = cube_finish;
        cube_busy += cube_extra + t.cube_s;

        // Handoff to Vector costs one synchronization.
        let vec_start = vector_free.max(cube_finish + cfg.sync_s);
        syncs += 1;
        let finish = vec_start + t.vector_s;
        vector_free = finish;
        vector_busy += t.vector_s;
        vec_finish[i] = finish;
    }

    let makespan = vector_free;
    PipelineResult {
        makespan_s: makespan,
        cube_busy_s: cube_busy,
        vector_busy_s: vector_busy,
        cube_util: cube_busy / makespan,
        vector_util: vector_busy / makespan,
        syncs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize, cube: f64, vector: f64, load: f64) -> Vec<BlockTask> {
        vec![BlockTask { cube_s: cube, vector_s: vector, load_s: load }; n]
    }

    #[test]
    fn empty_is_zero() {
        let r = simulate(&[], &PipelineConfig { sync_s: 0.0, depth: 2, overlap_loads: true });
        assert_eq!(r.makespan_s, 0.0);
    }

    #[test]
    fn single_task_serializes_stages() {
        let r = simulate(
            &uniform(1, 2.0, 1.0, 0.5),
            &PipelineConfig { sync_s: 0.1, depth: 2, overlap_loads: true },
        );
        assert!((r.makespan_s - (0.5 + 2.0 + 0.1 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn deep_pipeline_overlaps_stages() {
        // 100 balanced tasks: with depth 2, makespan → n·max(stage) + fill.
        let n = 100;
        let r = simulate(
            &uniform(n, 1.0, 1.0, 0.0),
            &PipelineConfig { sync_s: 0.0, depth: 2, overlap_loads: true },
        );
        assert!(r.makespan_s < n as f64 * 1.0 + 2.0, "{}", r.makespan_s);
        assert!(r.cube_util > 0.98);
    }

    #[test]
    fn depth_one_serializes() {
        // depth 1: every block's vector must finish before the next cube
        // starts → makespan ≈ n·(cube+vector+sync).
        let n = 50;
        let r = simulate(
            &uniform(n, 1.0, 1.0, 0.0),
            &PipelineConfig { sync_s: 0.1, depth: 1, overlap_loads: true },
        );
        assert!((r.makespan_s - n as f64 * 2.1).abs() < 1e-9, "{}", r.makespan_s);
    }

    #[test]
    fn sync_overhead_scales_with_task_count() {
        // Same total work split into 10× more blocks costs ~10× the syncs —
        // the unified-tiling pathology the two-level strategy removes.
        let coarse = simulate(
            &uniform(10, 1.0, 0.5, 0.0),
            &PipelineConfig { sync_s: 0.2, depth: 1, overlap_loads: true },
        );
        let fine = simulate(
            &uniform(100, 0.1, 0.05, 0.0),
            &PipelineConfig { sync_s: 0.2, depth: 1, overlap_loads: true },
        );
        assert_eq!(coarse.syncs, 10);
        assert_eq!(fine.syncs, 100);
        assert!(fine.makespan_s > coarse.makespan_s * 1.8);
    }

    #[test]
    fn load_overlap_hides_dma() {
        let with = simulate(
            &uniform(20, 1.0, 0.2, 0.9),
            &PipelineConfig { sync_s: 0.0, depth: 2, overlap_loads: true },
        );
        let without = simulate(
            &uniform(20, 1.0, 0.2, 0.9),
            &PipelineConfig { sync_s: 0.0, depth: 2, overlap_loads: false },
        );
        assert!(with.makespan_s < without.makespan_s * 0.75);
    }

    #[test]
    fn utilization_bounded() {
        let r = simulate(
            &uniform(30, 0.7, 0.4, 0.1),
            &PipelineConfig { sync_s: 0.05, depth: 2, overlap_loads: true },
        );
        assert!(r.cube_util > 0.0 && r.cube_util <= 1.0);
        assert!(r.vector_util > 0.0 && r.vector_util <= 1.0);
    }
}
