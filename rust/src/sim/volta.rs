//! Tesla V100 (Volta) cost model.
//!
//! Covers the paper's low-resource-GPU experiments:
//!
//! * Fig 8 — FastAttention (redesigned m8n8k4 SRAM layout, FP16
//!   accumulators, bank-conflict-free) vs xformers' memory-efficient /
//!   FlashAttention kernel, as achieved TFLOPs/s across sequence lengths;
//! * Table 3 — decode attention: GPU compute vs PCIe KV upload vs host
//!   CPU compute (the CPU–GPU cooperative strategy's crossover);
//! * Fig 11 / Table 5 — end-to-end FasterTransformer / DeepSpeed layers.
//!
//! Calibration anchors (paper Table 3, PanGu-38B on 8 V100):
//!   GPU_Calc(1K) = 0.058 ms → fixed launch ≈ 42 µs + KV read at an
//!   effective ~160 GB/s;  Upload(16K) = 3.58 ms → PCIe ≈ 11.7 GB/s;
//!   CPU_Calc(16K) = 2.676 ms → host ≈ 17.5 GB/s streaming.

use super::AttnWorkload;

/// V100 + host parameters.
#[derive(Debug, Clone, Copy)]
pub struct VoltaSpec {
    /// Tensor-core peak, FP16 FLOP/s (V100: 112–125 TFLOPs).
    pub tc_flops_fp16: f64,
    /// HBM2 bandwidth, B/s.
    pub hbm_bw: f64,
    /// Effective HBM bandwidth for the small, latency-bound decode
    /// attention reads (calibrated from Table 3 GPU_Calc slope).
    pub decode_eff_bw: f64,
    /// Fixed per-kernel launch + sync overhead, seconds (Table 3
    /// GPU_Calc intercept).
    pub kernel_overhead_s: f64,
    /// Effective PCIe 3.0 ×16 bandwidth per direction, B/s (Table 3
    /// Upload slope; theoretical 16 GB/s, real ~11.7).
    pub pcie_bw: f64,
    /// PCIe transfer setup latency, seconds.
    pub pcie_latency_s: f64,
    /// Host CPU effective streaming rate for attention over the resident
    /// KV cache, B/s (Table 3 CPU_Calc slope).
    pub cpu_stream_bw: f64,
    /// Host attention fixed overhead, seconds.
    pub cpu_overhead_s: f64,
    /// NVLink bandwidth per GPU for the 8-GPU AllReduce, B/s.
    pub nvlink_bw: f64,
    /// Per-op launch overhead without CUDA graphs (Table 5's
    /// torch-DeepSpeed penalty), seconds.
    pub torch_op_overhead_s: f64,
}

impl Default for VoltaSpec {
    fn default() -> Self {
        Self {
            tc_flops_fp16: 112e12,
            hbm_bw: 900e9,
            decode_eff_bw: 160e9,
            kernel_overhead_s: 42e-6,
            pcie_bw: 11.7e9,
            pcie_latency_s: 22e-6,
            cpu_stream_bw: 17.5e9,
            cpu_overhead_s: 0.2e-3,
            nvlink_bw: 130e9,
            torch_op_overhead_s: 45e-6,
        }
    }
}

/// Which Volta attention kernel to model (Fig 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VoltaKernel {
    /// xformers' cutlass-based FlashAttention: FP32 accumulators force an
    /// inter-thread element exchange between the two GEMMs (Appendix B,
    /// Fig 14) and its generic layouts leave SRAM bank conflicts.
    Xformers,
    /// FastAttention: m8n8k4 with FP16 accumulators — GEMM1's C feeds
    /// GEMM2's A without exchange (Fig 15), bank-conflict-free SRAM
    /// layout, coalesced HBM access.
    FastAttention,
}

impl VoltaSpec {
    /// Achieved fraction of tensor-core peak for a prefill attention
    /// kernel.  Efficiency grows with sequence length (tile-quantization
    /// and launch overheads amortize) and saturates at a kernel-specific
    /// ceiling.
    pub fn kernel_efficiency(&self, kernel: VoltaKernel, w: &AttnWorkload) -> f64 {
        let s = w.seq_q as f64;
        // Saturation half-point and ceiling per kernel.
        let (ceil, half) = match kernel {
            // xformers: layout exchange + bank conflicts cap efficiency
            // and it saturates early (its masked-block handling also
            // costs more, see below).
            VoltaKernel::Xformers => (0.36, 600.0),
            // FastAttention: FP16-accumulator path, conflict-free SRAM.
            VoltaKernel::FastAttention => (0.42, 900.0),
        };
        let mut eff = ceil * s / (s + half);
        if w.causal {
            // Causal handling: FastAttention skips fully-masked blocks
            // exactly (tiling classification); xformers still pays
            // partial-block overhead that grows with S (paper: causal
            // speedup rises to 1.43× at 16K).
            let waste = match kernel {
                VoltaKernel::Xformers => 0.12 + 0.05 * (s / 16384.0).min(1.0),
                VoltaKernel::FastAttention => 0.04,
            };
            eff *= 1.0 - waste;
        }
        eff
    }

    /// Prefill kernel latency (Fig 8 workloads).
    pub fn attention_latency(&self, kernel: VoltaKernel, w: &AttnWorkload) -> f64 {
        // Fig 8's FLOP convention counts the full S² (no causal discount);
        // causal kernels do less work but report against full FLOPs.
        let useful = w.flops() * w.causal_keep_fraction(128);
        let eff = self.kernel_efficiency(kernel, w);
        useful / (self.tc_flops_fp16 * eff) + self.kernel_overhead_s
    }

    /// Achieved TFLOPs/s as Fig 8 reports it (full-FLOPs convention).
    pub fn attention_tflops(&self, kernel: VoltaKernel, w: &AttnWorkload) -> f64 {
        w.flops() / self.attention_latency(kernel, w) / 1e12
    }

    /// Decode attention on the GPU over `kv_bytes` of cache (Table 3
    /// GPU_Calc).
    pub fn decode_attention_gpu(&self, kv_bytes: u64) -> f64 {
        self.kernel_overhead_s + kv_bytes as f64 / self.decode_eff_bw
    }

    /// PCIe upload of `bytes` host→device (Table 3 Upload).
    pub fn pcie_transfer(&self, bytes: u64) -> f64 {
        self.pcie_latency_s + bytes as f64 / self.pcie_bw
    }

    /// Decode attention on the host CPU over `kv_bytes` of resident cache
    /// (Table 3 CPU_Calc).  The analytical twin of the real kernel in
    /// `attention::flash` (see `sim::cpu` for the measured cross-check).
    pub fn decode_attention_cpu(&self, kv_bytes: u64) -> f64 {
        self.cpu_overhead_s + kv_bytes as f64 / self.cpu_stream_bw
    }

    /// The cooperative strategy's Off_Upload: ship the one-token QKV down
    /// and the attention result back (fixed-size, Table 3's ~constant
    /// 0.04–0.07 ms column).
    pub fn offload_roundtrip(&self, qkv_bytes: u64, result_bytes: u64) -> f64 {
        2.0 * self.pcie_latency_s
            + (qkv_bytes + result_bytes) as f64 / self.pcie_bw
    }

    /// One dense GEMM of `m×k×n` on tensor cores at large-tile efficiency.
    pub fn gemm(&self, m: u64, k: u64, n: u64) -> f64 {
        let flops = 2.0 * (m * k * n) as f64;
        let eff = 0.55; // large weight GEMMs on cutlass/V100
        flops / (self.tc_flops_fp16 * eff) + self.kernel_overhead_s
    }

    /// Ring AllReduce over NVLink for `bytes` on `n` GPUs.
    pub fn allreduce(&self, bytes: u64, n: u64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        2.0 * (n - 1) as f64 / n as f64 * bytes as f64 / self.nvlink_bw
            + 2.0 * (n - 1) as f64 * 8e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fig8_w(s: u64, causal: bool) -> AttnWorkload {
        // Fig 8: batch 8, hidden 2048, 64 heads → D = 32.
        AttnWorkload::prefill(8, 64, s, 32, causal)
    }

    #[test]
    fn fastattn_beats_xformers_noncausal_paper_range() {
        // Fig 8 w/o causal: 1.03–1.17× from 2K to 16K.
        let spec = VoltaSpec::default();
        let mut prev = 0.0;
        for (s, lo, hi) in
            [(2048u64, 1.0, 1.12), (4096, 1.02, 1.14), (8192, 1.04, 1.2), (16384, 1.06, 1.3)]
        {
            let w = fig8_w(s, false);
            let x = spec.attention_latency(VoltaKernel::Xformers, &w);
            let f = spec.attention_latency(VoltaKernel::FastAttention, &w);
            let speedup = x / f;
            assert!(speedup >= lo && speedup <= hi, "S={s}: {speedup:.3}");
            assert!(speedup >= prev, "monotone in S");
            prev = speedup;
        }
    }

    #[test]
    fn causal_speedup_grows_toward_1_43() {
        let spec = VoltaSpec::default();
        let w = fig8_w(16384, true);
        let x = spec.attention_latency(VoltaKernel::Xformers, &w);
        let f = spec.attention_latency(VoltaKernel::FastAttention, &w);
        let speedup = x / f;
        assert!(speedup > 1.25 && speedup < 1.6, "{speedup:.3}");
    }

    #[test]
    fn tflops_increase_with_seqlen() {
        let spec = VoltaSpec::default();
        let a = spec.attention_tflops(VoltaKernel::FastAttention, &fig8_w(2048, false));
        let b = spec.attention_tflops(VoltaKernel::FastAttention, &fig8_w(16384, false));
        assert!(b > a);
        assert!(b < 112.0); // below peak
    }

    #[test]
    fn table3_gpu_calc_anchors() {
        // KV bytes per GPU per layer for PanGu-38B: 4·B·H1·S / n.
        let spec = VoltaSpec::default();
        for (s, want_ms, tol) in [(1024u64, 0.058, 0.02), (16384, 0.312, 0.06), (262144, 4.11, 0.6)]
        {
            let kv = 4 * s * 5120 / 8;
            let got = spec.decode_attention_gpu(kv) * 1e3;
            assert!(
                (got - want_ms).abs() < tol,
                "S={s}: got {got:.3} ms want {want_ms}"
            );
        }
    }

    #[test]
    fn table3_upload_anchor() {
        let spec = VoltaSpec::default();
        let kv = 4u64 * 16384 * 5120 / 8;
        let got = spec.pcie_transfer(kv) * 1e3;
        assert!((got - 3.58).abs() < 0.4, "got {got:.2} ms");
    }

    #[test]
    fn table3_cpu_calc_anchor() {
        let spec = VoltaSpec::default();
        let kv = 4u64 * 16384 * 5120 / 8;
        let got = spec.decode_attention_cpu(kv) * 1e3;
        assert!((got - 2.676).abs() < 0.4, "got {got:.2} ms");
    }

    #[test]
    fn cpu_calc_beats_classical_upload() {
        // Table 3's headline: CPU compute < PCIe upload + GPU compute.
        let spec = VoltaSpec::default();
        for s in [16384u64, 65536, 262144] {
            let kv = 4 * s * 5120 / 8;
            let classical = spec.pcie_transfer(kv) + spec.decode_attention_gpu(kv);
            let coop = spec.decode_attention_cpu(kv)
                + spec.offload_roundtrip(3 * 2 * 5120 / 8, 2 * 5120 / 8);
            let speedup = classical / coop;
            assert!(speedup > 1.2 && speedup < 1.7, "S={s}: {speedup:.2}");
        }
    }

    #[test]
    fn offload_roundtrip_nearly_constant() {
        let spec = VoltaSpec::default();
        let a = spec.offload_roundtrip(1280, 1280);
        let b = spec.offload_roundtrip(1280 * 4, 1280 * 4);
        assert!((b - a).abs() / a < 0.05);
        assert!(a * 1e3 > 0.03 && a * 1e3 < 0.08, "{} ms", a * 1e3);
    }
}
