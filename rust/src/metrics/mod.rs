//! Serving metrics: engine counters + latency histogram + throughput.

use std::time::Instant;

/// Counters maintained by the engine loop.
#[derive(Debug, Default, Clone)]
pub struct EngineMetrics {
    pub prefill_steps: u64,
    pub decode_steps: u64,
    /// Chunked-prefill steps (paged engine).  One step runs one batched
    /// forward pass; several admitting sequences' chunk rows can pack
    /// into it under the prefill-token budget (`chunk_rows` counts the
    /// per-sequence chunks, so `chunk_rows / chunk_steps` is the mean
    /// packed chunk batch).
    pub chunk_steps: u64,
    /// Per-sequence chunks executed inside chunked-prefill steps.
    pub chunk_rows: u64,
    /// New-admission prefill slots the scheduler deferred to decode
    /// because recent decode step time exceeded the TPOT SLO
    /// (`EngineConfig::tpot_slo_s`) while the waiting queue was not yet
    /// starved past `waiting_served_ratio`.
    pub slo_deferrals: u64,
    pub prefilled_tokens: u64,
    pub decoded_tokens: u64,
    pub completed: u64,
    /// Cumulative seconds inside prefill / decode execution.
    pub prefill_s: f64,
    pub decode_s: f64,
    /// Paged KV, device tier: pages in use after the latest step /
    /// pool size / high-water mark.  Zero on contiguous engines.
    /// Pages retained by the prefix cache count as in use (at idle,
    /// `pages_used == shared_pages`).
    pub pages_used: u64,
    pub pages_total: u64,
    pub peak_pages_used: u64,
    /// Paged KV, host tier (cold-page offload): pages in use after the
    /// latest step / pool size.  Zero when no host tier is configured.
    pub host_pages_used: u64,
    pub host_pages_total: u64,
    /// Cold-page migration: pages moved device→host, batched PCIe
    /// transfers performed, bytes moved, and modeled link seconds
    /// charged (`PcieLink::transfer_s` per batch).
    pub pages_migrated: u64,
    pub migrations: u64,
    pub migrated_bytes: u64,
    pub pcie_modeled_s: f64,
    /// Cold pages promoted back host→device when pressure cleared:
    /// batched transfers performed and pages moved (includes swap-in
    /// restores).
    pub promotions: u64,
    pub promoted_pages: u64,
    /// Link transfers (either direction) that folded two or more block
    /// groups — possibly from several sequences — into one modeled
    /// PCIe charge.
    pub grouped_transfers: u64,
    /// Page-allocation failures (each one runs the reclamation ladder:
    /// prefix-cache eviction, then migration, then swap-out or
    /// recompute preemption) and sequences actually preempted — by
    /// either mechanism; `swaps_out` counts the swap subset.
    pub alloc_failures: u64,
    pub preemptions: u64,
    /// Swap-out preemptions (block table parked on the host tier) and
    /// the matching resumes.
    pub swaps_out: u64,
    pub swaps_in: u64,
    /// Cached tokens (prefilled prompt + generated) that swap-out
    /// preserved — work a recompute preemption would have replayed.
    pub recompute_tokens_avoided: u64,
    /// Prefix sharing (paged engines, per-request opt-in): pages
    /// currently retained by the prefix index after the latest step.
    pub shared_pages: u64,
    /// Admissions that adopted a shared prompt-prefix run.
    pub prefix_hits: u64,
    /// Copy-on-write block splits (first divergent write into an
    /// adopted block).
    pub cow_splits: u64,
    /// Prompt tokens whose prefill was skipped thanks to an adopted
    /// prefix run.
    pub prefix_tokens_saved: u64,
    /// Analytic KV gather bandwidth (paged engines): bytes of on-page
    /// K/V streamed through attention at the pool's
    /// [`PageCodec`](crate::coordinator::PageCodec) row encoding —
    /// int8 pools report ~4× fewer bytes than f32 for the same tokens.
    pub kv_bytes_gathered: u64,
    /// KV rows dequantized inside the fused gather (zero on f32 pools).
    pub dequant_rows: u64,
    /// Batched shared-prefix attention passes executed by cascade
    /// decode (one per adopter group per layer per step; zero with
    /// `EngineConfig::cascade` off).
    pub cascade_passes: u64,
    /// K+V row reads cascade decode skipped versus the per-sequence
    /// gather: tile-aligned shared rows × KV heads × 2, counted for
    /// every adopter beyond the first of each group.  Already
    /// subtracted from [`Self::kv_bytes_gathered`].
    pub shared_rows_saved: u64,
    /// Speculative decoding (`EngineConfig::speculate > 0`): draft
    /// tokens proposed by the prompt-lookup drafter and the subset the
    /// verify pass accepted.  `draft_accepted / draft_proposed` is the
    /// acceptance rate; a spec step always emits at least one real
    /// token on top of the accepted drafts.
    pub draft_proposed: u64,
    pub draft_accepted: u64,
    /// Histogram of tokens emitted per speculative step: bucket `i`
    /// counts steps that emitted `i + 1` tokens (the bonus token plus
    /// `i` accepted drafts).  Grows lazily to the deepest step seen.
    pub accept_len_hist: Vec<u64>,
    /// Pages speculatively allocated for draft KV rows and the subset
    /// popped back to the free list by `BlockTable::truncate` after
    /// the verify pass rejected their rows (exactly `written -
    /// accepted` every step — the rollback accounting identity).
    pub spec_pages_written: u64,
    pub spec_rollback_pages: u64,
    /// Tensor-parallel combine (sharded backends only; zero on
    /// single-device engines): B-allreduce tiles issued and activation
    /// bytes combined across shards.
    pub allreduce_tiles: u64,
    pub allreduce_bytes: u64,
    /// Modeled AllReduce communication seconds (as if serialized) and
    /// the subset hidden under the next tile's compute by the
    /// tiling-AllReduce overlap — the multi-device counterpart of
    /// `pcie_modeled_s`.
    pub allreduce_modeled_s: f64,
    pub allreduce_hidden_s: f64,
    /// Modeled makespan of the executed combine schedule and of the
    /// serial (monolithic-AllReduce) baseline over the same workload.
    pub allreduce_makespan_s: f64,
    pub allreduce_serial_s: f64,
    /// Per-request time-to-first-token histogram (seconds from
    /// submission to the first generated token).
    pub ttft: LatencyHistogram,
    /// Per-request time-per-output-token histogram (seconds per
    /// generated token over the decode phase) — groundwork for
    /// scheduler latency SLOs.
    pub tpot: LatencyHistogram,
}

impl EngineMetrics {
    /// Fraction of the page pool in use after the latest step,
    /// 0.0 ..= 1.0 (0.0 on contiguous engines).
    pub fn page_occupancy(&self) -> f64 {
        if self.pages_total == 0 {
            return 0.0;
        }
        self.pages_used as f64 / self.pages_total as f64
    }

    /// High-water page occupancy over the engine's lifetime.
    pub fn peak_page_occupancy(&self) -> f64 {
        if self.pages_total == 0 {
            return 0.0;
        }
        self.peak_pages_used as f64 / self.pages_total as f64
    }

    /// Fraction of the host-tier pool in use after the latest step,
    /// 0.0 ..= 1.0 (0.0 when the host tier is absent).
    pub fn host_page_occupancy(&self) -> f64 {
        if self.host_pages_total == 0 {
            return 0.0;
        }
        self.host_pages_used as f64 / self.host_pages_total as f64
    }

    /// Mean pages per batched migration (0.0 before any migration).
    pub fn mean_migration_batch(&self) -> f64 {
        if self.migrations == 0 {
            return 0.0;
        }
        self.pages_migrated as f64 / self.migrations as f64
    }

    /// Fraction of all prefilled-or-saved prompt tokens that prefix
    /// sharing skipped, 0.0 ..= 1.0 (0.0 with sharing unused).
    pub fn prefix_savings(&self) -> f64 {
        let total = self.prefilled_tokens + self.prefix_tokens_saved;
        if total == 0 {
            return 0.0;
        }
        self.prefix_tokens_saved as f64 / total as f64
    }
    /// Decode throughput, tokens/second of decode wall time.
    pub fn decode_tps(&self) -> f64 {
        if self.decode_s <= 0.0 {
            return 0.0;
        }
        self.decoded_tokens as f64 / self.decode_s
    }

    /// Prefill throughput, prompt tokens/second of prefill wall time.
    pub fn prefill_tps(&self) -> f64 {
        if self.prefill_s <= 0.0 {
            return 0.0;
        }
        self.prefilled_tokens as f64 / self.prefill_s
    }

    /// Mean batched sequences per decode step.
    pub fn mean_decode_batch(&self) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.decoded_tokens as f64 / self.decode_steps as f64
    }

    /// Mean per-sequence chunks packed into one chunked-prefill step
    /// (1.0 = no packing; 0.0 when no chunk step ran).
    pub fn mean_chunk_batch(&self) -> f64 {
        if self.chunk_steps == 0 {
            return 0.0;
        }
        self.chunk_rows as f64 / self.chunk_steps as f64
    }

    /// Fraction of proposed draft tokens the verify pass accepted,
    /// 0.0 ..= 1.0 (0.0 with speculation off or nothing proposed).
    pub fn draft_acceptance(&self) -> f64 {
        if self.draft_proposed == 0 {
            return 0.0;
        }
        self.draft_accepted as f64 / self.draft_proposed as f64
    }

    /// Mean tokens emitted per speculative step from the accept-length
    /// histogram (0.0 before any spec step; > 1.0 means speculation is
    /// beating one-token-per-pass decode).
    pub fn mean_accept_len(&self) -> f64 {
        let steps: u64 = self.accept_len_hist.iter().sum();
        if steps == 0 {
            return 0.0;
        }
        let tokens: u64 = self
            .accept_len_hist
            .iter()
            .enumerate()
            .map(|(i, &c)| (i as u64 + 1) * c)
            .sum();
        tokens as f64 / steps as f64
    }

    /// Fraction of modeled AllReduce seconds hidden under compute,
    /// 0.0 ..= 1.0 (0.0 on single-device engines).
    pub fn allreduce_hidden_frac(&self) -> f64 {
        if self.allreduce_modeled_s <= 0.0 {
            return 0.0;
        }
        (self.allreduce_hidden_s / self.allreduce_modeled_s).clamp(0.0, 1.0)
    }

    /// Tiling-AllReduce speedup over the serial combine on the same
    /// workload (`serial / makespan`; 1.0 on single-device engines).
    pub fn allreduce_overlap_speedup(&self) -> f64 {
        if self.allreduce_makespan_s <= 0.0 {
            return 1.0;
        }
        self.allreduce_serial_s / self.allreduce_makespan_s
    }
}

/// A simple latency histogram with power-of-two microsecond buckets.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) µs; 32 buckets ≈ 71 min.
    buckets: [u64; 32],
    count: u64,
    sum_s: f64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self { buckets: [0; 32], count: 0, sum_s: 0.0, max_s: 0.0 }
    }
}

impl LatencyHistogram {
    pub fn record(&mut self, seconds: f64) {
        let us = (seconds * 1e6).max(1.0);
        let idx = (us.log2() as usize).min(31);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_s += seconds;
        if seconds > self.max_s {
            self.max_s = seconds;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    pub fn max_s(&self) -> f64 {
        self.max_s
    }

    /// Approximate quantile from bucket boundaries (upper bound).
    pub fn quantile_s(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 2f64.powi(i as i32 + 1) / 1e6;
            }
        }
        self.max_s
    }
}

/// Windowless throughput counter.
#[derive(Debug)]
pub struct Throughput {
    started: Instant,
    events: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self { started: Instant::now(), events: 0 }
    }
}

impl Throughput {
    pub fn add(&mut self, n: u64) {
        self.events += n;
    }

    pub fn per_second(&self) -> f64 {
        let dt = self.started.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.events as f64 / dt
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_metrics_rates() {
        let m = EngineMetrics {
            decode_steps: 10,
            decoded_tokens: 30,
            decode_s: 3.0,
            prefilled_tokens: 100,
            prefill_s: 2.0,
            ..Default::default()
        };
        assert!((m.decode_tps() - 10.0).abs() < 1e-9);
        assert!((m.prefill_tps() - 50.0).abs() < 1e-9);
        assert!((m.mean_decode_batch() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn page_occupancy_ratios() {
        let m = EngineMetrics {
            pages_used: 3,
            pages_total: 12,
            peak_pages_used: 9,
            ..Default::default()
        };
        assert!((m.page_occupancy() - 0.25).abs() < 1e-12);
        assert!((m.peak_page_occupancy() - 0.75).abs() < 1e-12);
        // contiguous engines report zero, not NaN
        let z = EngineMetrics::default();
        assert_eq!(z.page_occupancy(), 0.0);
        assert_eq!(z.peak_page_occupancy(), 0.0);
    }

    #[test]
    fn host_tier_and_migration_ratios() {
        let m = EngineMetrics {
            host_pages_used: 6,
            host_pages_total: 24,
            pages_migrated: 12,
            migrations: 3,
            migrated_bytes: 12 * 1024,
            pcie_modeled_s: 1.5e-4,
            ..Default::default()
        };
        assert!((m.host_page_occupancy() - 0.25).abs() < 1e-12);
        assert!((m.mean_migration_batch() - 4.0).abs() < 1e-12);
        // engines without a host tier report zero, not NaN
        let z = EngineMetrics::default();
        assert_eq!(z.host_page_occupancy(), 0.0);
        assert_eq!(z.mean_migration_batch(), 0.0);
    }

    #[test]
    fn reclaim_counters_and_latency_histograms() {
        let mut m = EngineMetrics {
            preemptions: 5,
            swaps_out: 3,
            swaps_in: 3,
            recompute_tokens_avoided: 120,
            promotions: 2,
            promoted_pages: 8,
            grouped_transfers: 1,
            ..Default::default()
        };
        assert!(m.swaps_out <= m.preemptions, "swaps are a preemption subset");
        m.ttft.record(0.010);
        m.ttft.record(0.020);
        m.tpot.record(0.002);
        assert_eq!(m.ttft.count(), 2);
        assert_eq!(m.tpot.count(), 1);
        assert!(m.ttft.quantile_s(0.5) > 0.0);
        // cloned metrics carry the histograms (the server snapshot path)
        let snap = m.clone();
        assert_eq!(snap.ttft.count(), 2);
        assert!((snap.tpot.mean_s() - 0.002).abs() < 1e-9);
        // a fresh engine reports empty histograms, not NaNs
        let z = EngineMetrics::default();
        assert_eq!(z.ttft.count(), 0);
        assert_eq!(z.tpot.quantile_s(0.99), 0.0);
    }

    #[test]
    fn allreduce_ratios() {
        let m = EngineMetrics {
            allreduce_tiles: 8,
            allreduce_bytes: 1 << 20,
            allreduce_modeled_s: 4e-3,
            allreduce_hidden_s: 3e-3,
            allreduce_makespan_s: 5e-3,
            allreduce_serial_s: 6e-3,
            ..Default::default()
        };
        assert!((m.allreduce_hidden_frac() - 0.75).abs() < 1e-12);
        assert!((m.allreduce_overlap_speedup() - 1.2).abs() < 1e-12);
        // single-device engines report identity, not NaN
        let z = EngineMetrics::default();
        assert_eq!(z.allreduce_hidden_frac(), 0.0);
        assert_eq!(z.allreduce_overlap_speedup(), 1.0);
    }

    #[test]
    fn prefix_savings_ratio() {
        let m = EngineMetrics {
            prefilled_tokens: 30,
            prefix_tokens_saved: 10,
            prefix_hits: 2,
            cow_splits: 1,
            shared_pages: 8,
            ..Default::default()
        };
        assert!((m.prefix_savings() - 0.25).abs() < 1e-12);
        // engines without sharing report zero, not NaN
        assert_eq!(EngineMetrics::default().prefix_savings(), 0.0);
    }

    #[test]
    fn speculation_ratios() {
        let m = EngineMetrics {
            draft_proposed: 40,
            draft_accepted: 30,
            // 2 steps emitted 1 token, 3 steps emitted 3 tokens
            accept_len_hist: vec![2, 0, 3],
            spec_pages_written: 12,
            spec_rollback_pages: 5,
            ..Default::default()
        };
        assert!((m.draft_acceptance() - 0.75).abs() < 1e-12);
        assert!((m.mean_accept_len() - 11.0 / 5.0).abs() < 1e-12);
        assert!(m.spec_rollback_pages <= m.spec_pages_written);
        // speculation off reports zero, not NaN
        let z = EngineMetrics::default();
        assert_eq!(z.draft_acceptance(), 0.0);
        assert_eq!(z.mean_accept_len(), 0.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::default();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-5); // 10µs .. 10ms
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile_s(0.5) <= h.quantile_s(0.99));
        assert!(h.quantile_s(0.99) <= h.max_s() * 2.0 + 1e-9);
        assert!(h.mean_s() > 0.0);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_s(0.9), 0.0);
        assert_eq!(h.mean_s(), 0.0);
    }
}
