//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust serving path.
//!
//! `artifacts/manifest.json` records the model config, every lowered
//! entrypoint with its input/output shapes, and the ordered weight dumps.
//! This module parses it (via the in-crate JSON parser) and loads weight
//! binaries; compilation/execution lives in [`super::client`].

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;
use crate::runtime::tensor::HostTensor;

/// One named input or output of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

/// One lowered HLO entrypoint.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// Path of the HLO text file, relative to the artifact dir.
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// One dumped weight tensor (little-endian f32, `param_specs` order).
#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub file: String,
    pub shape: Vec<usize>,
}

/// Model config as recorded by the AOT step.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub n_params: usize,
}

/// The parsed manifest plus its base directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub prefill_batches: Vec<usize>,
    pub prefill_seqs: Vec<usize>,
    pub decode_batches: Vec<usize>,
    pub weights: Vec<WeightSpec>,
    pub artifacts: Vec<ArtifactSpec>,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.req_str("name")?.to_string(),
        shape: j
            .req_arr("shape")?
            .iter()
            .map(|d| d.u64().map(|v| v as usize))
            .collect::<Option<Vec<_>>>()
            .context("bad shape entry")?,
        dtype: j.req_str("dtype")?.to_string(),
    })
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let m = j.get("model").context("manifest: missing 'model'")?;
        let model = ModelInfo {
            name: m.req_str("name")?.to_string(),
            vocab: m.req_u64("vocab")? as usize,
            n_layers: m.req_u64("n_layers")? as usize,
            d_model: m.req_u64("d_model")? as usize,
            n_heads: m.req_u64("n_heads")? as usize,
            n_kv_heads: m.req_u64("n_kv_heads")? as usize,
            head_dim: m.req_u64("head_dim")? as usize,
            d_ff: m.req_u64("d_ff")? as usize,
            max_seq: m.req_u64("max_seq")? as usize,
            n_params: m.req_u64("n_params")? as usize,
        };

        let usize_arr = |key: &str| -> Result<Vec<usize>> {
            j.req_arr(key)?
                .iter()
                .map(|d| d.u64().map(|v| v as usize))
                .collect::<Option<Vec<_>>>()
                .with_context(|| format!("bad '{key}'"))
        };

        let weights = j
            .req_arr("weights")?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    name: w.req_str("name")?.to_string(),
                    file: w.req_str("file")?.to_string(),
                    shape: w
                        .req_arr("shape")?
                        .iter()
                        .map(|d| d.u64().map(|v| v as usize))
                        .collect::<Option<Vec<_>>>()
                        .context("bad weight shape")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let artifacts = j
            .req_arr("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a.req_str("name")?.to_string(),
                    file: a.req_str("file")?.to_string(),
                    inputs: a
                        .req_arr("inputs")?
                        .iter()
                        .map(io_spec)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .req_arr("outputs")?
                        .iter()
                        .map(io_spec)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            dir,
            model,
            prefill_batches: usize_arr("prefill_batches")?,
            prefill_seqs: usize_arr("prefill_seqs")?,
            decode_batches: usize_arr("decode_batches")?,
            weights,
            artifacts,
        })
    }

    /// Look up an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Load every weight dump as a host tensor, in manifest order (the
    /// positional parameter order every model entrypoint expects).
    pub fn load_weights(&self) -> Result<Vec<HostTensor>> {
        self.weights
            .iter()
            .map(|w| {
                let path = self.dir.join(&w.file);
                let bytes = fs::read(&path)
                    .with_context(|| format!("reading weight {path:?}"))?;
                if bytes.len() % 4 != 0 {
                    bail!("weight {:?}: {} bytes not a multiple of 4", w.file, bytes.len());
                }
                let n: usize = w.shape.iter().product();
                if bytes.len() / 4 != n {
                    bail!(
                        "weight {:?}: {} elements on disk, shape {:?} needs {n}",
                        w.file,
                        bytes.len() / 4,
                        w.shape
                    );
                }
                let data: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(HostTensor::f32(w.shape.clone(), data))
            })
            .collect()
    }

    /// The best prefill bucket for `seq` tokens: smallest lowered S ≥ seq.
    pub fn prefill_bucket(&self, seq: usize) -> Result<usize> {
        self.prefill_seqs
            .iter()
            .copied()
            .filter(|&s| s >= seq)
            .min()
            .with_context(|| {
                format!(
                    "prompt of {seq} tokens exceeds the largest prefill bucket {:?}",
                    self.prefill_seqs
                )
            })
    }

    /// The best batch bucket: smallest lowered B ≥ want.
    pub fn batch_bucket(&self, buckets: &[usize], want: usize) -> Result<usize> {
        buckets
            .iter()
            .copied()
            .filter(|&b| b >= want)
            .min()
            .with_context(|| format!("batch {want} exceeds buckets {buckets:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_manifest() -> Option<Manifest> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        Manifest::load(dir).ok()
    }

    #[test]
    fn loads_repo_manifest() {
        let Some(m) = repo_manifest() else { return };
        assert_eq!(m.model.name, "tiny-3m");
        assert_eq!(m.model.n_layers, 4);
        assert!(m.artifact("decode_b1").is_ok());
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn weights_match_param_count() {
        let Some(m) = repo_manifest() else { return };
        let weights = m.load_weights().unwrap();
        let total: usize = weights.iter().map(|w| w.len()).sum();
        assert_eq!(total, m.model.n_params);
    }

    #[test]
    fn artifact_io_shapes_sane() {
        let Some(m) = repo_manifest() else { return };
        let a = m.artifact("prefill_b1_s32").unwrap();
        assert_eq!(a.inputs[0].name, "tokens");
        assert_eq!(a.inputs[0].shape, vec![1, 32]);
        assert_eq!(a.outputs[0].shape, vec![1, m.model.vocab]);
        // inputs = tokens + lengths + every weight
        assert_eq!(a.inputs[1].name, "lengths");
        assert_eq!(a.inputs.len(), 2 + m.weights.len());
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = repo_manifest() else { return };
        assert_eq!(m.prefill_bucket(1).unwrap(), 32);
        assert_eq!(m.prefill_bucket(32).unwrap(), 32);
        assert_eq!(m.prefill_bucket(33).unwrap(), 64);
        assert_eq!(m.prefill_bucket(128).unwrap(), 128);
        assert!(m.prefill_bucket(129).is_err());
        assert_eq!(m.batch_bucket(&m.decode_batches, 2).unwrap(), 4);
    }
}
