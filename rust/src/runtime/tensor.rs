//! Plain host-side tensors and their PJRT `Literal` conversions.

use anyhow::{bail, Context, Result};

/// A host tensor: row-major data + shape.  Two element types cover the
//  serving path (f32 activations/weights, i32 tokens/positions).
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    /// f32 tensor; panics on shape/len mismatch.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/len");
        HostTensor::F32 { shape, data }
    }

    /// i32 tensor; panics on shape/len mismatch.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/len");
        HostTensor::I32 { shape, data }
    }

    /// Scalar i32 (shape `[]`).
    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::I32 { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow f32 data; errors if the tensor is i32.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    /// Take ownership of f32 data; errors if the tensor is i32.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            HostTensor::I32 { .. } => bail!("tensor is i32, expected f32"),
        }
    }

    /// Borrow i32 data; errors if the tensor is f32.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            HostTensor::F32 { .. } => bail!("tensor is f32, expected i32"),
        }
    }

    /// Convert to a PJRT literal (reshaped to this tensor's dims).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data),
        };
        if dims.len() == 1 {
            return Ok(lit);
        }
        lit.reshape(&dims).context("literal reshape")
    }

    /// Read a literal back into a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    /// Index of the max element (argmax over flat data) — greedy sampling.
    pub fn argmax_f32(&self) -> Result<usize> {
        let data = self.as_f32()?;
        if data.is_empty() {
            bail!("argmax of empty tensor");
        }
        let mut best = 0;
        for (i, &v) in data.iter().enumerate() {
            if v > data[best] {
                best = i;
            }
        }
        Ok(best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/len")]
    fn bad_shape_panics() {
        HostTensor::f32(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn typed_accessors() {
        let f = HostTensor::f32(vec![1], vec![1.5]);
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = HostTensor::i32(vec![1], vec![7]);
        assert!(i.as_i32().is_ok());
        assert!(i.as_f32().is_err());
    }

    #[test]
    fn argmax() {
        let t = HostTensor::f32(vec![4], vec![0.1, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax_f32().unwrap(), 1);
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_scalar() {
        let t = HostTensor::scalar_i32(42);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[42]);
    }
}
