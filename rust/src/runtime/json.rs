//! Minimal JSON parser for the AOT artifact manifest.
//!
//! serde is not available in this offline environment, so the manifest
//! (`artifacts/manifest.json`, written by `python/compile/aot.py`) is
//! parsed by this self-contained recursive-descent parser.  Supports the
//! full JSON grammar minus exotic number forms; good enough for any
//! machine-generated manifest and fully unit-tested.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array view.
    pub fn arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// String view.
    pub fn str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number as f64.
    pub fn num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Number as u64 (must be a non-negative integer).
    pub fn u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// Convenience: `get(key)` then `.str()`, with a descriptive error.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::str)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing string field '{key}'"))
    }

    /// Convenience: `get(key)` then `.u64()`.
    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(Json::u64)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing integer field '{key}'"))
    }

    /// Convenience: `get(key)` then `.arr()`.
    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::arr)
            .ok_or_else(|| anyhow::anyhow!("manifest: missing array field '{key}'"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => write!(f, "{n}"),
            Json::Str(s) => write!(f, "{s:?}"),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{k:?}:{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // surrogate pairs unsupported (not emitted by
                            // our manifest writer); map to replacement.
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn escapes() {
        assert_eq!(
            Json::parse(r#""a\n\t\"\\A""#).unwrap(),
            Json::Str("a\n\t\"\\A".into())
        );
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().str().unwrap(), "x");
        let arr = v.get("a").unwrap().arr().unwrap();
        assert_eq!(arr[0].u64().unwrap(), 1);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n \"k\" :\t[ ]\r\n} ").unwrap();
        assert_eq!(v.get("k").unwrap().arr().unwrap().len(), 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("1.5").unwrap().u64(), None);
        assert_eq!(Json::parse("-3").unwrap().u64(), None);
        assert_eq!(Json::parse("3").unwrap().u64(), Some(3));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).expect("manifest parses");
            assert!(m.get("artifacts").is_some());
            assert!(m.get("weights").is_some());
            assert_eq!(
                m.get("model").unwrap().req_str("name").unwrap(),
                "tiny-3m"
            );
        }
    }
}
