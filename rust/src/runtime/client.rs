//! The PJRT execution wrapper: compile HLO-text artifacts once, execute
//! them from the serving hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  Entrypoints are lowered with
//! `return_tuple=True`, so each execution yields one tuple literal that we
//! decompose into the manifest's declared outputs.
//!
//! Weights are staged as device buffers once at load time and passed
//! positionally after the dynamic inputs (the manifest wire order) via
//! `execute_b` — re-uploading them per call cost 2.8× on the decode step
//! (EXPERIMENTS.md §Perf).

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifacts::Manifest;
use super::tensor::HostTensor;

/// Compiled artifact bundle + staged weight buffers + the PJRT client.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Weights staged on the device ONCE at load time (§Perf item 1:
    /// re-uploading 36 weight literals per call dominated the decode
    /// step before this).
    weight_buffers: Vec<xla::PjRtBuffer>,
    /// Compile seconds per artifact (startup cost report).
    pub compile_times: Vec<(String, f64)>,
}

impl Runtime {
    /// Load the manifest, compile every artifact, stage the weights.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::load_with_filter(manifest, |_| true)
    }

    /// Load compiling only artifacts accepted by `keep` (examples that
    /// need a single kernel avoid compiling the full model bundle).
    pub fn load_filtered(
        dir: impl AsRef<Path>,
        keep: impl Fn(&str) -> bool,
    ) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::load_with_filter(manifest, keep)
    }

    fn load_with_filter(manifest: Manifest, keep: impl Fn(&str) -> bool) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        let mut compile_times = Vec::new();
        for spec in &manifest.artifacts {
            if !keep(&spec.name) {
                continue;
            }
            let path = manifest.hlo_path(spec);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{}'", spec.name))?;
            compile_times.push((spec.name.clone(), t0.elapsed().as_secs_f64()));
            executables.insert(spec.name.clone(), exe);
        }
        let weight_buffers = manifest
            .load_weights()?
            .iter()
            .map(|w| match w {
                HostTensor::F32 { shape, data } => client
                    .buffer_from_host_buffer(data, shape, None)
                    .map_err(anyhow::Error::from),
                HostTensor::I32 { shape, data } => client
                    .buffer_from_host_buffer(data, shape, None)
                    .map_err(anyhow::Error::from),
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self { manifest, client, executables, weight_buffers, compile_times })
    }

    fn input_buffer(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        Ok(match t {
            HostTensor::F32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::I32 { shape, data } => {
                self.client.buffer_from_host_buffer(data, shape, None)?
            }
        })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of the compiled artifacts.
    pub fn loaded(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    /// Execute artifact `name` with `inputs` (dynamic inputs only; weight
    /// parameters are appended automatically when the artifact declares
    /// them).  Returns the decomposed output literals in manifest order.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.artifact(name)?;
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' was not compiled (filtered?)"))?;

        let needs_weights =
            spec.inputs.len() == inputs.len() + self.weight_buffers.len();
        if !needs_weights && spec.inputs.len() != inputs.len() {
            bail!(
                "artifact '{name}' wants {} inputs, got {} (+{} weights staged)",
                spec.inputs.len(),
                inputs.len(),
                self.weight_buffers.len()
            );
        }

        // Validate the dynamic inputs against the manifest.
        for (io, t) in spec.inputs.iter().zip(inputs) {
            if io.shape != t.shape() {
                bail!(
                    "artifact '{name}' input '{}' expects shape {:?}, got {:?}",
                    io.name,
                    io.shape,
                    t.shape()
                );
            }
        }

        let args: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| self.input_buffer(t))
            .collect::<Result<Vec<_>>>()?;
        let arg_refs: Vec<&xla::PjRtBuffer> = if needs_weights {
            args.iter().chain(self.weight_buffers.iter()).collect()
        } else {
            args.iter().collect()
        };

        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&arg_refs)
            .with_context(|| format!("executing '{name}'"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = tuple.to_tuple().context("decomposing result tuple")?;
        if outs.len() != spec.outputs.len() {
            bail!(
                "artifact '{name}' returned {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            );
        }
        Ok(outs)
    }

    /// Execute and convert every output to a host tensor.
    pub fn run_host(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.run(name, inputs)?
            .iter()
            .map(HostTensor::from_literal)
            .collect()
    }

    /// Execute with caller-provided device buffers appended after the
    /// staged weights — the decode loop's fast lane.
    pub fn run_buffers(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
        with_weights: bool,
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact '{name}' was not compiled"))?;
        let arg_refs: Vec<&xla::PjRtBuffer> = if with_weights {
            inputs.iter().copied().chain(self.weight_buffers.iter()).collect()
        } else {
            inputs.to_vec()
        };
        let result = exe.execute_b::<&xla::PjRtBuffer>(&arg_refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime_with(names: &'static [&'static str]) -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("manifest.json").exists() {
            return None;
        }
        Some(
            Runtime::load_filtered(dir, |n| names.contains(&n))
                .expect("runtime loads"),
        )
    }

    #[test]
    fn kernel_artifact_executes_and_matches_reference() {
        let Some(rt) =
            runtime_with(&["kernel_fastattn_causal", "kernel_standard_causal"])
        else {
            return;
        };
        // (1, 4, 128, 64) deterministic inputs
        let n = 4 * 128 * 64;
        let mk = |salt: f32| {
            let data: Vec<f32> = (0..n)
                .map(|i| ((i as f32 * 0.137 + salt).sin()) * 0.5)
                .collect();
            HostTensor::f32(vec![1, 4, 128, 64], data)
        };
        let (q, k, v) = (mk(0.0), mk(1.0), mk(2.0));
        let fast = rt
            .run_host("kernel_fastattn_causal", &[q.clone(), k.clone(), v.clone()])
            .unwrap();
        let std = rt
            .run_host("kernel_standard_causal", &[q, k, v])
            .unwrap();
        let a = fast[0].as_f32().unwrap();
        let b = std[0].as_f32().unwrap();
        let max_err = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 2e-5, "pallas vs standard max err {max_err}");
    }

    #[test]
    fn shape_validation_rejects_bad_input() {
        let Some(rt) = runtime_with(&["kernel_fastattn_causal"]) else {
            return;
        };
        let bad = HostTensor::f32(vec![1, 4, 64, 64], vec![0.0; 4 * 64 * 64]);
        let err = match rt.run("kernel_fastattn_causal", &[bad.clone(), bad.clone(), bad]) {
            Err(e) => e,
            Ok(_) => panic!("bad-shape input unexpectedly accepted"),
        };
        assert!(err.to_string().contains("expects shape"), "{err}");
    }
}
