//! PJRT runtime — loads and executes the AOT artifacts.
//!
//! The compile path (`make artifacts`) runs Python once; from then on this
//! module is the only thing that touches the model: it parses
//! `artifacts/manifest.json` ([`artifacts`]), loads the HLO *text* files
//! (`HloModuleProto::from_text_file` — text is the interchange format, see
//! `python/compile/aot.py`), compiles them on the PJRT CPU client and
//! executes them from the serving hot path ([`client`]).
//!
//! serde being unavailable offline, the manifest is parsed with the
//! in-crate [`json`] parser; host tensors are the plain [`tensor`] types.

pub mod artifacts;
pub mod client;
pub mod json;
pub mod tensor;

pub use artifacts::{ArtifactSpec, IoSpec, Manifest, WeightSpec};
pub use client::Runtime;
pub use tensor::HostTensor;
