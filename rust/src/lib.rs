//! # FastAttention — reproduction library
//!
//! Rust + JAX + Pallas reproduction of *FastAttention: Extend
//! FlashAttention2 to NPUs and Low-resource GPUs for Efficient Inference*
//! (Lin, Yu, Zhao, et al., 2024).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack
//! (see `DESIGN.md`):
//!
//! * [`runtime`] — loads AOT-compiled HLO artifacts (produced once by
//!   `python/compile/aot.py` from the JAX model + Pallas kernel) and runs
//!   them on the PJRT CPU client.  Python is never on the request path.
//! * [`coordinator`] — the serving engine: request router, continuous
//!   batcher, prefill/decode scheduler, KV-cache manager, the paper's
//!   tiling-AllReduce orchestrator and CPU–GPU cooperative offload.
//! * [`sim`] — the hardware substrates the paper's evaluation ran on
//!   (Ascend 910B, Tesla V100, PCIe, HCCS ring), rebuilt as calibrated
//!   analytical + discrete-event models (repro band 0: no NPU/V100 here).
//! * [`attention`] — real CPU implementations (naive + FlashAttention2
//!   online-softmax) plus the paper's tiling planner and tiling-mask
//!   generator.
//! * [`models`] — the paper's model zoo (Table 1) as shape configs.

pub mod attention;
pub mod benchkit;
pub mod coordinator;
pub mod metrics;
pub mod models;
pub mod proptest;
pub mod reports;
pub mod runtime;
pub mod sim;

pub use models::ModelShape;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
