//! Multi-device serving sweep behind `BENCH_multi.json`.
//!
//! Two layers of evidence for the §4.2 tiling-AllReduce claim at
//! system scale, shared by the `fig10_multi_npu`,
//! `fig16_allreduce_tokens` and `fig17_allreduce_ablation` bench
//! binaries:
//!
//! 1. an end-to-end **sharded-engine** sweep (shard count × decode
//!    batch, tiled vs serial combine) in which every run's tokens are
//!    asserted identical to the single-device engine before any timing
//!    is reported, and
//! 2. the calibrated **PanGu-38B 8×910B** modeled points (batch × seq,
//!    the Fig 16/17 grid) where the tiled schedule must beat the
//!    serial one outright.
//!
//! All values are modeled serial/tiled speedups (unit `x`), so one
//! JSON file stays machine-diffable across PRs.

use std::path::Path;

use crate::attention::batch::ParallelConfig;
use crate::benchkit::{fmt_time, write_bench_json, x, Table};
use crate::coordinator::scheduler::Policy;
use crate::coordinator::{
    Engine, EngineConfig, GenParams, HostModelBackend, HostModelConfig, KvLayout, ShardedBackend,
    ShardedConfig,
};
use crate::metrics::EngineMetrics;
use crate::models::ModelShape;
use crate::reports::allreduce::pangu38_layer_compute_and_bytes;
use crate::sim::collective::{best_block_count, make_blocks, serial_schedule, RingSpec};

/// Eight KV heads so the sweep divides across 2/4/8 shards.
fn sweep_model() -> HostModelConfig {
    HostModelConfig {
        model: ModelShape {
            name: "host-multi-sweep",
            params: 0,
            layers: 2,
            heads: 8,
            kv_heads: 8,
            head_dim: 4,
            ffn: 32,
            vocab: 32,
        },
        max_seq: 64,
        ..HostModelConfig::tiny_gqa()
    }
}

fn ecfg() -> EngineConfig {
    EngineConfig {
        // admit the whole batch before decoding so decode steps carry
        // the full row count (= combine tiles per layer)
        policy: Policy::PrefillFirst,
        parallel: ParallelConfig { threads: 1, min_work_per_thread: 0 },
        kv_layout: KvLayout::Paged,
        page_size: 16,
        ..EngineConfig::default()
    }
}

fn prompts(batch: usize) -> Vec<Vec<i32>> {
    (0..batch).map(|i| (0..6).map(|t| (t * 3 + i as i32 + 1) % 32).collect()).collect()
}

fn run(mut e: Engine, batch: usize) -> (Vec<Vec<i32>>, EngineMetrics) {
    let p = GenParams { max_new_tokens: 12, eos_token: None, share_prefix: false };
    for pr in prompts(batch) {
        e.submit(pr, p).expect("submit");
    }
    let mut out = e.run_until_idle().expect("run_until_idle");
    out.sort_by_key(|r| r.id);
    (out.into_iter().map(|r| r.tokens).collect(), e.metrics.clone())
}

/// One sweep point: engine-modeled combine times for a shard count ×
/// decode batch, tokens already checked against the single-device run.
#[derive(Debug, Clone, Copy)]
pub struct ShardPoint {
    /// Simulated devices the KV heads were split across.
    pub shards: usize,
    /// Concurrent sequences (decode rows per step).
    pub batch: usize,
    /// Modeled makespan of the serial (monolithic-AllReduce) schedule.
    pub serial_s: f64,
    /// Modeled makespan of the tiled, overlapped schedule.
    pub tiled_s: f64,
    /// Fraction of the tiled run's comm hidden under compute.
    pub hidden_frac: f64,
}

impl ShardPoint {
    /// Serial-vs-tiled modeled speedup (1.0 when nothing is combined).
    pub fn speedup(&self) -> f64 {
        if self.tiled_s <= 0.0 { 1.0 } else { self.serial_s / self.tiled_s }
    }
}

/// Run the sharded engine across shards × batch in both combine modes,
/// assert token parity with the single-device engine, and return the
/// modeled combine times.
pub fn engine_sweep() -> Vec<ShardPoint> {
    let cfg = sweep_model();
    let mut out = Vec::new();
    for batch in [2usize, 8] {
        let (want, _) =
            run(Engine::with_backend(Box::new(HostModelBackend::new(cfg.clone())), ecfg()), batch);
        for shards in [2usize, 4, 8] {
            let mk = |sc: ShardedConfig| {
                Engine::with_backend(
                    Box::new(ShardedBackend::new(cfg.clone(), sc).expect("shard geometry")),
                    ecfg(),
                )
            };
            let tiled = ShardedConfig { tile_rows: 2, ..ShardedConfig::for_shards(shards) };
            let serial = ShardedConfig { tile_rows: 2, ..ShardedConfig::serial(shards) };
            let (tokens, tm) = run(mk(tiled), batch);
            assert_eq!(tokens, want, "{shards}-shard tiled run diverged at batch {batch}");
            let (tokens, sm) = run(mk(serial), batch);
            assert_eq!(tokens, want, "{shards}-shard serial run diverged at batch {batch}");
            // both modes combined the same activations; serial runs at
            // its own baseline makespan
            assert_eq!(sm.allreduce_bytes, tm.allreduce_bytes);
            assert!(
                tm.allreduce_serial_s >= tm.allreduce_makespan_s - 1e-12,
                "overlap can only help"
            );
            out.push(ShardPoint {
                shards,
                batch,
                serial_s: tm.allreduce_serial_s,
                tiled_s: tm.allreduce_makespan_s,
                hidden_frac: tm.allreduce_hidden_frac(),
            });
        }
    }
    out
}

/// Calibrated PanGu-38B 8×910B point (Fig 16/17 shapes): modeled
/// serial and tiled layer makespans plus the chosen block count.
pub fn paper_point(b: u64, s: u64) -> (f64, f64, usize) {
    let ring = RingSpec::default();
    let (compute, bytes) = pangu38_layer_compute_and_bytes(b, s);
    let serial = serial_schedule(&ring, &make_blocks(bytes, compute, 1, 1.0));
    let (nb, over) = best_block_count(&ring, bytes, compute);
    (serial, over, nb)
}

/// Rows for `BENCH_multi.json` (unit `x`: modeled serial/tiled
/// speedup).  Engine rows have token parity asserted; paper-scale rows
/// must beat serial outright.
pub fn bench_rows() -> Vec<(String, f64)> {
    let mut rows = Vec::new();
    for p in engine_sweep() {
        rows.push((format!("engine/shards{}/batch{}", p.shards, p.batch), p.speedup()));
    }
    for b in [1u64, 4, 16] {
        for s in [2048u64, 8192, 32768] {
            let (serial, over, _) = paper_point(b, s);
            let sp = serial / over;
            assert!(sp > 1.0, "pangu38 b={b} s={s}: tiled {sp:.3}x must beat serial");
            rows.push((format!("pangu38/b{b}/s{}k", s / 1024), sp));
        }
    }
    rows
}

/// Human-readable view of the same sweep (printed by the bench
/// binaries before they write the JSON).
pub fn multi_table() -> Table {
    let mut t = Table::new(
        "multi-device serving — serial vs tiling-AllReduce (engine runs token-parity-checked)",
        &["point", "serial", "tiled", "speedup", "hidden/blocks"],
    );
    for p in engine_sweep() {
        t.row(&[
            format!("engine {}sh b{}", p.shards, p.batch),
            fmt_time(p.serial_s),
            fmt_time(p.tiled_s),
            x(p.speedup()),
            format!("{:.0}%", p.hidden_frac * 100.0),
        ]);
    }
    for (b, s) in [(1u64, 8192u64), (4, 8192), (16, 32768)] {
        let (serial, over, nb) = paper_point(b, s);
        t.row(&[
            format!("pangu38 b{b} s{}K", s / 1024),
            fmt_time(serial),
            fmt_time(over),
            x(serial / over),
            format!("{nb} blocks"),
        ]);
    }
    t
}

/// Write `BENCH_multi.json` at `path`.
pub fn write_bench_multi(path: &Path) -> std::io::Result<()> {
    write_bench_json(path, "multi", "x", &bench_rows())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_sweep_parity_and_overlap() {
        let pts = engine_sweep(); // token parity asserted inside
        assert_eq!(pts.len(), 6);
        for p in &pts {
            assert!(p.serial_s > 0.0 && p.tiled_s > 0.0, "{p:?} modeled no comm");
            assert!(p.speedup() >= 1.0 - 1e-12, "{p:?} slower than serial");
        }
        // 8 decode rows at tile_rows 2 = 4 tiles per layer: overlap
        // must strictly win and hide real communication
        let p = pts.iter().find(|p| p.batch == 8 && p.shards == 4).unwrap();
        assert!(p.speedup() > 1.0, "batch-8 tiling speedup {:.3} must beat 1.0", p.speedup());
        assert!(p.hidden_frac > 0.0);
    }

    #[test]
    fn bench_rows_all_at_least_serial() {
        let rows = bench_rows(); // paper-scale > 1.0 asserted inside
        assert_eq!(rows.len(), 6 + 9);
        for (label, sp) in &rows {
            assert!(*sp >= 1.0 - 1e-12, "{label}: {sp}");
            assert!(sp.is_finite());
        }
    }

    #[test]
    fn table_renders() {
        multi_table().print();
    }
}
