//! Tiling-AllReduce experiment reports (Appendix D.3 — Figs 16, 17).

use crate::benchkit::{ms, x, Table};
use crate::models;
use crate::sim::ascend::{AscendSpec, FastAttnOptions};
use crate::sim::collective::{best_block_count, make_blocks, serial_schedule, RingSpec};
use crate::sim::AttnWorkload;

/// Fused attention+Linear compute seconds and AllReduce bytes for one
/// PanGu-38B layer on one of 8 NPUs (shared with examples/multi_npu.rs).
pub fn pangu38_layer_compute_and_bytes(b: u64, s: u64) -> (f64, u64) {
    let spec = AscendSpec::default();
    let model = models::PANGU_38B;
    let heads_dev = model.heads_per_device(8) as u64;
    let w = AttnWorkload::prefill(b, heads_dev, s, model.head_dim as u64, true);
    let attn = spec.fastattn_latency(&w, &FastAttnOptions::default()).latency_s;
    let linear = spec.linear_latency(b * s, model.hidden(), model.ffn as u64, 8, 2, true);
    (attn + linear, 2 * b * s * model.hidden())
}

/// Fig 16: constant 32K total tokens, batch × seq sweep.
pub fn fig16_tokens_sweep() -> Table {
    let ring = RingSpec::default();
    let mut t = Table::new(
        "Fig 16 — tiling-AllReduce at 32K total tokens, PanGu-38B 8×910B (paper: ≤1.53×)",
        &["batch", "seq", "serial (ms)", "tiling-AR (ms)", "blocks", "speedup"],
    );
    for (b, s) in [(32u64, 1024u64), (16, 2048), (8, 4096), (4, 8192), (2, 16384), (1, 32768)] {
        let (compute, bytes) = pangu38_layer_compute_and_bytes(b, s);
        let serial = serial_schedule(&ring, &make_blocks(bytes, compute, 1, 1.0));
        let (nb, over) = best_block_count(&ring, bytes, compute);
        t.row(&[
            format!("{b}"),
            format!("{}K", s / 1024),
            ms(serial),
            ms(over),
            format!("{nb}"),
            x(serial / over),
        ]);
    }
    t
}

/// Fig 17: with/without tiling-AllReduce across batch and sequence.
pub fn fig17_ablation() -> Table {
    let ring = RingSpec::default();
    let mut t = Table::new(
        "Fig 17 — ± tiling-AllReduce, PanGu-38B 8×910B (paper: 1.2–1.5×)",
        &["batch", "seq", "without (ms)", "with (ms)", "speedup", "hidden comm"],
    );
    for b in [1u64, 4, 16] {
        for s in [2048u64, 8192, 32768] {
            let (compute, bytes) = pangu38_layer_compute_and_bytes(b, s);
            let serial = serial_schedule(&ring, &make_blocks(bytes, compute, 1, 1.0));
            let (nb, over) = best_block_count(&ring, bytes, compute);
            let blocks = make_blocks(bytes, compute, nb.max(1), 0.5 / nb.max(1) as f64);
            let detail = crate::sim::collective::overlapped_schedule(&ring, &blocks);
            t.row(&[
                format!("{b}"),
                format!("{}K", s / 1024),
                ms(serial),
                ms(over),
                x(serial / over),
                format!("{:.0}%", detail.hidden_comm_s / detail.total_comm_s.max(1e-12) * 100.0),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16_speedups_in_band() {
        let ring = RingSpec::default();
        for (b, s) in [(32u64, 1024u64), (1, 32768)] {
            let (compute, bytes) = pangu38_layer_compute_and_bytes(b, s);
            let serial = serial_schedule(&ring, &make_blocks(bytes, compute, 1, 1.0));
            let (_, over) = best_block_count(&ring, bytes, compute);
            let sp = serial / over;
            assert!(sp >= 1.0 && sp < 1.8, "b={b} s={s}: {sp:.2}");
        }
    }

    #[test]
    fn tables_render() {
        fig16_tokens_sweep().print();
        fig17_ablation().print();
    }
}
