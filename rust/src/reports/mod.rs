//! Experiment reports: one function per paper table/figure.
//!
//! Each function regenerates its experiment through the simulators /
//! real kernels and returns a [`Table`](crate::benchkit::Table) whose
//! rows put the paper's reported value next to the reproduced one.
//! The `rust/benches/*` binaries and the `repro table <id>` CLI
//! subcommand are thin wrappers over these.

pub mod allreduce;
pub mod multi;
pub mod npu;
pub mod volta;

use crate::benchkit::Table;

/// Every experiment id, in paper order.
pub const ALL: &[&str] = &[
    "fig7", "fig8", "fig9", "fig10", "fig11", "fig16", "fig17", "table2",
    "table3", "table4", "table5", "table6", "table7", "table8", "table9",
];

/// Dispatch by experiment id.
pub fn by_id(id: &str) -> Option<Table> {
    match id {
        "fig7" => Some(npu::fig7_single_npu()),
        "fig8" => Some(volta::fig8_xformers()),
        "fig9" => Some(npu::fig9_blocksize_sweep()),
        "fig10" => Some(npu::fig10_multi_npu()),
        "fig11" => Some(volta::fig11_ft_v100()),
        "fig16" => Some(allreduce::fig16_tokens_sweep()),
        "fig17" => Some(allreduce::fig17_ablation()),
        "table2" => Some(npu::table2_ablation()),
        "table3" => Some(volta::table3_offload()),
        "table4" => Some(npu::table4_e2e()),
        "table5" => Some(volta::table5_deepspeed()),
        "table6" => Some(npu::table6_throughput()),
        "table7" => Some(npu::table7_vit_breakdown()),
        "table8" => Some(npu::table8_deit()),
        "table9" => Some(npu::table9_quant()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_dispatches() {
        for id in ALL {
            assert!(by_id(id).is_some(), "{id}");
        }
        assert!(by_id("nope").is_none());
    }
}
